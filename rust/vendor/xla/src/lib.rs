//! Offline stub of the `xla` (PJRT) crate.
//!
//! The real crate wraps the PJRT C API to compile and execute AOT HLO
//! artifacts; it cannot build in the offline environment (it links
//! against the XLA runtime). This stub exposes the same API surface used
//! by `llmcompass::runtime` so the whole workspace — simulator, serving
//! simulator, experiments, CLI — builds and tests on a bare checkout.
//! Every execution entry point returns a descriptive error, and the
//! artifact-gated tests skip long before reaching one (no
//! `artifacts/manifest.json` on a bare checkout).
//!
//! To run real artifacts, replace the `xla = { path = "vendor/xla" }`
//! dependency with the PJRT-backed crate; no call sites change.

use std::fmt;

/// Stub error: always "PJRT unavailable".
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT is unavailable in this build (vendored `xla` stub); \
         swap rust/vendor/xla for the PJRT-backed crate to execute artifacts"
    )))
}

/// PJRT client handle (stub: construction fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (stub: loading fails).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable (stub: unobtainable, execution fails).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host literal (stub: construction works so argument marshalling
/// type-checks; consumption fails).
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(self, _dims: &[i64]) -> Result<Literal> {
        Ok(self)
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("Literal::array_shape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Array shape of a literal.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn new(dims: Vec<i64>) -> ArrayShape {
        ArrayShape { dims }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_paths_error_descriptively() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("stub"));
        let lit = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert_eq!(ArrayShape::new(vec![2, 3]).dims(), &[2, 3]);
    }
}
