//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment's vendored registry carries no external crates,
//! so this shim provides the slice of `anyhow` the workspace actually
//! uses: [`Error`] (a message + context chain), [`Result`], the
//! [`anyhow!`] / [`bail!`] macros, [`Context`] for `Result`, and
//! `{:#}`-style chained formatting. It follows the real crate's API shapes
//! so swapping the genuine `anyhow` back in is a one-line Cargo change.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: the top-most message plus a chain of causes.
///
/// Deliberately does **not** implement `std::error::Error` (mirroring the
/// real crate) so the blanket `From<E: std::error::Error>` conversion can
/// coexist with `From<Error> for Error` from `core`.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), cause: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), cause: Some(Box::new(self)) }
    }

    /// The cause messages, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = vec![self.msg.as_str()];
        let mut cur = &self.cause;
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = &e.cause;
        }
        out
    }

    /// The innermost cause message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().copied().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, colon-separated (anyhow's format).
            write!(f, "{}", self.chain().join(": "))
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let chain = self.chain();
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the std source chain into our cause chain.
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut it = msgs.into_iter().rev();
        let mut err = Error { msg: it.next().unwrap(), cause: None };
        for msg in it {
            err = Error { msg, cause: Some(Box::new(err)) };
        }
        err
    }
}

/// Anything convertible into [`Error`] — implemented for `Error` itself
/// and for every std error (the same split the real crate uses).
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

impl<E: StdError + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

/// Attach context to the error arm of a `Result`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: IntoError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(format!("{e}"), "bad value 7");
        assert_eq!(format!("{e:#}"), "bad value 7");
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading manifest".to_string()).unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        // Context on an already-anyhow Result.
        let r2: Result<()> = Err(e);
        let e2 = r2.context("loading artifacts").unwrap_err();
        assert_eq!(format!("{e2:#}"), "loading artifacts: reading manifest: gone");
        assert_eq!(e2.root_cause(), "gone");
        assert!(format!("{e2:?}").contains("Caused by:"));
    }

    #[test]
    fn bail_and_question_mark() {
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("nope: {}", 1 + 1);
            }
            let n: u32 = "42".parse()?; // std error converts via `?`
            Ok(n)
        }
        assert_eq!(inner(false).unwrap(), 42);
        assert_eq!(format!("{}", inner(true).unwrap_err()), "nope: 2");
    }
}
