//! The operator-graph IR: a DAG of named [`crate::perf::Op`] nodes with
//! explicit dependency edges, plus the deterministic parallelism
//! transforms every workload lowers through.
//!
//! * [`Graph`] — nodes are appended in topological order (`add` /
//!   [`Graph::add_on`] only accept already-present predecessors), so the
//!   structure is acyclic **by construction** and insertion order is a
//!   valid schedule order. A serial operator list is just a chain
//!   ([`Graph::chain`], [`Graph::is_chain`]); branchy blocks, MoE routers,
//!   and pipeline grids are graphs with the same API.
//! * [`Graph::tensor_parallel`] — Megatron-style sharding: every compute
//!   node's work is split `tp` ways along its preferred divisible
//!   dimension, and one `AllReduce` of the (full, unsharded) output is
//!   appended after each graph sink to recombine activations. Interior
//!   nodes keep sharded activations — the deferred-reduction convention
//!   that makes column→row matmul pairs cost a single all-reduce.
//! * [`Graph::pipeline_parallel`] — GPipe-style staging: the topological
//!   order is cut into `pp` contiguous stages balanced by FLOPs + bytes,
//!   each microbatch gets a row-sharded copy of the graph, and every
//!   stage-crossing edge routes through a `PeerToPeer` node carrying the
//!   producer's output activation. Pipeline fill/drain bubbles are not
//!   modeled here — they emerge from resource contention when
//!   [`crate::perf::graph_sched::schedule`] runs the grid.
//!
//! Both transforms are pure functions of the input graph: same input,
//! same output, no randomness — a scenario that names `{tp, pp,
//! microbatches}` is exactly reproducible.

use crate::perf::Op;

/// Index of a node within its [`Graph`] (insertion order).
pub type NodeId = usize;

/// One operator instance in the graph.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub name: String,
    pub op: Op,
    /// Pipeline stage (compute resource) executing this node. Single-
    /// device and tensor-parallel-only graphs keep every node on stage 0.
    pub stage: u64,
}

/// A DAG of operators. Nodes are stored in topological (insertion)
/// order; edges point from predecessors to the nodes depending on them.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Graph {
    nodes: Vec<Node>,
    /// `preds[i]` — the nodes that must finish before node `i` starts.
    preds: Vec<Vec<NodeId>>,
}

/// Scenario-level parallelism mapping: `tp`-way tensor parallelism inside
/// each of `pp` pipeline stages, with the batch split into `microbatches`
/// pipeline microbatches. `tp × pp` must equal the system's device count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    pub tp: u64,
    pub pp: u64,
    pub microbatches: u64,
}

impl Parallelism {
    /// No parallelism: one device, one stage, one microbatch.
    pub fn single() -> Parallelism {
        Parallelism { tp: 1, pp: 1, microbatches: 1 }
    }

    /// Validate the mapping against a concrete system size.
    pub fn validate(&self, device_count: u64) -> Result<(), String> {
        if self.tp == 0 || self.pp == 0 || self.microbatches == 0 {
            return Err("parallelism tp / pp / microbatches must all be ≥ 1".to_string());
        }
        if self.tp * self.pp != device_count {
            return Err(format!(
                "parallelism tp {} × pp {} = {} must equal the system's {} devices",
                self.tp,
                self.pp,
                self.tp * self.pp,
                device_count
            ));
        }
        if self.microbatches > 1 && self.pp == 1 {
            return Err("microbatches > 1 needs pp ≥ 2 (nothing to pipeline)".to_string());
        }
        Ok(())
    }

    /// The attention-head divisibility constraint of Megatron-style
    /// tensor parallelism, shared by every surface that maps a model
    /// (evaluator, lowering) so the error can never drift between them.
    pub fn validate_heads(&self, heads: u64, model_name: &str) -> Result<(), String> {
        if heads % self.tp != 0 {
            return Err(format!(
                "model `{model_name}` has {heads} heads, not divisible by tp {}",
                self.tp
            ));
        }
        Ok(())
    }
}

impl Graph {
    pub fn new() -> Graph {
        Graph::default()
    }

    /// Append a node on stage 0. `deps` must name already-added nodes
    /// (this is what keeps the graph acyclic by construction).
    pub fn add(&mut self, name: impl Into<String>, op: Op, deps: &[NodeId]) -> NodeId {
        self.add_on(0, name, op, deps)
    }

    /// Append a node on an explicit pipeline stage.
    pub fn add_on(
        &mut self,
        stage: u64,
        name: impl Into<String>,
        op: Op,
        deps: &[NodeId],
    ) -> NodeId {
        let id = self.nodes.len();
        for &d in deps {
            assert!(d < id, "graph edge {d} -> {id} must point to an earlier node");
        }
        self.nodes.push(Node { name: name.into(), op, stage });
        let mut p = deps.to_vec();
        p.sort_unstable();
        p.dedup();
        self.preds.push(p);
        id
    }

    /// A serial chain: each op depends on the previous one.
    pub fn chain(ops: impl IntoIterator<Item = (String, Op)>) -> Graph {
        let mut g = Graph::new();
        let mut prev: Option<NodeId> = None;
        for (name, op) in ops {
            let deps: Vec<NodeId> = prev.into_iter().collect();
            prev = Some(g.add(name, op, &deps));
        }
        g
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn preds(&self, id: NodeId) -> &[NodeId] {
        &self.preds[id]
    }

    /// True when the graph is a serial chain in insertion order — the
    /// shape on which scheduling degenerates to the serial sum.
    pub fn is_chain(&self) -> bool {
        self.preds
            .iter()
            .enumerate()
            .all(|(i, p)| if i == 0 { p.is_empty() } else { p.as_slice() == [i - 1] })
    }

    /// Nodes with no successors (graph outputs).
    pub fn sinks(&self) -> Vec<NodeId> {
        let mut has_succ = vec![false; self.nodes.len()];
        for p in &self.preds {
            for &d in p {
                has_succ[d] = true;
            }
        }
        (0..self.nodes.len()).filter(|&i| !has_succ[i]).collect()
    }

    /// Megatron-style tensor parallelism: shard every compute node's work
    /// `tp` ways along its preferred divisible dimension (matmul: `n`,
    /// then `m`, then `b`; row-wise vector ops: `m`; elementwise: the
    /// element count — a dimension that does not divide stays whole), and
    /// append one `AllReduce` of each sink's full output to recombine the
    /// activations. `tp == 1` returns the graph unchanged.
    pub fn tensor_parallel(&self, tp: u64) -> Result<Graph, String> {
        if tp == 0 {
            return Err("tensor parallelism degree must be ≥ 1".to_string());
        }
        if tp == 1 {
            return Ok(self.clone());
        }
        let mut g = Graph::new();
        for (i, n) in self.nodes.iter().enumerate() {
            g.add_on(n.stage, n.name.clone(), shard_compute(&n.op, tp), &self.preds[i]);
        }
        for sink in self.sinks() {
            let n = &self.nodes[sink];
            if matches!(n.op, Op::AllReduce { .. } | Op::PeerToPeer { .. }) {
                continue; // already a communication boundary
            }
            g.add_on(
                n.stage,
                format!("AllReduce_{}", n.name),
                Op::AllReduce { bytes: n.op.out_bytes(), devices: tp },
                &[sink],
            );
        }
        Ok(g)
    }

    /// GPipe-style pipeline parallelism: cut the topological order into
    /// `pp` contiguous stages balanced by FLOPs + memory traffic, then
    /// emit `microbatches` row-sharded copies of the graph; every edge
    /// that crosses a stage boundary routes through a `PeerToPeer` node
    /// carrying the producer's (sharded) output activation. The copies
    /// share per-stage compute resources, so scheduling the result yields
    /// the classic fill/steady/drain pipeline timeline.
    pub fn pipeline_parallel(&self, pp: u64, microbatches: u64) -> Result<Graph, String> {
        if pp == 0 || microbatches == 0 {
            return Err("pp and microbatches must be ≥ 1".to_string());
        }
        if pp as usize > self.nodes.len() {
            return Err(format!(
                "pipeline stages ({pp}) exceed the graph's {} nodes",
                self.nodes.len()
            ));
        }
        if pp == 1 && microbatches == 1 {
            return Ok(self.clone());
        }
        if pp == 1 {
            return Err("microbatches > 1 needs pp ≥ 2 (nothing to pipeline)".to_string());
        }
        if microbatches > 1 {
            // A node whose row dimension does not divide would be copied
            // at full size `microbatches` times — silently multiplying the
            // modeled work. Refuse instead.
            for n in &self.nodes {
                if shard_rows(&n.op, microbatches) == n.op {
                    return Err(format!(
                        "node `{}` cannot split its rows across {microbatches} microbatches \
                         (no dimension divides evenly)",
                        n.name
                    ));
                }
            }
        }
        let stage_of = self.balanced_stages(pp);
        let mut g = Graph::new();
        for j in 0..microbatches {
            let mut map: Vec<NodeId> = Vec::with_capacity(self.nodes.len());
            // One transfer per (producer, consumer stage): a producer
            // feeding several consumers on the same stage sends its
            // activation across the boundary once, not once per edge.
            let mut p2p: std::collections::HashMap<(NodeId, u64), NodeId> =
                std::collections::HashMap::new();
            for (i, n) in self.nodes.iter().enumerate() {
                let name = if microbatches > 1 {
                    format!("{}@mb{j}", n.name)
                } else {
                    n.name.clone()
                };
                let mut deps: Vec<NodeId> = Vec::with_capacity(self.preds[i].len());
                for &p in &self.preds[i] {
                    if stage_of[p] == stage_of[i] {
                        deps.push(map[p]);
                    } else {
                        // Stage boundary: the producer's activation moves
                        // over the interconnect.
                        let pid = *p2p.entry((p, stage_of[i])).or_insert_with(|| {
                            let bytes = shard_rows(&self.nodes[p].op, microbatches).out_bytes();
                            let pname = if microbatches > 1 {
                                format!("P2P_{}_s{}@mb{j}", self.nodes[p].name, stage_of[i])
                            } else {
                                format!("P2P_{}_s{}", self.nodes[p].name, stage_of[i])
                            };
                            g.add_on(stage_of[i], pname, Op::PeerToPeer { bytes }, &[map[p]])
                        });
                        deps.push(pid);
                    }
                }
                map.push(g.add_on(stage_of[i], name, shard_rows(&n.op, microbatches), &deps));
            }
        }
        Ok(g)
    }

    /// Contiguous stage assignment balanced by `flops + min_dram_bytes`,
    /// guaranteeing every stage gets at least one node.
    fn balanced_stages(&self, pp: u64) -> Vec<u64> {
        let w: Vec<f64> = self.nodes.iter().map(|n| n.op.flops() + n.op.min_dram_bytes()).collect();
        let total: f64 = w.iter().sum();
        let len = self.nodes.len();
        let mut stage_of = vec![0u64; len];
        let mut acc = 0.0f64;
        let mut s = 0u64;
        for i in 0..len {
            stage_of[i] = s;
            acc += w[i];
            let nodes_left = len - 1 - i;
            let stages_left = (pp - 1 - s) as usize;
            let quota_met = acc >= total * (s + 1) as f64 / pp as f64;
            if s + 1 < pp && nodes_left >= 1 && (quota_met || nodes_left == stages_left) {
                s += 1;
            }
        }
        stage_of
    }
}

/// Shard a compute op's work `parts` ways for tensor parallelism,
/// preferring the output-column dimension (Megatron column-parallel),
/// then rows, then the batch. A dimension that does not divide evenly is
/// left whole (the op simply does not shard) — deterministic, never
/// lossy.
fn shard_compute(op: &Op, parts: u64) -> Op {
    match *op {
        Op::Matmul { b, m, k, n, dtype, batched_b } => {
            if n % parts == 0 && n >= parts {
                Op::Matmul { b, m, k, n: n / parts, dtype, batched_b }
            } else if m % parts == 0 && m >= parts {
                Op::Matmul { b, m: m / parts, k, n, dtype, batched_b }
            } else if b % parts == 0 && b >= parts {
                Op::Matmul { b: b / parts, m, k, n, dtype, batched_b }
            } else {
                op.clone()
            }
        }
        Op::Softmax { m, n, dtype } if m % parts == 0 && m >= parts => {
            Op::Softmax { m: m / parts, n, dtype }
        }
        Op::LayerNorm { m, n, dtype } if m % parts == 0 && m >= parts => {
            Op::LayerNorm { m: m / parts, n, dtype }
        }
        Op::Gelu { elements, dtype } if elements % parts == 0 && elements >= parts => {
            Op::Gelu { elements: elements / parts, dtype }
        }
        _ => op.clone(),
    }
}

/// Shard an op's *row* (batch-like) dimension `parts` ways for
/// microbatching: matmul rows `m` first, then the batch `b`; row-wise
/// vector ops shard `m`; elementwise ops shard the element count; comm
/// ops shard their payload. Non-dividing dimensions stay whole.
fn shard_rows(op: &Op, parts: u64) -> Op {
    if parts <= 1 {
        return op.clone();
    }
    match *op {
        Op::Matmul { b, m, k, n, dtype, batched_b } => {
            if m % parts == 0 && m >= parts {
                Op::Matmul { b, m: m / parts, k, n, dtype, batched_b }
            } else if b % parts == 0 && b >= parts {
                Op::Matmul { b: b / parts, m, k, n, dtype, batched_b }
            } else {
                op.clone()
            }
        }
        Op::Softmax { m, n, dtype } if m % parts == 0 && m >= parts => {
            Op::Softmax { m: m / parts, n, dtype }
        }
        Op::LayerNorm { m, n, dtype } if m % parts == 0 && m >= parts => {
            Op::LayerNorm { m: m / parts, n, dtype }
        }
        Op::Gelu { elements, dtype } if elements % parts == 0 && elements >= parts => {
            Op::Gelu { elements: elements / parts, dtype }
        }
        Op::AllReduce { bytes, devices } if bytes % parts == 0 => {
            Op::AllReduce { bytes: bytes / parts, devices }
        }
        Op::PeerToPeer { bytes } if bytes % parts == 0 => {
            Op::PeerToPeer { bytes: bytes / parts }
        }
        _ => op.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::DType;

    fn mm(m: u64, k: u64, n: u64) -> Op {
        Op::Matmul { b: 1, m, k, n, dtype: DType::FP16, batched_b: false }
    }

    fn chain3() -> Graph {
        Graph::chain(vec![
            ("a".to_string(), mm(64, 64, 64)),
            ("b".to_string(), mm(64, 64, 128)),
            ("c".to_string(), mm(64, 128, 64)),
        ])
    }

    #[test]
    fn chain_is_chain() {
        let g = chain3();
        assert_eq!(g.len(), 3);
        assert!(g.is_chain());
        assert_eq!(g.sinks(), vec![2]);
        assert_eq!(g.preds(2), &[1]);
    }

    #[test]
    fn branchy_graph_is_not_a_chain() {
        let mut g = Graph::new();
        let a = g.add("a", mm(8, 8, 8), &[]);
        let b = g.add("b", mm(8, 8, 8), &[a]);
        let c = g.add("c", mm(8, 8, 8), &[a]);
        g.add("d", mm(8, 8, 8), &[b, c]);
        assert!(!g.is_chain());
        assert_eq!(g.sinks(), vec![3]);
    }

    #[test]
    #[should_panic(expected = "earlier node")]
    fn forward_edges_are_rejected() {
        let mut g = Graph::new();
        g.add("a", mm(8, 8, 8), &[3]);
    }

    #[test]
    fn duplicate_deps_collapse() {
        let mut g = Graph::new();
        let a = g.add("a", mm(8, 8, 8), &[]);
        let b = g.add("b", mm(8, 8, 8), &[a, a, a]);
        assert_eq!(g.preds(b), &[a]);
    }

    #[test]
    fn tensor_parallel_shards_and_appends_allreduce() {
        let g = chain3();
        let t = g.tensor_parallel(4).unwrap();
        // 3 sharded nodes + 1 all-reduce after the sink.
        assert_eq!(t.len(), 4);
        // `n` shards first.
        match t.node(0).op {
            Op::Matmul { n, .. } => assert_eq!(n, 16),
            _ => panic!("not a matmul"),
        }
        let last = t.node(3);
        assert_eq!(last.name, "AllReduce_c");
        match last.op {
            Op::AllReduce { bytes, devices } => {
                assert_eq!(devices, 4);
                // Full (unsharded) sink output: 64×64 fp16.
                assert_eq!(bytes, 64 * 64 * 2);
            }
            _ => panic!("not an all-reduce"),
        }
        assert_eq!(t.preds(3), &[2]);
        // tp=1 is the identity.
        assert_eq!(g.tensor_parallel(1).unwrap(), g);
        // Total FLOPs shrink by tp on every compute node.
        let f = |g: &Graph, i: usize| g.node(i).op.flops();
        for i in 0..3 {
            assert_eq!(f(&g, i) / 4.0, f(&t, i));
        }
    }

    #[test]
    fn tensor_parallel_leaves_indivisible_dims_whole() {
        let g = Graph::chain(vec![("odd".to_string(), mm(7, 5, 3))]);
        let t = g.tensor_parallel(4).unwrap();
        assert_eq!(t.node(0).op, mm(7, 5, 3));
    }

    #[test]
    fn pipeline_splits_stages_and_inserts_p2p() {
        let g = chain3();
        let p = g.pipeline_parallel(3, 1).unwrap();
        // 3 nodes on 3 stages + 2 boundary transfers.
        assert_eq!(p.len(), 5);
        let stages: Vec<u64> = p.nodes().iter().map(|n| n.stage).collect();
        assert!(stages.contains(&0) && stages.contains(&1) && stages.contains(&2));
        let p2ps = p
            .nodes()
            .iter()
            .filter(|n| matches!(n.op, Op::PeerToPeer { .. }))
            .count();
        assert_eq!(p2ps, 2);
        // pp=1, mb=1 is the identity.
        assert_eq!(g.pipeline_parallel(1, 1).unwrap(), g);
    }

    #[test]
    fn pipeline_microbatches_replicate_and_shard_rows() {
        let g = chain3();
        let p = g.pipeline_parallel(3, 2).unwrap();
        assert_eq!(p.len(), 10, "two copies of (3 nodes + 2 transfers)");
        // Rows halve per microbatch.
        let first = p.nodes().iter().find(|n| n.name == "a@mb0").unwrap();
        match first.op {
            Op::Matmul { m, .. } => assert_eq!(m, 32),
            _ => panic!("not a matmul"),
        }
        // Microbatch copies are independent: mb1 never depends on mb0.
        let mb0_len = p.len() / 2;
        for i in mb0_len..p.len() {
            for &d in p.preds(i) {
                assert!(d >= mb0_len, "cross-microbatch edge {d} -> {i}");
            }
        }
    }

    #[test]
    fn pipeline_rejects_degenerate_configs() {
        let g = chain3();
        assert!(g.pipeline_parallel(0, 1).is_err());
        assert!(g.pipeline_parallel(4, 1).is_err(), "more stages than nodes");
        assert!(g.pipeline_parallel(1, 2).is_err(), "microbatching needs stages");
        // Rows that cannot split across the microbatches are an error,
        // not a silent x-microbatches inflation of the modeled work.
        let odd = Graph::chain(vec![
            ("a".to_string(), mm(7, 8, 8)),
            ("b".to_string(), mm(7, 8, 8)),
        ]);
        let err = odd.pipeline_parallel(2, 2).unwrap_err();
        assert!(err.contains("microbatches"), "{err}");
    }

    #[test]
    fn stage_boundary_transfer_is_shared_by_same_stage_consumers() {
        // a → (b, c): if a sits on stage 0 and both consumers on stage 1,
        // the boundary pays ONE transfer, not one per edge.
        let mut g = Graph::new();
        // `a` carries most of the weight, so the 2-way split puts it
        // alone on stage 0 with b/c/d downstream on stage 1.
        let a = g.add("a", mm(64, 2048, 2048), &[]);
        let b = g.add("b", mm(64, 2048, 64), &[a]);
        let c = g.add("c", mm(64, 2048, 64), &[a]);
        g.add("d", mm(64, 128, 64), &[b, c]);
        let p = g.pipeline_parallel(2, 1).unwrap();
        let p2ps = p.nodes().iter().filter(|n| matches!(n.op, Op::PeerToPeer { .. })).count();
        // `a` alone on stage 0 (b/c/d dominate the weight): one transfer
        // feeds both b and c.
        assert_eq!(p2ps, 1, "duplicate boundary transfers: {:?}", p.nodes());
    }

    #[test]
    fn parallelism_validation() {
        assert!(Parallelism::single().validate(1).is_ok());
        assert!(Parallelism { tp: 2, pp: 2, microbatches: 4 }.validate(4).is_ok());
        assert!(Parallelism { tp: 2, pp: 2, microbatches: 1 }.validate(8).is_err());
        assert!(Parallelism { tp: 0, pp: 1, microbatches: 1 }.validate(1).is_err());
        assert!(
            Parallelism { tp: 4, pp: 1, microbatches: 2 }.validate(4).is_err(),
            "microbatches without pipeline stages"
        );
    }

    #[test]
    fn balanced_stages_cover_all_stages_nonempty() {
        // 6 equal-weight nodes over 3 stages → 2 per stage.
        let g = Graph::chain((0..6).map(|i| (format!("n{i}"), mm(64, 64, 64))));
        let p = g.pipeline_parallel(3, 1).unwrap();
        for s in 0..3u64 {
            assert!(
                p.nodes().iter().any(|n| n.stage == s && !matches!(n.op, Op::PeerToPeer { .. })),
                "stage {s} empty"
            );
        }
    }
}
