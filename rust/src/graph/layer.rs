//! Operator list for one decoder-only Transformer layer with tensor
//! parallelism (paper Fig. 2).
//!
//! Under `tp`-way tensor parallelism (Megatron-style [59]) the attention
//! heads and the MLP hidden dimension are split across devices; each layer
//! needs two all-reduces of the activations — one after the attention
//! block, one after the MLP block.
//!
//! Operator names follow the paper's Fig. 8 breakdown legend:
//! `Q_K_V`, `Q_mul_K`, `Softmax`, `A_mul_V`, `Wo_proj`, `AllReduce_MHA`,
//! `LayerNorm_MHA`, `W1_proj`, `GeLU`, `W2_proj`, `AllReduce_FFN`,
//! `LayerNorm_FFN`.

use super::ir::{Graph, NodeId};
use super::ModelConfig;
use crate::perf::Op;

/// Inference phase (paper §II-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Process the whole input prompt, building the KV cache.
    Prefill { batch: u64, seq: u64 },
    /// Generate one token; attention reads a KV cache of length `kv_len`.
    Decode { batch: u64, kv_len: u64 },
}

impl Phase {
    /// Rows through the dense projections: batch·seq for prefill, batch
    /// for decode.
    pub fn rows(&self) -> u64 {
        match *self {
            Phase::Prefill { batch, seq } => batch * seq,
            Phase::Decode { batch, .. } => batch,
        }
    }

    pub fn batch(&self) -> u64 {
        match *self {
            Phase::Prefill { batch, .. } | Phase::Decode { batch, .. } => batch,
        }
    }
}

/// One named operator within a layer.
#[derive(Debug, Clone)]
pub struct NamedOp {
    pub name: &'static str,
    pub op: Op,
}

/// Build the operator list for one Transformer layer under `tp`-way tensor
/// parallelism, as executed by **one** device (per-device head and FFN
/// slices), in execution order.
pub fn layer_ops(model: &ModelConfig, phase: Phase, tp: u64) -> Vec<NamedOp> {
    assert!(tp >= 1, "tensor parallelism degree must be ≥ 1");
    assert!(model.heads % tp == 0, "heads {} not divisible by tp {}", model.heads, tp);
    let d = model.d_model;
    let dh = model.d_head();
    let h_local = model.heads / tp;
    let ff_local = model.d_ff / tp;
    let dt = model.dtype;
    let rows = phase.rows();
    let batch = phase.batch();

    // Attention geometry: queries per sequence and KV length.
    let (q_len, kv_len) = match phase {
        Phase::Prefill { seq, .. } => (seq, seq),
        Phase::Decode { kv_len, .. } => (1, kv_len),
    };

    // K/V heads after head-sharing (MQA/GQA); at least one per device.
    let kv_heads = model.attention.kv_heads(model.heads);
    let kv_local = (kv_heads / tp).max(1);
    // Query heads sharing each local K/V head.
    let group = h_local / kv_local.min(h_local);

    let mut ops: Vec<NamedOp> = Vec::with_capacity(12);
    let mm = |m: u64, k: u64, n: u64| Op::Matmul { b: 1, m, k, n, dtype: dt, batched_b: false };

    // --- Attention block ----------------------------------------------------
    ops.push(NamedOp { name: "LayerNorm_MHA", op: Op::LayerNorm { m: rows, n: d, dtype: dt } });
    // Fused Q/K/V projection: d → (h_local + 2·kv_local)·dh per device
    // (3·h_local·dh for MHA; shrinks under MQA/GQA).
    ops.push(NamedOp { name: "Q_K_V", op: mm(rows, d, (h_local + 2 * kv_local) * dh) });
    // Attention scores: one GEMM per (sequence, K/V head); the `group`
    // query heads sharing a K/V head stack into the row dimension, which
    // is exactly why MQA decodes faster — the narrow m=1 GEMM becomes
    // m=group and the KV cache is read once per group, not per head.
    ops.push(NamedOp {
        name: "Q_mul_K",
        op: Op::Matmul {
            b: batch * kv_local,
            m: q_len * group,
            k: dh,
            n: kv_len,
            dtype: dt,
            batched_b: true,
        },
    });
    ops.push(NamedOp {
        name: "Softmax",
        op: Op::Softmax { m: batch * h_local * q_len, n: kv_len, dtype: dt },
    });
    // Attention-weighted values: A(q_len·group × kv_len) · V(kv_len × dh).
    ops.push(NamedOp {
        name: "A_mul_V",
        op: Op::Matmul {
            b: batch * kv_local,
            m: q_len * group,
            k: kv_len,
            n: dh,
            dtype: dt,
            batched_b: true,
        },
    });
    // Output projection: h_local·dh → d.
    ops.push(NamedOp { name: "Wo_proj", op: mm(rows, h_local * dh, d) });
    if tp > 1 && !model.parallel_blocks {
        ops.push(NamedOp {
            name: "AllReduce_MHA",
            op: Op::AllReduce { bytes: rows * d * dt.bytes(), devices: tp },
        });
    }

    // --- MLP block ----------------------------------------------------------
    if !model.parallel_blocks {
        // PaLM-style parallel blocks share the attention layernorm.
        ops.push(NamedOp {
            name: "LayerNorm_FFN",
            op: Op::LayerNorm { m: rows, n: d, dtype: dt },
        });
    }
    if model.moe_experts > 1 {
        // Mixture-of-Experts: each token routes to `moe_active` experts.
        // Per device, the distinct expert weight matrices touched is
        // bounded by both the expert count and the routed token count —
        // for decode (few tokens) only a few experts stream in, for
        // prefill effectively all of them do.
        let routed_rows = rows * model.moe_active;
        let touched = model.moe_experts.min(routed_rows).max(1);
        let rows_per_expert = (routed_rows + touched - 1) / touched;
        ops.push(NamedOp { name: "MoE_router", op: mm(rows, d, model.moe_experts) });
        ops.push(NamedOp {
            name: "W1_proj",
            op: Op::Matmul {
                b: touched,
                m: rows_per_expert,
                k: d,
                n: ff_local,
                dtype: dt,
                batched_b: true,
            },
        });
        ops.push(NamedOp {
            name: "GeLU",
            op: Op::Gelu { elements: routed_rows * ff_local, dtype: dt },
        });
        ops.push(NamedOp {
            name: "W2_proj",
            op: Op::Matmul {
                b: touched,
                m: rows_per_expert,
                k: ff_local,
                n: d,
                dtype: dt,
                batched_b: true,
            },
        });
    } else {
        ops.push(NamedOp { name: "W1_proj", op: mm(rows, d, ff_local) });
        ops.push(NamedOp { name: "GeLU", op: Op::Gelu { elements: rows * ff_local, dtype: dt } });
        ops.push(NamedOp { name: "W2_proj", op: mm(rows, ff_local, d) });
    }
    if tp > 1 {
        // With parallel blocks a single all-reduce covers attention + MLP.
        ops.push(NamedOp {
            name: "AllReduce_FFN",
            op: Op::AllReduce { bytes: rows * d * dt.bytes(), devices: tp },
        });
    }

    ops
}

/// Lower one Transformer layer onto the operator-graph IR: the op list of
/// [`layer_ops`] as a dependency chain. This is the graph the simulator
/// schedules — a chain schedules to exactly the serial op-walk latency
/// (bit for bit, see `perf::graph_sched`), so the lowering is free.
pub fn layer_graph(model: &ModelConfig, phase: Phase, tp: u64) -> Graph {
    Graph::chain(layer_ops(model, phase, tp).into_iter().map(|n| (n.name.to_string(), n.op)))
}

/// Append `layers` chained copies of one layer onto `g`, placed on
/// pipeline stage `stage`, depending on `after` (if any). Returns the id
/// of the last appended node — the stack's output. This is the building
/// block pipeline-parallel lowerings stack into per-stage subgraphs.
pub fn append_layer_stack(
    g: &mut Graph,
    stage: u64,
    model: &ModelConfig,
    phase: Phase,
    tp: u64,
    layers: u64,
    after: Option<NodeId>,
) -> Option<NodeId> {
    let ops = layer_ops(model, phase, tp);
    let mut prev = after;
    for l in 0..layers {
        for nop in &ops {
            let deps: Vec<NodeId> = prev.into_iter().collect();
            prev = Some(g.add_on(stage, format!("{}_L{l}", nop.name), nop.op.clone(), &deps));
        }
    }
    prev
}

/// Total FLOPs of one layer (sanity/reporting).
pub fn layer_flops(model: &ModelConfig, phase: Phase, tp: u64) -> f64 {
    layer_ops(model, phase, tp).iter().map(|o| o.op.flops()).sum()
}

/// Minimum DRAM traffic of one layer on one device.
pub fn layer_min_bytes(model: &ModelConfig, phase: Phase, tp: u64) -> f64 {
    layer_ops(model, phase, tp).iter().map(|o| o.op.min_dram_bytes()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::DType;

    fn gpt3() -> ModelConfig {
        ModelConfig::gpt3_175b()
    }

    #[test]
    fn prefill_op_list_structure() {
        let ops = layer_ops(&gpt3(), Phase::Prefill { batch: 8, seq: 2048 }, 4);
        let names: Vec<&str> = ops.iter().map(|o| o.name).collect();
        assert_eq!(
            names,
            vec![
                "LayerNorm_MHA",
                "Q_K_V",
                "Q_mul_K",
                "Softmax",
                "A_mul_V",
                "Wo_proj",
                "AllReduce_MHA",
                "LayerNorm_FFN",
                "W1_proj",
                "GeLU",
                "W2_proj",
                "AllReduce_FFN"
            ]
        );
    }

    #[test]
    fn no_allreduce_without_tp() {
        let ops = layer_ops(&gpt3(), Phase::Decode { batch: 8, kv_len: 2048 }, 1);
        assert!(ops.iter().all(|o| o.name != "AllReduce_MHA" && o.name != "AllReduce_FFN"));
        assert_eq!(ops.len(), 10);
    }

    #[test]
    fn prefill_flops_match_analytic() {
        // Dense-projection FLOPs per layer per token ≈ 2 · 12 d² (whole
        // layer, summed over tp devices); attention adds 2·2·s·d per token.
        let m = gpt3();
        let (b, s, tp) = (8u64, 2048u64, 4u64);
        let tokens = (b * s) as f64;
        let d = m.d_model as f64;
        let dense = 2.0 * 12.0 * d * d * tokens / tp as f64;
        let attn = 2.0 * 2.0 * (s as f64) * d * tokens / tp as f64;
        let analytic = dense + attn;
        let got_matmul: f64 = layer_ops(&m, Phase::Prefill { batch: b, seq: s }, tp)
            .iter()
            .filter(|o| matches!(o.op, crate::perf::Op::Matmul { .. }))
            .map(|o| o.op.flops())
            .sum();
        assert!(
            (got_matmul - analytic).abs() / analytic < 0.01,
            "matmul flops {got_matmul:.3e} vs analytic {analytic:.3e}"
        );
    }

    #[test]
    fn decode_reads_all_params_and_kv() {
        // Decode min traffic per device ≥ params/tp + KV/tp.
        let m = gpt3();
        let (b, kv, tp) = (8u64, 2048u64, 4u64);
        let bytes = layer_min_bytes(&m, Phase::Decode { batch: b, kv_len: kv }, tp);
        let params = m.params_per_layer() as f64 * 2.0 / tp as f64;
        let kv_bytes = (b * kv) as f64 * m.kv_bytes_per_token_per_layer() as f64 / tp as f64;
        assert!(bytes > params + kv_bytes * 0.99, "{bytes:.3e} vs {:.3e}", params + kv_bytes);
        // ... but not wildly more (activations are small at decode).
        assert!(bytes < (params + kv_bytes) * 1.2);
    }

    #[test]
    fn small_model_ops_well_formed() {
        let m = ModelConfig::gpt_small();
        let ops = layer_ops(&m, Phase::Prefill { batch: 2, seq: 128 }, 1);
        for o in &ops {
            assert!(o.op.flops() >= 0.0);
            assert!(o.op.min_dram_bytes() > 0.0, "{} has zero traffic", o.name);
        }
        let _ = DType::FP16;
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn tp_must_divide_heads() {
        layer_ops(&gpt3(), Phase::Prefill { batch: 1, seq: 8 }, 7);
    }

    #[test]
    fn layer_graph_is_the_op_chain() {
        let m = gpt3();
        let phase = Phase::Prefill { batch: 8, seq: 2048 };
        let ops = layer_ops(&m, phase, 4);
        let g = layer_graph(&m, phase, 4);
        assert!(g.is_chain());
        assert_eq!(g.len(), ops.len());
        for (node, op) in g.nodes().iter().zip(&ops) {
            assert_eq!(node.name, op.name);
            assert_eq!(node.op, op.op);
            assert_eq!(node.stage, 0);
        }
    }

    #[test]
    fn layer_stack_chains_layers_on_a_stage() {
        let m = ModelConfig::gpt_small();
        let phase = Phase::Decode { batch: 2, kv_len: 64 };
        let per_layer = layer_ops(&m, phase, 1).len();
        let mut g = crate::graph::ir::Graph::new();
        let last = append_layer_stack(&mut g, 3, &m, phase, 1, 4, None);
        assert_eq!(g.len(), 4 * per_layer);
        assert_eq!(last, Some(g.len() - 1));
        assert!(g.is_chain());
        assert!(g.nodes().iter().all(|n| n.stage == 3));
    }

    #[test]
    fn mqa_shrinks_kv_and_qkv_projection() {
        let mha = gpt3();
        let mqa = ModelConfig::gpt3_palm_style();
        // KV cache per token shrinks by the head count (96x).
        assert_eq!(
            mha.kv_bytes_per_token_per_layer(),
            96 * mqa.kv_bytes_per_token_per_layer()
        );
        // Decode KV read traffic shrinks accordingly.
        let phase = Phase::Decode { batch: 8, kv_len: 2048 };
        let mha_attn: f64 = layer_ops(&mha, phase, 4)
            .iter()
            .filter(|o| o.name == "Q_mul_K" || o.name == "A_mul_V")
            .map(|o| o.op.min_dram_bytes())
            .sum();
        let mqa_attn: f64 = layer_ops(&mqa, phase, 4)
            .iter()
            .filter(|o| o.name == "Q_mul_K" || o.name == "A_mul_V")
            .map(|o| o.op.min_dram_bytes())
            .sum();
        assert!(
            mha_attn / mqa_attn > 10.0,
            "MQA attention traffic should collapse: {mha_attn:.3e} vs {mqa_attn:.3e}"
        );
        // FLOPs stay equal (same scores computed).
        let qk_flops = |m: &ModelConfig| -> f64 {
            layer_ops(m, phase, 4)
                .iter()
                .filter(|o| o.name == "Q_mul_K")
                .map(|o| o.op.flops())
                .sum()
        };
        let f_mha = qk_flops(&mha);
        let f_mqa = qk_flops(&mqa);
        assert!((f_mha - f_mqa).abs() / f_mha < 1e-9);
    }

    #[test]
    fn parallel_blocks_drop_one_layernorm_and_allreduce() {
        let palm = ModelConfig::gpt3_palm_style();
        let ops = layer_ops(&palm, Phase::Prefill { batch: 8, seq: 128 }, 4);
        let names: Vec<&str> = ops.iter().map(|o| o.name).collect();
        assert!(!names.contains(&"LayerNorm_FFN"));
        assert!(!names.contains(&"AllReduce_MHA"));
        assert!(names.contains(&"AllReduce_FFN"));
    }

    #[test]
    fn moe_decode_touches_few_experts() {
        let moe = ModelConfig::gpt3_moe(64);
        // 64 experts but only batch=8 tokens routed: W1 reads ≤ 8 experts.
        let phase = Phase::Decode { batch: 8, kv_len: 128 };
        let w1 = layer_ops(&moe, phase, 4)
            .into_iter()
            .find(|o| o.name == "W1_proj")
            .unwrap();
        match w1.op {
            crate::perf::Op::Matmul { b, batched_b, .. } => {
                assert_eq!(b, 8);
                assert!(batched_b);
            }
            _ => panic!("W1 not a matmul"),
        }
        // Total parameters scale with the expert count.
        assert!(moe.params_per_layer() > 32 * gpt3().params_per_layer());
        // Prefill touches all experts.
        let w1p = layer_ops(&moe, Phase::Prefill { batch: 8, seq: 2048 }, 4)
            .into_iter()
            .find(|o| o.name == "W1_proj")
            .unwrap();
        match w1p.op {
            crate::perf::Op::Matmul { b, .. } => assert_eq!(b, 64),
            _ => panic!(),
        }
    }
}
