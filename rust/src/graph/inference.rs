//! End-to-end inference simulation: per-layer latency, prefill/decode,
//! KV-growth integration, memory-capacity batch sizing, and pipeline-
//! parallel requests/throughput (paper §IV experimental setup and §V
//! designs).
//!
//! Every workload is lowered onto the operator-graph IR
//! ([`crate::graph::ir`]) and simulated by scheduling the DAG
//! ([`crate::perf::graph_sched`]): a layer is a chain (which schedules to
//! exactly the serial op walk, bit for bit), and a pipeline-parallel
//! request is a stages × microbatches grid whose fill/drain bubbles and
//! compute/communication overlap fall out of the schedule.

use super::ir::{Graph, NodeId, Parallelism};
use super::layer::{append_layer_stack, layer_graph, Phase};
use super::ModelConfig;
use crate::hardware::{DeviceSpec, SystemSpec};
use crate::perf::graph_sched::{self, Schedule};
use crate::perf::mapper::Mapper;
use crate::perf::matmul::Shape;
use crate::perf::{comm, vecop, Op, OpResult};
use crate::serve::oracle::OracleCache;
use crate::util::telemetry::Recorder;
use std::sync::Arc;

/// Latency report for one Transformer layer.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub total_s: f64,
    /// (operator name, seconds) in execution order.
    pub breakdown: Vec<(String, f64)>,
}

impl LayerReport {
    /// Seconds attributed to an operator name (0 if absent).
    pub fn time_of(&self, name: &str) -> f64 {
        self.breakdown.iter().filter(|(n, _)| *n == name).map(|(_, s)| s).sum()
    }
}

/// The inference simulator: owns a [`Mapper`] whose caches persist across
/// calls (the same GEMM shapes recur for every layer and every sweep
/// point — this is what makes a full GPT-3 simulation take minutes, not
/// hours, exactly as the paper's LUT + mapper-cache design intends).
pub struct Simulator {
    pub mapper: Mapper,
    /// Telemetry recorder shared with everything the simulator drives
    /// (the serving scheduler reads it through its `&Simulator`; the
    /// mapper holds a clone for its host-clock search spans). Disabled
    /// by default — every record call is then a no-op branch.
    pub recorder: Arc<Recorder>,
    /// Shared quantizing latency oracles for the serving layer, deduped
    /// by hardware+model fingerprint so fleet replicas and sweep cells
    /// over unchanged systems reuse one warm cache (see `serve::oracle`).
    pub oracles: OracleCache,
}

impl Default for Simulator {
    fn default() -> Self {
        Self::with_mapper(Mapper::default())
    }
}

impl Simulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// A simulator whose mapper fans each candidate search across all
    /// cores as a fixed pool — for single-stream callers that own the
    /// whole machine (the CLI, the serving oracle). Prefer
    /// [`Simulator::hybrid`] under outer sweeps.
    pub fn pooled() -> Self {
        Self::with_mapper(Mapper::pooled())
    }

    /// A simulator whose mapper runs in work-stealing hybrid mode: its
    /// candidate loops borrow idle workers from the process-wide token
    /// budget, so outer sweeps (experiment cells, eval suites) and the
    /// per-candidate loop share the cores without multiplying threads.
    pub fn hybrid() -> Self {
        Self::with_mapper(Mapper::hybrid())
    }

    /// A simulator around a caller-built mapper (e.g.
    /// [`Mapper::with_cache`] for the persistent on-disk mapping cache).
    pub fn with_mapper(mapper: Mapper) -> Self {
        Simulator {
            mapper,
            recorder: Arc::new(Recorder::disabled()),
            oracles: OracleCache::new(),
        }
    }

    /// Attach a telemetry recorder (builder style). The mapper shares
    /// the handle so its parameter-search spans land in the same trace.
    pub fn with_recorder(mut self, rec: Arc<Recorder>) -> Self {
        self.set_recorder(rec);
        self
    }

    /// Attach a telemetry recorder in place.
    pub fn set_recorder(&mut self, rec: Arc<Recorder>) {
        self.mapper.set_recorder(rec.clone());
        self.recorder = rec;
    }

    /// Simulate one operator on the system (device for compute ops, the
    /// interconnect for communication ops). Kernel-launch overhead is
    /// added per operator, as measured by the paper with size-1 inputs.
    pub fn op_latency(&self, sys: &SystemSpec, op: &Op) -> OpResult {
        let dev = &sys.device;
        match *op {
            Op::Matmul { b, m, k, n, dtype, batched_b } => {
                let best = self.mapper.matmul(dev, &Shape { b, m, k, n, dtype, batched_b });
                let flops = 2.0 * b as f64 * m as f64 * k as f64 * n as f64;
                OpResult {
                    latency_s: dev.launch_overhead_s + best.outcome.seconds,
                    compute_bound_s: flops / dev.peak_matrix_flops(),
                    memory_bound_s: op.min_dram_bytes() / dev.memory.bandwidth_bytes_per_s,
                    mapper_rounds: best.rounds,
                    mapping_desc: best.mapping.describe(),
                }
            }
            Op::Softmax { m, n, dtype } => vecop::softmax(dev, m, n, dtype),
            Op::LayerNorm { m, n, dtype } => vecop::layernorm(dev, m, n, dtype),
            Op::Gelu { elements, dtype } => vecop::gelu(dev, elements, dtype),
            Op::AllReduce { bytes, devices } => {
                let mut r = comm::all_reduce(&sys.interconnect, bytes, devices);
                r.latency_s += dev.launch_overhead_s;
                r
            }
            Op::PeerToPeer { bytes } => comm::peer_to_peer(&sys.interconnect, bytes),
        }
    }

    /// Schedule an arbitrary operator graph on the system: list
    /// scheduling over the graph's stage/interconnect resources, with
    /// every node's latency simulated through [`Simulator::op_latency`]
    /// (and therefore the mapper caches).
    pub fn schedule_graph(&self, sys: &SystemSpec, g: &Graph) -> Schedule {
        graph_sched::schedule(g, |n| self.op_latency(sys, &n.op).latency_s)
    }

    /// Simulate one Transformer layer; `tp` defaults to the system size.
    pub fn layer(&self, sys: &SystemSpec, model: &ModelConfig, phase: Phase) -> LayerReport {
        self.layer_tp(sys, model, phase, sys.device_count)
    }

    /// Simulate one Transformer layer at an explicit tensor-parallel
    /// degree: lower it to its chain graph and schedule that. A chain
    /// schedules to exactly the serial sum of its op latencies, so this
    /// reproduces the pre-IR serial walk bit for bit.
    pub fn layer_tp(
        &self,
        sys: &SystemSpec,
        model: &ModelConfig,
        phase: Phase,
        tp: u64,
    ) -> LayerReport {
        let g = layer_graph(model, phase, tp);
        let sched = self.schedule_graph(sys, &g);
        LayerReport {
            total_s: sched.total_s,
            breakdown: sched.timings.into_iter().map(|t| (t.name, t.latency_s)).collect(),
        }
    }

    /// Prefill latency for `layers` stacked layers.
    pub fn prefill(
        &self,
        sys: &SystemSpec,
        model: &ModelConfig,
        batch: u64,
        seq: u64,
        layers: u64,
    ) -> f64 {
        layers as f64 * self.layer(sys, model, Phase::Prefill { batch, seq }).total_s
    }

    /// Decode latency (one output token) for `layers` stacked layers at a
    /// given KV length.
    pub fn decode(
        &self,
        sys: &SystemSpec,
        model: &ModelConfig,
        batch: u64,
        kv_len: u64,
        layers: u64,
    ) -> f64 {
        layers as f64 * self.layer(sys, model, Phase::Decode { batch, kv_len }).total_s
    }

    /// End-to-end request latency: prefill(s_in) + Σ_{t=1..s_out}
    /// decode(kv = s_in + t). Decode latency is affine in the KV length, so
    /// it is sampled at up to `samples` points and integrated with the
    /// trapezoid rule (validated to <0.5% against dense evaluation in the
    /// integration tests).
    pub fn e2e_latency(
        &self,
        sys: &SystemSpec,
        model: &ModelConfig,
        batch: u64,
        s_in: u64,
        s_out: u64,
        layers: u64,
    ) -> f64 {
        let prefill = self.prefill(sys, model, batch, s_in, layers);
        prefill + self.decode_sum(sys, model, batch, s_in, s_out, layers)
    }

    /// Σ over output tokens of per-token decode latency, via sampling.
    pub fn decode_sum(
        &self,
        sys: &SystemSpec,
        model: &ModelConfig,
        batch: u64,
        s_in: u64,
        s_out: u64,
        layers: u64,
    ) -> f64 {
        integrate_tokens(s_out, |t| self.decode(sys, model, batch, s_in + t, layers))
    }

    /// End-to-end request latency under an explicit `{tp, pp,
    /// microbatches}` mapping. With `pp == 1` this is exactly
    /// [`Simulator::e2e_latency`] (tensor parallelism over the whole
    /// system — the legacy path, bit for bit). With `pp ≥ 2` the layer
    /// stack is cut into `pp` stages of `tp`-parallel devices:
    ///
    /// * **prefill** lowers to a stages × microbatches grid — each
    ///   microbatch's activations flow through the stages over the
    ///   interconnect, stage resources serialize the microbatches, and
    ///   the GPipe fill/drain bubbles emerge from the schedule;
    /// * **decode** is sequential in tokens (token *t+1* consumes token
    ///   *t*), so each token's graph is a chain of stage stacks joined by
    ///   peer-to-peer activation handoffs, integrated over KV growth with
    ///   the same sampling as the serial path.
    pub fn e2e_latency_parallel(
        &self,
        sys: &SystemSpec,
        model: &ModelConfig,
        batch: u64,
        s_in: u64,
        s_out: u64,
        layers: u64,
        par: &Parallelism,
    ) -> Result<f64, String> {
        par.validate(sys.device_count)?;
        par.validate_heads(model.heads, &model.name)?;
        if par.pp == 1 {
            return Ok(self.e2e_latency(sys, model, batch, s_in, s_out, layers));
        }
        if par.pp > layers {
            return Err(format!(
                "pipeline stages ({}) exceed the {layers} layers to run",
                par.pp
            ));
        }
        let mb = par.microbatches;
        if batch % mb != 0 {
            return Err(format!("microbatches ({mb}) must divide the batch ({batch})"));
        }
        // The tensor-parallel degree enters through the per-layer op
        // shapes (`layer_ops(.., tp)`) and each AllReduce's own `devices`
        // field — op_latency never reads `sys.device_count`, so the
        // system is passed through as-is.
        // Layers per stage; any remainder goes to the earliest stages.
        let stage_layers: Vec<u64> = (0..par.pp)
            .map(|s| layers / par.pp + u64::from(s < layers % par.pp))
            .collect();
        let mb_batch = batch / mb;
        let act_bytes = |b: u64, toks: u64| b * toks * model.d_model * model.dtype.bytes();

        // Prefill grid.
        let mut g = Graph::new();
        for j in 0..mb {
            let mut prev: Option<NodeId> = None;
            for (s, &ls) in stage_layers.iter().enumerate() {
                let stage = s as u64;
                if s > 0 {
                    let deps: Vec<NodeId> = prev.into_iter().collect();
                    prev = Some(g.add_on(
                        stage,
                        format!("P2P_s{s}@mb{j}"),
                        Op::PeerToPeer { bytes: act_bytes(mb_batch, s_in) },
                        &deps,
                    ));
                }
                let phase = Phase::Prefill { batch: mb_batch, seq: s_in };
                prev = append_layer_stack(&mut g, stage, model, phase, par.tp, ls, prev);
            }
        }
        let prefill_sched = self.schedule_graph(sys, &g);
        graph_sched::emit_trace(&self.recorder, "pipeline prefill", &prefill_sched);
        let prefill_s = prefill_sched.total_s;

        // Decode: one chain of stage stacks per token, sampled over KV.
        let decode_tok = |kv: u64| -> f64 {
            let mut g = Graph::new();
            let mut prev: Option<NodeId> = None;
            for (s, &ls) in stage_layers.iter().enumerate() {
                let stage = s as u64;
                if s > 0 {
                    let deps: Vec<NodeId> = prev.into_iter().collect();
                    prev = Some(g.add_on(
                        stage,
                        format!("P2P_s{s}"),
                        Op::PeerToPeer { bytes: act_bytes(batch, 1) },
                        &deps,
                    ));
                }
                let phase = Phase::Decode { batch, kv_len: kv };
                prev = append_layer_stack(&mut g, stage, model, phase, par.tp, ls, prev);
            }
            let sched = self.schedule_graph(sys, &g);
            if self.recorder.is_enabled() {
                graph_sched::emit_trace(
                    &self.recorder,
                    &format!("pipeline decode kv={kv}"),
                    &sched,
                );
            }
            sched.total_s
        };
        let decode_s = integrate_tokens(s_out, |t| decode_tok(s_in + t));
        Ok(prefill_s + decode_s)
    }

    /// Pipeline-parallel throughput (paper Fig. 12 setting): the system's
    /// devices form `device_count` pipeline stages, each running
    /// `layers/device_count` layers with tp=1. Batch is the largest that
    /// fits each device's memory; returns (tokens/s, batch, stage_time_s).
    pub fn pipeline_throughput(
        &self,
        sys: &SystemSpec,
        model: &ModelConfig,
        s_in: u64,
        s_out: u64,
    ) -> (f64, u64, f64) {
        let stages = sys.device_count;
        let layers_per_stage = model.layers / stages;
        let batch = max_batch(&sys.device, model, layers_per_stage, 1, s_in + s_out);
        if batch == 0 {
            return (0.0, 0, f64::INFINITY);
        }
        let single = SystemSpec { device_count: 1, ..sys.clone() };
        // Per-stage work for one full request batch.
        let prefill = self.prefill(&single, model, batch, s_in, layers_per_stage);
        let decode = self.decode_sum(&single, model, batch, s_in, s_out, layers_per_stage);
        // Stage handoffs: activations (batch × d) per generated token plus
        // the prefill activation block, through the interconnect.
        let act_bytes = batch * model.d_model * model.dtype.bytes();
        let p2p_tok = comm::peer_to_peer(&sys.interconnect, act_bytes).latency_s;
        let p2p_prefill =
            comm::peer_to_peer(&sys.interconnect, act_bytes * s_in).latency_s;
        let stage_time = prefill + decode + s_out as f64 * p2p_tok + p2p_prefill;
        let tokens_per_s = batch as f64 * s_out as f64 / stage_time;
        (tokens_per_s, batch, stage_time)
    }
}

/// Σ_{t=1..s_out} f(t) for a per-token latency `f` that is affine-ish in
/// `t`: evaluated densely for tiny `s_out`, otherwise sampled at up to 6
/// points and integrated with the trapezoid rule (validated to <0.5%
/// against dense evaluation in the integration tests). Shared by the
/// serial decode path and the pipeline-parallel lowering so both
/// integrate KV growth identically.
fn integrate_tokens(s_out: u64, f: impl Fn(u64) -> f64) -> f64 {
    if s_out == 0 {
        return 0.0;
    }
    let samples = 6usize.min(s_out as usize);
    if samples <= 2 {
        return (1..=s_out).map(f).sum();
    }
    // Sample token indices from 1 to s_out inclusive.
    let mut pts: Vec<(f64, f64)> = Vec::with_capacity(samples);
    for i in 0..samples {
        let t = 1 + (s_out - 1) * i as u64 / (samples as u64 - 1);
        pts.push((t as f64, f(t)));
    }
    // Trapezoid over token index t ∈ [1, s_out].
    let mut sum = 0.0;
    for w in pts.windows(2) {
        let (t0, l0) = w[0];
        let (t1, l1) = w[1];
        sum += (t1 - t0) * (l0 + l1) / 2.0;
    }
    // The trapezoid covers (s_out − 1) token intervals; add one endpoint
    // token so Σ has s_out terms.
    sum + (pts[0].1 + pts[pts.len() - 1].1) / 2.0
}

/// Largest batch fitting device memory: capacity − resident parameters,
/// divided by per-sequence KV + activation footprint. `shard` is the
/// tensor-parallel degree (params and KV split `shard` ways); pipeline
/// parallelism instead reduces `layers_resident`.
pub fn max_batch(
    dev: &DeviceSpec,
    model: &ModelConfig,
    layers_resident: u64,
    shard: u64,
    max_seq_len: u64,
) -> u64 {
    let cap = dev.memory.capacity_bytes as f64;
    let params = model.param_bytes(layers_resident) as f64 / shard as f64;
    if params >= cap {
        return 0;
    }
    let kv_per_seq = (layers_resident * model.kv_bytes_per_token_per_layer() * max_seq_len) as f64
        / shard as f64;
    // Activations / workspace: a few activation tensors of batch × d_ff.
    let act_per_seq = (4 * model.d_ff * model.dtype.bytes()) as f64 / shard as f64;
    ((cap - params) / (kv_per_seq + act_per_seq)).floor() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::presets;

    fn sim() -> Simulator {
        Simulator::new()
    }

    fn gpt3() -> ModelConfig {
        ModelConfig::gpt3_175b()
    }

    fn a100x4() -> SystemSpec {
        presets::system("a100x4").unwrap()
    }

    #[test]
    fn prefill_layer_latency_in_paper_ballpark() {
        // One GPT-3 layer, batch 8, seq 2048, 4-way TP: the dense GEMMs
        // alone are 24·(b·s)·d² ≈ 5.9e16 FLOPs, i.e. ≥47.5 ms at the full
        // 312-TFLOPS tensor peak of 4 A100s — so a credible simulation
        // must land in the tens of milliseconds, above the roofline but
        // within ~2.5x of it.
        let s = sim();
        let lat = s.layer(&a100x4(), &gpt3(), Phase::Prefill { batch: 8, seq: 2048 }).total_s;
        let roofline = 24.0 * (8.0 * 2048.0) * 12288.0f64.powi(2) / (4.0 * 312e12);
        assert!(lat >= roofline, "below compute roofline");
        assert!(lat < 2.5 * roofline, "prefill layer {lat:.4}s vs roofline {roofline:.4}s");
    }

    #[test]
    fn decode_layer_latency_in_paper_ballpark() {
        // Paper Fig. 5i: decoding the 1024th token of one GPT-3 layer,
        // batch 8, input 2048 on 4×A100: ~1.1-1.4 ms.
        let s = sim();
        let lat =
            s.layer(&a100x4(), &gpt3(), Phase::Decode { batch: 8, kv_len: 2048 + 1024 }).total_s;
        assert!(
            (0.0004..0.004).contains(&lat),
            "decode layer latency {lat:.5}s outside [0.4ms, 4ms]"
        );
    }

    #[test]
    fn decode_dominated_by_weight_io() {
        // Implication ③ groundwork: one decode layer's latency should sit
        // near (params + KV)/tp / bandwidth.
        let s = sim();
        let sys = a100x4();
        let m = gpt3();
        let lat = s.layer(&sys, &m, Phase::Decode { batch: 8, kv_len: 3072 }).total_s;
        let phase = Phase::Decode { batch: 8, kv_len: 3072 };
        let io = crate::graph::layer::layer_min_bytes(&m, phase, 4)
            / sys.device.memory.bandwidth_bytes_per_s;
        assert!(lat >= io, "latency {lat} below io bound {io}");
        assert!(lat < io * 4.0, "decode layer {:.1}x io bound", lat / io);
    }

    #[test]
    fn breakdown_names_cover_fig8_legend() {
        let s = sim();
        let rep = s.layer(&a100x4(), &gpt3(), Phase::Prefill { batch: 8, seq: 2048 });
        for name in ["Q_K_V", "Softmax", "W1_proj", "AllReduce_FFN", "GeLU"] {
            assert!(rep.time_of(name) > 0.0, "{name} missing from breakdown");
        }
        let sum: f64 = rep.breakdown.iter().map(|(_, s)| s).sum();
        assert!((sum - rep.total_s).abs() < 1e-12);
    }

    #[test]
    fn decode_sum_matches_dense_evaluation() {
        // Trapezoid sampling vs token-by-token evaluation on a small case.
        let s = sim();
        let sys = presets::system("a100").unwrap();
        let m = ModelConfig::gpt_small();
        let (b, s_in, s_out) = (4u64, 64u64, 32u64);
        let sampled = s.decode_sum(&sys, &m, b, s_in, s_out, 1);
        let dense: f64 =
            (1..=s_out).map(|t| s.decode(&sys, &m, b, s_in + t, 1)).sum();
        let err = (sampled - dense).abs() / dense;
        assert!(err < 0.005, "sampling error {err:.4}");
    }

    #[test]
    fn max_batch_matches_paper_ratios() {
        // Throughput design: 512 GB, 12 resident layers → >12x the batch
        // of a GA100 with 80 GB (paper §V-B discussion).
        let m = gpt3();
        let ga = presets::ga100();
        let thr = presets::throughput_oriented();
        let b_ga = max_batch(&ga, &m, 12, 1, 4096);
        let b_thr = max_batch(&thr, &m, 12, 1, 4096);
        assert!(b_ga > 0);
        assert!(
            b_thr as f64 / b_ga as f64 > 12.0,
            "batch ratio {} / {} = {:.1}",
            b_thr,
            b_ga,
            b_thr as f64 / b_ga as f64
        );
    }

    #[test]
    fn max_batch_zero_when_params_do_not_fit() {
        let m = gpt3();
        let a100 = presets::a100();
        // All 96 layers on one 80 GB device: 350 GB of weights — impossible.
        assert_eq!(max_batch(&a100, &m, 96, 1, 2048), 0);
    }

    #[test]
    fn layer_schedule_is_bit_identical_to_serial_op_walk() {
        // The chain lowering must reproduce the pre-IR serial walk over
        // `layer_ops` exactly — same sums, same order, same bits.
        let s = sim();
        let sys = a100x4();
        let m = gpt3();
        for phase in [
            Phase::Prefill { batch: 8, seq: 2048 },
            Phase::Decode { batch: 8, kv_len: 3072 },
        ] {
            let rep = s.layer(&sys, &m, phase);
            let ops = crate::graph::layer::layer_ops(&m, phase, sys.device_count);
            let mut serial = 0.0f64;
            for nop in &ops {
                serial += s.op_latency(&sys, &nop.op).latency_s;
            }
            assert_eq!(rep.total_s.to_bits(), serial.to_bits(), "{phase:?} drifted");
            assert_eq!(rep.breakdown.len(), ops.len());
            for ((name, sec), nop) in rep.breakdown.iter().zip(&ops) {
                assert_eq!(name, nop.name);
                assert_eq!(sec.to_bits(), s.op_latency(&sys, &nop.op).latency_s.to_bits());
            }
        }
    }

    #[test]
    fn parallel_request_with_pp1_matches_legacy_path() {
        let s = sim();
        let sys = presets::system("a100x2").unwrap();
        let m = ModelConfig::gpt_small();
        let par = crate::graph::ir::Parallelism { tp: 2, pp: 1, microbatches: 1 };
        let legacy = s.e2e_latency(&sys, &m, 4, 64, 16, m.layers);
        let parallel = s.e2e_latency_parallel(&sys, &m, 4, 64, 16, m.layers, &par).unwrap();
        assert_eq!(legacy.to_bits(), parallel.to_bits());
    }

    #[test]
    fn pipeline_parallel_request_is_sane() {
        let s = sim();
        let sys = presets::system("a100x2").unwrap();
        let m = ModelConfig::gpt_small();
        let par = crate::graph::ir::Parallelism { tp: 1, pp: 2, microbatches: 2 };
        let (b, s_in, s_out) = (4u64, 128u64, 8u64);
        let lat = s.e2e_latency_parallel(&sys, &m, b, s_in, s_out, m.layers, &par).unwrap();
        assert!(lat.is_finite() && lat > 0.0);
        // A pipeline can never beat the same work on one giant stage with
        // no communication and no bubbles: half the layers on one device.
        let one_stage_half =
            s.prefill(&presets::system("a100").unwrap(), &m, b / 2, s_in, m.layers / 2);
        assert!(lat > one_stage_half, "{lat} vs per-stage floor {one_stage_half}");
        // And it must stay below fully serial execution on one device.
        let serial_one_dev = s.e2e_latency(&presets::system("a100").unwrap(), &m, b, s_in, s_out, m.layers);
        assert!(
            lat < serial_one_dev * 1.5,
            "pipeline {lat} not in the ballpark of serial {serial_one_dev}"
        );
    }

    #[test]
    fn parallel_request_validates_its_mapping() {
        let s = sim();
        let sys = presets::system("a100x4").unwrap();
        let m = ModelConfig::gpt_small();
        let bad = |tp, pp, mb| crate::graph::ir::Parallelism { tp, pp, microbatches: mb };
        // tp × pp must match the device count.
        assert!(s.e2e_latency_parallel(&sys, &m, 4, 64, 8, 12, &bad(2, 1, 1)).is_err());
        // microbatches must divide the batch.
        assert!(s.e2e_latency_parallel(&sys, &m, 6, 64, 8, 12, &bad(1, 4, 4)).is_err());
        // stages cannot exceed layers.
        assert!(s.e2e_latency_parallel(&sys, &m, 4, 64, 8, 2, &bad(1, 4, 1)).is_err());
        // tp must divide the head count (gpt-small has 12 heads).
        let sys8 = presets::system("a100x8").unwrap();
        assert!(s.e2e_latency_parallel(&sys8, &m, 4, 64, 8, 12, &bad(8, 1, 1)).is_err());
    }

    #[test]
    fn pipeline_throughput_positive_and_capacity_limited() {
        let s = sim();
        let m = gpt3();
        let ga_node = presets::system("ga100x8").unwrap();
        let thr_node = presets::system("throughput-orientedx8").unwrap();
        let (tok_ga, b_ga, _) = s.pipeline_throughput(&ga_node, &m, 512, 512);
        let (tok_thr, b_thr, _) = s.pipeline_throughput(&thr_node, &m, 512, 512);
        assert!(tok_ga > 0.0 && tok_thr > 0.0);
        assert!(b_thr > b_ga);
        // Paper Fig. 12b: the DRAM design beats the 8-GA100 node.
        assert!(
            tok_thr > tok_ga,
            "throughput design {tok_thr:.1} tok/s vs GA100 {tok_ga:.1}"
        );
    }
}
