//! Transformer computational graphs and end-to-end inference simulation
//! (paper §II, Fig. 2).
//!
//! * [`ModelConfig`] — GPT-style decoder-only model hyperparameters.
//! * [`layer`] — the per-layer operator list (Multi-Head Attention block +
//!   MLP block, with tensor-parallel all-reduces) for the *prefill* and
//!   *decoding* phases.
//! * [`ir`] — the operator-graph IR: a DAG of named `perf::Op` nodes with
//!   explicit edges, plus deterministic `tensor_parallel` /
//!   `pipeline_parallel` transforms that rewrite a graph into per-device
//!   subgraphs joined by `AllReduce`/`PeerToPeer` comm nodes. Every
//!   workload lowers onto it; `perf::graph_sched` simulates the result.
//! * [`inference`] — simulates layers on a [`crate::hardware::SystemSpec`]
//!   by scheduling their lowered graphs via the mapper, integrates decode
//!   latency over the growing KV cache, sizes the maximum batch under
//!   memory capacity, and models pipeline-parallel requests/throughput.

pub mod layer;
pub mod ir;
pub mod inference;

use crate::hardware::DType;

/// Attention variant (paper §II-A: "There are other variations such as
/// Multi-Query Attention … LLMCompass seamlessly supports all these
/// possible variations as they share a common set of operators").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Attention {
    /// Multi-Head Attention (GPT-3): one K/V head per Q head.
    MultiHead,
    /// Multi-Query Attention (PaLM): all Q heads share one K/V head —
    /// shrinks the KV cache and its decode read traffic by `heads`×.
    MultiQuery,
    /// Grouped-Query Attention: `groups` K/V heads (MHA = heads groups,
    /// MQA = 1 group).
    GroupedQuery { groups: u64 },
}

impl Attention {
    /// Number of K/V heads given `q_heads` query heads.
    pub fn kv_heads(self, q_heads: u64) -> u64 {
        match self {
            Attention::MultiHead => q_heads,
            Attention::MultiQuery => 1,
            Attention::GroupedQuery { groups } => groups.clamp(1, q_heads),
        }
    }
}

/// Decoder-only Transformer hyperparameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub layers: u64,
    pub d_model: u64,
    pub heads: u64,
    /// MLP hidden dimension (4·d_model for GPT).
    pub d_ff: u64,
    pub vocab: u64,
    pub dtype: DType,
    /// Attention variant (KV-head sharing).
    pub attention: Attention,
    /// PaLM-style parallel attention + MLP: both blocks read the same
    /// layernorm output and their results are summed, halving the
    /// layernorms and letting one all-reduce cover the layer.
    pub parallel_blocks: bool,
    /// Mixture-of-Experts: experts per MLP layer and how many are active
    /// per token (Switch-style = 1 active). `experts = 1` is dense.
    pub moe_experts: u64,
    pub moe_active: u64,
}

impl ModelConfig {
    /// GPT-3 175B [7]: 96 layers × d_model 12288 × 96 heads.
    pub fn gpt3_175b() -> ModelConfig {
        ModelConfig {
            name: "gpt3-175b".into(),
            layers: 96,
            d_model: 12288,
            heads: 96,
            d_ff: 4 * 12288,
            vocab: 50257,
            dtype: DType::FP16,
            attention: Attention::MultiHead,
            parallel_blocks: false,
            moe_experts: 1,
            moe_active: 1,
        }
    }

    /// A ~117M-parameter GPT (GPT-2-small geometry) — the model the
    /// end-to-end example actually *executes* through PJRT.
    pub fn gpt_small() -> ModelConfig {
        ModelConfig {
            name: "gpt-small".into(),
            layers: 12,
            d_model: 768,
            heads: 12,
            d_ff: 4 * 768,
            vocab: 50257,
            dtype: DType::FP16,
            attention: Attention::MultiHead,
            parallel_blocks: false,
            moe_experts: 1,
            moe_active: 1,
        }
    }

    /// PaLM-540B-style variant of GPT-3 geometry: multi-query attention +
    /// parallel attention/MLP blocks (paper §II-A's cited variations),
    /// used by the `variants` ablation experiment.
    pub fn gpt3_palm_style() -> ModelConfig {
        let mut m = Self::gpt3_175b();
        m.name = "gpt3-mqa-parallel".into();
        m.attention = Attention::MultiQuery;
        m.parallel_blocks = true;
        m
    }

    /// Look up a model by name — the registry behind the CLI's `--model`
    /// and the `eval` scenario `model` field. Names equal the returned
    /// config's `name`.
    pub fn by_name(name: &str) -> Option<ModelConfig> {
        match name {
            "gpt3-175b" => Some(Self::gpt3_175b()),
            "gpt-small" => Some(Self::gpt_small()),
            "gpt3-mqa-parallel" => Some(Self::gpt3_palm_style()),
            _ => None,
        }
    }

    /// The names accepted by [`ModelConfig::by_name`].
    pub fn known_names() -> Vec<&'static str> {
        vec!["gpt3-175b", "gpt-small", "gpt3-mqa-parallel"]
    }

    /// Switch-Transformer-style MoE on GPT-3 geometry: `experts` experts,
    /// one active per token.
    pub fn gpt3_moe(experts: u64) -> ModelConfig {
        let mut m = Self::gpt3_175b();
        m.name = format!("gpt3-moe{experts}");
        m.moe_experts = experts;
        m.moe_active = 1;
        m
    }

    /// Head dimension.
    pub fn d_head(&self) -> u64 {
        self.d_model / self.heads
    }

    /// The layer count a partial-model workload actually runs: the
    /// requested depth, defaulting to — and clamped by — the model's own.
    /// This is the single source of truth for `layers: Some(n)` semantics,
    /// shared by the evaluator and the graph lowering so the two can never
    /// disagree on what a partial model means.
    pub fn resolve_layers(&self, requested: Option<u64>) -> u64 {
        requested.unwrap_or(self.layers).clamp(1, self.layers)
    }

    /// Parameters in one Transformer layer: Q (d²) + K/V (2·d·kv_dim) +
    /// output projection (d²) + MLP experts (2·d·d_ff each) +
    /// layernorm/bias terms (≈4d, negligible).
    pub fn params_per_layer(&self) -> u64 {
        let kv_dim = self.attention.kv_heads(self.heads) * self.d_head();
        2 * self.d_model * self.d_model
            + 2 * self.d_model * kv_dim
            + self.moe_experts * 2 * self.d_model * self.d_ff
            + 4 * self.d_model
    }

    /// Total parameters in the layer stack (embeddings excluded; <2% for
    /// GPT-3-scale models, per the paper).
    pub fn params_total(&self) -> u64 {
        self.layers * self.params_per_layer()
    }

    /// Bytes of model weights for `layers_resident` layers at the model
    /// dtype.
    pub fn param_bytes(&self, layers_resident: u64) -> u64 {
        layers_resident * self.params_per_layer() * self.dtype.bytes()
    }

    /// KV-cache bytes per token per layer: K and V of size
    /// `kv_heads · d_head` each — MQA/GQA shrink this by the head-sharing
    /// factor, which is exactly their serving appeal.
    pub fn kv_bytes_per_token_per_layer(&self) -> u64 {
        let kv_dim = self.attention.kv_heads(self.heads) * self.d_head();
        2 * kv_dim * self.dtype.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_registry_names_are_canonical() {
        for name in ModelConfig::known_names() {
            let m = ModelConfig::by_name(name).unwrap();
            assert_eq!(m.name, name, "registry key must equal the config name");
        }
        assert!(ModelConfig::by_name("gpt-unknown").is_none());
    }

    #[test]
    fn gpt3_parameter_count() {
        let m = ModelConfig::gpt3_175b();
        // 96 · 12·12288² ≈ 174B (embeddings excluded).
        let params = m.params_total() as f64;
        assert!(
            (params - 174e9).abs() / 174e9 < 0.01,
            "gpt3 params {params:.3e}"
        );
        assert_eq!(m.d_head(), 128);
    }

    #[test]
    fn gpt_small_is_about_117m() {
        let m = ModelConfig::gpt_small();
        let params = m.params_total() as f64;
        // layer stack ≈ 85M; embeddings (excluded) add ~38M more.
        assert!(params > 80e6 && params < 90e6, "{params:.3e}");
    }

    #[test]
    fn resolve_layers_defaults_and_clamps() {
        let m = ModelConfig::gpt3_175b();
        assert_eq!(m.resolve_layers(None), 96);
        assert_eq!(m.resolve_layers(Some(12)), 12);
        assert_eq!(m.resolve_layers(Some(500)), 96, "clamped to the model depth");
        assert_eq!(m.resolve_layers(Some(0)), 1, "at least one layer runs");
    }

    #[test]
    fn kv_cache_sizing() {
        let m = ModelConfig::gpt3_175b();
        // GPT-3 KV: 2·12288·2 B = 48 KiB per token per layer;
        // ×96 layers = 4.5 MiB per token.
        assert_eq!(m.kv_bytes_per_token_per_layer(), 49152);
        let per_token_all_layers = m.kv_bytes_per_token_per_layer() * m.layers;
        assert_eq!(per_token_all_layers, 4718592);
    }

    #[test]
    fn five_a100_needed_for_gpt3_params() {
        // Paper §I: "a minimum of five NVIDIA A100s solely to accommodate
        // the model parameters (in half precision)".
        let m = ModelConfig::gpt3_175b();
        let bytes = m.param_bytes(m.layers) as f64;
        let per_a100 = 80e9;
        let needed = (bytes / per_a100).ceil() as u64;
        assert_eq!(needed, 5);
    }
}
