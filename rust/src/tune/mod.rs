//! `tune` — the design-space autotuner: the outer search loop that turns
//! the framework from a simulator of fixed designs into a tool that
//! *finds* cost-effective ones (the paper's Section VII payoff: up to
//! 3.41× perf/cost over an A100 by cutting compute capability or
//! swapping HBM for commodity DRAM).
//!
//! The subsystem reuses the mapper's own tricks one level up:
//!
//! * a typed [`DesignSpace`] (core/device counts, vector lane count,
//!   systolic array dims, SRAM sizes, memory technology, fabric preset)
//!   enumerates into concrete [`SystemSpec`] candidates in a fixed,
//!   documented nest order;
//! * a provable per-design floor — the op-level roofline bound (the same
//!   quantity the mapper's `matmul::lower_bound` prunes tilings with)
//!   aggregated over the scenario's operators — rules a candidate out
//!   *before any mapper search runs*. A design is skipped only when some
//!   already-evaluated design beats its floor latency, floor
//!   $/1M-tokens, *and* exact area strictly; since the floor never
//!   exceeds the true metric, every pruned design is strictly dominated,
//!   so the reported Pareto frontier is bit-identical to exhaustive
//!   enumeration under any evaluation order (see [`tune`]);
//! * candidate fan-out rides the process-wide work-stealing pool
//!   ([`crate::util::pool::parallel_map_shared`]), sharing the worker
//!   budget with each design's own mapper searches;
//! * evaluated designs land in a persistent cache keyed by design
//!   fingerprint + scenario hash, so re-running over a grown space only
//!   evaluates the new designs.
//!
//! The objective is perf/$ ([`Objective::PerfPerDollar`]) or goodput/$
//! ([`Objective::GoodputPerDollar`]) under optional area/power
//! constraints, and the output is a [`TuneReport`]: a Pareto frontier
//! over (latency, $/1M output tokens, die area) carrying the full
//! hardware config of every non-dominated point, plus the stock
//! baseline the scenario named, for direct best-vs-stock comparison.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Mutex;

use crate::area::die_breakdown;
use crate::cost::device_cost;
use crate::eval::{model_by_name, traffic_requests, EvalReport, EvalResult, Evaluator};
use crate::eval::{Output, Scenario, Workload};
use crate::graph::layer::{layer_ops, Phase};
use crate::hardware::{
    presets, DeviceSpec, InterconnectSpec, MemProtocol, MemorySpec, SystemSpec,
};
use crate::perf::Op;
use crate::serve::sweep::usd_per_mtok_at_slo;
use crate::util::json::{num, obj, s, Json};
use crate::util::pool;

/// Bump when the `TuneReport` JSON layout changes shape.
pub const TUNE_SCHEMA_VERSION: u64 = 1;

/// Bump when the on-disk tune-cache layout changes; mismatched entries
/// are preserved verbatim but not reused.
pub const TUNE_CACHE_VERSION: u64 = 1;

/// Refuse to materialize spaces larger than this: the search is meant
/// for curated grids, not accidental combinatorial explosions.
pub const MAX_DESIGNS: usize = 4096;

/// $/1M-tokens is clamped here so reports stay valid JSON even when a
/// design serves zero goodput (infinite cost per token).
pub const UNAFFORDABLE_USD_PER_MTOK: f64 = 1e30;

// ---------------------------------------------------------------------------
// Objective + constraints
// ---------------------------------------------------------------------------

/// What "better" means for [`best`](TuneReport::best) selection. The
/// Pareto frontier itself is objective-independent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// `(1 / latency) / cluster cost` — for request scenarios, where
    /// latency is the end-to-end request time. Monotone in $/1M-tokens
    /// there, so the winner always sits on the frontier.
    PerfPerDollar,
    /// `goodput tokens/s / cluster cost` — for traffic scenarios;
    /// equivalent to minimizing $/1M tokens at the SLO.
    GoodputPerDollar,
}

impl Objective {
    pub fn name(self) -> &'static str {
        match self {
            Objective::PerfPerDollar => "perf-per-dollar",
            Objective::GoodputPerDollar => "goodput-per-dollar",
        }
    }

    pub fn parse(v: &str) -> Option<Objective> {
        match v {
            "perf-per-dollar" | "perf" => Some(Objective::PerfPerDollar),
            "goodput-per-dollar" | "goodput" => Some(Objective::GoodputPerDollar),
            _ => None,
        }
    }

    /// Objective value of a point — higher is better.
    pub fn value(self, p: &DesignPoint) -> f64 {
        match self {
            Objective::PerfPerDollar => 1.0 / (p.latency_s * p.cluster_cost_usd),
            Objective::GoodputPerDollar => p.tok_s / p.cluster_cost_usd,
        }
    }

    /// The natural objective for a workload: request latency → perf/$,
    /// serving traffic → goodput/$.
    pub fn default_for(w: &Workload) -> Objective {
        match w {
            Workload::Traffic(_) => Objective::GoodputPerDollar,
            _ => Objective::PerfPerDollar,
        }
    }
}

/// User-set feasibility screens, applied before floors or evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Constraints {
    /// Per-die area budget, mm².
    pub max_area_mm2: Option<f64>,
    /// Per-device power budget (the [`power_proxy_w`] estimate), watts.
    pub max_power_w: Option<f64>,
}

impl Constraints {
    pub fn satisfied(&self, area_mm2: f64, power_w: f64) -> bool {
        self.max_area_mm2.map_or(true, |m| area_mm2 <= m)
            && self.max_power_w.map_or(true, |m| power_w <= m)
    }

    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = Vec::new();
        if let Some(a) = self.max_area_mm2 {
            fields.push(("max_area_mm2", num(a)));
        }
        if let Some(p) = self.max_power_w {
            fields.push(("max_power_w", num(p)));
        }
        obj(fields)
    }
}

/// A coarse analytic power estimate used only as a constraint screen —
/// there is no microarchitectural power model in the framework (the
/// paper stops at area and cost), so this charges published-order
/// energy-per-op rates: ~0.5 pJ/FLOP for the FP16 systolic arrays,
/// ~1 pJ/FLOP for the FP32 vector units, a per-byte toll on the full
/// memory bandwidth by technology (HBM is the cheapest per bit), a
/// small SRAM leakage term, and a fixed uncore floor. The A100 preset
/// lands near 300 W against its 400 W TDP — good enough to rank
/// designs, not to size a heatsink.
pub fn power_proxy_w(dev: &DeviceSpec) -> f64 {
    let mem_pj_per_byte = match dev.memory.protocol {
        MemProtocol::HBM2E => 30.0,
        MemProtocol::DDR5 => 50.0,
        MemProtocol::PCIE5CXL => 60.0,
        MemProtocol::HostDRAM => 60.0,
    };
    let compute_w = dev.peak_matrix_flops() * 0.5e-12 + dev.peak_vector_flops() * 1.0e-12;
    let memory_w = dev.memory.bandwidth_bytes_per_s * mem_pj_per_byte * 1e-12;
    let sram_w = dev.total_sram_bytes() as f64 * 0.05e-6;
    50.0 + compute_w + memory_w + sram_w
}

// ---------------------------------------------------------------------------
// Memory technology + fabric presets
// ---------------------------------------------------------------------------

/// A memory technology choice: protocol (drives PHY area and $/GB in
/// the cost model) plus the bandwidth/capacity it ships with.
#[derive(Debug, Clone, PartialEq)]
pub struct MemTech {
    pub name: String,
    pub protocol: MemProtocol,
    pub bandwidth_bytes_per_s: f64,
    pub capacity_bytes: u64,
}

impl MemTech {
    /// Named presets: `hbm2e` (A100-class stacks), `ddr5` (commodity
    /// DIMMs, the paper's HBM→DRAM swap), `lpddr5` (mobile-class DRAM;
    /// the cost/area models have no dedicated LPDDR entry so it rides
    /// the DDR5 protocol and commodity pricing with LPDDR-class
    /// bandwidth), and `cxl` (DRAM behind PCIe 5.0/CXL, the paper's
    /// throughput-oriented design memory).
    pub fn preset(name: &str) -> Option<MemTech> {
        let (protocol, bw, cap_gb): (MemProtocol, f64, u64) = match name {
            "hbm2e" => (MemProtocol::HBM2E, 2.0e12, 80),
            "ddr5" => (MemProtocol::DDR5, 0.3e12, 256),
            "lpddr5" => (MemProtocol::DDR5, 0.55e12, 128),
            "cxl" => (MemProtocol::PCIE5CXL, 1.0e12, 512),
            _ => return None,
        };
        Some(MemTech {
            name: name.to_string(),
            protocol,
            bandwidth_bytes_per_s: bw,
            capacity_bytes: cap_gb * 1_000_000_000,
        })
    }

    pub fn preset_names() -> &'static [&'static str] {
        &["hbm2e", "ddr5", "lpddr5", "cxl"]
    }

    /// The memory a device already has, as an axis value (used when a
    /// space leaves the memory axis empty).
    pub fn of_device(dev: &DeviceSpec) -> MemTech {
        MemTech {
            name: short_mem_label(dev.memory.protocol).to_string(),
            protocol: dev.memory.protocol,
            bandwidth_bytes_per_s: dev.memory.bandwidth_bytes_per_s,
            capacity_bytes: dev.memory.capacity_bytes,
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            ("protocol", s(self.protocol.name())),
            ("bandwidth_gbs", num(self.bandwidth_bytes_per_s / 1e9)),
            ("capacity_gb", num(self.capacity_bytes as f64 / 1e9)),
        ])
    }

    /// A preset name string or a full `{name, protocol, bandwidth_gbs,
    /// capacity_gb}` object.
    pub fn from_json(v: &Json) -> Result<MemTech, String> {
        if let Some(name) = v.as_str() {
            return MemTech::preset(name).ok_or_else(|| {
                format!(
                    "unknown memory preset `{name}` (known: {})",
                    MemTech::preset_names().join(", ")
                )
            });
        }
        let e = |x: crate::util::json::JsonError| x.msg;
        Ok(MemTech {
            name: v.req_str("name").map_err(e)?.to_string(),
            protocol: MemProtocol::parse(v.req_str("protocol").map_err(e)?)
                .ok_or_else(|| "unknown memory `protocol`".to_string())?,
            bandwidth_bytes_per_s: v.req_f64("bandwidth_gbs").map_err(e)? * 1e9,
            capacity_bytes: (v.req_f64("capacity_gb").map_err(e)? * 1e9) as u64,
        })
    }
}

fn short_mem_label(p: MemProtocol) -> &'static str {
    match p {
        MemProtocol::HBM2E => "hbm2e",
        MemProtocol::DDR5 => "ddr5",
        MemProtocol::PCIE5CXL => "cxl",
        MemProtocol::HostDRAM => "host",
    }
}

/// Fabric presets: `nvlink` (NVLink-class 600 GB/s links) or `pcie`
/// (host PCIe-class links).
pub fn fabric_preset(name: &str) -> Option<InterconnectSpec> {
    match name {
        "nvlink" => Some(InterconnectSpec::nvlink_like(600e9)),
        "pcie" => Some(InterconnectSpec::pcie_host_like()),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// DesignSpace
// ---------------------------------------------------------------------------

/// A grid of hardware designs around a base device preset. Empty axes
/// inherit the base device's value (and `device_counts` defaults to
/// `[1]`), so a space names only the dimensions it explores.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignSpace {
    pub name: String,
    /// Base device preset the grid perturbs (e.g. `a100`).
    pub base: String,
    pub core_counts: Vec<u64>,
    pub device_counts: Vec<u64>,
    /// Vector/systolic lanes per core.
    pub lane_counts: Vec<u64>,
    /// Systolic array (rows, cols) per lane.
    pub systolic: Vec<(u64, u64)>,
    pub local_buffer_kb: Vec<u64>,
    pub global_buffer_mb: Vec<u64>,
    pub memories: Vec<MemTech>,
    /// Fabric preset for multi-device candidates (`nvlink` | `pcie`).
    pub fabric: String,
}

/// One materialized design: a readable name plus the full system spec.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    pub name: String,
    pub system: SystemSpec,
}

impl DesignSpace {
    /// An empty space around a base device: every axis inherits.
    pub fn around(name: &str, base: &str) -> DesignSpace {
        DesignSpace {
            name: name.to_string(),
            base: base.to_string(),
            core_counts: Vec::new(),
            device_counts: Vec::new(),
            lane_counts: Vec::new(),
            systolic: Vec::new(),
            local_buffer_kb: Vec::new(),
            global_buffer_mb: Vec::new(),
            memories: Vec::new(),
            fabric: "nvlink".to_string(),
        }
    }

    /// Built-in spaces: `smoke` (2 core counts × 2 memories around the
    /// A100 — the CI-sized space) and `section7` (the paper's
    /// Section-VII moves: full/half/quarter compute × HBM-vs-DRAM).
    pub fn preset(name: &str) -> Option<DesignSpace> {
        match name {
            "smoke" => {
                let mut sp = DesignSpace::around("smoke", "a100");
                sp.core_counts = vec![54, 108];
                sp.memories =
                    vec![MemTech::preset("hbm2e").unwrap(), MemTech::preset("ddr5").unwrap()];
                Some(sp)
            }
            "section7" => {
                let mut sp = DesignSpace::around("section7", "a100");
                sp.core_counts = vec![27, 54, 108];
                sp.memories =
                    vec![MemTech::preset("hbm2e").unwrap(), MemTech::preset("ddr5").unwrap()];
                Some(sp)
            }
            _ => None,
        }
    }

    pub fn preset_names() -> &'static [&'static str] {
        &["smoke", "section7"]
    }

    /// A preset name or a design-space JSON file path.
    pub fn resolve(spec: &str) -> Result<DesignSpace, String> {
        if let Some(sp) = DesignSpace::preset(spec) {
            return Ok(sp);
        }
        let text = std::fs::read_to_string(spec).map_err(|e| {
            format!(
                "design space `{spec}` is neither a preset ({}) nor a readable file: {e}",
                DesignSpace::preset_names().join(", ")
            )
        })?;
        let v = Json::parse(&text).map_err(|e| format!("{spec}: {e}"))?;
        DesignSpace::from_json(&v).map_err(|e| format!("{spec}: {e}"))
    }

    pub fn to_json(&self) -> Json {
        let ints = |vals: &[u64]| {
            Json::Arr(vals.iter().map(|v| num(*v as f64)).collect())
        };
        let mut fields = vec![
            ("name", s(&self.name)),
            ("base", s(&self.base)),
            ("fabric", s(&self.fabric)),
        ];
        if !self.core_counts.is_empty() {
            fields.push(("core_counts", ints(&self.core_counts)));
        }
        if !self.device_counts.is_empty() {
            fields.push(("device_counts", ints(&self.device_counts)));
        }
        if !self.lane_counts.is_empty() {
            fields.push(("lane_counts", ints(&self.lane_counts)));
        }
        if !self.systolic.is_empty() {
            fields.push((
                "systolic",
                Json::Arr(
                    self.systolic
                        .iter()
                        .map(|(r, c)| Json::Arr(vec![num(*r as f64), num(*c as f64)]))
                        .collect(),
                ),
            ));
        }
        if !self.local_buffer_kb.is_empty() {
            fields.push(("local_buffer_kb", ints(&self.local_buffer_kb)));
        }
        if !self.global_buffer_mb.is_empty() {
            fields.push(("global_buffer_mb", ints(&self.global_buffer_mb)));
        }
        if !self.memories.is_empty() {
            fields.push((
                "memories",
                Json::Arr(self.memories.iter().map(MemTech::to_json).collect()),
            ));
        }
        obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<DesignSpace, String> {
        let e = |x: crate::util::json::JsonError| x.msg;
        let base = v.req_str("base").map_err(e)?.to_string();
        let name = match v.get("name") {
            None => "custom".to_string(),
            Some(j) => j
                .as_str()
                .ok_or_else(|| "design space `name` must be a string".to_string())?
                .to_string(),
        };
        let fabric = match v.get("fabric") {
            None => "nvlink".to_string(),
            Some(j) => j
                .as_str()
                .ok_or_else(|| "design space `fabric` must be a string".to_string())?
                .to_string(),
        };
        let mut sp = DesignSpace::around(&name, &base);
        sp.fabric = fabric;
        sp.core_counts = u64_axis(v, "core_counts")?;
        sp.device_counts = u64_axis(v, "device_counts")?;
        sp.lane_counts = u64_axis(v, "lane_counts")?;
        sp.local_buffer_kb = u64_axis(v, "local_buffer_kb")?;
        sp.global_buffer_mb = u64_axis(v, "global_buffer_mb")?;
        sp.systolic = systolic_axis(v)?;
        if let Some(mems) = v.get("memories") {
            let items = mems
                .as_arr()
                .ok_or_else(|| "design space `memories` must be an array".to_string())?;
            for item in items {
                sp.memories.push(MemTech::from_json(item)?);
            }
        }
        Ok(sp)
    }

    /// Enumerate the grid into concrete systems, in a fixed nest order
    /// (cores → lanes → systolic → local SRAM → global SRAM → memory →
    /// device count). The order is part of the report contract: frontier
    /// ties and `best` ties resolve to the earliest design.
    pub fn materialize(&self) -> Result<Vec<Candidate>, String> {
        let base = presets::device(&self.base).ok_or_else(|| {
            format!(
                "unknown base device `{}` (known: {})",
                self.base,
                presets::all_device_names().join(", ")
            )
        })?;
        let fabric = fabric_preset(&self.fabric)
            .ok_or_else(|| format!("unknown fabric preset `{}` (nvlink | pcie)", self.fabric))?;
        let cores = axis_or(&self.core_counts, base.core_count, "core_counts")?;
        let lanes = axis_or(&self.lane_counts, base.core.lane_count, "lane_counts")?;
        let systolic = if self.systolic.is_empty() {
            vec![(base.core.lane.systolic_rows, base.core.lane.systolic_cols)]
        } else {
            for (r, c) in &self.systolic {
                if *r == 0 || *c == 0 {
                    return Err("design space `systolic` dims must be ≥ 1".to_string());
                }
            }
            self.systolic.clone()
        };
        let locals =
            axis_or(&self.local_buffer_kb, base.core.local_buffer_bytes / 1024, "local_buffer_kb")?;
        let globals = axis_or(
            &self.global_buffer_mb,
            base.global_buffer_bytes / (1024 * 1024),
            "global_buffer_mb",
        )?;
        let mems = if self.memories.is_empty() {
            vec![MemTech::of_device(&base)]
        } else {
            for m in &self.memories {
                if m.bandwidth_bytes_per_s <= 0.0 || m.capacity_bytes == 0 {
                    return Err(format!("memory `{}` needs bandwidth and capacity > 0", m.name));
                }
            }
            self.memories.clone()
        };
        let counts = axis_or(&self.device_counts, 1, "device_counts")?;

        let total = cores.len()
            * lanes.len()
            * systolic.len()
            * locals.len()
            * globals.len()
            * mems.len()
            * counts.len();
        if total > MAX_DESIGNS {
            return Err(format!(
                "design space `{}` materializes {total} designs (max {MAX_DESIGNS})",
                self.name
            ));
        }

        let mut out: Vec<Candidate> = Vec::with_capacity(total);
        for &c in &cores {
            for &l in &lanes {
                for &(r, cl) in &systolic {
                    for &lkb in &locals {
                        for &gmb in &globals {
                            for mem in &mems {
                                for &nd in &counts {
                                    let name = format!(
                                        "{}-c{}l{}-s{}x{}-lb{}k-gb{}m-{}-x{}",
                                        self.base, c, l, r, cl, lkb, gmb, mem.name, nd
                                    );
                                    let mut dev = base.clone();
                                    dev.name = name.clone();
                                    dev.core_count = c;
                                    dev.core.lane_count = l;
                                    dev.core.lane.systolic_rows = r;
                                    dev.core.lane.systolic_cols = cl;
                                    dev.core.local_buffer_bytes = lkb * 1024;
                                    dev.global_buffer_bytes = gmb * 1024 * 1024;
                                    dev.memory = MemorySpec {
                                        bandwidth_bytes_per_s: mem.bandwidth_bytes_per_s,
                                        capacity_bytes: mem.capacity_bytes,
                                        protocol: mem.protocol,
                                    };
                                    out.push(Candidate {
                                        name,
                                        system: SystemSpec {
                                            device: dev,
                                            device_count: nd,
                                            interconnect: fabric.clone(),
                                        },
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(out)
    }
}

fn u64_axis(v: &Json, key: &str) -> Result<Vec<u64>, String> {
    match v.get(key) {
        None => Ok(Vec::new()),
        Some(j) => {
            let items =
                j.as_arr().ok_or_else(|| format!("design space `{key}` must be an array"))?;
            items
                .iter()
                .map(|x| {
                    x.as_u64().ok_or_else(|| {
                        format!("design space `{key}` entries must be non-negative integers")
                    })
                })
                .collect()
        }
    }
}

fn systolic_axis(v: &Json) -> Result<Vec<(u64, u64)>, String> {
    let Some(j) = v.get("systolic") else { return Ok(Vec::new()) };
    let items = j
        .as_arr()
        .ok_or_else(|| "design space `systolic` must be an array of [rows, cols]".to_string())?;
    let mut out = Vec::new();
    for item in items {
        let pair = item.as_arr().filter(|p| p.len() == 2).ok_or_else(|| {
            "design space `systolic` entries must be [rows, cols] pairs".to_string()
        })?;
        let r = pair[0].as_u64().ok_or_else(|| "systolic rows must be an integer".to_string())?;
        let c = pair[1].as_u64().ok_or_else(|| "systolic cols must be an integer".to_string())?;
        out.push((r, c));
    }
    Ok(out)
}

fn axis_or(vals: &[u64], default: u64, key: &str) -> Result<Vec<u64>, String> {
    if vals.is_empty() {
        return Ok(vec![default]);
    }
    if vals.iter().any(|&v| v == 0) {
        return Err(format!("design space `{key}` values must be ≥ 1"));
    }
    Ok(vals.to_vec())
}

// ---------------------------------------------------------------------------
// Design points + Pareto frontier
// ---------------------------------------------------------------------------

/// One evaluated design with its frontier metrics. `latency_s` is the
/// end-to-end request time (request workloads) or mean TTFT (traffic);
/// `tok_s` is generated tokens/s (request) or goodput at the SLO
/// (traffic); `usd_per_mtok` amortizes the cluster cost over
/// [`crate::serve::sweep::AMORT_SECONDS`] at that token rate.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignPoint {
    pub name: String,
    pub system: SystemSpec,
    pub latency_s: f64,
    pub tok_s: f64,
    pub area_mm2: f64,
    pub power_w: f64,
    pub cluster_cost_usd: f64,
    pub usd_per_mtok: f64,
}

impl DesignPoint {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            ("system", self.system.to_json()),
            ("latency_s", num(self.latency_s)),
            ("tok_s", num(self.tok_s)),
            ("area_mm2", num(self.area_mm2)),
            ("power_w", num(self.power_w)),
            ("cluster_cost_usd", num(self.cluster_cost_usd)),
            ("usd_per_mtok", num(self.usd_per_mtok)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<DesignPoint, String> {
        let e = |x: crate::util::json::JsonError| x.msg;
        Ok(DesignPoint {
            name: v.req_str("name").map_err(e)?.to_string(),
            system: SystemSpec::from_json(
                v.get("system").ok_or("design point missing `system`")?,
            )?,
            latency_s: v.req_f64("latency_s").map_err(e)?,
            tok_s: v.req_f64("tok_s").map_err(e)?,
            area_mm2: v.req_f64("area_mm2").map_err(e)?,
            power_w: v.req_f64("power_w").map_err(e)?,
            cluster_cost_usd: v.req_f64("cluster_cost_usd").map_err(e)?,
            usd_per_mtok: v.req_f64("usd_per_mtok").map_err(e)?,
        })
    }
}

/// `a` dominates `b` over (latency, $/1M-tokens, area): no worse on
/// every axis and strictly better on at least one.
pub fn dominates(a: &DesignPoint, b: &DesignPoint) -> bool {
    a.latency_s <= b.latency_s
        && a.usd_per_mtok <= b.usd_per_mtok
        && a.area_mm2 <= b.area_mm2
        && (a.latency_s < b.latency_s
            || a.usd_per_mtok < b.usd_per_mtok
            || a.area_mm2 < b.area_mm2)
}

/// The non-dominated subset, preserving input order. Axis-for-axis
/// duplicates are all kept (none dominates the other).
pub fn pareto_frontier(points: &[DesignPoint]) -> Vec<DesignPoint> {
    points
        .iter()
        .filter(|p| !points.iter().any(|q| dominates(q, p)))
        .cloned()
        .collect()
}

// ---------------------------------------------------------------------------
// Per-design floors
// ---------------------------------------------------------------------------

/// Device-independent description of the scenario's work, from which a
/// per-design lower bound is computed without running the mapper: op
/// groups with multiplicities for the latency floor, and (for traffic)
/// the dense FLOPs every generated token must pay for the goodput
/// ceiling. Decode ops are taken at the *smallest* KV length and
/// traffic prefill at the *shortest* prompt, keeping the bound provable.
struct WorkFloor {
    groups: Vec<(Vec<Op>, f64)>,
    /// Generated tokens per request-workload run (0 for traffic).
    tokens: f64,
    /// Matrix FLOPs per generated token (0 for request workloads).
    flops_per_token: f64,
    traffic: bool,
}

/// Roofline floor for one op on one device: compute bound against the
/// matching peak, memory bound against compulsory DRAM traffic.
/// Communication ops floor at zero (a single-device design does none).
fn op_floor_s(dev: &DeviceSpec, op: &Op) -> f64 {
    let bw = dev.memory.bandwidth_bytes_per_s;
    match op {
        Op::Matmul { .. } => {
            (op.flops() / dev.peak_matrix_flops()).max(op.min_dram_bytes() / bw)
        }
        Op::Softmax { .. } | Op::LayerNorm { .. } | Op::Gelu { .. } => {
            (op.flops() / dev.peak_vector_flops()).max(op.min_dram_bytes() / bw)
        }
        Op::AllReduce { .. } | Op::PeerToPeer { .. } => 0.0,
    }
}

impl WorkFloor {
    fn of(sc: &Scenario) -> Result<WorkFloor, String> {
        match &sc.workload {
            Workload::Request { model, batch, prefill, decode, layers } => {
                let m = model_by_name(model)?;
                let layers = m.resolve_layers(*layers) as f64;
                let prefill_ops: Vec<Op> =
                    layer_ops(&m, Phase::Prefill { batch: *batch, seq: *prefill }, 1)
                        .into_iter()
                        .map(|n| n.op)
                        .collect();
                let decode_ops: Vec<Op> =
                    layer_ops(&m, Phase::Decode { batch: *batch, kv_len: *prefill + 1 }, 1)
                        .into_iter()
                        .map(|n| n.op)
                        .collect();
                Ok(WorkFloor {
                    groups: vec![
                        (prefill_ops, layers),
                        (decode_ops, layers * *decode as f64),
                    ],
                    tokens: (*batch * *decode) as f64,
                    flops_per_token: 0.0,
                    traffic: false,
                })
            }
            Workload::Traffic(t) => {
                let m = model_by_name(&t.model)?;
                let requests = traffic_requests(t)?;
                let min_prompt =
                    requests.iter().map(|r| r.prompt_tokens).min().unwrap_or(1).max(1);
                let prefill_ops: Vec<Op> =
                    layer_ops(&m, Phase::Prefill { batch: 1, seq: min_prompt }, 1)
                        .into_iter()
                        .map(|n| n.op)
                        .collect();
                Ok(WorkFloor {
                    groups: vec![(prefill_ops, m.layers as f64)],
                    tokens: 0.0,
                    flops_per_token: 2.0 * m.params_total() as f64,
                    traffic: true,
                })
            }
            _ => Err(
                "tune needs a `request` or `traffic` workload (op/layer/graph/hardware \
                 scenarios have no perf-per-dollar story)"
                    .to_string(),
            ),
        }
    }

    /// Lower bound on the point's latency metric, assuming perfect
    /// scaling across devices (real parallelism only adds overhead).
    fn latency_floor_s(&self, dev: &DeviceSpec, devices: u64) -> f64 {
        let one: f64 = self
            .groups
            .iter()
            .map(|(ops, mult)| mult * ops.iter().map(|op| op_floor_s(dev, op)).sum::<f64>())
            .sum();
        one / devices as f64
    }

    /// Lower bound on $/1M tokens: the cluster cost amortized at the
    /// highest token rate the design could possibly sustain.
    fn usd_per_mtok_floor(&self, dev: &DeviceSpec, devices: u64, cluster_cost_usd: f64) -> f64 {
        let tok_s_max = if self.traffic {
            devices as f64 * dev.peak_matrix_flops() / self.flops_per_token
        } else {
            self.tokens / self.latency_floor_s(dev, devices)
        };
        clamp_mtok(usd_per_mtok_at_slo(cluster_cost_usd, tok_s_max))
    }
}

fn clamp_mtok(v: f64) -> f64 {
    v.min(UNAFFORDABLE_USD_PER_MTOK)
}

// ---------------------------------------------------------------------------
// Persistent design-evaluation cache
// ---------------------------------------------------------------------------

fn fnv1a(text: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in text.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Fingerprint of a candidate system — every field of the device,
/// count, and fabric participates via the `Debug` rendering.
pub fn design_fingerprint(sys: &SystemSpec) -> u64 {
    fnv1a(&format!("{sys:?}"))
}

/// Hash of what the evaluation actually depends on: the workload and
/// device mapping. The scenario's `hardware` (overridden per design),
/// outputs, and `tune` section deliberately do not participate, so
/// editing the search setup never invalidates cached evaluations.
pub fn scenario_hash(sc: &Scenario) -> u64 {
    let par = match &sc.parallelism {
        Some(p) => format!("{p:?}"),
        None => "none".to_string(),
    };
    fnv1a(&format!("{}|{par}", sc.workload.to_json().to_string_compact()))
}

/// On-disk cache of evaluated design points, keyed by (design
/// fingerprint, scenario hash). Mirrors the mapper cache's contract:
/// corrupt or missing files load as empty, entries from other versions
/// are preserved verbatim, and persisting merges with whatever another
/// process wrote since load before the tmp-file + rename swap.
struct TuneCache {
    path: Option<PathBuf>,
    entries: BTreeMap<(u64, u64), DesignPoint>,
    foreign: Vec<Json>,
    dirty: bool,
}

impl TuneCache {
    fn load(path: Option<PathBuf>) -> TuneCache {
        let mut cache =
            TuneCache { path, entries: BTreeMap::new(), foreign: Vec::new(), dirty: false };
        if let Some(p) = cache.path.clone() {
            if let Ok(text) = std::fs::read_to_string(&p) {
                if let Ok(j) = Json::parse(&text) {
                    cache.absorb(&j);
                }
            }
        }
        cache
    }

    fn absorb(&mut self, j: &Json) {
        let version_ok =
            j.get("version").and_then(|v| v.as_u64()) == Some(TUNE_CACHE_VERSION);
        let Some(items) = j.get("entries").and_then(|e| e.as_arr()) else { return };
        for item in items {
            match TuneCache::parse_entry(item) {
                Some((key, point)) if version_ok => {
                    self.entries.entry(key).or_insert(point);
                }
                // Deduplicate: persist() re-absorbs the on-disk file to
                // merge concurrent writers, and without this check every
                // persist would append another copy of each foreign row.
                _ if self.foreign.contains(item) => {}
                _ => self.foreign.push(item.clone()),
            }
        }
    }

    fn parse_entry(item: &Json) -> Option<((u64, u64), DesignPoint)> {
        let design = u64::from_str_radix(item.get("design")?.as_str()?, 16).ok()?;
        let scenario = u64::from_str_radix(item.get("scenario")?.as_str()?, 16).ok()?;
        let point = DesignPoint::from_json(item.get("point")?).ok()?;
        Some(((design, scenario), point))
    }

    fn get(&self, design: u64, scenario: u64) -> Option<&DesignPoint> {
        self.entries.get(&(design, scenario))
    }

    fn insert(&mut self, design: u64, scenario: u64, point: &DesignPoint) {
        self.entries.insert((design, scenario), point.clone());
        self.dirty = true;
    }

    fn len(&self) -> usize {
        self.entries.len()
    }

    fn persist(&mut self) -> Result<(), String> {
        let Some(path) = self.path.clone() else { return Ok(()) };
        if !self.dirty {
            return Ok(());
        }
        // Pick up entries another process persisted since we loaded;
        // ours win on key collisions (they are the freshest).
        if let Ok(text) = std::fs::read_to_string(&path) {
            if let Ok(j) = Json::parse(&text) {
                self.absorb(&j);
            }
        }
        let mut items: Vec<Json> = self
            .entries
            .iter()
            .map(|((d, sc), p)| {
                obj(vec![
                    ("design", s(&format!("{d:016x}"))),
                    ("scenario", s(&format!("{sc:016x}"))),
                    ("point", p.to_json()),
                ])
            })
            .collect();
        items.extend(self.foreign.iter().cloned());
        let out = obj(vec![
            ("version", num(TUNE_CACHE_VERSION as f64)),
            ("entries", Json::Arr(items)),
        ]);
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, out.to_string_pretty())
            .map_err(|e| format!("write tune cache {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .map_err(|e| format!("rename tune cache {}: {e}", path.display()))?;
        self.dirty = false;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// The search
// ---------------------------------------------------------------------------

/// Knobs of one tune run.
#[derive(Debug, Clone, Default)]
pub struct TuneOptions {
    pub constraints: Constraints,
    /// Disable branch-and-bound pruning and evaluate every feasible
    /// design (the frontier is identical either way — this exists for
    /// the identity test and for timing comparisons).
    pub exhaustive: bool,
    /// Persistent design-evaluation cache file (None = in-memory only).
    pub cache_path: Option<PathBuf>,
}

/// The tune run's result: search accounting, the Pareto frontier with
/// full configs, the best-objective point, and the stock baseline.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneReport {
    pub scenario: String,
    pub scenario_hash: u64,
    pub space: String,
    pub objective: Objective,
    pub constraints: Constraints,
    pub exhaustive: bool,
    pub designs_total: u64,
    pub infeasible: u64,
    pub pruned: u64,
    pub evaluated: u64,
    pub cache_hits: u64,
    pub baseline: Option<DesignPoint>,
    pub frontier: Vec<DesignPoint>,
    pub best: Option<DesignPoint>,
}

impl TuneReport {
    /// best objective / baseline objective (> 1 means the search found
    /// a design that beats the scenario's stock hardware).
    pub fn gain_vs_baseline(&self) -> Option<f64> {
        let best = self.best.as_ref()?;
        let base = self.baseline.as_ref()?;
        Some(self.objective.value(best) / self.objective.value(base))
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("schema_version", num(TUNE_SCHEMA_VERSION as f64)),
            ("scenario", s(&self.scenario)),
            ("scenario_hash", s(&format!("{:016x}", self.scenario_hash))),
            ("space", s(&self.space)),
            ("objective", s(self.objective.name())),
            ("constraints", self.constraints.to_json()),
            (
                "search",
                obj(vec![
                    ("designs", num(self.designs_total as f64)),
                    ("infeasible", num(self.infeasible as f64)),
                    ("pruned", num(self.pruned as f64)),
                    ("evaluated", num(self.evaluated as f64)),
                    ("cache_hits", num(self.cache_hits as f64)),
                    ("exhaustive", Json::Bool(self.exhaustive)),
                ]),
            ),
            (
                "frontier",
                Json::Arr(self.frontier.iter().map(DesignPoint::to_json).collect()),
            ),
        ];
        if let Some(b) = &self.best {
            fields.push(("best", b.to_json()));
        }
        if let Some(b) = &self.baseline {
            fields.push(("baseline", b.to_json()));
        }
        if let Some(g) = self.gain_vs_baseline() {
            fields.push(("gain_vs_baseline", num(g)));
        }
        obj(fields)
    }
}

enum Verdict {
    Point(DesignPoint, bool),
    Pruned,
    Infeasible,
}

/// Search a design space for the scenario's workload.
///
/// Why pruning cannot change the frontier: a candidate is skipped only
/// when some evaluated point `e` satisfies `e.latency <
/// floor_latency(d)`, `e.usd_per_mtok < floor_mtok(d)`, and `e.area <
/// area(d)` — strictly, on all three axes. The floors never exceed the
/// true metrics and the area is exact, so `e` strictly dominates the
/// values `d` would have evaluated to; by transitivity anything `d`
/// would have excluded from the frontier is also excluded by `e`, and
/// `d` itself can never be non-dominated. Hence
/// `frontier(evaluated) == frontier(all feasible designs)` under any
/// evaluation order — the branch-and-bound result is bit-identical to
/// `exhaustive: true`.
pub fn tune(
    ev: &Evaluator,
    sc: &Scenario,
    space: &DesignSpace,
    objective: Objective,
    opts: &TuneOptions,
) -> Result<TuneReport, String> {
    let work = WorkFloor::of(sc)?;
    let candidates = space.materialize()?;
    if candidates.is_empty() {
        return Err(format!("design space `{}` is empty", space.name));
    }
    let sc_hash = scenario_hash(sc);
    let rec = ev.recorder().clone();
    let t_search = rec.host_now_s();

    let baseline = evaluate_baseline(ev, sc, &work)?;

    let cache = Mutex::new(TuneCache::load(opts.cache_path.clone()));
    let seen: Mutex<Vec<DesignPoint>> = Mutex::new(Vec::new());

    let verdicts: Vec<Result<Verdict, String>> = pool::parallel_map_shared(&candidates, |cand| {
        let dev = &cand.system.device;
        let devices = cand.system.device_count;
        let link_bw = cand.system.interconnect.link_bandwidth_bytes_per_s;
        let area_mm2 = die_breakdown(&ev.area_params, dev, link_bw).total_mm2();
        let power_w = power_proxy_w(dev);
        if !opts.constraints.satisfied(area_mm2, power_w) {
            return Ok(Verdict::Infeasible);
        }
        let fingerprint = design_fingerprint(&cand.system);
        if let Some(hit) = cache.lock().unwrap().get(fingerprint, sc_hash).cloned() {
            seen.lock().unwrap().push(hit.clone());
            return Ok(Verdict::Point(hit, true));
        }
        let cluster_cost = device_cost(&ev.cost_params, dev).total_usd() * devices as f64;
        if !opts.exhaustive {
            let floor_lat = work.latency_floor_s(dev, devices);
            let floor_mtok = work.usd_per_mtok_floor(dev, devices, cluster_cost);
            let dominated = seen.lock().unwrap().iter().any(|e| {
                e.latency_s < floor_lat && e.usd_per_mtok < floor_mtok && e.area_mm2 < area_mm2
            });
            if dominated {
                return Ok(Verdict::Pruned);
            }
        }
        let t_design = rec.host_now_s();
        let point = evaluate_design(ev, sc, &work, cand, area_mm2, power_w, cluster_cost)
            .map_err(|e| format!("design `{}`: {e}", cand.name))?;
        rec.span_host(
            "tune",
            &format!("design {}", cand.name),
            t_design,
            &[
                ("latency_s", num(point.latency_s)),
                ("usd_per_mtok", num(point.usd_per_mtok)),
                ("area_mm2", num(point.area_mm2)),
            ],
        );
        seen.lock().unwrap().push(point.clone());
        cache.lock().unwrap().insert(fingerprint, sc_hash, &point);
        Ok(Verdict::Point(point, false))
    });

    // Rebuild results in enumeration order (the shared `seen` list is
    // completion-ordered and only used for pruning checks).
    let mut points: Vec<DesignPoint> = Vec::new();
    let (mut cache_hits, mut pruned, mut infeasible) = (0u64, 0u64, 0u64);
    let mut first_err: Option<String> = None;
    for v in verdicts {
        match v {
            Ok(Verdict::Point(p, was_cached)) => {
                if was_cached {
                    cache_hits += 1;
                }
                points.push(p);
            }
            Ok(Verdict::Pruned) => pruned += 1,
            Ok(Verdict::Infeasible) => infeasible += 1,
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    cache.lock().unwrap().persist()?;
    if let Some(e) = first_err {
        return Err(e);
    }

    let mut frontier = pareto_frontier(&points);
    frontier.sort_by(|a, b| {
        a.latency_s
            .partial_cmp(&b.latency_s)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(
                a.usd_per_mtok
                    .partial_cmp(&b.usd_per_mtok)
                    .unwrap_or(std::cmp::Ordering::Equal),
            )
            .then(a.name.cmp(&b.name))
    });
    let best = points
        .iter()
        .fold(None::<DesignPoint>, |acc, p| match acc {
            Some(a) if objective.value(&a) >= objective.value(p) => Some(a),
            _ => Some(p.clone()),
        });

    let evaluated = points.len() as u64 - cache_hits;
    rec.span_host(
        "tune",
        &format!("search {} ({} designs)", space.name, candidates.len()),
        t_search,
        &[
            ("evaluated", num(evaluated as f64)),
            ("pruned", num(pruned as f64)),
            ("cache_hits", num(cache_hits as f64)),
            ("frontier", num(frontier.len() as f64)),
        ],
    );

    Ok(TuneReport {
        scenario: sc.name.clone(),
        scenario_hash: sc_hash,
        space: space.name.clone(),
        objective,
        constraints: opts.constraints,
        exhaustive: opts.exhaustive,
        designs_total: candidates.len() as u64,
        infeasible,
        pruned,
        evaluated,
        cache_hits,
        baseline,
        frontier,
        best,
    })
}

/// Evaluate the scenario's own (stock) hardware as a comparison point.
/// The baseline never seeds pruning: it is not part of the space, so
/// letting it eliminate candidates could hide genuine frontier points.
fn evaluate_baseline(
    ev: &Evaluator,
    sc: &Scenario,
    work: &WorkFloor,
) -> Result<Option<DesignPoint>, String> {
    let mut sub = sc.clone();
    sub.tune = None;
    sub.outputs = vec![if work.traffic { Output::Serving } else { Output::Latency }];
    let report = ev.evaluate(&sub)?;
    let name = format!("baseline:{}", sc.hardware);
    point_from_report(ev, &name, &report, work).map(Some)
}

fn evaluate_design(
    ev: &Evaluator,
    sc: &Scenario,
    work: &WorkFloor,
    cand: &Candidate,
    area_mm2: f64,
    power_w: f64,
    cluster_cost_usd: f64,
) -> Result<DesignPoint, String> {
    let mut sub = sc.clone();
    sub.tune = None;
    sub.outputs = vec![if work.traffic { Output::Serving } else { Output::Latency }];
    let report = ev.evaluate_on(&sub, cand.system.clone())?;
    let mut point = point_from_report(ev, &cand.name, &report, work)?;
    // Reuse the screening-time values verbatim so the report can never
    // disagree with the feasibility decision.
    point.area_mm2 = area_mm2;
    point.power_w = power_w;
    if !work.traffic {
        point.cluster_cost_usd = cluster_cost_usd;
    }
    Ok(point)
}

fn point_from_report(
    ev: &Evaluator,
    name: &str,
    report: &EvalReport,
    work: &WorkFloor,
) -> Result<DesignPoint, String> {
    let sys = &report.system;
    let link_bw = sys.interconnect.link_bandwidth_bytes_per_s;
    let area_mm2 = die_breakdown(&ev.area_params, &sys.device, link_bw).total_mm2();
    let power_w = power_proxy_w(&sys.device);
    let (latency_s, tok_s, cluster_cost_usd, usd_per_mtok) = match report.results.first() {
        Some(EvalResult::RequestLatency { total_s, .. }) => {
            let cost = device_cost(&ev.cost_params, &sys.device).total_usd()
                * sys.device_count as f64;
            let tok_s = if *total_s > 0.0 { work.tokens / total_s } else { 0.0 };
            (*total_s, tok_s, cost, usd_per_mtok_at_slo(cost, tok_s))
        }
        Some(EvalResult::Serving(sr)) => (
            sr.summary.ttft_mean_s,
            sr.summary.goodput_tok_s,
            sr.cluster_cost_usd,
            sr.usd_per_mtok,
        ),
        _ => return Err(format!("design `{name}`: unexpected evaluation result")),
    };
    Ok(DesignPoint {
        name: name.to_string(),
        system: sys.clone(),
        latency_s,
        tok_s,
        area_mm2,
        power_w,
        cluster_cost_usd,
        usd_per_mtok: clamp_mtok(usd_per_mtok),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick;

    fn request_scenario() -> Scenario {
        Scenario::new(
            "tune-unit",
            "a100",
            Workload::Request {
                model: "gpt-small".to_string(),
                batch: 2,
                prefill: 16,
                decode: 4,
                layers: Some(1),
            },
        )
    }

    #[test]
    fn objective_names_roundtrip() {
        for o in [Objective::PerfPerDollar, Objective::GoodputPerDollar] {
            assert_eq!(Objective::parse(o.name()), Some(o));
        }
        assert_eq!(Objective::parse("nope"), None);
        assert_eq!(
            Objective::default_for(&request_scenario().workload),
            Objective::PerfPerDollar
        );
    }

    #[test]
    fn memtech_presets_and_json_roundtrip() {
        for name in MemTech::preset_names() {
            let m = MemTech::preset(name).unwrap();
            let back = MemTech::from_json(&m.to_json()).unwrap();
            assert_eq!(m, back, "{name}");
            // Preset-string form parses too.
            let short = MemTech::from_json(&s(name)).unwrap();
            assert_eq!(m, short);
        }
        // The hbm2e preset matches the A100's stock memory, so a space
        // over [hbm2e] contains the unmodified base device.
        let a100 = presets::device("a100").unwrap();
        let hbm = MemTech::preset("hbm2e").unwrap();
        assert_eq!(hbm.bandwidth_bytes_per_s, a100.memory.bandwidth_bytes_per_s);
        assert_eq!(hbm.capacity_bytes, a100.memory.capacity_bytes);
        assert_eq!(hbm.protocol, a100.memory.protocol);
    }

    #[test]
    fn design_space_json_roundtrip() {
        for name in DesignSpace::preset_names() {
            let sp = DesignSpace::preset(name).unwrap();
            let back = DesignSpace::from_json(&sp.to_json()).unwrap();
            assert_eq!(sp, back, "{name}");
        }
        let mut sp = DesignSpace::around("x", "a100");
        sp.systolic = vec![(8, 8), (16, 16)];
        sp.device_counts = vec![1, 2];
        let back = DesignSpace::from_json(&sp.to_json()).unwrap();
        assert_eq!(sp, back);
    }

    #[test]
    fn materialize_counts_and_contains_stock() {
        let sp = DesignSpace::preset("smoke").unwrap();
        let cands = sp.materialize().unwrap();
        assert_eq!(cands.len(), 4);
        let names: std::collections::BTreeSet<&str> =
            cands.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(names.len(), 4, "duplicate candidate names");
        // One candidate is the stock A100 in everything but name.
        let a100 = presets::device("a100").unwrap();
        assert!(cands.iter().any(|c| {
            let d = &c.system.device;
            d.core_count == a100.core_count
                && d.memory == a100.memory
                && d.core == a100.core
                && c.system.device_count == 1
        }));
    }

    #[test]
    fn materialize_rejects_bad_axes() {
        let mut sp = DesignSpace::around("bad", "a100");
        sp.core_counts = vec![0];
        assert!(sp.materialize().unwrap_err().contains("core_counts"));
        let mut huge = DesignSpace::around("huge", "a100");
        huge.core_counts = (1..=100).collect();
        huge.lane_counts = (1..=100).collect();
        assert!(huge.materialize().unwrap_err().contains("max"));
        assert!(DesignSpace::around("x", "nope").materialize().is_err());
    }

    #[test]
    fn power_proxy_is_sane() {
        let w = power_proxy_w(&presets::device("a100").unwrap());
        assert!((100.0..1000.0).contains(&w), "A100 proxy {w} W");
        // Cutting compute must cut power.
        let mut half = presets::device("a100").unwrap();
        half.core_count /= 2;
        assert!(power_proxy_w(&half) < w);
    }

    #[test]
    fn frontier_never_contains_dominated_points() {
        quick::forall("tune_frontier_nondominated", 200, |g| {
            let n = g.usize(1, 12);
            let points: Vec<DesignPoint> = (0..n)
                .map(|i| DesignPoint {
                    name: format!("p{i}"),
                    system: SystemSpec::single(presets::device("a100").unwrap()),
                    latency_s: g.f64(0.1, 10.0),
                    tok_s: g.f64(1.0, 100.0),
                    area_mm2: g.f64(100.0, 1000.0),
                    power_w: 100.0,
                    cluster_cost_usd: g.f64(100.0, 1000.0),
                    usd_per_mtok: g.f64(0.01, 10.0),
                })
                .collect();
            let front = pareto_frontier(&points);
            let mut ok = !front.is_empty();
            // No frontier point dominates another...
            for a in &front {
                for b in &front {
                    if dominates(a, b) {
                        ok = false;
                    }
                }
            }
            // ...and every dropped point is dominated by a frontier one.
            for p in &points {
                if !front.iter().any(|f| f.name == p.name)
                    && !front.iter().any(|f| dominates(f, p))
                {
                    ok = false;
                }
            }
            let case: Vec<(f64, f64, f64)> =
                points.iter().map(|p| (p.latency_s, p.usd_per_mtok, p.area_mm2)).collect();
            (case, ok)
        });
    }

    #[test]
    fn floors_never_exceed_actuals() {
        let sc = request_scenario();
        let work = WorkFloor::of(&sc).unwrap();
        let ev = Evaluator::new();
        let report = ev.evaluate(&sc).unwrap();
        let Some(EvalResult::RequestLatency { total_s, .. }) = report.results.first() else {
            panic!("expected a request latency result");
        };
        let dev = presets::device("a100").unwrap();
        let floor = work.latency_floor_s(&dev, 1);
        assert!(floor > 0.0);
        assert!(
            floor <= *total_s,
            "floor {floor} exceeds simulated latency {total_s}"
        );
        let cost = device_cost(&ev.cost_params, &dev).total_usd();
        let actual_mtok = clamp_mtok(usd_per_mtok_at_slo(cost, work.tokens / total_s));
        let floor_mtok = work.usd_per_mtok_floor(&dev, 1, cost);
        assert!(floor_mtok <= actual_mtok);
    }

    #[test]
    fn work_floor_rejects_op_workloads() {
        let sc = Scenario::new("op", "a100", Workload::Hardware);
        assert!(WorkFloor::of(&sc).is_err());
    }

    #[test]
    fn tune_cache_roundtrips_and_survives_corruption() {
        let path = std::env::temp_dir()
            .join(format!("llmcompass_tune_cache_test_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let point = DesignPoint {
            name: "d1".to_string(),
            system: SystemSpec::single(presets::device("a100").unwrap()),
            latency_s: 0.5,
            tok_s: 16.0,
            area_mm2: 800.0,
            power_w: 300.0,
            cluster_cost_usd: 700.0,
            usd_per_mtok: 0.9,
        };
        let mut cache = TuneCache::load(Some(path.clone()));
        cache.insert(7, 9, &point);
        cache.persist().unwrap();
        let reloaded = TuneCache::load(Some(path.clone()));
        assert_eq!(reloaded.len(), 1);
        assert_eq!(reloaded.get(7, 9), Some(&point));
        assert_eq!(reloaded.get(7, 8), None);
        // Corrupt files load as empty instead of failing.
        std::fs::write(&path, "{ not json").unwrap();
        assert_eq!(TuneCache::load(Some(path.clone())).len(), 0);
        // Outright garbage bytes (not even UTF-8 structure) also load as
        // empty — and persisting over the wreckage replaces it with a
        // valid cache file instead of panicking or appending to it.
        std::fs::write(&path, [0xffu8, 0x00, 0x9c, 0x7b, 0x22, 0xfe, 0x01]).unwrap();
        let mut over = TuneCache::load(Some(path.clone()));
        assert_eq!(over.len(), 0);
        over.insert(3, 4, &point);
        over.persist().unwrap();
        let healed = TuneCache::load(Some(path.clone()));
        assert_eq!(healed.len(), 1);
        assert_eq!(healed.get(3, 4), Some(&point));
        // A version-mismatched file contributes no entries to this
        // process, but its rows ride along verbatim through persist so a
        // newer toolchain's cache is never destroyed by an older one.
        let foreign_text = format!(
            "{{\"version\": 999, \"entries\": [{}]}}",
            "{\"design\": \"00000000000000aa\", \"scenario\": \"00000000000000bb\", \
             \"point\": {\"future\": true}}"
        );
        std::fs::write(&path, foreign_text).unwrap();
        let mut mixed = TuneCache::load(Some(path.clone()));
        assert_eq!(mixed.len(), 0, "foreign-version entries must not be trusted");
        mixed.insert(7, 9, &point);
        mixed.persist().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(&text).expect("persisted cache is valid JSON again");
        let entries = j.get("entries").and_then(|e| e.as_arr()).unwrap();
        assert_eq!(entries.len(), 2, "ours plus the preserved foreign row");
        assert!(text.contains("00000000000000aa"), "foreign row dropped on persist");
        assert_eq!(TuneCache::load(Some(path.clone())).len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tune_smoke_on_tiny_space() {
        let sc = request_scenario();
        let mut sp = DesignSpace::around("tiny", "a100");
        sp.core_counts = vec![54, 108];
        let report = tune(
            &Evaluator::new(),
            &sc,
            &sp,
            Objective::PerfPerDollar,
            &TuneOptions::default(),
        )
        .unwrap();
        assert_eq!(report.designs_total, 2);
        assert_eq!(report.infeasible, 0);
        assert!(!report.frontier.is_empty());
        assert!(report.best.is_some());
        assert!(report.baseline.is_some());
        assert!(report.gain_vs_baseline().unwrap() > 0.0);
        // Report JSON parses back.
        let text = report.to_json().to_string_pretty();
        Json::parse(&text).unwrap();
    }

    #[test]
    fn constraints_screen_infeasible_designs() {
        let sc = request_scenario();
        let mut sp = DesignSpace::around("tiny", "a100");
        sp.core_counts = vec![54, 108];
        let opts = TuneOptions {
            constraints: Constraints { max_area_mm2: Some(1.0), max_power_w: None },
            ..TuneOptions::default()
        };
        let report =
            tune(&Evaluator::new(), &sc, &sp, Objective::PerfPerDollar, &opts).unwrap();
        assert_eq!(report.infeasible, 2);
        assert!(report.frontier.is_empty());
        assert!(report.best.is_none());
    }
}
