//! Systolic array timing model (paper §III-B1, "From local buffer to
//! lanes"). The paper drives SCALE-Sim [56,57] per sub-sub-tile and caches
//! results in a look-up table; we reimplement the SCALE-Sim analytical
//! timing equations directly (they are exact for dense GEMM on an idealized
//! array) plus a cycle-walk reference simulator used in tests, and keep the
//! same LUT layer.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Dataflow of the systolic array.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    /// Weight stationary (TPU-style): the k×n operand is pinned in the PEs.
    WeightStationary,
    /// Output stationary: C accumulates in place, A/B stream through.
    OutputStationary,
}

/// A GEMM tile to run on the array: C[m,n] += A[m,k] · B[k,n].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tile {
    pub m: u64,
    pub k: u64,
    pub n: u64,
}

/// Array geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Array {
    pub rows: u64,
    pub cols: u64,
    pub dataflow: Dataflow,
}

/// Cycle count for one tile on one array, SCALE-Sim analytical model.
///
/// Weight-stationary: B(k×n) is laid out k→rows, n→cols, so the array holds
/// an R×C slice of B per *fold*; there are ⌈k/R⌉·⌈n/C⌉ folds. Per fold:
/// `R` cycles to preload weights (double-buffered preload overlaps with the
/// previous fold's drain when m ≥ R, which the analytical min-term models),
/// then `m` rows stream in and the last result drains after `R + C − 2`
/// more cycles.
///
/// Output-stationary: C(m×n) maps m→rows, n→cols; ⌈m/R⌉·⌈n/C⌉ folds, each
/// taking `2·min(m,R)` skews + `k` accumulation cycles (SCALE-Sim's
/// `2·R + k − 2` for a full fold).
pub fn cycles_analytical(tile: Tile, array: Array) -> u64 {
    let Tile { m, k, n } = tile;
    if m == 0 || k == 0 || n == 0 {
        return 0;
    }
    let r = array.rows;
    let c = array.cols;
    // Fold classes in closed form: splitting a dimension `d` over array
    // extent `e` yields `d / e` full chunks of size `e` plus at most one
    // ragged chunk of size `d % e`. The cross product gives ≤ 4 classes,
    // so arbitrarily large tiles cost O(1) to evaluate (the paper instead
    // caches SCALE-Sim runs; we get the same effect analytically).
    let classes = |d: u64, e: u64| -> [(u64, u64); 2] {
        [(d / e, e), (u64::from(d % e > 0), d % e)]
    };
    match array.dataflow {
        Dataflow::WeightStationary => {
            // Per fold with kk rows / nn cols in use: preload kk weights,
            // stream m rows, fill+drain kk + nn − 2, +1 writeback skew.
            let mut total = 0u64;
            let mut folds = 0u64;
            let mut min_preload = u64::MAX;
            for (ck, kk) in classes(k, r) {
                for (cn, nn) in classes(n, c) {
                    let count = ck * cn;
                    if count == 0 {
                        continue;
                    }
                    total += count * (kk + m + kk + nn - 2 + 1);
                    folds += count;
                    min_preload = min_preload.min(kk);
                }
            }
            // Consecutive folds overlap the next preload with the current
            // fold's streaming (double-buffered weights): subtract
            // min(m, preload) per transition, conservatively using the
            // smallest preload so the result stays ≤ the no-overlap
            // reference.
            total - (folds - 1) * m.min(min_preload)
        }
        Dataflow::OutputStationary => {
            let mut total = 0u64;
            for (cm, mm) in classes(m, r) {
                for (cn, nn) in classes(n, c) {
                    total += cm * cn * (2 * mm.max(nn) + k - 2 + 1);
                }
            }
            total
        }
    }
}

/// Cycle-walk reference: simulate the wavefront of the array fold-by-fold
/// without the overlap optimizations. Used in tests to bound the analytical
/// model (analytical ≤ reference ≤ analytical + preload slack) and in the
/// `--reference` simulator mode.
pub fn cycles_reference(tile: Tile, array: Array) -> u64 {
    let Tile { m, k, n } = tile;
    if m == 0 || k == 0 || n == 0 {
        return 0;
    }
    let r = array.rows;
    let c = array.cols;
    match array.dataflow {
        Dataflow::WeightStationary => {
            let mut total = 0u64;
            for fk in chunks(k, r) {
                for fn_ in chunks(n, c) {
                    // preload + stream m rows + drain; no cross-fold overlap
                    total += fk + (m + fk + fn_ - 2) + 1;
                }
            }
            total
        }
        Dataflow::OutputStationary => {
            let mut total = 0u64;
            for fm in chunks(m, r) {
                for fn_ in chunks(n, c) {
                    total += 2 * fm.max(fn_) + k - 2 + 1;
                }
            }
            total
        }
    }
}

/// Utilization of the array for a tile: useful MACs / (cycles × PEs).
pub fn utilization(tile: Tile, array: Array) -> f64 {
    let cycles = cycles_analytical(tile, array);
    if cycles == 0 {
        return 0.0;
    }
    let macs = (tile.m * tile.k * tile.n) as f64;
    macs / (cycles as f64 * (array.rows * array.cols) as f64)
}

/// Iterate chunk sizes covering `total` in steps of `step`.
fn chunks(total: u64, step: u64) -> impl Iterator<Item = u64> {
    let full = total / step;
    let rem = total % step;
    (0..full).map(move |_| step).chain((rem > 0).then_some(rem))
}

/// Number of independent LUT shards. Each shard is its own mutex-guarded
/// map, so concurrent mapper workers hitting *different* tiles almost
/// never contend; 16 shards keeps the worst case at 1/16th of the old
/// single-mutex serialization.
const LUT_SHARDS: usize = 16;

/// Memoizing LUT over (tile, array) — mirrors the paper's caching of
/// SCALE-Sim results ("LLMCompass caches the results of SCALE-Sim into a
/// look-up table to avoid duplicated simulation").
///
/// The table is sharded by key hash and the hit/miss counters are atomics,
/// so a parallel candidate loop never serializes on a global lock (the
/// pre-engine design took one `Mutex` per simulated candidate). Two
/// threads racing on the *same* cold key may both compute it — the value
/// is deterministic, so the second insert is a harmless overwrite (and
/// both count as misses, exactly as the old implementation did).
pub struct SystolicLut {
    shards: Vec<Mutex<HashMap<(Tile, Array), u64>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for SystolicLut {
    fn default() -> Self {
        Self::new()
    }
}

impl SystolicLut {
    pub fn new() -> Self {
        SystolicLut {
            shards: (0..LUT_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: &(Tile, Array)) -> &Mutex<HashMap<(Tile, Array), u64>> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[h.finish() as usize % LUT_SHARDS]
    }

    pub fn cycles(&self, tile: Tile, array: Array) -> u64 {
        let key = (tile, array);
        let shard = self.shard(&key);
        if let Some(&c) = shard.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return c;
        }
        let c = cycles_analytical(tile, array);
        shard.lock().unwrap().insert(key, c);
        self.misses.fetch_add(1, Ordering::Relaxed);
        c
    }

    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const WS16: Array = Array { rows: 16, cols: 16, dataflow: Dataflow::WeightStationary };
    const OS16: Array = Array { rows: 16, cols: 16, dataflow: Dataflow::OutputStationary };

    #[test]
    fn zero_tiles_cost_nothing() {
        assert_eq!(cycles_analytical(Tile { m: 0, k: 4, n: 4 }, WS16), 0);
        assert_eq!(cycles_reference(Tile { m: 4, k: 0, n: 4 }, WS16), 0);
    }

    #[test]
    fn single_fold_ws_formula() {
        // m=16,k=16,n=16 on 16x16 WS: preload 16 + stream 16 + drain 30 + 1.
        let t = Tile { m: 16, k: 16, n: 16 };
        let c = cycles_analytical(t, WS16);
        assert_eq!(c, 16 + 16 + 30 + 1);
        // Reference with no overlap equals analytical for a single fold.
        assert_eq!(c, cycles_reference(t, WS16));
    }

    #[test]
    fn analytical_bounded_by_reference() {
        // The analytical model overlaps preload across folds, so it must be
        // ≤ the no-overlap reference, and never less than the streaming
        // lower bound.
        for &(m, k, n) in
            &[(1, 16, 16), (64, 64, 64), (100, 30, 7), (128, 16, 256), (16, 128, 16)]
        {
            let t = Tile { m, k, n };
            for array in [WS16, OS16] {
                let a = cycles_analytical(t, array);
                let r = cycles_reference(t, array);
                assert!(a <= r, "analytical {a} > reference {r} for {t:?} {array:?}");
                assert!(a > 0);
            }
        }
    }

    #[test]
    fn utilization_peaks_near_one_for_big_tiles() {
        let u = utilization(Tile { m: 4096, k: 16, n: 16 }, WS16);
        assert!(u > 0.95, "long-stream WS utilization {u}");
        let u_small = utilization(Tile { m: 1, k: 16, n: 16 }, WS16);
        assert!(u_small < 0.05, "m=1 decode-style utilization {u_small}");
    }

    #[test]
    fn bigger_array_worse_for_narrow_tiles() {
        // Paper implication ②: large systolic arrays are harder to utilize
        // for narrow (decode) matmuls.
        let big = Array { rows: 128, cols: 128, dataflow: Dataflow::WeightStationary };
        let narrow = Tile { m: 1, k: 128, n: 128 };
        assert!(utilization(narrow, big) < utilization(Tile { m: 1, k: 16, n: 16 }, WS16) + 1e-9);
    }

    #[test]
    fn folds_scale_cycles() {
        // Doubling n doubles folds (k=n=array size) and roughly doubles cost.
        let c1 = cycles_analytical(Tile { m: 64, k: 16, n: 16 }, WS16);
        let c2 = cycles_analytical(Tile { m: 64, k: 16, n: 32 }, WS16);
        assert!(c2 > c1 && c2 <= 2 * c1 + 8, "c1={c1} c2={c2}");
    }

    #[test]
    fn lut_caches() {
        let lut = SystolicLut::new();
        let t = Tile { m: 32, k: 16, n: 16 };
        let a = lut.cycles(t, WS16);
        let b = lut.cycles(t, WS16);
        assert_eq!(a, b);
        assert_eq!(lut.stats(), (1, 1));
        assert_eq!(lut.len(), 1);
    }

    #[test]
    fn sharded_lut_counts_and_sums_across_shards() {
        // More distinct keys than shards: `len` must sum the shards, and
        // a re-read of every key must be a pure hit.
        let lut = SystolicLut::new();
        for m in 1..=64u64 {
            lut.cycles(Tile { m, k: 16, n: 16 }, WS16);
        }
        assert_eq!(lut.len(), 64);
        assert_eq!(lut.stats(), (0, 64));
        for m in 1..=64u64 {
            lut.cycles(Tile { m, k: 16, n: 16 }, WS16);
        }
        assert_eq!(lut.stats(), (64, 64));
        assert_eq!(lut.len(), 64);
    }

    #[test]
    fn chunks_cover_total() {
        let total: u64 = chunks(100, 16).sum();
        assert_eq!(total, 100);
        assert_eq!(chunks(100, 16).count(), 7);
        assert_eq!(chunks(96, 16).count(), 6);
    }
}
