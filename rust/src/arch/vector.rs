//! Vector-unit timing model.
//!
//! Each lane owns a `vector_width`-wide SIMD unit. Elementwise work costs
//! `ceil(elements / width) × op_cost` cycles; row reductions add a
//! `log2(width)`-deep shuffle tree plus a serial tail when a row spans
//! multiple vector iterations. Primitive costs follow typical GPU special-
//! function-unit throughput ratios (1 for add/mul/fma, 4 for exp/div via
//! SFU, 6 for tanh).

/// Cost (in vector-unit issue slots) of one primitive applied element-wise.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Prim {
    Add,
    Mul,
    Fma,
    Max,
    Exp,
    Div,
    Sqrt,
    Tanh,
    Copy,
}

impl Prim {
    pub fn cost(self) -> u64 {
        match self {
            Prim::Add | Prim::Mul | Prim::Fma | Prim::Max | Prim::Copy => 1,
            Prim::Exp | Prim::Div | Prim::Sqrt => 4,
            Prim::Tanh => 6,
        }
    }
}

/// Cycles for applying `prim` to `elements` elements on one lane of SIMD
/// width `width`.
pub fn elementwise_cycles(elements: u64, width: u64, prim: Prim) -> u64 {
    if elements == 0 {
        return 0;
    }
    let iters = (elements + width - 1) / width;
    iters * prim.cost()
}

/// Cycles to reduce `elements` values to one (sum/max) on one lane:
/// sequential accumulate over vector iterations, then a log2-tree across
/// the final vector register.
pub fn reduce_cycles(elements: u64, width: u64, prim: Prim) -> u64 {
    if elements == 0 {
        return 0;
    }
    let iters = (elements + width - 1) / width;
    // Accumulate each vector chunk into a running register (iters ops),
    // then fold the register with a shuffle tree (log2(width) ops).
    let tree = 64 - u64::leading_zeros(width.max(1)) as u64; // ≈ log2+1
    (iters + tree) * prim.cost()
}

/// A composite elementwise pipeline: total issue slots per element, used by
/// the operator models (e.g. GELU ≈ 2 fma + 1 tanh + 2 mul/add).
#[derive(Debug, Clone)]
pub struct Pipeline {
    pub prims: Vec<(Prim, u64)>,
}

impl Pipeline {
    pub fn cost_per_element(&self) -> u64 {
        self.prims.iter().map(|(p, count)| p.cost() * count).sum()
    }

    pub fn cycles(&self, elements: u64, width: u64) -> u64 {
        if elements == 0 {
            return 0;
        }
        let iters = (elements + width - 1) / width;
        iters * self.cost_per_element()
    }
}

/// The tanh-approximated GELU pipeline (paper §III-B3 / [26]):
/// `0.5·x·(1 + tanh(√(2/π)·(x + 0.044715·x³)))` — ~6 mul/add/fma + 1 tanh.
pub fn gelu_pipeline() -> Pipeline {
    Pipeline {
        prims: vec![
            (Prim::Mul, 2),
            (Prim::Fma, 2),
            (Prim::Tanh, 1),
            (Prim::Add, 1),
            (Prim::Mul, 1),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elementwise_scales_with_iterations() {
        assert_eq!(elementwise_cycles(32, 32, Prim::Add), 1);
        assert_eq!(elementwise_cycles(33, 32, Prim::Add), 2);
        assert_eq!(elementwise_cycles(64, 32, Prim::Exp), 2 * 4);
        assert_eq!(elementwise_cycles(0, 32, Prim::Add), 0);
    }

    #[test]
    fn reduction_has_tree_tail() {
        let r = reduce_cycles(32, 32, Prim::Add);
        // 1 accumulate iteration + ceil(log2(32))+1 = 6 tree steps.
        assert_eq!(r, 1 + 6);
        assert!(reduce_cycles(1024, 32, Prim::Add) > elementwise_cycles(1024, 32, Prim::Add));
    }

    #[test]
    fn gelu_pipeline_cost() {
        let p = gelu_pipeline();
        // 2·1 + 2·1 + 1·6 + 1·1 + 1·1 = 12 slots per element.
        assert_eq!(p.cost_per_element(), 12);
        assert_eq!(p.cycles(32, 32), 12);
        assert_eq!(p.cycles(0, 32), 0);
    }

    #[test]
    fn wider_vector_never_slower() {
        for w in [8u64, 16, 32, 64] {
            assert!(
                elementwise_cycles(1000, 2 * w, Prim::Mul) <= elementwise_cycles(1000, w, Prim::Mul)
            );
        }
    }
}
