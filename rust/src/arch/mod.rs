//! Low-level architectural timing models.
//!
//! * [`systolic`] — SCALE-Sim-style systolic array cycle counts (weight- and
//!   output-stationary dataflows), with an analytical fast path, a
//!   cycle-walk reference used for cross-validation, and a memoizing LUT
//!   (the paper caches SCALE-Sim results the same way).
//! * [`vector`] — vector-unit cycle counts for elementwise and reduction
//!   work, with a per-primitive cost table.
//! * [`link`] — the LogGP-style link model of paper Eq. 1–2 with
//!   flit/max-payload framing.

pub mod systolic;
pub mod vector;
pub mod link;
