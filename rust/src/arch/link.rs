//! Device-device link model (paper §III-B2, Eq. 1–2), following AHEAD [1]
//! and LogGP [3]:
//!
//! ```text
//! T  = L + O + n̂ / B                          (Eq. 1)
//! n̂ = ⌈n / MaxPayload⌉ · Flit_size + n        (Eq. 2)
//! ```
//!
//! where `L` is link latency, `O` the per-transfer overhead, `B` the link
//! bandwidth, and `n̂` the wire bytes after packet framing (one header flit
//! per MaxPayload-sized packet; 16 B flits / 256 B payloads for NVLink).

use crate::hardware::InterconnectSpec;

/// Wire bytes for a transfer of `n` payload bytes (Eq. 2).
pub fn wire_bytes(ic: &InterconnectSpec, n: u64) -> u64 {
    if n == 0 {
        return 0;
    }
    let packets = (n + ic.max_payload_bytes - 1) / ic.max_payload_bytes;
    packets * ic.flit_bytes + n
}

/// Latency in seconds to move `n` bytes across one link (Eq. 1).
pub fn transfer_time(ic: &InterconnectSpec, n: u64) -> f64 {
    if n == 0 {
        return 0.0;
    }
    ic.link_latency_s + ic.overhead_s + wire_bytes(ic, n) as f64 / ic.link_bandwidth_bytes_per_s
}

/// Effective bandwidth (payload bytes / time) for a transfer of `n` bytes —
/// approaches `B / (1 + flit/MaxPayload)` asymptotically.
pub fn effective_bandwidth(ic: &InterconnectSpec, n: u64) -> f64 {
    if n == 0 {
        return 0.0;
    }
    n as f64 / transfer_time(ic, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nvlink() -> InterconnectSpec {
        InterconnectSpec::nvlink_like(600e9)
    }

    #[test]
    fn framing_overhead_matches_eq2() {
        let ic = nvlink();
        // 256 B payload → exactly one packet → +16 B flit.
        assert_eq!(wire_bytes(&ic, 256), 256 + 16);
        // 257 B → two packets → +32 B.
        assert_eq!(wire_bytes(&ic, 257), 257 + 32);
        assert_eq!(wire_bytes(&ic, 0), 0);
    }

    #[test]
    fn latency_floor_for_tiny_messages() {
        let ic = nvlink();
        let t = transfer_time(&ic, 1);
        assert!(t >= ic.link_latency_s + ic.overhead_s);
        assert!(t < ic.link_latency_s + ic.overhead_s + 1e-9);
    }

    #[test]
    fn asymptotic_efficiency() {
        let ic = nvlink();
        let eff = effective_bandwidth(&ic, 1 << 30);
        // 16/256 = 6.25% framing tax → ~564 GB/s of 600 GB/s.
        let expected = 600e9 / (1.0 + 16.0 / 256.0);
        assert!((eff - expected).abs() / expected < 0.01, "eff {eff}");
    }

    #[test]
    fn monotone_in_size() {
        let ic = nvlink();
        let mut last = 0.0;
        for n in [1u64, 100, 10_000, 1_000_000, 100_000_000] {
            let t = transfer_time(&ic, n);
            assert!(t > last);
            last = t;
        }
    }
}
