//! `llmcompass` — CLI for the LLMCompass hardware evaluation framework.
//!
//! Subcommands:
//! * `hardware`   — list / show hardware descriptions (Table I presets,
//!   Table III designs, Table IV proposals, JSON files)
//! * `eval`       — evaluate typed JSON scenarios (`--scenario file` /
//!   `--suite dir`) through the unified `eval::Evaluator`, emitting
//!   stable-schema JSON reports with a shared mapper cache across the
//!   suite (and, with `--mapper-cache`, across processes); scenarios
//!   cover operators, layers, requests, arbitrary operator DAGs
//!   (`"type": "graph"`), and serving traffic, with `parallelism`
//!   `{tp, pp, microbatches}` device mappings
//! * `tune`       — search a hardware design space for the most
//!   cost-effective design: branch-and-bound over core/device counts,
//!   systolic dims, SRAM sizes, and memory technology, pruned by a
//!   provable per-design roofline floor, emitting a Pareto frontier
//!   (latency vs $/1M-tokens vs area) and the best perf/$ or goodput/$
//!   point vs the scenario's stock hardware
//! * `simulate`   — simulate one operator or a Transformer layer/request
//!   (`--pp`/`--microbatches` pipeline a request across device stages)
//! * `area`       — die area breakdown (Fig. 6) and Table II parameters
//! * `cost`       — die + memory cost (Table IV economics)
//! * `experiment` — regenerate a paper table/figure (`--list` for ids)
//! * `calibrate`  — measure AOT artifacts, fit the CPU device description
//! * `serve`      — simulate an inference cluster under traffic: Poisson /
//!   bursty / replayed arrivals through the scheduler's three execution
//!   modes (`--mode monolithic | chunked | disaggregated`) with
//!   conservative or eviction-based KV admission (`--preemption`),
//!   TTFT/TPOT/goodput metrics plus preemption counters, and `--sweep
//!   [--modes ...]` for the SLO-aware $/1M-token comparison across
//!   presets and scheduler modes
//! * `serve-pjrt` — run the batched-serving coordinator on a synthetic
//!   trace through PJRT (the end-to-end request path)
//!
//! `simulate`, `area`, `cost`, and `serve` are thin adapters: each builds
//! an [`eval::Scenario`] and routes it through [`eval::Evaluator`], the
//! same entry point `eval --scenario` exposes directly.

use llmcompass::eval::{self, EvalResult, Evaluator, Output, Scenario, TrafficSpec, Workload};
use llmcompass::experiments::{self, Ctx};
use llmcompass::graph::inference::Simulator;
use llmcompass::graph::layer::Phase;
use llmcompass::hardware::{config, presets, DType};
use llmcompass::perf::mapper::{Mapper, SearchBudget};
use llmcompass::util::cli::Command;
use llmcompass::util::json::Json;
use llmcompass::util::table::Table;
use llmcompass::util::telemetry::Recorder;
use std::process::ExitCode;
use std::sync::Arc;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        print_usage();
        return ExitCode::FAILURE;
    };
    let result = match cmd.as_str() {
        "hardware" => cmd_hardware(rest),
        "eval" => cmd_eval(rest),
        "tune" => cmd_tune(rest),
        "simulate" => cmd_simulate(rest),
        "area" => cmd_area(rest),
        "cost" => cmd_cost(rest),
        "experiment" => cmd_experiment(rest),
        "calibrate" => cmd_calibrate(rest),
        "serve" => cmd_serve(rest),
        "serve-pjrt" => cmd_serve_pjrt(rest),
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command `{other}`\n");
            print_usage();
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("{e}");
            ExitCode::FAILURE
        }
    }
}

fn print_usage() {
    println!(
        "llmcompass {} — hardware evaluation framework for LLM inference\n\n\
         usage: llmcompass <command> [options]\n\n\
         commands:\n\
         \x20 hardware    list/show hardware descriptions\n\
         \x20 eval        evaluate JSON scenarios (--scenario file | --suite dir)\n\
         \x20 tune        search a design space for cost-effective hardware (Pareto frontier)\n\
         \x20 simulate    simulate an operator or a transformer layer\n\
         \x20 area        die area breakdown\n\
         \x20 cost        die + memory cost\n\
         \x20 experiment  regenerate a paper table/figure\n\
         \x20 calibrate   fit a CPU device description from AOT artifacts\n\
         \x20 serve       simulate an inference cluster under traffic (--sweep for $/1M tok)\n\
         \x20 serve-pjrt  run the batched serving coordinator (PJRT)\n\n\
         run `llmcompass <command> --help` for options",
        llmcompass::VERSION
    );
}

type R = Result<(), String>;

fn err<E: std::fmt::Display>(e: E) -> String {
    format!("error: {e}")
}

// `--model` arguments resolve through `eval::model_by_name`, the same
// registry lookup (and error message) scenario files get.

const MAPPER_CACHE_HELP: &str = "persistent mapping cache: a JSON path, or `auto` for \
     $LLMCOMPASS_ARTIFACT_DIR/mapper_cache.json (created on exit; repeated runs skip searches)";

const TRACE_HELP: &str = "write a Chrome trace-event JSON here (open it in ui.perfetto.dev \
     or chrome://tracing); without this flag tracing is a no-op and costs nothing";

const MAPPER_CACHE_CAP_HELP: &str = "LRU bound on the persistent mapping cache: keep at most \
     N entries on save, evicting the least recently used (requires --mapper-cache)";

/// `--trace <path>`: build an enabled telemetry recorder, or `None` when
/// the flag is absent (every evaluator then keeps its no-op recorder).
fn trace_recorder(trace_arg: Option<&str>) -> Option<Arc<Recorder>> {
    trace_arg.map(|_| Arc::new(Recorder::enabled()))
}

/// Serialize a `--trace` recorder to its path, with an event-count note
/// on stderr so stdout report JSON stays clean.
fn write_trace(rec: Option<&Arc<Recorder>>, path: Option<&str>) -> R {
    if let (Some(rec), Some(path)) = (rec, path) {
        rec.write_chrome_trace(std::path::Path::new(path))?;
        eprintln!("[trace: {} events written to {path}]", rec.event_count());
    }
    Ok(())
}

/// Resolve a `--mapper-cache` argument: `auto` places the cache under the
/// artifact directory; anything else is used as a path verbatim.
fn mapper_cache_path(arg: &str) -> std::path::PathBuf {
    if arg == "auto" {
        experiments::default_artifact_dir().join("mapper_cache.json")
    } else {
        std::path::PathBuf::from(arg)
    }
}

/// Build an evaluator for a CLI command: `budget` picks the mapper's
/// candidate-loop mode; `--mapper-cache` backs it with the persistent
/// on-disk mapping cache, optionally LRU-bounded to `cache_cap` entries
/// (`--mapper-cache-cap`).
fn evaluator_for(budget: SearchBudget, cache: Option<&str>, cache_cap: Option<usize>) -> Evaluator {
    let mapper = match cache {
        None => Mapper::new(budget),
        Some(arg) => {
            let path = mapper_cache_path(arg);
            let mapper = match cache_cap {
                Some(cap) => Mapper::with_cache_capacity(budget, &path, cap),
                None => Mapper::with_cache(budget, &path),
            };
            if mapper.loaded_from_disk() > 0 {
                eprintln!(
                    "[mapper cache: {} mappings loaded from {}]",
                    mapper.loaded_from_disk(),
                    path.display()
                );
            }
            mapper
        }
    };
    Evaluator::with_sim(Simulator::with_mapper(mapper))
}

/// Save the evaluator's mapper cache (no-op without `--mapper-cache`),
/// reporting where it went — or why it could not be written.
fn persist_mapper_cache(ev: &Evaluator) {
    match ev.sim.mapper.persist() {
        Ok(Some(path)) => eprintln!("[mapper cache saved to {}]", path.display()),
        Ok(None) => {}
        Err(e) => eprintln!("warning: mapper cache not saved: {e}"),
    }
}

fn cmd_hardware(raw: &[String]) -> R {
    let cmd = Command::new("hardware", "list or show hardware descriptions")
        .opt("show", None, "preset name or JSON path to display")
        .opt("save", None, "write the shown system to a JSON file")
        .flag("list", "list all presets (Table I / III / IV)");
    let a = cmd.parse(raw).map_err(|e| e.0)?;
    if a.flag("list") || a.get("show").is_none() {
        let mut t = Table::new(&["name", "cores", "systolic", "mem BW", "capacity", "protocol"])
            .with_title("hardware presets (Table I devices, Table III designs, Table IV proposals)");
        for name in presets::all_device_names() {
            let d = presets::device(name).unwrap();
            t.row(vec![
                name.to_string(),
                d.core_count.to_string(),
                format!(
                    "{}x{}x{}",
                    d.core.lane_count, d.core.lane.systolic_rows, d.core.lane.systolic_cols
                ),
                format!("{:.1} TB/s", d.memory.bandwidth_bytes_per_s / 1e12),
                format!("{:.0} GB", d.memory.capacity_bytes as f64 / 1e9),
                d.memory.protocol.name().to_string(),
            ]);
        }
        println!("{}", t.render());
        println!(
            "systems: `<name>x<count>` (e.g. a100x4, ga100x8), fabric suffix `@nvlink` \
             (default) or `@pcie` (e.g. a100x4@pcie); files: any JSON path"
        );
        return Ok(());
    }
    let name = a.get("show").unwrap();
    let sys = config::resolve(name)?;
    println!("{}", sys.to_json().to_string_pretty());
    if let Some(path) = a.get("save") {
        config::save_system(&sys, std::path::Path::new(path))?;
        println!("saved to {path}");
    }
    Ok(())
}

fn cmd_eval(raw: &[String]) -> R {
    let cmd = Command::new("eval", "evaluate typed scenarios through the unified entry point")
        .opt("scenario", None, "one scenario JSON file (see scenarios/ for examples)")
        .opt("suite", None, "directory of scenario JSON files (shared mapper cache)")
        .opt(
            "threads",
            None,
            "suite fan-out: fixed worker threads with a serial per-search mapper — \
             run-to-run reproducible `mapper_rounds` stats (default: work-stealing \
             hybrid over all cores; winners identical, rounds counters may vary)",
        )
        .opt("mapper-cache", None, MAPPER_CACHE_HELP)
        .opt("mapper-cache-cap", None, MAPPER_CACHE_CAP_HELP)
        .opt("trace", None, TRACE_HELP)
        .flag("compact", "emit compact JSON instead of pretty-printed")
        .flag("pooled", "use the pooled (multi-threaded) mapper search");
    let a = cmd.parse(raw).map_err(|e| e.0)?;
    if a.get("scenario").is_some() && a.get("suite").is_some() {
        return Err("pass exactly one of --scenario and --suite".into());
    }
    if a.flag("pooled") && a.get("suite").is_some() {
        // Suites already fan out one thread per scenario; a pooled mapper
        // on top would oversubscribe cores multiplicatively.
        return Err("--pooled applies to --scenario only (suites already fan out)".into());
    }
    if a.get("threads").is_some() && a.get("scenario").is_some() {
        return Err("--threads applies to --suite only (use --pooled for one scenario)".into());
    }
    let cache = a.get("mapper-cache");
    let cache_cap = match a.get_u64("mapper-cache-cap").map_err(|e| e.0)? {
        Some(0) => return Err("--mapper-cache-cap must be ≥ 1".into()),
        Some(n) => {
            if cache.is_none() {
                return Err("--mapper-cache-cap requires --mapper-cache".into());
            }
            Some(n as usize)
        }
        None => None,
    };
    let emit = |j: &Json| {
        if a.flag("compact") {
            println!("{}", j.to_string_compact());
        } else {
            // to_string_pretty already ends with a newline.
            print!("{}", j.to_string_pretty());
        }
    };

    if let Some(path) = a.get("scenario") {
        let budget = if a.flag("pooled") { SearchBudget::pooled() } else { SearchBudget::default() };
        let mut ev = evaluator_for(budget, cache, cache_cap);
        let rec = trace_recorder(a.get("trace"));
        if let Some(r) = &rec {
            ev = ev.with_recorder(r.clone());
        }
        let sc = Scenario::load(std::path::Path::new(path))?;
        let rep = ev.evaluate(&sc)?;
        emit(&rep.to_json());
        write_trace(rec.as_ref(), a.get("trace"))?;
        persist_mapper_cache(&ev);
        return Ok(());
    }

    if let Some(dir) = a.get("suite") {
        let scenarios = eval::load_suite(std::path::Path::new(dir))?;
        let threads = match a.get_u64("threads").map_err(|e| e.0)? {
            Some(n) if n >= 1 => Some(n as usize),
            Some(_) => return Err("--threads must be ≥ 1".into()),
            None => None,
        };
        // Default fan-out is the work-stealing hybrid: scenario workers
        // and the mapper candidate loops share one process-wide worker
        // budget, so the suite's tail donates idle cores to the searches
        // still running. An explicit --threads pins a fixed pool with a
        // serial per-search loop instead.
        let budget = if threads.is_some() { SearchBudget::default() } else { SearchBudget::hybrid() };
        let mut ev = evaluator_for(budget, cache, cache_cap);
        let rec = trace_recorder(a.get("trace"));
        if let Some(r) = &rec {
            ev = ev.with_recorder(r.clone());
        }
        let start = std::time::Instant::now();
        let reports = match threads {
            Some(n) => ev.evaluate_suite(&scenarios, n),
            None => ev.evaluate_suite_shared(&scenarios),
        };
        let mut failed = 0usize;
        let items: Vec<Json> = scenarios
            .iter()
            .zip(&reports)
            .map(|(sc, rep)| match rep {
                Ok(r) => r.to_json(),
                Err(e) => {
                    failed += 1;
                    // Same schema shape as a success report (versioned,
                    // object-valued `scenario`), plus an `error` field
                    // consumers can key on.
                    llmcompass::util::json::obj(vec![
                        (
                            "schema_version",
                            llmcompass::util::json::num(eval::SCHEMA_VERSION as f64),
                        ),
                        ("scenario", sc.to_json()),
                        ("error", llmcompass::util::json::s(e)),
                    ])
                }
            })
            .collect();
        emit(&Json::Arr(items));
        let (lut_hits, lut_misses) = ev.sim.mapper.lut_stats();
        eprintln!(
            "[{} scenarios in {} | mapper: {} searches, {} rounds, {} pruned, \
             {} memo hits, {} cached shapes | systolic LUT: {} hits, {} misses]",
            scenarios.len(),
            llmcompass::util::fmt_seconds(start.elapsed().as_secs_f64()),
            ev.sim.mapper.searches(),
            ev.sim.mapper.total_rounds(),
            ev.sim.mapper.pruned_candidates(),
            ev.sim.mapper.cache_hits(),
            ev.sim.mapper.cache_len(),
            lut_hits,
            lut_misses
        );
        if let Some(path) = ev.sim.mapper.cache_path() {
            let cap = match ev.sim.mapper.cache_capacity() {
                Some(c) => format!(", LRU cap {c}"),
                None => String::new(),
            };
            eprintln!(
                "[mapper cache: {} ({} entries{cap})]",
                path.display(),
                ev.sim.mapper.cache_len()
            );
        }
        write_trace(rec.as_ref(), a.get("trace"))?;
        persist_mapper_cache(&ev);
        if failed > 0 {
            return Err(format!("{failed} of {} scenario(s) failed", scenarios.len()));
        }
        return Ok(());
    }

    Err(format!("eval needs --scenario <file> or --suite <dir>\n\n{}", cmd.help()))
}

fn cmd_tune(raw: &[String]) -> R {
    use llmcompass::tune::{self, DesignSpace, Objective, TuneOptions};
    let cmd = Command::new("tune", "search a design space for cost-effective hardware")
        .opt("scenario", None, "scenario JSON file (an optional `tune` section supplies defaults)")
        .opt(
            "space",
            None,
            "design space: a preset (smoke | section7) or a JSON file \
             (overrides the scenario's `tune.space`)",
        )
        .opt(
            "objective",
            None,
            "perf-per-dollar | goodput-per-dollar (default: the scenario's `tune.objective`, \
             else perf/$ for request workloads and goodput/$ for traffic)",
        )
        .opt(
            "constraints",
            None,
            "comma-separated feasibility caps, e.g. `area=900,power=500` \
             (die mm² / device W; override the scenario's)",
        )
        .opt(
            "tune-cache",
            None,
            "persistent design-point cache: a JSON path, or `auto` for \
             $LLMCOMPASS_ARTIFACT_DIR/tune_cache.json (keyed by design fingerprint + \
             scenario hash; repeated runs skip evaluated designs)",
        )
        .opt("mapper-cache", None, MAPPER_CACHE_HELP)
        .opt("mapper-cache-cap", None, MAPPER_CACHE_CAP_HELP)
        .opt("trace", None, TRACE_HELP)
        .flag(
            "exhaustive",
            "evaluate every feasible design instead of branch-and-bound pruning \
             (identical frontier, more work — for verification and timing)",
        )
        .flag("compact", "emit compact JSON instead of pretty-printed");
    let a = cmd.parse(raw).map_err(|e| e.0)?;
    let Some(path) = a.get("scenario") else {
        return Err(format!("tune needs --scenario <file>\n\n{}", cmd.help()));
    };
    let sc = Scenario::load(std::path::Path::new(path))?;
    let spec = sc.tune.clone();
    let space_arg = a
        .get("space")
        .map(str::to_string)
        .or_else(|| spec.as_ref().map(|t| t.space.clone()))
        .ok_or("no design space: pass --space <preset|file> or add a `tune` scenario section")?;
    let space = DesignSpace::resolve(&space_arg)?;
    let objective = match a.get("objective") {
        Some(text) => Objective::parse(text).ok_or_else(|| {
            format!("unknown --objective `{text}` (perf-per-dollar | goodput-per-dollar)")
        })?,
        None => spec
            .as_ref()
            .and_then(|t| t.objective)
            .unwrap_or_else(|| Objective::default_for(&sc.workload)),
    };
    let mut constraints = tune::Constraints {
        max_area_mm2: spec.as_ref().and_then(|t| t.max_area_mm2),
        max_power_w: spec.as_ref().and_then(|t| t.max_power_w),
    };
    if let Some(text) = a.get("constraints") {
        for part in text.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((key, val)) = part.split_once('=') else {
                return Err(format!(
                    "bad --constraints entry `{part}` (want area=<mm2> or power=<w>)"
                ));
            };
            let v: f64 = val
                .trim()
                .parse()
                .map_err(|_| format!("bad --constraints value in `{part}`"))?;
            if !v.is_finite() || v <= 0.0 {
                return Err(format!("--constraints values must be positive, got `{part}`"));
            }
            match key.trim() {
                "area" => constraints.max_area_mm2 = Some(v),
                "power" => constraints.max_power_w = Some(v),
                other => return Err(format!("unknown constraint `{other}` (area | power)")),
            }
        }
    }
    let cache = a.get("mapper-cache");
    let cache_cap = match a.get_u64("mapper-cache-cap").map_err(|e| e.0)? {
        Some(0) => return Err("--mapper-cache-cap must be ≥ 1".into()),
        Some(n) => {
            if cache.is_none() {
                return Err("--mapper-cache-cap requires --mapper-cache".into());
            }
            Some(n as usize)
        }
        None => None,
    };
    let tune_cache = a.get("tune-cache").map(|arg| {
        if arg == "auto" {
            experiments::default_artifact_dir().join("tune_cache.json")
        } else {
            std::path::PathBuf::from(arg)
        }
    });
    // The design fan-out rides the shared work-stealing pool; the hybrid
    // mapper budget lets idle design workers donate cores to whichever
    // mapper search is still running (same policy as `eval --suite`).
    let mut ev = evaluator_for(SearchBudget::hybrid(), cache, cache_cap);
    let rec = trace_recorder(a.get("trace"));
    if let Some(r) = &rec {
        ev = ev.with_recorder(r.clone());
    }
    let opts =
        TuneOptions { constraints, exhaustive: a.flag("exhaustive"), cache_path: tune_cache };
    let start = std::time::Instant::now();
    let report = tune::tune(&ev, &sc, &space, objective, &opts)?;
    let j = report.to_json();
    if a.flag("compact") {
        println!("{}", j.to_string_compact());
    } else {
        print!("{}", j.to_string_pretty());
    }
    eprintln!(
        "[tune: {} designs → {} infeasible, {} pruned, {} evaluated, {} cache hits in {} | \
         frontier {} point(s)]",
        report.designs_total,
        report.infeasible,
        report.pruned,
        report.evaluated,
        report.cache_hits,
        llmcompass::util::fmt_seconds(start.elapsed().as_secs_f64()),
        report.frontier.len()
    );
    match (&report.best, report.gain_vs_baseline()) {
        (Some(best), Some(gain)) => eprintln!(
            "[best {}: {} = {:.3e}, {:.2}x the stock `{}` baseline]",
            objective.name(),
            best.name,
            objective.value(best),
            gain,
            sc.hardware
        ),
        (Some(best), None) => eprintln!(
            "[best {}: {} = {:.3e} (no baseline to compare)]",
            objective.name(),
            best.name,
            objective.value(best)
        ),
        _ => eprintln!("[no feasible design in the space]"),
    }
    write_trace(rec.as_ref(), a.get("trace"))?;
    persist_mapper_cache(&ev);
    Ok(())
}

fn cmd_simulate(raw: &[String]) -> R {
    let cmd = Command::new("simulate", "simulate an operator or transformer workload")
        .opt("hardware", Some("a100x4"), "system preset or JSON path")
        .opt("op", None, "operator: matmul MxKxN | softmax MxN | layernorm MxN | gelu N")
        .opt("phase", Some("prefill"), "layer phase: prefill | decode | e2e")
        .opt("model", Some("gpt3-175b"), "model: gpt3-175b | gpt-small | gpt3-mqa-parallel")
        .opt("batch", Some("8"), "batch size")
        .opt("seq", Some("2048"), "input sequence length")
        .opt("out-tokens", Some("1024"), "output tokens (decode kv offset / e2e length)")
        .opt("layers", None, "layer count (default: whole model)")
        .opt("dtype", Some("fp16"), "fp32 | fp16 | bf16 | int8")
        .opt("tp", None, "tensor-parallel degree (default: all devices; tp×pp must equal them)")
        .opt("pp", None, "pipeline stages for --phase e2e (default 1)")
        .opt("microbatches", None, "pipeline microbatches for --phase e2e (default 1)")
        .opt("mapper-cache", None, MAPPER_CACHE_HELP)
        .opt("trace", None, TRACE_HELP);
    let a = cmd.parse(raw).map_err(|e| e.0)?;
    let hw = a.get_or("hardware", "a100x4");
    let mut ev = evaluator_for(SearchBudget::default(), a.get("mapper-cache"), None);
    let rec = trace_recorder(a.get("trace"));
    if let Some(r) = &rec {
        ev = ev.with_recorder(r.clone());
    }
    let dtype = DType::parse(a.get_or("dtype", "fp16")).ok_or("bad --dtype")?;

    if let Some(op_spec) = a.get("op") {
        let dims: Vec<u64> = a
            .positional
            .first()
            .map(|d| d.split('x').filter_map(|v| v.parse().ok()).collect())
            .unwrap_or_default();
        let op = match (op_spec, dims.as_slice()) {
            ("matmul", [m, k, n]) => llmcompass::perf::Op::Matmul {
                b: 1,
                m: *m,
                k: *k,
                n: *n,
                dtype,
                batched_b: false,
            },
            ("softmax", [m, n]) => llmcompass::perf::Op::Softmax { m: *m, n: *n, dtype },
            ("layernorm", [m, n]) => llmcompass::perf::Op::LayerNorm { m: *m, n: *n, dtype },
            ("gelu", [n]) => llmcompass::perf::Op::Gelu { elements: *n, dtype },
            _ => return Err("usage: simulate --op matmul 256x12288x12288".into()),
        };
        let rep = ev.evaluate(&Scenario::new("cli-op", hw, Workload::Op(op)))?;
        let EvalResult::OpLatency { op_name, result: r } = &rep.results[0] else {
            return Err("internal: op scenario produced no op latency".into());
        };
        println!(
            "{} on {}: {}  (compute bound {}, memory bound {}, roofline {:.1}%, {} mapper rounds)\n  mapping: {}",
            op_name,
            rep.system.device.name,
            llmcompass::util::fmt_seconds(r.latency_s),
            llmcompass::util::fmt_seconds(r.compute_bound_s),
            llmcompass::util::fmt_seconds(r.memory_bound_s),
            r.roofline_fraction() * 100.0,
            r.mapper_rounds,
            r.mapping_desc
        );
        write_trace(rec.as_ref(), a.get("trace"))?;
        persist_mapper_cache(&ev);
        return Ok(());
    }

    let model_name = a.get_or("model", "gpt3-175b");
    let model = eval::model_by_name(model_name)?;
    let batch = a.get_u64("batch").map_err(|e| e.0)?.unwrap();
    let seq = a.get_u64("seq").map_err(|e| e.0)?.unwrap();
    let out_tokens = a.get_u64("out-tokens").map_err(|e| e.0)?.unwrap();
    let layers = a.get_u64("layers").map_err(|e| e.0)?.unwrap_or(model.layers);
    // Explicit device mapping: any of --tp/--pp/--microbatches switches
    // the scenario onto the parallelism knobs (missing pieces default to
    // tp = remaining devices, pp = 1, microbatches = 1).
    let tp_arg = a.get_u64("tp").map_err(|e| e.0)?;
    let pp_arg = a.get_u64("pp").map_err(|e| e.0)?;
    let mb_arg = a.get_u64("microbatches").map_err(|e| e.0)?;
    let parallelism = if tp_arg.is_some() || pp_arg.is_some() || mb_arg.is_some() {
        if tp_arg == Some(0) || pp_arg == Some(0) || mb_arg == Some(0) {
            return Err("--tp/--pp/--microbatches must be ≥ 1".into());
        }
        let sys = config::resolve(hw)?;
        let pp = pp_arg.unwrap_or(1);
        let tp = tp_arg.unwrap_or_else(|| (sys.device_count / pp).max(1));
        Some(llmcompass::eval::Parallelism { tp, pp, microbatches: mb_arg.unwrap_or(1) })
    } else {
        None
    };
    let layer_scenario = |phase: Phase| {
        let sc = Scenario::new(
            "cli-layer",
            hw,
            Workload::Layer { model: model_name.to_string(), phase },
        );
        match parallelism {
            Some(p) => sc.with_parallelism(p),
            None => sc,
        }
    };
    match a.get_or("phase", "prefill") {
        "prefill" => {
            let rep = ev.evaluate(&layer_scenario(Phase::Prefill { batch, seq }))?;
            let EvalResult::LayerLatency { per_layer, .. } = &rep.results[0] else {
                return Err("internal: layer scenario produced no layer latency".into());
            };
            print_layer("prefill", per_layer, layers);
        }
        "decode" => {
            let rep =
                ev.evaluate(&layer_scenario(Phase::Decode { batch, kv_len: seq + out_tokens }))?;
            let EvalResult::LayerLatency { per_layer, .. } = &rep.results[0] else {
                return Err("internal: layer scenario produced no layer latency".into());
            };
            print_layer("decode", per_layer, layers);
        }
        "e2e" => {
            let mut sc = Scenario::new(
                "cli-e2e",
                hw,
                Workload::Request {
                    model: model_name.to_string(),
                    batch,
                    prefill: seq,
                    decode: out_tokens,
                    layers: Some(layers),
                },
            );
            if let Some(p) = parallelism {
                sc = sc.with_parallelism(p);
            }
            let rep = ev.evaluate(&sc)?;
            let EvalResult::RequestLatency { total_s, .. } = &rep.results[0] else {
                return Err("internal: request scenario produced no latency".into());
            };
            let t = *total_s;
            let mapping = match parallelism {
                Some(p) => format!(" (tp={} pp={} mb={})", p.tp, p.pp, p.microbatches),
                None => String::new(),
            };
            println!(
                "end-to-end {} layers, b={batch}, in={seq}, out={out_tokens}{mapping}: {} \
                 ({:.2} tok/s/request)",
                layers,
                llmcompass::util::fmt_seconds(t),
                out_tokens as f64 / t
            );
        }
        other => return Err(format!("unknown phase `{other}`")),
    }
    write_trace(rec.as_ref(), a.get("trace"))?;
    persist_mapper_cache(&ev);
    Ok(())
}

fn print_layer(phase: &str, rep: &llmcompass::graph::inference::LayerReport, layers: u64) {
    let title =
        format!("{phase} latency per layer: {}", llmcompass::util::fmt_seconds(rep.total_s));
    let mut t = Table::new(&["operator", "latency", "share %"]).with_title(&title);
    for (name, s) in &rep.breakdown {
        t.row(vec![
            name.to_string(),
            llmcompass::util::fmt_seconds(*s),
            format!("{:.1}", s / rep.total_s * 100.0),
        ]);
    }
    println!("{}", t.render());
    println!(
        "× {layers} layers = {}",
        llmcompass::util::fmt_seconds(rep.total_s * layers as f64)
    );
}

fn cmd_area(raw: &[String]) -> R {
    let cmd = Command::new("area", "die area breakdown")
        .opt("hardware", Some("ga100"), "device preset or JSON path")
        .flag("params", "print the Table II component parameters");
    let a = cmd.parse(raw).map_err(|e| e.0)?;
    if a.flag("params") {
        let p = llmcompass::area::AreaParams::default();
        let mut t = Table::new(&["parameter", "7nm area (µm²)"])
            .with_title("Table II — area model parameters");
        for (k, v) in [
            ("64-bit FPU", p.fp64_unit_um2),
            ("32-bit FPU", p.fp32_unit_um2),
            ("32-bit int ALU", p.int32_alu_um2),
            ("FP16 systolic MAC", p.fp16_mac_um2),
            ("per-lane overhead", p.lane_overhead_um2),
            ("per-core overhead", p.core_overhead_um2),
            ("1024-bit HBM2e control", p.hbm_ctrl_um2),
            ("1024-bit HBM2e PHY", p.hbm_phy_um2),
            ("PCIe 5.0 channel", p.pcie5_channel_um2),
            ("NVLink-class link", p.nvlink_um2),
        ] {
            t.row(vec![k.to_string(), format!("{v:.0}")]);
        }
        println!("{}", t.render());
        return Ok(());
    }
    let ev = Evaluator::new();
    let sc = Scenario::new("cli-area", a.get_or("hardware", "ga100"), Workload::Hardware)
        .with_outputs(&[Output::Area]);
    let rep = ev.evaluate(&sc)?;
    let EvalResult::Area(b) = &rep.results[0] else {
        return Err("internal: area scenario produced no area breakdown".into());
    };
    let title = format!("die breakdown: {}", rep.system.device.name);
    let mut t = Table::new(&["component", "mm²", "share %"]).with_title(&title);
    for (name, v) in b.rows() {
        t.row(vec![
            name.to_string(),
            format!("{v:.1}"),
            format!("{:.1}", v / b.total_mm2() * 100.0),
        ]);
    }
    t.row(vec!["TOTAL".into(), format!("{:.1}", b.total_mm2()), "100".into()]);
    println!("{}", t.render());
    Ok(())
}

fn cmd_cost(raw: &[String]) -> R {
    let cmd = Command::new("cost", "die + memory cost").opt(
        "hardware",
        Some("ga100"),
        "device preset or JSON path",
    );
    let a = cmd.parse(raw).map_err(|e| e.0)?;
    let ev = Evaluator::new();
    let sc = Scenario::new("cli-cost", a.get_or("hardware", "ga100"), Workload::Hardware)
        .with_outputs(&[Output::Cost]);
    let rep = ev.evaluate(&sc)?;
    let EvalResult::Cost(c) = &rep.results[0] else {
        return Err("internal: cost scenario produced no cost report".into());
    };
    let p = &ev.cost_params;
    println!(
        "{}: die {:.0} mm² → yield {:.1}%, {:.0} gross dies/wafer, die ${:.0}; memory ${:.0}; total ${:.0}",
        rep.system.device.name,
        c.die_mm2,
        llmcompass::cost::murphy_yield(p, c.die_mm2) * 100.0,
        llmcompass::cost::dies_per_wafer(p, c.die_mm2),
        c.die_cost_usd,
        c.memory_cost_usd,
        c.total_usd()
    );
    Ok(())
}

fn cmd_experiment(raw: &[String]) -> R {
    let cmd = Command::new("experiment", "regenerate a paper table/figure")
        .flag("list", "list experiment ids")
        .flag("quick", "trimmed sweeps (smoke test)")
        .flag("all", "run every experiment")
        .opt(
            "artifact-dir",
            None,
            "artifact directory for fig5 (default: $LLMCOMPASS_ARTIFACT_DIR or ./artifacts)",
        )
        .opt("artifacts", None, "alias for --artifact-dir");
    let a = cmd.parse(raw).map_err(|e| e.0)?;
    if a.flag("list") || (a.positional.is_empty() && !a.flag("all")) {
        let mut t = Table::new(&["id", "description"]).with_title("experiments");
        for (id, desc, _) in experiments::registry() {
            t.row(vec![id.to_string(), desc.to_string()]);
        }
        println!("{}", t.render());
        return Ok(());
    }
    let mut ctx = Ctx::new(a.flag("quick"));
    if let Some(dir) = a.get("artifact-dir").or_else(|| a.get("artifacts")) {
        ctx.artifact_dir = std::path::PathBuf::from(dir);
    }
    let ids: Vec<String> = if a.flag("all") {
        experiments::registry().iter().map(|(n, _, _)| n.to_string()).collect()
    } else {
        a.positional.clone()
    };
    for id in &ids {
        let start = std::time::Instant::now();
        match experiments::run(id, &ctx) {
            Ok(report) => {
                println!("{report}");
                println!(
                    "[{id} done in {} | mapper: {} rounds total, {} cached shapes]\n",
                    llmcompass::util::fmt_seconds(start.elapsed().as_secs_f64()),
                    ctx.sim().mapper.total_rounds(),
                    ctx.sim().mapper.cache_len()
                );
            }
            Err(e) => eprintln!("[{id}] failed: {e:#}"),
        }
    }
    Ok(())
}

fn cmd_calibrate(raw: &[String]) -> R {
    let cmd = Command::new("calibrate", "fit a CPU device description from artifacts")
        .opt(
            "artifacts",
            None,
            "artifact directory (default: $LLMCOMPASS_ARTIFACT_DIR or ./artifacts)",
        )
        .opt("out", Some("hardware/cpu.json"), "output JSON path")
        .opt("iters", Some("3"), "timing iterations per artifact");
    let a = cmd.parse(raw).map_err(|e| e.0)?;
    let iters = a.get_u64("iters").map_err(|e| e.0)?.unwrap() as usize;
    let artifact_dir = a
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(experiments::default_artifact_dir);
    let (meas, dev) = llmcompass::calibrate::calibrate(
        &artifact_dir,
        std::path::Path::new(a.get_or("out", "hardware/cpu.json")),
        iters,
    )
    .map_err(err)?;
    let mut t = Table::new(&["artifact", "seconds", "GFLOP/s", "GB/s"])
        .with_title("measured operators (PJRT CPU)");
    for m in &meas {
        t.row(vec![
            m.name.clone(),
            llmcompass::util::fmt_seconds(m.seconds),
            format!("{:.2}", m.flops / m.seconds / 1e9),
            format!("{:.2}", m.bytes / m.seconds / 1e9),
        ]);
    }
    println!("{}", t.render());
    println!(
        "fitted cpu device: {} cores, systolic {}x{}, matrix peak {:.1} GFLOP/s, bw {:.2} GB/s, launch {:.1} us\nwrote {}",
        dev.core_count,
        dev.core.lane.systolic_rows,
        dev.core.lane.systolic_cols,
        dev.peak_matrix_flops() / 1e9,
        dev.memory.bandwidth_bytes_per_s / 1e9,
        dev.launch_overhead_s * 1e6,
        a.get_or("out", "hardware/cpu.json")
    );
    Ok(())
}

fn cmd_serve(raw: &[String]) -> R {
    let cmd = Command::new("serve", "simulate an inference cluster under traffic")
        .opt("hardware", Some("a100x8"), "system preset or JSON path")
        .opt("model", Some("gpt3-175b"), "model: gpt3-175b | gpt-small | gpt3-mqa-parallel")
        .opt("requests", Some("1000"), "number of requests in the trace")
        .opt("rate", Some("2.0"), "mean arrival rate, requests/second")
        .opt("arrival", Some("poisson"), "arrival process: poisson | bursty")
        .opt("burst-mult", Some("8.0"), "bursty: rate multiplier in the burst state")
        .opt("replay", None, "replay an arrival trace file (`arrival_s,prompt,output` lines)")
        .opt("policy", Some("fcfs"), "admission policy: fcfs | spf")
        .opt("max-batch", Some("64"), "max concurrent sequences")
        .opt(
            "mode",
            Some("monolithic"),
            "scheduler mode: monolithic | chunked | disaggregated",
        )
        .opt("chunk-tokens", Some("2048"), "chunked: per-iteration token budget")
        .opt(
            "prefill-devices",
            Some("0"),
            "disaggregated: devices in the prefill pool (0 = half the system)",
        )
        .opt(
            "transfer-base-s",
            Some("0.001"),
            "disaggregated: base KV-handoff latency, seconds (plus modeled link time)",
        )
        .opt("preemption", Some("conservative"), "KV admission: conservative | evict")
        .opt("max-kv-tokens", None, "clamp the derived KV budget (forces preemption pressure)")
        .opt(
            "handoff-capacity",
            None,
            "disaggregated: max sequences queued between the pools — the prefill pool \
             stalls when full (default: decode-pool KV budget in sequences)",
        )
        .opt("slo-ttft", Some("2.0"), "SLO: max time-to-first-token, seconds")
        .opt("slo-tpot", Some("0.1"), "SLO: max time-per-output-token, seconds")
        .opt("seed", Some("42"), "workload seed")
        .opt(
            "replicas",
            Some("1"),
            "data-parallel fleet size — each replica is a full copy of the system \
             behind the load balancer (1 = the single-engine path)",
        )
        .opt(
            "balancer",
            Some("round_robin"),
            "fleet load balancer: round_robin | least_kv_pressure | session_affinity",
        )
        .opt(
            "diurnal-period-s",
            None,
            "modulate the arrival rate with a raised-cosine diurnal cycle of this \
             period, seconds (requires --diurnal-peak)",
        )
        .opt(
            "diurnal-peak",
            None,
            "diurnal: peak rate multiplier at the top of the cycle (trough stays \
             at the base rate)",
        )
        .opt(
            "flash-at-s",
            None,
            "flash crowd: multiply the arrival rate from this time (requires \
             --flash-duration-s and --flash-mult)",
        )
        .opt("flash-duration-s", None, "flash crowd: window length, seconds")
        .opt("flash-mult", None, "flash crowd: rate multiplier inside the window")
        .opt(
            "fault-spec",
            None,
            "fault-injection spec JSON file (the scenario `faults` object: seed, \
             events, mtbf_s/mtbf_hours, recovery)",
        )
        .opt(
            "fault-mtbf-hours",
            None,
            "inject seeded MTBF-driven crash faults with this mean time between \
             failures in hours (with --sweep: comma-separated list of MTBF points, \
             each swept alongside the fault-free baseline)",
        )
        .opt(
            "fault-mttr-s",
            Some("30.0"),
            "mean time to recovery for --fault-mtbf-hours faults, seconds",
        )
        .opt(
            "fault-seed",
            None,
            "fault RNG seed — an independent stream from the workload seed \
             (default: --seed; also overrides the seed in --fault-spec)",
        )
        .flag(
            "sweep",
            "run the SLO-aware $/1M-token sweep across the paper's preset ladder \
             (uses --model/--requests/--policy/--modes/--preemption/--slo-*/--seed; \
             ignores --hardware, --rate and the arrival options)",
        )
        .opt(
            "modes",
            Some("monolithic"),
            "sweep: comma-separated scheduler modes to compare on every system \
             (monolithic,chunked,disaggregated; knob flags above apply)",
        )
        .opt(
            "fleet-sizes",
            None,
            "sweep: comma-separated replica counts to add as a fleet-size axis \
             (cluster cost scales with the count; default 1)",
        )
        .opt(
            "systems",
            None,
            "sweep: comma-separated system presets to sweep instead of the \
             paper's preset ladder",
        )
        .flag("pooled", "use the pooled (multi-threaded) mapper search")
        .opt("mapper-cache", None, MAPPER_CACHE_HELP)
        .opt("trace", None, TRACE_HELP);
    let a = cmd.parse(raw).map_err(|e| e.0)?;
    let model_name = a.get_or("model", "gpt3-175b");
    let model = eval::model_by_name(model_name)?;
    let slo = llmcompass::serve::Slo {
        ttft_s: a.get_f64("slo-ttft").map_err(|e| e.0)?.unwrap(),
        tpot_s: a.get_f64("slo-tpot").map_err(|e| e.0)?.unwrap(),
    };
    let requests_n = a.get_u64("requests").map_err(|e| e.0)?.unwrap() as usize;
    let seed = a.get_u64("seed").map_err(|e| e.0)?.unwrap();
    let policy = llmcompass::serve::Policy::parse(a.get_or("policy", "fcfs"))
        .ok_or("bad --policy (fcfs | spf)")?;
    let preemption = llmcompass::serve::Preemption::parse(a.get_or("preemption", "conservative"))
        .ok_or("bad --preemption (conservative | evict)")?;
    let chunk_tokens = a.get_u64("chunk-tokens").map_err(|e| e.0)?.unwrap();
    let prefill_devices = a.get_u64("prefill-devices").map_err(|e| e.0)?.unwrap();
    let transfer_base_s = a.get_f64("transfer-base-s").map_err(|e| e.0)?.unwrap();
    let mode_of = |name: &str| -> Result<llmcompass::serve::ServeMode, String> {
        use llmcompass::serve::ServeMode;
        match name {
            "monolithic" => Ok(ServeMode::Monolithic),
            "chunked" => Ok(ServeMode::Chunked { chunk_tokens }),
            "disaggregated" => Ok(ServeMode::Disaggregated { prefill_devices, transfer_base_s }),
            other => Err(format!("bad mode `{other}` (monolithic | chunked | disaggregated)")),
        }
    };
    let budget = if a.flag("pooled") { SearchBudget::pooled() } else { SearchBudget::default() };
    let mut ev = evaluator_for(budget, a.get("mapper-cache"), None);
    let rec = trace_recorder(a.get("trace"));
    if let Some(r) = &rec {
        ev = ev.with_recorder(r.clone());
    }
    let start = std::time::Instant::now();

    if a.flag("sweep") {
        if a.get("replay").is_some() {
            return Err("--sweep generates its own workloads; drop --replay".into());
        }
        if a.get("fault-spec").is_some() {
            return Err("--sweep injects faults via --fault-mtbf-hours; drop --fault-spec".into());
        }
        let mut cfg = llmcompass::serve::sweep::SweepConfig::paper_default(requests_n, slo);
        cfg.seed = seed;
        cfg.policy = policy;
        cfg.preemption = preemption;
        cfg.modes = a
            .get_or("modes", "monolithic")
            .split(',')
            .map(|m| mode_of(m.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        if let Some(list) = a.get("fault-mtbf-hours") {
            cfg.fault_mtbf_hours = list
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<f64>()
                        .map_err(|_| format!("bad --fault-mtbf-hours entry `{}`", s.trim()))
                })
                .collect::<Result<Vec<_>, _>>()?;
            cfg.fault_mttr_s = a.get_f64("fault-mttr-s").map_err(|e| e.0)?.unwrap();
        }
        if let Some(list) = a.get("fleet-sizes") {
            cfg.fleet_sizes = list
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<u64>()
                        .map_err(|_| format!("bad --fleet-sizes entry `{}`", s.trim()))
                })
                .collect::<Result<Vec<_>, _>>()?;
        }
        if let Some(list) = a.get("systems") {
            cfg.systems = list.split(',').map(|s| s.trim().to_string()).collect();
        }
        let rows = llmcompass::serve::sweep::run_sweep(&ev.sim, &model, &cfg)?;
        let mut t = Table::new(&[
            "system", "mode", "repl", "rate/s", "MTBF h", "avail %", "TTFT mean",
            "goodput tok/s", "SLO %", "preempt", "$/1M tok",
        ])
        .with_title("SLO-aware serving sweep");
        for r in &rows {
            t.row(vec![
                r.system.clone(),
                r.mode.to_string(),
                r.replicas.to_string(),
                format!("{:.1}", r.rate_per_s),
                match r.mtbf_hours {
                    Some(h) => format!("{h:.2}"),
                    None => "-".into(),
                },
                format!("{:.2}", r.availability * 100.0),
                llmcompass::util::fmt_seconds(r.summary.ttft_mean_s),
                format!("{:.1}", r.summary.goodput_tok_s),
                format!("{:.1}", r.summary.slo_attainment * 100.0),
                r.preemptions.to_string(),
                if r.usd_per_mtok.is_finite() {
                    format!("{:.3}", r.usd_per_mtok)
                } else {
                    "inf".into()
                },
            ]);
        }
        println!("{}", t.render());
        println!("best per system/mode/fleet ($/1M output tokens at SLO):");
        for b in llmcompass::serve::sweep::best_per_system(&rows) {
            println!(
                "  {:<24} {:<14} x{:<3} {:>10} at {:.1} req/s",
                b.system,
                b.mode,
                b.replicas,
                if b.usd_per_mtok.is_finite() {
                    format!("${:.3}", b.usd_per_mtok)
                } else {
                    "unserved".into()
                },
                b.rate_per_s
            );
        }
        // Key=value so scripts (and the CI sweep smoke) can grep the fields;
        // cross-cell reuse of the shared oracle shows up as hits > 0.
        let osnap = ev.sim.oracles.snapshot();
        println!(
            "oracle: sim_calls={} hits={} misses={} decode_fits={} prefill_points={} oracles={}",
            osnap.sim_calls,
            osnap.hits,
            osnap.misses,
            osnap.decode_fits,
            osnap.prefill_points,
            ev.sim.oracles.len()
        );
        println!("[swept in {}]", llmcompass::util::fmt_seconds(start.elapsed().as_secs_f64()));
        write_trace(rec.as_ref(), a.get("trace"))?;
        persist_mapper_cache(&ev);
        return Ok(());
    }

    let hw = a.get_or("hardware", "a100x8");
    let sys = config::resolve(hw)?;
    let rate = a.get_f64("rate").map_err(|e| e.0)?.unwrap();
    if !rate.is_finite() || rate <= 0.0 {
        return Err(format!("--rate must be a positive number, got {rate}"));
    }
    let fault_seed = a.get_u64("fault-seed").map_err(|e| e.0)?;
    let faults: Option<llmcompass::serve::FaultSpec> = match (a.get("fault-spec"), a.get_f64("fault-mtbf-hours").map_err(|e| e.0)?) {
        (Some(_), Some(_)) => {
            return Err("pass either --fault-spec or --fault-mtbf-hours, not both".into())
        }
        (Some(path), None) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| format!("read fault spec {path}: {e}"))?;
            let v = llmcompass::util::json::Json::parse(&text)
                .map_err(|e| format!("{path}: {e}"))?;
            let mut spec = llmcompass::serve::FaultSpec::from_json(&v)
                .map_err(|e| format!("{path}: {e}"))?;
            if let Some(fs) = fault_seed {
                spec.seed = fs;
            }
            Some(spec)
        }
        (None, Some(h)) => {
            if !h.is_finite() || h <= 0.0 {
                return Err(format!("--fault-mtbf-hours must be positive, got {h}"));
            }
            let mttr = a.get_f64("fault-mttr-s").map_err(|e| e.0)?.unwrap();
            Some(llmcompass::serve::FaultSpec::mtbf(
                fault_seed.unwrap_or(seed),
                h * 3600.0,
                mttr,
            ))
        }
        (None, None) => None,
    };
    let fault_run = faults.is_some();
    let replicas = a.get_u64("replicas").map_err(|e| e.0)?.unwrap();
    if replicas == 0 {
        return Err("--replicas must be ≥ 1".into());
    }
    let balancer = llmcompass::serve::Balancer::parse(a.get_or("balancer", "round_robin"))
        .ok_or("bad --balancer (round_robin | least_kv_pressure | session_affinity)")?;
    let diurnal = match (
        a.get_f64("diurnal-period-s").map_err(|e| e.0)?,
        a.get_f64("diurnal-peak").map_err(|e| e.0)?,
    ) {
        (Some(period_s), Some(peak_multiplier)) => {
            Some(llmcompass::serve::Diurnal { period_s, peak_multiplier })
        }
        (None, None) => None,
        _ => return Err("--diurnal-period-s and --diurnal-peak must be passed together".into()),
    };
    let flash_crowd = match (
        a.get_f64("flash-at-s").map_err(|e| e.0)?,
        a.get_f64("flash-duration-s").map_err(|e| e.0)?,
        a.get_f64("flash-mult").map_err(|e| e.0)?,
    ) {
        (Some(at_s), Some(duration_s), Some(multiplier)) => {
            Some(llmcompass::serve::FlashCrowd { at_s, duration_s, multiplier })
        }
        (None, None, None) => None,
        _ => {
            return Err(
                "--flash-at-s, --flash-duration-s and --flash-mult must be passed together".into()
            )
        }
    };
    let traffic = TrafficSpec {
        model: model_name.to_string(),
        requests: requests_n,
        rate_per_s: rate,
        burst_multiplier: if a.get_or("arrival", "poisson") == "bursty" {
            Some(a.get_f64("burst-mult").map_err(|e| e.0)?.unwrap())
        } else {
            None
        },
        trace: a.get("replay").map(str::to_string),
        policy,
        max_batch: a.get_u64("max-batch").map_err(|e| e.0)?.unwrap(),
        mode: mode_of(a.get_or("mode", "monolithic"))?,
        preemption,
        max_kv_tokens: a.get_u64("max-kv-tokens").map_err(|e| e.0)?,
        handoff_capacity: a.get_u64("handoff-capacity").map_err(|e| e.0)?,
        slo,
        seed,
        faults,
        replicas,
        balancer,
        diurnal,
        flash_crowd,
    };
    // Materialize the trace up front so the fit checks and the preamble
    // banner run before the (slow) simulation, matching the historical
    // CLI behavior. The evaluator materializes its own copy: generated
    // workloads are deterministic in the seed; `--trace` files are read
    // twice, so edits between the reads can slip past these checks (the
    // evaluator re-checks and errors rather than misbehaving).
    let trace = eval::traffic_requests(&traffic)?;
    let sched = eval::scheduler_config_for(&sys, &model, &traffic)?;
    let fleet = llmcompass::serve::FleetConfig { replicas, balancer };
    llmcompass::serve::validate_fleet(&sched, sys.device_count, &fleet, &trace)?;
    let fleet_note = if replicas > 1 {
        format!(", {replicas} replicas via {}", balancer.name())
    } else {
        String::new()
    };
    println!(
        "serving {} requests of {} on {} x{} (mode {}, policy {policy:?}, preemption {}, \
         KV budget {} tokens{fleet_note})…",
        trace.len(),
        model.name,
        sys.device.name,
        sys.device_count,
        sched.mode.name(),
        sched.preemption.name(),
        sched.kv_capacity_tokens
    );
    let rep = ev.evaluate(&Scenario::new("cli-serve", hw, Workload::Traffic(traffic)))?;
    let EvalResult::Serving(sr) = &rep.results[0] else {
        return Err("internal: traffic scenario produced no serving report".into());
    };
    println!("{}", sr.summary.render());
    let stats = &sr.stats;
    println!(
        "iterations: {} prefill ({}) + {} decode ({}) + {} mixed ({}) | idle {} | \
         peak batch {} | peak KV {} tokens",
        stats.prefill_iterations,
        llmcompass::util::fmt_seconds(stats.prefill_busy_s),
        stats.decode_iterations,
        llmcompass::util::fmt_seconds(stats.decode_busy_s),
        stats.mixed_iterations,
        llmcompass::util::fmt_seconds(stats.mixed_busy_s),
        llmcompass::util::fmt_seconds(stats.idle_s),
        stats.peak_batch,
        stats.peak_kv_tokens
    );
    println!(
        "preemption: {} events over {} requests ({} recompute tokens) | transfer {} | \
         handoff wait {} | handoff stall {}",
        stats.preemptions,
        stats.preempted_requests,
        stats.recompute_tokens,
        llmcompass::util::fmt_seconds(stats.transfer_total_s),
        llmcompass::util::fmt_seconds(stats.handoff_wait_s),
        llmcompass::util::fmt_seconds(stats.handoff_stall_s)
    );
    for (i, rs) in sr.replica_stats.iter().enumerate() {
        println!(
            "replica {i}: {} prefill + {} decode + {} mixed iterations | makespan {} | \
             peak KV {} tokens | downtime {}",
            rs.prefill_iterations,
            rs.decode_iterations,
            rs.mixed_iterations,
            llmcompass::util::fmt_seconds(rs.makespan_s),
            rs.peak_kv_tokens,
            llmcompass::util::fmt_seconds(rs.fault_downtime_s)
        );
    }
    if fault_run {
        // Key=value so scripts (and the CI fault smoke) can grep the fields.
        println!(
            "faults: injected={} lost={} retried={} shed={} retry_tokens_recomputed={} \
             downtime_s={:.3} availability={:.6}",
            stats.faults_injected,
            stats.requests_lost,
            stats.requests_retried,
            stats.requests_shed,
            stats.retry_tokens_recomputed,
            stats.fault_downtime_s,
            stats.availability
        );
    }
    // Key=value like the faults line above, so scripts can grep the fields.
    let osnap = ev.sim.oracles.snapshot();
    println!(
        "oracle: sim_calls={} hits={} misses={} decode_fits={} prefill_points={} oracles={}",
        osnap.sim_calls,
        osnap.hits,
        osnap.misses,
        osnap.decode_fits,
        osnap.prefill_points,
        ev.sim.oracles.len()
    );
    println!(
        "[simulated in {} wall-clock | mapper: {} rounds, {} cached shapes]",
        llmcompass::util::fmt_seconds(start.elapsed().as_secs_f64()),
        ev.sim.mapper.total_rounds(),
        ev.sim.mapper.cache_len()
    );
    write_trace(rec.as_ref(), a.get("trace"))?;
    persist_mapper_cache(&ev);
    Ok(())
}

fn cmd_serve_pjrt(raw: &[String]) -> R {
    let cmd = Command::new("serve-pjrt", "run the batched serving coordinator over PJRT")
        .opt(
            "artifacts",
            None,
            "artifact directory (default: $LLMCOMPASS_ARTIFACT_DIR or ./artifacts)",
        )
        .opt("requests", Some("16"), "number of synthetic requests")
        .opt("max-out", Some("8"), "max output tokens per request")
        .opt("policy", Some("fifo"), "batching policy: fifo | sjf")
        .opt("seed", Some("42"), "trace seed");
    let a = cmd.parse(raw).map_err(|e| e.0)?;
    let artifact_dir = a
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(experiments::default_artifact_dir);
    let mut coord = llmcompass::coordinator::Coordinator::new(&artifact_dir).map_err(err)?;
    let n = a.get_u64("requests").map_err(|e| e.0)?.unwrap() as usize;
    let max_out = a.get_u64("max-out").map_err(|e| e.0)?.unwrap() as usize;
    let seed = a.get_u64("seed").map_err(|e| e.0)?.unwrap();
    let policy = match a.get_or("policy", "fifo") {
        "fifo" => llmcompass::coordinator::queue::Policy::Fifo,
        "sjf" => llmcompass::coordinator::queue::Policy::ShortestFirst,
        other => return Err(format!("unknown policy `{other}`")),
    };
    let trace = llmcompass::coordinator::queue::synthetic_trace(
        n,
        coord.vocab() as i32,
        coord.prefill_seq,
        max_out,
        seed,
    );
    let trace = llmcompass::coordinator::queue::order(trace, policy);
    println!(
        "serving {n} requests (batch={}, prefill seq={}, policy={policy:?}) on PJRT CPU…",
        coord.batch, coord.prefill_seq
    );
    let rep = coord.serve(&trace).map_err(err)?;
    println!(
        "generated {} tokens in {:.2}s → {:.2} tok/s | prefill {:.2}s decode {:.2}s | latency p50 {:.2}s p95 {:.2}s",
        rep.tokens_generated,
        rep.total_s,
        rep.tokens_per_s(),
        rep.prefill_s,
        rep.decode_s,
        rep.latency_percentile(50.0),
        rep.latency_percentile(95.0),
    );
    Ok(())
}
