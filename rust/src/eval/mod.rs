//! The unified scenario API: one typed, serializable entry point for
//! perf, cost, area, and serving evaluations.
//!
//! The framework's versatility used to be spread across four disjoint
//! entry points (the simulator's positional-arg methods, the serving
//! sweep's config struct, free functions in `cost`/`area`, and the
//! experiment context). This module gives them a single front door:
//!
//! * [`Scenario`] — a typed description of *what to evaluate*: a hardware
//!   target (preset name, `<name>xN` system, or JSON file), a workload
//!   (operator, Transformer layer, end-to-end request, arbitrary operator
//!   graph, or serving traffic), an optional `{tp, pp, microbatches}`
//!   device mapping, and the requested [`Output`]s. Builder-constructed
//!   in code or loaded from JSON; `to_json`/`parse` round-trip
//!   losslessly.
//! * [`Evaluator`] — turns scenarios into [`EvalReport`]s with a stable
//!   JSON schema, routing each output through the right model (mapper +
//!   graph simulation, area, cost, or the serving simulator). One
//!   evaluator owns one simulator, so mapper searches are cached *across*
//!   scenarios: suites that revisit shapes do strictly fewer searches
//!   than independent runs.
//! * [`load_suite`] — a directory of `*.json` scenarios as one suite,
//!   evaluated by [`Evaluator::evaluate_suite`] across the thread pool.
//!
//! The CLI's `simulate` / `area` / `cost` / `serve` subcommands are thin
//! adapters over this module, and `llmcompass eval --scenario file.json`
//! / `--suite dir/` expose it directly.

pub mod evaluator;
pub mod scenario;

pub use evaluator::{
    load_suite, model_by_name, scheduler_config_for, traffic_requests, EvalReport, EvalResult,
    Evaluator, ServingReport, TelemetrySummary, SCHEMA_VERSION, TELEMETRY_SCHEMA_VERSION,
};
pub use crate::graph::ir::Parallelism;
pub use scenario::{build_graph, GraphNodeSpec, Output, Scenario, TrafficSpec, TuneSpec, Workload};
