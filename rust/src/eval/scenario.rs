//! The [`Scenario`] type: a typed, JSON-serializable description of one
//! evaluation — a hardware target, a workload, and the requested outputs.
//!
//! A scenario names its hardware the same way the CLI does (a preset like
//! `a100`, a system like `ga100x8`, or a JSON file path), picks one of six
//! workload kinds, and lists the outputs it wants:
//!
//! | workload   | meaning                                              |
//! |------------|------------------------------------------------------|
//! | `hardware` | no workload — hardware-only outputs (area, cost)     |
//! | `op`       | one operator (matmul / softmax / layernorm / gelu …) |
//! | `layer`    | one Transformer layer at a prefill/decode phase      |
//! | `request`  | one end-to-end request (prefill + decode tokens)     |
//! | `graph`    | an arbitrary operator DAG (named nodes + edges)      |
//! | `traffic`  | an open-loop trace through the serving simulator     |
//!
//! A scenario may also carry a `parallelism` object (`{tp, pp,
//! microbatches}`) mapping the workload onto the system's devices:
//! `tp`-way tensor parallelism inside each of `pp` pipeline stages, with
//! requests split into `microbatches`. Absent, the historical default
//! applies (tensor parallelism across all devices).
//!
//! Scenarios are built with the struct constructors here or parsed from
//! JSON (`Scenario::parse` / `Scenario::load`); `to_json` round-trips
//! losslessly, which the tests assert both structurally and by evaluating
//! the reparsed scenario to identical numbers.

use crate::graph::ir::{self, Parallelism};
use crate::graph::layer::Phase;
use crate::hardware::DType;
use crate::perf::Op;
use crate::serve::{
    Balancer, Diurnal, FaultSpec, FlashCrowd, Policy, Preemption, ServeMode, Slo,
};
use crate::util::json::{num, obj, s, Json, JsonError};

fn jerr(e: JsonError) -> String {
    e.to_string()
}

/// Reject object keys outside `allowed`, naming the offending key — a
/// typo'd knob must fail loudly instead of silently running a different
/// experiment with the default value ([`Scenario::load`] prefixes the
/// scenario file path).
fn check_known_fields(v: &Json, allowed: &[&str], ctx: &str) -> Result<(), String> {
    if let Some(m) = v.as_obj() {
        for k in m.keys() {
            if !allowed.contains(&k.as_str()) {
                return Err(format!(
                    "unknown {ctx} field `{k}` (allowed: {})",
                    allowed.join(", ")
                ));
            }
        }
    }
    Ok(())
}

/// Top-level keys a scenario file may carry.
const SCENARIO_KEYS: &[&str] = &["name", "hardware", "workload", "parallelism", "outputs", "tune"];
/// Keys of a `traffic` workload object.
const TRAFFIC_KEYS: &[&str] = &[
    "type",
    "model",
    "requests",
    "rate_per_s",
    "burst_multiplier",
    "trace",
    "policy",
    "max_batch",
    "mode",
    "chunk_tokens",
    "prefill_devices",
    "transfer_base_s",
    "preemption",
    "max_kv_tokens",
    "handoff_capacity",
    "slo",
    "seed",
    "faults",
    "replicas",
    "balancer",
    "diurnal",
    "flash_crowd",
];

/// Optional-field accessors that error when the key is present but has
/// the wrong type — in a hand-written schema, silently falling back to a
/// default on a typo'd value is worse than rejecting the file.
fn opt_u64(v: &Json, key: &str) -> Result<Option<u64>, String> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => match x.as_u64() {
            Some(n) => Ok(Some(n)),
            None => Err(format!("`{key}` must be a non-negative integer")),
        },
    }
}

fn opt_f64(v: &Json, key: &str) -> Result<Option<f64>, String> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => match x.as_f64() {
            Some(n) => Ok(Some(n)),
            None => Err(format!("`{key}` must be a number")),
        },
    }
}

fn opt_bool(v: &Json, key: &str) -> Result<Option<bool>, String> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => match x.as_bool() {
            Some(b) => Ok(Some(b)),
            None => Err(format!("`{key}` must be a boolean")),
        },
    }
}

fn opt_str<'a>(v: &'a Json, key: &str) -> Result<Option<&'a str>, String> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => match x.as_str() {
            Some(s2) => Ok(Some(s2)),
            None => Err(format!("`{key}` must be a string")),
        },
    }
}

/// One requested output of a scenario evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Output {
    /// Operator / layer / request latency (op, layer, request workloads).
    Latency,
    /// Request-level generation throughput (request workloads).
    Throughput,
    /// Die-area breakdown of the device (any workload).
    Area,
    /// Die + memory cost of the device (any workload).
    Cost,
    /// Serving metrics under traffic: TTFT/TPOT tails, goodput,
    /// $/1M-tokens-at-SLO (traffic workloads).
    Serving,
}

impl Output {
    pub fn name(self) -> &'static str {
        match self {
            Output::Latency => "latency",
            Output::Throughput => "throughput",
            Output::Area => "area",
            Output::Cost => "cost",
            Output::Serving => "serving",
        }
    }

    pub fn parse(v: &str) -> Option<Output> {
        match v {
            "latency" => Some(Output::Latency),
            "throughput" => Some(Output::Throughput),
            "area" => Some(Output::Area),
            "cost" => Some(Output::Cost),
            "serving" => Some(Output::Serving),
            _ => None,
        }
    }
}

/// Traffic workload: the serving simulator's knobs in declarative form.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficSpec {
    pub model: String,
    /// Requests in the generated trace (ignored when `trace` is set).
    pub requests: usize,
    /// Mean Poisson arrival rate, requests/second.
    pub rate_per_s: f64,
    /// `Some(mult)` switches to the bursty (Markov-modulated) arrival
    /// process with the burst state at `mult × rate_per_s`.
    pub burst_multiplier: Option<f64>,
    /// Replay a trace file (`arrival_s,prompt,output` lines) instead of
    /// generating arrivals.
    pub trace: Option<String>,
    pub policy: Policy,
    pub max_batch: u64,
    /// Scheduler execution mode: monolithic, chunked prefill, or
    /// disaggregated prefill/decode pools (`"mode"` + the mode's knobs:
    /// `chunk_tokens`, `prefill_devices`, `transfer_base_s`).
    pub mode: ServeMode,
    /// KV admission strategy (`"preemption"`: conservative | evict).
    pub preemption: Preemption,
    /// Optional cap on the derived KV budget, in tokens — models a
    /// hypothetical memory budget (or forces KV pressure for preemption
    /// studies) without editing the hardware description.
    pub max_kv_tokens: Option<u64>,
    /// Disaggregated mode: bound on prefilled-but-not-yet-decoding
    /// sequences in the KV-handoff queue — the prefill pool stalls
    /// instead of queueing unboundedly. `None` derives the decode pool's
    /// KV budget in (mean-trace-length) sequences.
    pub handoff_capacity: Option<u64>,
    pub slo: Slo,
    pub seed: u64,
    /// Optional fault-injection schedule + recovery policy
    /// ([`crate::serve::fault`]). `None` (and the inert
    /// [`FaultSpec::none`]) serve the trace in a perfect world.
    pub faults: Option<FaultSpec>,
    /// Data-parallel replica count ([`crate::serve::fleet`]). 1 is the
    /// historical single-engine path.
    pub replicas: u64,
    /// Fleet load balancer (`"balancer"`: round_robin | least_kv_pressure
    /// | session_affinity); only consulted when `replicas > 1`.
    pub balancer: Balancer,
    /// Optional diurnal (raised-cosine) arrival-rate modulation.
    pub diurnal: Option<Diurnal>,
    /// Optional flash-crowd burst window multiplying the arrival rate.
    pub flash_crowd: Option<FlashCrowd>,
}

impl TrafficSpec {
    /// Poisson traffic with the serving defaults (FCFS, max batch 64,
    /// monolithic/conservative scheduling, interactive SLO, seed 42).
    pub fn poisson(model: &str, rate_per_s: f64, requests: usize) -> TrafficSpec {
        TrafficSpec {
            model: model.to_string(),
            requests,
            rate_per_s,
            burst_multiplier: None,
            trace: None,
            policy: Policy::Fcfs,
            max_batch: 64,
            mode: ServeMode::Monolithic,
            preemption: Preemption::Conservative,
            max_kv_tokens: None,
            handoff_capacity: None,
            slo: Slo::interactive(),
            seed: 42,
            faults: None,
            replicas: 1,
            balancer: Balancer::RoundRobin,
            diurnal: None,
            flash_crowd: None,
        }
    }
}

/// Default per-iteration token budget of chunked mode when a scenario
/// says `"mode": "chunked"` without `chunk_tokens`.
pub const DEFAULT_CHUNK_TOKENS: u64 = 2048;
/// Default handoff base latency of disaggregated mode, seconds.
pub const DEFAULT_TRANSFER_BASE_S: f64 = 1e-3;

/// One node of a `graph` workload: a named operator.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphNodeSpec {
    pub name: String,
    pub op: Op,
}

/// The workload a scenario evaluates.
#[derive(Debug, Clone, PartialEq)]
pub enum Workload {
    /// No workload: hardware-only outputs (area, cost).
    Hardware,
    /// One operator on the device (or interconnect, for comm ops).
    Op(Op),
    /// One Transformer layer of `model` at a phase.
    Layer { model: String, phase: Phase },
    /// One end-to-end request: prefill `prefill` tokens, then generate
    /// `decode` tokens, at batch size `batch`. `layers` defaults to the
    /// whole model (and is clamped to it — see
    /// [`crate::graph::ModelConfig::resolve_layers`]).
    Request { model: String, batch: u64, prefill: u64, decode: u64, layers: Option<u64> },
    /// An arbitrary operator DAG: named nodes plus `(from, to)` dependency
    /// edges. Nodes must be listed in topological order (edges point from
    /// an earlier node to a later one), which makes the DAG property a
    /// parse-time check instead of a runtime surprise. Lowered onto
    /// [`crate::graph::ir::Graph`] and scheduled by
    /// `perf::graph_sched`; the scenario's `parallelism` knobs apply the
    /// `tensor_parallel` / `pipeline_parallel` transforms first.
    Graph { nodes: Vec<GraphNodeSpec>, edges: Vec<(String, String)> },
    /// An open-loop trace through the cluster serving simulator.
    Traffic(TrafficSpec),
}

/// Build the IR graph of a `graph` workload. Node names must be unique;
/// edges must reference known names and point forward in list order.
pub fn build_graph(
    nodes: &[GraphNodeSpec],
    edges: &[(String, String)],
) -> Result<ir::Graph, String> {
    if nodes.is_empty() {
        return Err("graph workload needs at least one node".to_string());
    }
    let mut index: std::collections::HashMap<&str, usize> = std::collections::HashMap::new();
    for (i, n) in nodes.iter().enumerate() {
        if n.name.is_empty() {
            return Err("graph node names must be non-empty".to_string());
        }
        if index.insert(n.name.as_str(), i).is_some() {
            return Err(format!("duplicate graph node name `{}`", n.name));
        }
    }
    let mut preds: Vec<Vec<usize>> = vec![Vec::new(); nodes.len()];
    for (from, to) in edges {
        let f = *index
            .get(from.as_str())
            .ok_or_else(|| format!("graph edge from unknown node `{from}`"))?;
        let t = *index
            .get(to.as_str())
            .ok_or_else(|| format!("graph edge to unknown node `{to}`"))?;
        if f >= t {
            return Err(format!(
                "graph edge `{from}` -> `{to}` must point from an earlier node to a later \
                 one (list nodes in topological order)"
            ));
        }
        preds[t].push(f);
    }
    let mut g = ir::Graph::new();
    for (i, n) in nodes.iter().enumerate() {
        g.add(n.name.clone(), n.op.clone(), &preds[i]);
    }
    Ok(g)
}

impl Workload {
    /// The outputs a scenario gets when it does not list any.
    pub fn default_outputs(&self) -> Vec<Output> {
        match self {
            Workload::Hardware => vec![Output::Area, Output::Cost],
            Workload::Traffic(_) => vec![Output::Serving],
            _ => vec![Output::Latency],
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            Workload::Hardware => obj(vec![("type", s("hardware"))]),
            Workload::Op(op) => op_to_json(op),
            Workload::Graph { nodes, edges } => obj(vec![
                ("type", s("graph")),
                (
                    "nodes",
                    Json::Arr(
                        nodes
                            .iter()
                            .map(|n| {
                                let mut fields = vec![("name", s(&n.name))];
                                fields.extend(op_fields(&n.op));
                                obj(fields)
                            })
                            .collect(),
                    ),
                ),
                (
                    "edges",
                    Json::Arr(
                        edges
                            .iter()
                            .map(|(f, t)| Json::Arr(vec![s(f), s(t)]))
                            .collect(),
                    ),
                ),
            ]),
            Workload::Layer { model, phase } => {
                let mut fields = vec![("type", s("layer")), ("model", s(model))];
                match *phase {
                    Phase::Prefill { batch, seq } => {
                        fields.push(("phase", s("prefill")));
                        fields.push(("batch", num(batch as f64)));
                        fields.push(("seq", num(seq as f64)));
                    }
                    Phase::Decode { batch, kv_len } => {
                        fields.push(("phase", s("decode")));
                        fields.push(("batch", num(batch as f64)));
                        fields.push(("kv_len", num(kv_len as f64)));
                    }
                }
                obj(fields)
            }
            Workload::Request { model, batch, prefill, decode, layers } => {
                let mut fields = vec![
                    ("type", s("request")),
                    ("model", s(model)),
                    ("batch", num(*batch as f64)),
                    ("prefill", num(*prefill as f64)),
                    ("decode", num(*decode as f64)),
                ];
                if let Some(l) = layers {
                    fields.push(("layers", num(*l as f64)));
                }
                obj(fields)
            }
            Workload::Traffic(t) => {
                let mut fields = vec![
                    ("type", s("traffic")),
                    ("model", s(&t.model)),
                    ("requests", num(t.requests as f64)),
                    ("rate_per_s", num(t.rate_per_s)),
                    ("policy", s(t.policy.name())),
                    ("max_batch", num(t.max_batch as f64)),
                    ("mode", s(t.mode.name())),
                    ("preemption", s(t.preemption.name())),
                    (
                        "slo",
                        obj(vec![("ttft_s", num(t.slo.ttft_s)), ("tpot_s", num(t.slo.tpot_s))]),
                    ),
                    ("seed", num(t.seed as f64)),
                ];
                match t.mode {
                    ServeMode::Monolithic => {}
                    ServeMode::Chunked { chunk_tokens } => {
                        fields.push(("chunk_tokens", num(chunk_tokens as f64)));
                    }
                    ServeMode::Disaggregated { prefill_devices, transfer_base_s } => {
                        if prefill_devices != 0 {
                            fields.push(("prefill_devices", num(prefill_devices as f64)));
                        }
                        fields.push(("transfer_base_s", num(transfer_base_s)));
                    }
                }
                if let Some(kv) = t.max_kv_tokens {
                    fields.push(("max_kv_tokens", num(kv as f64)));
                }
                if let Some(cap) = t.handoff_capacity {
                    fields.push(("handoff_capacity", num(cap as f64)));
                }
                if let Some(m) = t.burst_multiplier {
                    fields.push(("burst_multiplier", num(m)));
                }
                if let Some(path) = &t.trace {
                    fields.push(("trace", s(path)));
                }
                if let Some(f) = &t.faults {
                    fields.push(("faults", f.to_json()));
                }
                // Fleet + modulation knobs are emitted only off their
                // defaults, keeping legacy scenarios byte-identical.
                if t.replicas != 1 {
                    fields.push(("replicas", num(t.replicas as f64)));
                }
                if t.balancer != Balancer::RoundRobin {
                    fields.push(("balancer", s(t.balancer.name())));
                }
                if let Some(d) = t.diurnal {
                    fields.push((
                        "diurnal",
                        obj(vec![
                            ("period_s", num(d.period_s)),
                            ("peak_multiplier", num(d.peak_multiplier)),
                        ]),
                    ));
                }
                if let Some(fc) = t.flash_crowd {
                    fields.push((
                        "flash_crowd",
                        obj(vec![
                            ("at_s", num(fc.at_s)),
                            ("duration_s", num(fc.duration_s)),
                            ("multiplier", num(fc.multiplier)),
                        ]),
                    ));
                }
                obj(fields)
            }
        }
    }

    pub fn from_json(v: &Json) -> Result<Workload, String> {
        let ty = v.req_str("type").map_err(jerr)?;
        match ty {
            "hardware" => Ok(Workload::Hardware),
            "op" => op_from_json(v).map(Workload::Op),
            "layer" => {
                let model = v.req_str("model").map_err(jerr)?.to_string();
                let batch = v.req_u64("batch").map_err(jerr)?;
                let phase = match v.req_str("phase").map_err(jerr)? {
                    "prefill" => Phase::Prefill { batch, seq: v.req_u64("seq").map_err(jerr)? },
                    "decode" => {
                        Phase::Decode { batch, kv_len: v.req_u64("kv_len").map_err(jerr)? }
                    }
                    other => return Err(format!("unknown phase `{other}` (prefill | decode)")),
                };
                Ok(Workload::Layer { model, phase })
            }
            "request" => Ok(Workload::Request {
                model: v.req_str("model").map_err(jerr)?.to_string(),
                batch: v.req_u64("batch").map_err(jerr)?,
                prefill: v.req_u64("prefill").map_err(jerr)?,
                decode: v.req_u64("decode").map_err(jerr)?,
                layers: opt_u64(v, "layers")?,
            }),
            "graph" => {
                let Some(Json::Arr(items)) = v.get("nodes") else {
                    return Err("graph workload needs a `nodes` array".to_string());
                };
                let mut nodes: Vec<GraphNodeSpec> = Vec::with_capacity(items.len());
                let mut edges: Vec<(String, String)> = Vec::new();
                for item in items {
                    let name = item.req_str("name").map_err(jerr)?.to_string();
                    let op = op_from_json(item)?;
                    // Per-node `deps` are sugar for edges into this node.
                    match item.get("deps") {
                        None => {}
                        Some(Json::Arr(deps)) => {
                            for d in deps {
                                let dep = d.as_str().ok_or_else(|| {
                                    "graph node `deps` must be node names".to_string()
                                })?;
                                edges.push((dep.to_string(), name.clone()));
                            }
                        }
                        Some(_) => {
                            return Err("graph node `deps` must be an array".to_string())
                        }
                    }
                    nodes.push(GraphNodeSpec { name, op });
                }
                match v.get("edges") {
                    None => {}
                    Some(Json::Arr(items)) => {
                        for item in items {
                            let Json::Arr(pair) = item else {
                                return Err(
                                    "graph `edges` must be [from, to] pairs".to_string()
                                );
                            };
                            let [f, t] = pair.as_slice() else {
                                return Err(
                                    "graph `edges` must be [from, to] pairs".to_string()
                                );
                            };
                            let (Some(f), Some(t)) = (f.as_str(), t.as_str()) else {
                                return Err("graph edge endpoints must be node names".to_string());
                            };
                            edges.push((f.to_string(), t.to_string()));
                        }
                    }
                    Some(_) => return Err("graph `edges` must be an array".to_string()),
                }
                // Validate now so bad files fail at parse time, not when
                // the evaluator lowers the workload.
                build_graph(&nodes, &edges)?;
                Ok(Workload::Graph { nodes, edges })
            }
            "traffic" => {
                check_known_fields(v, TRAFFIC_KEYS, "traffic workload")?;
                let trace = opt_str(v, "trace")?.map(str::to_string);
                let rate_per_s = match opt_f64(v, "rate_per_s")? {
                    Some(r) => r,
                    None if trace.is_some() => 0.0,
                    None => {
                        return Err(
                            "traffic workload needs `rate_per_s` (or a `trace` file)".to_string()
                        )
                    }
                };
                let policy = match opt_str(v, "policy")? {
                    None => Policy::Fcfs,
                    Some(p) => Policy::parse(p)
                        .ok_or_else(|| "bad traffic `policy` (fcfs | spf)".to_string())?,
                };
                let mode = match opt_str(v, "mode")?.unwrap_or("monolithic") {
                    "monolithic" => ServeMode::Monolithic,
                    "chunked" => ServeMode::Chunked {
                        chunk_tokens: opt_u64(v, "chunk_tokens")?.unwrap_or(DEFAULT_CHUNK_TOKENS),
                    },
                    "disaggregated" => ServeMode::Disaggregated {
                        prefill_devices: opt_u64(v, "prefill_devices")?.unwrap_or(0),
                        transfer_base_s: opt_f64(v, "transfer_base_s")?
                            .unwrap_or(DEFAULT_TRANSFER_BASE_S),
                    },
                    other => {
                        return Err(format!(
                            "unknown traffic `mode` `{other}` (monolithic | chunked | disaggregated)"
                        ))
                    }
                };
                let preemption = match opt_str(v, "preemption")? {
                    None => Preemption::Conservative,
                    Some(p) => Preemption::parse(p).ok_or_else(|| {
                        "bad traffic `preemption` (conservative | evict)".to_string()
                    })?,
                };
                let slo = match v.get("slo") {
                    None => Slo::interactive(),
                    Some(sv) => {
                        check_known_fields(sv, &["ttft_s", "tpot_s"], "traffic `slo`")?;
                        Slo {
                            ttft_s: sv.req_f64("ttft_s").map_err(jerr)?,
                            tpot_s: sv.req_f64("tpot_s").map_err(jerr)?,
                        }
                    }
                };
                let faults = match v.get("faults") {
                    None => None,
                    Some(fv) => Some(FaultSpec::from_json(fv)?),
                };
                let replicas = opt_u64(v, "replicas")?.unwrap_or(1);
                if replicas == 0 {
                    return Err("traffic `replicas` must be ≥ 1".to_string());
                }
                let balancer = match opt_str(v, "balancer")? {
                    None => Balancer::RoundRobin,
                    Some(b) => Balancer::parse(b).ok_or_else(|| {
                        format!(
                            "unknown traffic `balancer` `{b}` (round_robin | \
                             least_kv_pressure | session_affinity)"
                        )
                    })?,
                };
                let diurnal = match v.get("diurnal") {
                    None => None,
                    Some(d) => {
                        check_known_fields(
                            d,
                            &["period_s", "peak_multiplier"],
                            "traffic `diurnal`",
                        )?;
                        Some(Diurnal {
                            period_s: d.req_f64("period_s").map_err(jerr)?,
                            peak_multiplier: d.req_f64("peak_multiplier").map_err(jerr)?,
                        })
                    }
                };
                let flash_crowd = match v.get("flash_crowd") {
                    None => None,
                    Some(fc) => {
                        check_known_fields(
                            fc,
                            &["at_s", "duration_s", "multiplier"],
                            "traffic `flash_crowd`",
                        )?;
                        Some(FlashCrowd {
                            at_s: fc.req_f64("at_s").map_err(jerr)?,
                            duration_s: fc.req_f64("duration_s").map_err(jerr)?,
                            multiplier: fc.req_f64("multiplier").map_err(jerr)?,
                        })
                    }
                };
                let requests = match opt_u64(v, "requests")? {
                    Some(n) => n as usize,
                    None if trace.is_some() => 0, // replay ignores `requests`
                    None => {
                        return Err(
                            "traffic workload needs `requests` (or a `trace` file)".to_string()
                        )
                    }
                };
                Ok(Workload::Traffic(TrafficSpec {
                    model: v.req_str("model").map_err(jerr)?.to_string(),
                    requests,
                    rate_per_s,
                    burst_multiplier: opt_f64(v, "burst_multiplier")?,
                    trace,
                    policy,
                    max_batch: opt_u64(v, "max_batch")?.unwrap_or(64),
                    mode,
                    preemption,
                    max_kv_tokens: opt_u64(v, "max_kv_tokens")?,
                    handoff_capacity: opt_u64(v, "handoff_capacity")?,
                    slo,
                    seed: opt_u64(v, "seed")?.unwrap_or(42),
                    faults,
                    replicas,
                    balancer,
                    diurnal,
                    flash_crowd,
                }))
            }
            other => Err(format!(
                "unknown workload type `{other}` (hardware | op | layer | request | graph | \
                 traffic)"
            )),
        }
    }
}

fn op_to_json(op: &Op) -> Json {
    let mut fields = vec![("type", s("op"))];
    fields.extend(op_fields(op));
    obj(fields)
}

/// The op-describing JSON fields (`op`, `dims`, `dtype`, …) shared by the
/// `op` workload and `graph` workload nodes. [`op_from_json`] parses them.
fn op_fields(op: &Op) -> Vec<(&'static str, Json)> {
    let mut fields = vec![("op", s(op.name()))];
    let dims = |vals: &[u64]| Json::Arr(vals.iter().map(|&d| num(d as f64)).collect());
    match *op {
        Op::Matmul { b, m, k, n, dtype, batched_b } => {
            fields.push(("dims", dims(&[m, k, n])));
            fields.push(("dtype", s(dtype.name())));
            if b != 1 {
                fields.push(("batch", num(b as f64)));
            }
            if batched_b {
                fields.push(("batched_b", Json::Bool(true)));
            }
        }
        Op::Softmax { m, n, dtype } | Op::LayerNorm { m, n, dtype } => {
            fields.push(("dims", dims(&[m, n])));
            fields.push(("dtype", s(dtype.name())));
        }
        Op::Gelu { elements, dtype } => {
            fields.push(("dims", dims(&[elements])));
            fields.push(("dtype", s(dtype.name())));
        }
        Op::AllReduce { bytes, devices } => {
            fields.push(("bytes", num(bytes as f64)));
            fields.push(("devices", num(devices as f64)));
        }
        Op::PeerToPeer { bytes } => fields.push(("bytes", num(bytes as f64))),
    }
    fields
}

fn op_from_json(v: &Json) -> Result<Op, String> {
    let name = v.req_str("op").map_err(jerr)?;
    let dtype = match v.get("dtype") {
        None => DType::FP16,
        Some(d) => {
            let d = d.as_str().ok_or_else(|| "op `dtype` must be a string".to_string())?;
            DType::parse(d).ok_or_else(|| format!("unknown dtype `{d}`"))?
        }
    };
    let dims: Vec<u64> = match v.get("dims") {
        None => Vec::new(),
        Some(Json::Arr(items)) => items
            .iter()
            .map(|i| {
                i.as_u64().ok_or_else(|| "op `dims` must be non-negative integers".to_string())
            })
            .collect::<Result<_, _>>()?,
        Some(_) => return Err("op `dims` must be an array".to_string()),
    };
    match (name, dims.as_slice()) {
        ("matmul", [m, k, n]) => Ok(Op::Matmul {
            b: opt_u64(v, "batch")?.unwrap_or(1),
            m: *m,
            k: *k,
            n: *n,
            dtype,
            batched_b: opt_bool(v, "batched_b")?.unwrap_or(false),
        }),
        ("softmax", [m, n]) => Ok(Op::Softmax { m: *m, n: *n, dtype }),
        ("layernorm", [m, n]) => Ok(Op::LayerNorm { m: *m, n: *n, dtype }),
        ("gelu", [n]) => Ok(Op::Gelu { elements: *n, dtype }),
        ("allreduce", _) => Ok(Op::AllReduce {
            bytes: v.req_u64("bytes").map_err(jerr)?,
            devices: v.req_u64("devices").map_err(jerr)?,
        }),
        ("p2p", _) => Ok(Op::PeerToPeer { bytes: v.req_u64("bytes").map_err(jerr)? }),
        _ => Err(format!(
            "op `{name}` with {} dims is not a scenario op (matmul [m,k,n] | softmax [m,n] | \
             layernorm [m,n] | gelu [n] | allreduce/p2p with bytes)",
            dims.len()
        )),
    }
}

/// Rewrite a relative path that does not exist from the CWD to live under
/// `dir`, when that resolves — used by [`Scenario::load`] so scenario
/// files can reference sibling hardware/trace files.
fn anchor_path(value: &mut String, dir: &std::path::Path) {
    let p = std::path::Path::new(value.as_str());
    if p.is_relative() && !p.exists() {
        let joined = dir.join(p);
        if joined.exists() {
            *value = joined.to_string_lossy().into_owned();
        }
    }
}

/// The optional `tune` section of a scenario: how `llmcompass tune`
/// should search a design space for this workload. Plain evaluation
/// ignores it entirely, so tune scenarios still run (and golden-gate)
/// as ordinary scenarios.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneSpec {
    /// Design-space preset name or JSON file path (anchored to the
    /// scenario's directory on load, like `hardware`).
    pub space: String,
    /// `perf-per-dollar` | `goodput-per-dollar`; `None` picks the
    /// workload's natural objective.
    pub objective: Option<crate::tune::Objective>,
    pub max_area_mm2: Option<f64>,
    pub max_power_w: Option<f64>,
}

impl TuneSpec {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("space", s(&self.space))];
        if let Some(o) = self.objective {
            fields.push(("objective", s(o.name())));
        }
        let mut cons: Vec<(&str, Json)> = Vec::new();
        if let Some(a) = self.max_area_mm2 {
            cons.push(("max_area_mm2", num(a)));
        }
        if let Some(p) = self.max_power_w {
            cons.push(("max_power_w", num(p)));
        }
        if !cons.is_empty() {
            fields.push(("constraints", obj(cons)));
        }
        obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<TuneSpec, String> {
        if v.as_obj().is_none() {
            return Err(
                "scenario `tune` must be an object like {\"space\": \"section7\"}".to_string()
            );
        }
        let objective = match opt_str(v, "objective")? {
            None => None,
            Some(text) => Some(crate::tune::Objective::parse(text).ok_or_else(|| {
                format!("unknown tune objective `{text}` (perf-per-dollar | goodput-per-dollar)")
            })?),
        };
        let (max_area_mm2, max_power_w) = match v.get("constraints") {
            None => (None, None),
            Some(c) => {
                if c.as_obj().is_none() {
                    return Err("tune `constraints` must be an object".to_string());
                }
                (opt_f64(c, "max_area_mm2")?, opt_f64(c, "max_power_w")?)
            }
        };
        Ok(TuneSpec {
            space: v.req_str("space").map_err(jerr)?.to_string(),
            objective,
            max_area_mm2,
            max_power_w,
        })
    }
}

/// One evaluation scenario: hardware target, workload, requested outputs,
/// and (optionally) how the workload maps onto the system's devices.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    /// Hardware target: preset (`a100`), system (`ga100x8`), or JSON path.
    pub hardware: String,
    pub workload: Workload,
    /// `{tp, pp, microbatches}` device mapping for layer/request/graph
    /// workloads. `None` keeps the historical default: tensor parallelism
    /// across every device.
    pub parallelism: Option<Parallelism>,
    pub outputs: Vec<Output>,
    /// Optional design-space search setup for `llmcompass tune`.
    pub tune: Option<TuneSpec>,
}

impl Scenario {
    /// A scenario with the workload's default outputs.
    pub fn new(name: &str, hardware: &str, workload: Workload) -> Scenario {
        let outputs = workload.default_outputs();
        Scenario {
            name: name.to_string(),
            hardware: hardware.to_string(),
            workload,
            parallelism: None,
            outputs,
            tune: None,
        }
    }

    /// Set the device mapping (`tp × pp` must equal the device count).
    pub fn with_parallelism(mut self, par: Parallelism) -> Scenario {
        self.parallelism = Some(par);
        self
    }

    /// Attach a `tune` section (the design-space search setup).
    pub fn with_tune(mut self, tune: TuneSpec) -> Scenario {
        self.tune = Some(tune);
        self
    }

    /// Append an output (no-op if already requested).
    pub fn with_output(mut self, out: Output) -> Scenario {
        if !self.outputs.contains(&out) {
            self.outputs.push(out);
        }
        self
    }

    /// Replace the output list.
    pub fn with_outputs(mut self, outs: &[Output]) -> Scenario {
        self.outputs.clear();
        for &o in outs {
            if !self.outputs.contains(&o) {
                self.outputs.push(o);
            }
        }
        self
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", s(&self.name)),
            ("hardware", s(&self.hardware)),
            ("workload", self.workload.to_json()),
        ];
        if let Some(p) = &self.parallelism {
            fields.push((
                "parallelism",
                obj(vec![
                    ("tp", num(p.tp as f64)),
                    ("pp", num(p.pp as f64)),
                    ("microbatches", num(p.microbatches as f64)),
                ]),
            ));
        }
        if let Some(t) = &self.tune {
            fields.push(("tune", t.to_json()));
        }
        fields.push(("outputs", Json::Arr(self.outputs.iter().map(|o| s(o.name())).collect())));
        obj(fields)
    }

    /// Parse a scenario from an already-parsed JSON value. A missing
    /// `name` defaults to `"scenario"` (overridden by the file stem in
    /// [`Scenario::load`]); missing `outputs` default per workload.
    pub fn from_json(v: &Json) -> Result<Scenario, String> {
        check_known_fields(v, SCENARIO_KEYS, "scenario")?;
        let workload = Workload::from_json(
            v.get("workload").ok_or_else(|| "scenario needs a `workload` object".to_string())?,
        )?;
        let outputs = match v.get("outputs") {
            None => workload.default_outputs(),
            Some(Json::Arr(items)) => {
                let mut outs: Vec<Output> = Vec::new();
                for item in items {
                    let text = item
                        .as_str()
                        .ok_or_else(|| "scenario `outputs` must be strings".to_string())?;
                    let o = Output::parse(text).ok_or_else(|| {
                        format!(
                            "unknown output `{text}` (latency | throughput | area | cost | serving)"
                        )
                    })?;
                    if !outs.contains(&o) {
                        outs.push(o);
                    }
                }
                outs
            }
            Some(_) => return Err("scenario `outputs` must be an array".to_string()),
        };
        let parallelism = match v.get("parallelism") {
            None => None,
            Some(p) => {
                if p.as_obj().is_none() {
                    return Err(
                        "scenario `parallelism` must be an object like \
                         {\"tp\": 1, \"pp\": 1, \"microbatches\": 1}"
                            .to_string(),
                    );
                }
                let par = Parallelism {
                    tp: opt_u64(p, "tp")?.unwrap_or(1),
                    pp: opt_u64(p, "pp")?.unwrap_or(1),
                    microbatches: opt_u64(p, "microbatches")?.unwrap_or(1),
                };
                if par.tp == 0 || par.pp == 0 || par.microbatches == 0 {
                    return Err(
                        "parallelism tp / pp / microbatches must all be ≥ 1".to_string()
                    );
                }
                Some(par)
            }
        };
        let tune = match v.get("tune") {
            None => None,
            Some(t) => Some(TuneSpec::from_json(t)?),
        };
        Ok(Scenario {
            name: opt_str(v, "name")?.unwrap_or("scenario").to_string(),
            hardware: v.req_str("hardware").map_err(jerr)?.to_string(),
            workload,
            parallelism,
            outputs,
            tune,
        })
    }

    /// Parse a scenario from JSON text.
    pub fn parse(text: &str) -> Result<Scenario, String> {
        let v = Json::parse(text).map_err(|e| e.to_string())?;
        Scenario::from_json(&v)
    }

    /// Load a scenario from a JSON file; an unnamed scenario takes the
    /// file stem as its name. Relative `hardware` / `trace` file paths
    /// that do not resolve from the process CWD are anchored to the
    /// scenario file's directory, so suites referencing sibling files
    /// stay relocatable.
    pub fn load(path: &std::path::Path) -> Result<Scenario, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read scenario {}: {e}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        let mut sc = Scenario::from_json(&v).map_err(|e| format!("{}: {e}", path.display()))?;
        if v.get("name").is_none() {
            if let Some(stem) = path.file_stem().and_then(|v| v.to_str()) {
                sc.name = stem.to_string();
            }
        }
        if let Some(dir) = path.parent() {
            if crate::hardware::presets::system(&sc.hardware).is_none() {
                anchor_path(&mut sc.hardware, dir);
            }
            if let Workload::Traffic(t) = &mut sc.workload {
                if let Some(trace) = &mut t.trace {
                    anchor_path(trace, dir);
                }
            }
            if let Some(t) = &mut sc.tune {
                if crate::tune::DesignSpace::preset(&t.space).is_none() {
                    anchor_path(&mut t.space, dir);
                }
            }
        }
        Ok(sc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(sc: &Scenario) {
        let text = sc.to_json().to_string_pretty();
        let again = Scenario::parse(&text).unwrap();
        assert_eq!(*sc, again, "round trip changed the scenario:\n{text}");
    }

    #[test]
    fn every_workload_kind_round_trips() {
        round_trip(&Scenario::new("hw", "ga100", Workload::Hardware));
        round_trip(&Scenario::new(
            "op",
            "a100",
            Workload::Op(Op::Matmul {
                b: 4,
                m: 256,
                k: 512,
                n: 256,
                dtype: DType::BF16,
                batched_b: true,
            }),
        ));
        round_trip(&Scenario::new(
            "layer",
            "a100x4",
            Workload::Layer {
                model: "gpt3-175b".into(),
                phase: Phase::Decode { batch: 8, kv_len: 3072 },
            },
        ));
        round_trip(
            &Scenario::new(
                "req",
                "ga100x8",
                Workload::Request {
                    model: "gpt-small".into(),
                    batch: 4,
                    prefill: 128,
                    decode: 32,
                    layers: Some(2),
                },
            )
            .with_output(Output::Throughput)
            .with_output(Output::Cost),
        );
        let mut t = TrafficSpec::poisson("gpt-small", 20.0, 48);
        t.burst_multiplier = Some(4.0);
        t.policy = Policy::ShortestPromptFirst;
        t.slo = Slo::relaxed();
        round_trip(&Scenario::new("traffic", "throughput-oriented", Workload::Traffic(t)));
        // Scheduler-v2 knobs survive the round trip in every mode.
        let mut t = TrafficSpec::poisson("gpt-small", 30.0, 64);
        t.mode = ServeMode::Chunked { chunk_tokens: 512 };
        t.preemption = Preemption::Evict;
        t.max_kv_tokens = Some(9000);
        round_trip(&Scenario::new("chunked", "a100", Workload::Traffic(t)));
        let mut t = TrafficSpec::poisson("gpt-small", 30.0, 64);
        t.mode = ServeMode::Disaggregated { prefill_devices: 2, transfer_base_s: 0.002 };
        round_trip(&Scenario::new("disagg", "a100x4", Workload::Traffic(t)));
        let mut t = TrafficSpec::poisson("gpt-small", 30.0, 64);
        t.mode = ServeMode::Disaggregated { prefill_devices: 0, transfer_base_s: 1e-3 };
        round_trip(&Scenario::new("disagg-auto", "a100x4", Workload::Traffic(t)));
    }

    #[test]
    fn tune_section_round_trips() {
        let req = Workload::Request {
            model: "gpt-small".into(),
            batch: 2,
            prefill: 16,
            decode: 8,
            layers: Some(1),
        };
        round_trip(&Scenario::new("tuned", "a100", req.clone()).with_tune(TuneSpec {
            space: "section7".into(),
            objective: Some(crate::tune::Objective::PerfPerDollar),
            max_area_mm2: Some(900.0),
            max_power_w: None,
        }));
        // Objective and constraints are optional.
        round_trip(&Scenario::new("tuned-min", "a100", req).with_tune(TuneSpec {
            space: "smoke".into(),
            objective: None,
            max_area_mm2: None,
            max_power_w: None,
        }));
        let bad = r#"{"hardware": "a100", "workload": {"type": "hardware"},
                      "tune": {"space": "smoke", "objective": "nope"}}"#;
        assert!(Scenario::parse(bad).unwrap_err().contains("objective"));
        let missing = r#"{"hardware": "a100", "workload": {"type": "hardware"},
                          "tune": {}}"#;
        assert!(Scenario::parse(missing).is_err());
    }

    fn branchy_graph() -> Workload {
        let mm = |m, k, n| Op::Matmul { b: 1, m, k, n, dtype: DType::FP16, batched_b: false };
        Workload::Graph {
            nodes: vec![
                GraphNodeSpec { name: "ln".into(), op: Op::LayerNorm { m: 256, n: 512, dtype: DType::FP16 } },
                GraphNodeSpec { name: "left".into(), op: mm(256, 512, 512) },
                GraphNodeSpec { name: "right".into(), op: mm(256, 512, 512) },
                GraphNodeSpec { name: "join".into(), op: Op::Gelu { elements: 256 * 512, dtype: DType::FP16 } },
            ],
            edges: vec![
                ("ln".into(), "left".into()),
                ("ln".into(), "right".into()),
                ("left".into(), "join".into()),
                ("right".into(), "join".into()),
            ],
        }
    }

    #[test]
    fn graph_workload_round_trips_and_builds() {
        let sc = Scenario::new("g", "a100", branchy_graph());
        assert_eq!(sc.outputs, vec![Output::Latency]);
        round_trip(&sc);
        let Workload::Graph { nodes, edges } = &sc.workload else { panic!("not graph") };
        let g = build_graph(nodes, edges).unwrap();
        assert_eq!(g.len(), 4);
        assert!(!g.is_chain());
        assert_eq!(g.preds(3), &[1, 2]);
        // With parallelism knobs attached.
        round_trip(&sc.clone().with_parallelism(Parallelism { tp: 2, pp: 1, microbatches: 1 }));
    }

    #[test]
    fn graph_deps_sugar_becomes_edges() {
        let sc = Scenario::parse(
            r#"{"hardware": "a100", "workload": {"type": "graph", "nodes": [
                  {"name": "a", "op": "matmul", "dims": [64, 64, 64]},
                  {"name": "b", "op": "gelu", "dims": [4096], "deps": ["a"]}
                ]}}"#,
        )
        .unwrap();
        let Workload::Graph { edges, .. } = &sc.workload else { panic!("not graph") };
        assert_eq!(edges, &[("a".to_string(), "b".to_string())]);
        round_trip(&sc);
    }

    #[test]
    fn bad_graph_workloads_error_at_parse_time() {
        for (bad, why) in [
            (
                r#"{"hardware": "a100", "workload": {"type": "graph", "nodes": []}}"#,
                "empty graph",
            ),
            (
                r#"{"hardware": "a100", "workload": {"type": "graph", "nodes": [
                      {"name": "a", "op": "matmul", "dims": [8, 8, 8]},
                      {"name": "a", "op": "gelu", "dims": [64]}]}}"#,
                "duplicate names",
            ),
            (
                r#"{"hardware": "a100", "workload": {"type": "graph", "nodes": [
                      {"name": "a", "op": "matmul", "dims": [8, 8, 8], "deps": ["z"]}]}}"#,
                "unknown dep",
            ),
            (
                r#"{"hardware": "a100", "workload": {"type": "graph", "nodes": [
                      {"name": "a", "op": "matmul", "dims": [8, 8, 8], "deps": ["b"]},
                      {"name": "b", "op": "gelu", "dims": [64]}]}}"#,
                "backward edge (cycle bait)",
            ),
            (
                r#"{"hardware": "a100", "workload": {"type": "graph", "nodes": [
                      {"name": "a", "op": "matmul", "dims": [8, 8, 8]}],
                    "edges": [["a"]]}}"#,
                "malformed edge pair",
            ),
        ] {
            assert!(Scenario::parse(bad).is_err(), "accepted {why}: {bad}");
        }
    }

    #[test]
    fn parallelism_knobs_round_trip_and_validate() {
        let sc = Scenario::parse(
            r#"{"hardware": "a100x4", "parallelism": {"pp": 4, "microbatches": 8},
                "workload": {"type": "request", "model": "gpt3-175b",
                             "batch": 8, "prefill": 2048, "decode": 4}}"#,
        )
        .unwrap();
        assert_eq!(sc.parallelism, Some(Parallelism { tp: 1, pp: 4, microbatches: 8 }));
        round_trip(&sc);
        // Zero degrees reject the file.
        assert!(Scenario::parse(
            r#"{"hardware": "a100", "parallelism": {"tp": 0},
                "workload": {"type": "hardware"}}"#,
        )
        .is_err());
        // Mistyped values reject the file.
        assert!(Scenario::parse(
            r#"{"hardware": "a100", "parallelism": {"tp": "four"},
                "workload": {"type": "hardware"}}"#,
        )
        .is_err());
        // A non-object parallelism value rejects the file rather than
        // silently defaulting every degree to 1.
        assert!(Scenario::parse(
            r#"{"hardware": "a100", "parallelism": "tp4",
                "workload": {"type": "hardware"}}"#,
        )
        .is_err());
        assert!(Scenario::parse(
            r#"{"hardware": "a100", "parallelism": [4, 1, 1],
                "workload": {"type": "hardware"}}"#,
        )
        .is_err());
        // Absent parallelism stays absent (legacy scenarios unchanged).
        let sc = Scenario::parse(r#"{"hardware": "a100", "workload": {"type": "hardware"}}"#)
            .unwrap();
        assert_eq!(sc.parallelism, None);
        assert!(sc.to_json().get("parallelism").is_none());
    }

    #[test]
    fn handoff_capacity_round_trips() {
        let mut t = TrafficSpec::poisson("gpt-small", 30.0, 64);
        t.mode = ServeMode::Disaggregated { prefill_devices: 1, transfer_base_s: 0.002 };
        t.handoff_capacity = Some(4);
        round_trip(&Scenario::new("disagg-capped", "a100x4", Workload::Traffic(t)));
        let sc = Scenario::parse(
            r#"{"hardware": "a100x4", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0, "mode": "disaggregated",
                "handoff_capacity": 2}}"#,
        )
        .unwrap();
        let Workload::Traffic(t) = &sc.workload else { panic!("not traffic") };
        assert_eq!(t.handoff_capacity, Some(2));
        // Mistyped value rejects the file.
        assert!(Scenario::parse(
            r#"{"hardware": "a100x4", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0, "handoff_capacity": "two"}}"#,
        )
        .is_err());
    }

    #[test]
    fn mode_knobs_parse_with_defaults() {
        let sc = Scenario::parse(
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0, "mode": "chunked"}}"#,
        )
        .unwrap();
        let Workload::Traffic(t) = &sc.workload else { panic!("not traffic") };
        assert_eq!(t.mode, ServeMode::Chunked { chunk_tokens: DEFAULT_CHUNK_TOKENS });
        let sc = Scenario::parse(
            r#"{"hardware": "a100x4", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0, "mode": "disaggregated",
                "preemption": "evict"}}"#,
        )
        .unwrap();
        let Workload::Traffic(t) = &sc.workload else { panic!("not traffic") };
        assert_eq!(
            t.mode,
            ServeMode::Disaggregated { prefill_devices: 0, transfer_base_s: DEFAULT_TRANSFER_BASE_S }
        );
        assert_eq!(t.preemption, Preemption::Evict);
        // Unknown values reject the file.
        for bad in [
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0, "mode": "teleported"}}"#,
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0, "preemption": "yolo"}}"#,
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0, "mode": "chunked", "chunk_tokens": "big"}}"#,
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0, "max_kv_tokens": -3}}"#,
        ] {
            assert!(Scenario::parse(bad).is_err(), "accepted bad scenario: {bad}");
        }
    }

    #[test]
    fn defaults_fill_in() {
        let sc = Scenario::parse(
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 10, "rate_per_s": 5.0}}"#,
        )
        .unwrap();
        assert_eq!(sc.name, "scenario");
        assert_eq!(sc.outputs, vec![Output::Serving]);
        let Workload::Traffic(t) = &sc.workload else { panic!("not traffic") };
        assert_eq!(t.policy, Policy::Fcfs);
        assert_eq!(t.max_batch, 64);
        assert_eq!(t.seed, 42);
        assert_eq!(t.slo, Slo::interactive());
        assert_eq!(t.mode, ServeMode::Monolithic);
        assert_eq!(t.preemption, Preemption::Conservative);
        assert_eq!(t.max_kv_tokens, None);
    }

    #[test]
    fn op_dims_and_dtype_parse() {
        let sc = Scenario::parse(
            r#"{"name": "m", "hardware": "a100",
                "workload": {"type": "op", "op": "matmul", "dims": [256, 512, 256],
                             "dtype": "fp32"}}"#,
        )
        .unwrap();
        assert_eq!(
            sc.workload,
            Workload::Op(Op::Matmul {
                b: 1,
                m: 256,
                k: 512,
                n: 256,
                dtype: DType::FP32,
                batched_b: false,
            })
        );
        assert_eq!(sc.outputs, vec![Output::Latency]);
    }

    #[test]
    fn bad_scenarios_error() {
        assert!(Scenario::parse("{}").is_err());
        assert!(Scenario::parse(r#"{"hardware": "a100"}"#).is_err());
        assert!(Scenario::parse(
            r#"{"hardware": "a100", "workload": {"type": "teleport"}}"#
        )
        .is_err());
        assert!(Scenario::parse(
            r#"{"hardware": "a100", "workload": {"type": "hardware"}, "outputs": ["speed"]}"#
        )
        .is_err());
        assert!(Scenario::parse(
            r#"{"hardware": "a100",
                "workload": {"type": "op", "op": "matmul", "dims": [1, 2]}}"#
        )
        .is_err());
        // traffic without rate or trace
        assert!(Scenario::parse(
            r#"{"hardware": "a100",
                "workload": {"type": "traffic", "model": "gpt-small", "requests": 4}}"#
        )
        .is_err());
        // traffic without requests or trace
        assert!(Scenario::parse(
            r#"{"hardware": "a100",
                "workload": {"type": "traffic", "model": "gpt-small", "rate_per_s": 5.0}}"#
        )
        .is_err());
    }

    #[test]
    fn mistyped_optional_fields_error_instead_of_defaulting() {
        // A typo'd optional value must reject the file, not silently run
        // a different experiment.
        for bad in [
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 4, "rate_per_s": 5.0, "burst_multiplier": "4.0"}}"#,
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 4, "rate_per_s": 5.0, "seed": "42"}}"#,
            r#"{"hardware": "a100", "workload": {"type": "request", "model": "gpt-small",
                "batch": 1, "prefill": 8, "decode": 4, "layers": "12"}}"#,
            r#"{"hardware": "a100", "workload": {"type": "op", "op": "matmul",
                "dims": [8, 8, 8], "batched_b": 1}}"#,
            r#"{"hardware": "a100", "name": 7, "workload": {"type": "hardware"}}"#,
        ] {
            assert!(Scenario::parse(bad).is_err(), "accepted mistyped scenario: {bad}");
        }
    }

    #[test]
    fn load_anchors_relative_hardware_paths_to_the_scenario_dir() {
        let dir = std::env::temp_dir().join("llmcompass-test-scenario-anchor");
        std::fs::create_dir_all(&dir).unwrap();
        let dev = crate::hardware::presets::a100();
        std::fs::write(dir.join("dev.json"), dev.to_json().to_string_pretty()).unwrap();
        std::fs::write(
            dir.join("sc.json"),
            r#"{"hardware": "dev.json", "workload": {"type": "hardware"}}"#,
        )
        .unwrap();
        let sc = Scenario::load(&dir.join("sc.json")).unwrap();
        assert_eq!(sc.name, "sc", "file stem becomes the name");
        assert!(
            std::path::Path::new(&sc.hardware).is_absolute(),
            "hardware path `{}` should be anchored to the suite dir",
            sc.hardware
        );
        assert!(crate::hardware::config::resolve(&sc.hardware).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trace_replay_needs_neither_rate_nor_requests() {
        let sc = Scenario::parse(
            r#"{"hardware": "a100",
                "workload": {"type": "traffic", "model": "gpt-small",
                             "trace": "trace.csv"}}"#,
        )
        .unwrap();
        let Workload::Traffic(t) = &sc.workload else { panic!("not traffic") };
        assert_eq!(t.trace.as_deref(), Some("trace.csv"));
        assert_eq!(t.requests, 0);
        assert_eq!(t.rate_per_s, 0.0);
        round_trip(&sc);
    }

    #[test]
    fn outputs_deduplicate() {
        let sc = Scenario::parse(
            r#"{"hardware": "a100", "workload": {"type": "hardware"},
                "outputs": ["cost", "area", "cost"]}"#,
        )
        .unwrap();
        assert_eq!(sc.outputs, vec![Output::Cost, Output::Area]);
    }

    #[test]
    fn fault_spec_round_trips_through_the_scenario() {
        use crate::serve::{FaultEvent, FaultKind, FaultTarget, RecoveryPolicy};
        let mut t = TrafficSpec::poisson("gpt-small", 20.0, 32);
        t.faults = Some(FaultSpec {
            seed: 7,
            events: vec![
                FaultEvent {
                    kind: FaultKind::Crash,
                    at_s: 0.5,
                    duration_s: 1.0,
                    target: FaultTarget::Decode,
                },
                FaultEvent {
                    kind: FaultKind::LinkDegrade { factor: 4.0 },
                    at_s: 0.0,
                    duration_s: 3.0,
                    target: FaultTarget::All,
                },
            ],
            mtbf_s: Some(3600.0),
            mttr_s: 20.0,
            correlated_fraction: 0.5,
            recovery: RecoveryPolicy {
                max_retries: 1,
                retry_backoff_s: 0.2,
                request_timeout_s: Some(10.0),
                shed_queue_depth: Some(128),
                degraded_chunk_tokens: None,
            },
        });
        round_trip(&Scenario::new("faulty", "a100x4", Workload::Traffic(t)));
        // Parsed from scratch, including the mtbf_hours sugar.
        let sc = Scenario::parse(
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0,
                "faults": {"seed": 3, "mtbf_hours": 1.0, "mttr_s": 30.0,
                           "events": [{"kind": "drain", "at_s": 1.0, "duration_s": 2.0}]}}}"#,
        )
        .unwrap();
        let Workload::Traffic(t) = &sc.workload else { panic!("not traffic") };
        let f = t.faults.as_ref().unwrap();
        assert_eq!(f.mtbf_s, Some(3600.0));
        assert_eq!(f.events.len(), 1);
        round_trip(&sc);
        // Absent faults stay absent (legacy scenarios byte-identical).
        let sc = Scenario::parse(
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0}}"#,
        )
        .unwrap();
        let Workload::Traffic(t) = &sc.workload else { panic!("not traffic") };
        assert_eq!(t.faults, None);
        assert!(sc.to_json().get("workload").unwrap().get("faults").is_none());
    }

    #[test]
    fn fleet_and_modulation_knobs_round_trip() {
        let mut t = TrafficSpec::poisson("gpt-small", 30.0, 64);
        t.replicas = 4;
        t.balancer = Balancer::LeastKvPressure;
        t.diurnal = Some(Diurnal { period_s: 60.0, peak_multiplier: 3.0 });
        t.flash_crowd = Some(FlashCrowd { at_s: 10.0, duration_s: 5.0, multiplier: 6.0 });
        round_trip(&Scenario::new("fleet", "a100x2", Workload::Traffic(t)));
        // Parsed from scratch.
        let sc = Scenario::parse(
            r#"{"hardware": "a100x2", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 16, "rate_per_s": 10.0, "replicas": 3,
                "balancer": "session_affinity",
                "diurnal": {"period_s": 120.0, "peak_multiplier": 2.0},
                "flash_crowd": {"at_s": 4.0, "duration_s": 2.0, "multiplier": 5.0}}}"#,
        )
        .unwrap();
        let Workload::Traffic(t) = &sc.workload else { panic!("not traffic") };
        assert_eq!(t.replicas, 3);
        assert_eq!(t.balancer, Balancer::SessionAffinity);
        assert_eq!(t.diurnal, Some(Diurnal { period_s: 120.0, peak_multiplier: 2.0 }));
        assert_eq!(
            t.flash_crowd,
            Some(FlashCrowd { at_s: 4.0, duration_s: 2.0, multiplier: 5.0 })
        );
        round_trip(&sc);
        // Defaults: absent knobs stay absent (legacy scenarios
        // byte-identical) and parse to the single-engine path.
        let sc = Scenario::parse(
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0}}"#,
        )
        .unwrap();
        let Workload::Traffic(t) = &sc.workload else { panic!("not traffic") };
        assert_eq!(t.replicas, 1);
        assert_eq!(t.balancer, Balancer::RoundRobin);
        let w = sc.to_json();
        let w = w.get("workload").unwrap();
        for absent in ["replicas", "balancer", "diurnal", "flash_crowd"] {
            assert!(w.get(absent).is_none(), "`{absent}` leaked into a legacy scenario");
        }
        // Bad values reject the file.
        for bad in [
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0, "replicas": 0}}"#,
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0, "balancer": "coin_flip"}}"#,
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0, "diurnal": {"period": 60.0}}}"#,
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0,
                "flash_crowd": {"at_s": 1.0, "duration_s": 2.0}}}"#,
        ] {
            assert!(Scenario::parse(bad).is_err(), "accepted bad scenario: {bad}");
        }
    }

    #[test]
    fn unknown_fields_are_rejected_by_name() {
        // Top-level scenario typo.
        let err = Scenario::parse(
            r#"{"hardware": "a100", "wrkload": {"type": "hardware"},
                "workload": {"type": "hardware"}}"#,
        )
        .unwrap_err();
        assert!(err.contains("unknown scenario field `wrkload`"), "{err}");
        // Traffic workload typo (the classic silently-ignored knob).
        let err = Scenario::parse(
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0, "max_bacth": 32}}"#,
        )
        .unwrap_err();
        assert!(err.contains("unknown traffic workload field `max_bacth`"), "{err}");
        // SLO object typo.
        let err = Scenario::parse(
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0, "slo": {"ttft": 2.0, "tpot_s": 0.1}}}"#,
        )
        .unwrap_err();
        assert!(err.contains("unknown traffic `slo` field `ttft`"), "{err}");
        // Fault-spec typo surfaces through the scenario parser too.
        let err = Scenario::parse(
            r#"{"hardware": "a100", "workload": {"type": "traffic", "model": "gpt-small",
                "requests": 8, "rate_per_s": 5.0, "faults": {"mtbf": 100.0}}}"#,
        )
        .unwrap_err();
        assert!(err.contains("unknown fault spec field `mtbf`"), "{err}");
    }

    #[test]
    fn load_prefixes_unknown_field_errors_with_the_file_path() {
        let dir = std::env::temp_dir().join("llmcompass-test-scenario-unknown-field");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("typo.json");
        std::fs::write(
            &path,
            r#"{"hardware": "a100", "workload": {"type": "hardware"}, "outpts": ["cost"]}"#,
        )
        .unwrap();
        let err = Scenario::load(&path).unwrap_err();
        assert!(err.contains("typo.json"), "no file path in `{err}`");
        assert!(err.contains("unknown scenario field `outpts`"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
