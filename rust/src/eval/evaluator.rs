//! The [`Evaluator`]: one entry point that turns a [`Scenario`] into an
//! [`EvalReport`] with a stable JSON schema ([`SCHEMA_VERSION`]).
//!
//! The evaluator owns the analytical [`Simulator`], so its mapper caches
//! persist across every scenario it evaluates: a suite of scenarios that
//! revisit the same (device, GEMM shape) pairs performs strictly fewer
//! mapper parameter searches than evaluating each scenario with its own
//! simulator — the cross-scenario caching that makes `--suite` runs take
//! seconds. [`Evaluator::evaluate_suite`] additionally fans scenarios
//! across the [`crate::util::pool`] worker threads.

use super::scenario::{build_graph, Output, Scenario, TrafficSpec, Workload};
use crate::area::{die_breakdown, AreaParams, DieBreakdown};
use crate::cost::{device_cost, CostParams, CostReport};
use crate::graph::inference::{LayerReport, Simulator};
use crate::graph::ModelConfig;
use crate::hardware::{config, SystemSpec};
use crate::perf::graph_sched::Schedule;
use crate::perf::OpResult;
use crate::serve;
use crate::util::json::{num, obj, s, Json};
use crate::util::telemetry::Recorder;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

/// Version of the [`EvalReport::to_json`] schema. Bump on breaking change.
pub const SCHEMA_VERSION: u64 = 1;

/// Version of the `telemetry` section inside [`EvalReport::to_json`].
/// Versioned independently of [`SCHEMA_VERSION`]: the summary can grow
/// counters without invalidating the report schema itself.
pub const TELEMETRY_SCHEMA_VERSION: u64 = 1;

/// Resolve a model name, with the known registry in the error message.
/// Shared by the evaluator and the CLI's `--model` arguments.
pub fn model_by_name(name: &str) -> Result<ModelConfig, String> {
    ModelConfig::by_name(name).ok_or_else(|| {
        format!("unknown model `{name}` (known: {})", ModelConfig::known_names().join(", "))
    })
}

/// Materialize the request trace of a traffic workload: replayed from its
/// `trace` file when set, generated from the spec otherwise.
pub fn traffic_requests(t: &TrafficSpec) -> Result<Vec<serve::Request>, String> {
    if let Some(path) = &t.trace {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read trace {path}: {e}"))?;
        return serve::workload::parse_trace(&text);
    }
    if !t.rate_per_s.is_finite() || t.rate_per_s <= 0.0 {
        return Err(format!("traffic rate_per_s must be positive, got {}", t.rate_per_s));
    }
    let mut spec = serve::WorkloadSpec::poisson(t.rate_per_s, t.requests, t.seed);
    if let Some(mult) = t.burst_multiplier {
        spec.arrival = serve::Arrival::Bursty {
            rate_per_s: t.rate_per_s,
            burst_multiplier: mult,
            mean_phase_requests: 50.0,
        };
    }
    spec.diurnal = t.diurnal;
    spec.flash_crowd = t.flash_crowd;
    Ok(serve::workload::generate(&spec))
}

/// Serving-level result of a traffic scenario.
#[derive(Debug, Clone)]
pub struct ServingReport {
    pub summary: serve::Summary,
    pub stats: serve::RunStats,
    /// Per-replica scheduler stats when the scenario ran a fleet
    /// (`replicas > 1`); empty — and omitted from the JSON — on the
    /// legacy single-engine path.
    pub replica_stats: Vec<serve::RunStats>,
    pub kv_capacity_tokens: u64,
    /// Die + memory cost of the whole cluster — all replicas, when the
    /// scenario runs a fleet.
    pub cluster_cost_usd: f64,
    /// $ per million output tokens at the SLO (hardware amortized over
    /// [`serve::sweep::AMORT_SECONDS`]); infinite when nothing met it.
    pub usd_per_mtok: f64,
}

/// One requested output, evaluated.
#[derive(Debug, Clone)]
pub enum EvalResult {
    /// `latency` of an op workload.
    OpLatency { op_name: String, result: OpResult },
    /// `latency` of a layer workload (per-layer breakdown; `layers` is the
    /// model depth for the stacked total).
    LayerLatency { layers: u64, per_layer: LayerReport },
    /// `latency` of a request workload (end-to-end seconds).
    RequestLatency { total_s: f64, tokens_per_s_per_request: f64 },
    /// `latency` of a graph workload: the full DAG schedule.
    GraphLatency { schedule: Schedule },
    /// `throughput` of a request workload (batch × decode tokens / total).
    Throughput { tokens_per_s: f64 },
    /// `area` of the device.
    Area(DieBreakdown),
    /// `cost` of the device.
    Cost(CostReport),
    /// `serving` metrics of a traffic workload.
    Serving(ServingReport),
}

impl EvalResult {
    /// The `results` key this result is filed under.
    pub fn output_key(&self) -> &'static str {
        match self {
            EvalResult::OpLatency { .. }
            | EvalResult::LayerLatency { .. }
            | EvalResult::RequestLatency { .. }
            | EvalResult::GraphLatency { .. } => "latency",
            EvalResult::Throughput { .. } => "throughput",
            EvalResult::Area(_) => "area",
            EvalResult::Cost(_) => "cost",
            EvalResult::Serving(_) => "serving",
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            EvalResult::OpLatency { op_name, result } => obj(vec![
                ("kind", s("op")),
                ("op", s(op_name)),
                ("latency_s", num(result.latency_s)),
                ("compute_bound_s", num(result.compute_bound_s)),
                ("memory_bound_s", num(result.memory_bound_s)),
                ("roofline_fraction", num(result.roofline_fraction())),
                ("mapper_rounds", num(result.mapper_rounds as f64)),
                ("mapping", s(&result.mapping_desc)),
            ]),
            EvalResult::LayerLatency { layers, per_layer } => obj(vec![
                ("kind", s("layer")),
                ("per_layer_s", num(per_layer.total_s)),
                ("layers", num(*layers as f64)),
                ("stack_s", num(per_layer.total_s * *layers as f64)),
                (
                    "breakdown",
                    Json::Arr(
                        per_layer
                            .breakdown
                            .iter()
                            .map(|(op, sec)| obj(vec![("op", s(op)), ("seconds", num(*sec))]))
                            .collect(),
                    ),
                ),
            ]),
            EvalResult::RequestLatency { total_s, tokens_per_s_per_request } => obj(vec![
                ("kind", s("request")),
                ("total_s", num(*total_s)),
                ("tokens_per_s_per_request", num(*tokens_per_s_per_request)),
            ]),
            EvalResult::GraphLatency { schedule } => obj(vec![
                ("kind", s("graph")),
                ("total_s", num(schedule.total_s)),
                ("critical_path_s", num(schedule.critical_path_s)),
                ("serial_s", num(schedule.serial_s)),
                (
                    "resources",
                    Json::Obj(
                        schedule
                            .resource_busy()
                            .into_iter()
                            .map(|(name, busy)| (name, num(busy)))
                            .collect(),
                    ),
                ),
                (
                    "nodes",
                    Json::Arr(
                        schedule
                            .timings
                            .iter()
                            .map(|t| {
                                obj(vec![
                                    ("name", s(&t.name)),
                                    ("stage", num(t.stage as f64)),
                                    ("resource", s(if t.comm { "comm" } else { "compute" })),
                                    ("start_s", num(t.start_s)),
                                    ("finish_s", num(t.finish_s)),
                                    ("latency_s", num(t.latency_s)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            EvalResult::Throughput { tokens_per_s } => {
                obj(vec![("kind", s("request")), ("tokens_per_s", num(*tokens_per_s))])
            }
            EvalResult::Area(b) => b.to_json(),
            EvalResult::Cost(c) => c.to_json(),
            EvalResult::Serving(r) => {
                let mut fields = vec![
                    ("kv_capacity_tokens", num(r.kv_capacity_tokens as f64)),
                    ("cluster_cost_usd", num(r.cluster_cost_usd)),
                    ("usd_per_mtok", num(r.usd_per_mtok)),
                    ("summary", r.summary.to_json()),
                    ("stats", r.stats.to_json()),
                ];
                if !r.replica_stats.is_empty() {
                    fields.push((
                        "replicas",
                        Json::Arr(r.replica_stats.iter().map(|st| st.to_json()).collect()),
                    ));
                }
                obj(fields)
            }
        }
    }
}

/// Framework self-profiling attached to every report (the `telemetry`
/// section, [`TELEMETRY_SCHEMA_VERSION`]).
///
/// Mapper counters are evaluator-wide deltas taken around this one
/// evaluation — exact under serial evaluation (the golden harness), an
/// approximate attribution when a suite fans scenarios across threads
/// (concurrent scenarios share the counters). `eval_wall_s` is host
/// wall-clock and inherently nondeterministic; the golden harness
/// excludes the `telemetry.host` subtree from comparison.
#[derive(Debug, Clone, Default)]
pub struct TelemetrySummary {
    /// Mapper parameter searches performed (cache misses).
    pub mapper_searches: u64,
    /// Candidates actually simulated across those searches.
    pub mapper_rounds: u64,
    /// Candidates enumerated (simulated + pruned).
    pub mapper_candidates: u64,
    /// Candidates skipped by lower-bound pruning.
    pub mapper_pruned: u64,
    /// In-memory memoization hits on the mapper fast path.
    pub mapper_cache_hits: u64,
    /// Systolic-array timing LUT hits / misses.
    pub lut_hits: u64,
    pub lut_misses: u64,
    /// Serving latency-oracle activity (shared-oracle cache): bucket hits
    /// and misses, unique decode fits / prefill points simulated, and the
    /// underlying analytical-simulator calls those cost. Deltas around
    /// this evaluation, like the mapper counters above; all zero for
    /// scenarios with no serving output.
    pub oracle_hits: u64,
    pub oracle_misses: u64,
    pub oracle_decode_fits: u64,
    pub oracle_prefill_points: u64,
    pub oracle_sim_calls: u64,
    /// Host wall-clock seconds this evaluation took.
    pub eval_wall_s: f64,
}

impl TelemetrySummary {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("schema_version", num(TELEMETRY_SCHEMA_VERSION as f64)),
            (
                "mapper",
                obj(vec![
                    ("searches", num(self.mapper_searches as f64)),
                    ("rounds", num(self.mapper_rounds as f64)),
                    ("candidates", num(self.mapper_candidates as f64)),
                    ("pruned_candidates", num(self.mapper_pruned as f64)),
                    ("cache_hits", num(self.mapper_cache_hits as f64)),
                    ("lut_hits", num(self.lut_hits as f64)),
                    ("lut_misses", num(self.lut_misses as f64)),
                ]),
            ),
            (
                "oracle",
                obj(vec![
                    ("hits", num(self.oracle_hits as f64)),
                    ("misses", num(self.oracle_misses as f64)),
                    ("decode_fits", num(self.oracle_decode_fits as f64)),
                    ("prefill_points", num(self.oracle_prefill_points as f64)),
                    ("sim_calls", num(self.oracle_sim_calls as f64)),
                ]),
            ),
            ("host", obj(vec![("eval_wall_s", num(self.eval_wall_s))])),
        ])
    }
}

/// The evaluation of one scenario: the resolved system plus one result per
/// requested output.
#[derive(Debug, Clone)]
pub struct EvalReport {
    pub scenario: Scenario,
    pub system: SystemSpec,
    /// One entry per requested output, in the scenario's output order.
    pub results: Vec<EvalResult>,
    /// Framework self-profiling for this evaluation.
    pub telemetry: TelemetrySummary,
}

impl EvalReport {
    /// Stable-schema JSON: `schema_version`, the scenario as written, the
    /// resolved hardware, and the results keyed by output name.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("schema_version", num(SCHEMA_VERSION as f64)),
            ("scenario", self.scenario.to_json()),
            (
                "hardware",
                obj(vec![
                    ("device", s(&self.system.device.name)),
                    ("device_count", num(self.system.device_count as f64)),
                ]),
            ),
            (
                "results",
                Json::Obj(
                    self.results
                        .iter()
                        .map(|r| (r.output_key().to_string(), r.to_json()))
                        .collect(),
                ),
            ),
            ("telemetry", self.telemetry.to_json()),
        ])
    }
}

/// The unified evaluator: resolves a scenario's hardware, runs its
/// workload, and produces every requested output through the performance,
/// area, cost, and serving models.
pub struct Evaluator {
    /// The analytical simulator; its mapper caches persist across every
    /// scenario this evaluator touches.
    pub sim: Simulator,
    pub area_params: AreaParams,
    pub cost_params: CostParams,
}

impl Default for Evaluator {
    fn default() -> Self {
        Self::new()
    }
}

impl Evaluator {
    pub fn new() -> Evaluator {
        Evaluator::with_sim(Simulator::new())
    }

    /// An evaluator whose mapper fans each candidate search across all
    /// cores as a fixed pool — for single-stream callers that own the
    /// whole machine.
    pub fn pooled() -> Evaluator {
        Evaluator::with_sim(Simulator::pooled())
    }

    /// An evaluator whose mapper runs in work-stealing hybrid mode —
    /// the right choice under [`Evaluator::evaluate_suite_shared`] (and
    /// the experiment context): scenario fan-out and the per-candidate
    /// loops borrow from one process-wide worker budget, so suites with
    /// few scenarios still use every core and suites with many never
    /// oversubscribe.
    pub fn hybrid() -> Evaluator {
        Evaluator::with_sim(Simulator::hybrid())
    }

    pub fn with_sim(sim: Simulator) -> Evaluator {
        Evaluator { sim, area_params: AreaParams::default(), cost_params: CostParams::default() }
    }

    /// Attach a telemetry recorder (builder style): threaded through the
    /// simulator into the serving scheduler and the mapper, so one
    /// `--trace` handle collects all three instrumentation layers.
    pub fn with_recorder(mut self, rec: Arc<Recorder>) -> Evaluator {
        self.sim.set_recorder(rec);
        self
    }

    /// The attached telemetry recorder (disabled unless one was attached).
    pub fn recorder(&self) -> &Arc<Recorder> {
        &self.sim.recorder
    }

    /// Evaluate one scenario into a report.
    pub fn evaluate(&self, sc: &Scenario) -> Result<EvalReport, String> {
        let system = config::resolve(&sc.hardware)?;
        self.evaluate_on(sc, system)
    }

    /// Evaluate a scenario on an explicitly provided system, bypassing
    /// the scenario's `hardware` field — the entry point the design-space
    /// autotuner ([`crate::tune`]) uses to score synthesized candidate
    /// designs that exist as no preset or file.
    pub fn evaluate_on(&self, sc: &Scenario, system: SystemSpec) -> Result<EvalReport, String> {
        // Counter baselines for the report's telemetry deltas (exact when
        // scenarios run serially; see [`TelemetrySummary`]).
        let wall = Instant::now();
        let host_t0 = self.sim.recorder.host_now_s();
        let (lut_hits0, lut_misses0) = self.sim.mapper.lut_stats();
        let oracle0 = self.sim.oracles.snapshot();
        let searches0 = self.sim.mapper.searches();
        let rounds0 = self.sim.mapper.total_rounds();
        let candidates0 = self.sim.mapper.total_candidates();
        let cache_hits0 = self.sim.mapper.cache_hits();
        if sc.outputs.is_empty() {
            return Err(format!("scenario `{}` requests no outputs", sc.name));
        }
        if let Some(p) = &sc.parallelism {
            if matches!(
                sc.workload,
                Workload::Hardware | Workload::Traffic(_) | Workload::Op(_)
            ) {
                return Err(format!(
                    "scenario `{}`: `parallelism` applies to layer/request/graph workloads",
                    sc.name
                ));
            }
            // Validate the device mapping up front so a typo'd scenario
            // fails even when it only requests area/cost outputs.
            p.validate(system.device_count)
                .map_err(|e| format!("scenario `{}`: {e}", sc.name))?;
        }
        let mut results = Vec::with_capacity(sc.outputs.len());
        for &out in &sc.outputs {
            let r = self.eval_output(&system, sc, out, &results)?;
            results.push(r);
        }
        let (lut_hits, lut_misses) = self.sim.mapper.lut_stats();
        let oracle = self.sim.oracles.snapshot();
        let telemetry = TelemetrySummary {
            mapper_searches: self.sim.mapper.searches() - searches0,
            mapper_rounds: self.sim.mapper.total_rounds() - rounds0,
            mapper_candidates: self.sim.mapper.total_candidates() - candidates0,
            mapper_pruned: (self.sim.mapper.total_candidates() - candidates0)
                .saturating_sub(self.sim.mapper.total_rounds() - rounds0),
            mapper_cache_hits: self.sim.mapper.cache_hits() - cache_hits0,
            lut_hits: lut_hits - lut_hits0,
            lut_misses: lut_misses - lut_misses0,
            oracle_hits: oracle.hits - oracle0.hits,
            oracle_misses: oracle.misses - oracle0.misses,
            oracle_decode_fits: oracle.decode_fits - oracle0.decode_fits,
            oracle_prefill_points: oracle.prefill_points - oracle0.prefill_points,
            oracle_sim_calls: oracle.sim_calls - oracle0.sim_calls,
            eval_wall_s: wall.elapsed().as_secs_f64(),
        };
        let rec = &self.sim.recorder;
        if rec.is_enabled() {
            rec.span_host(
                "eval",
                &format!("scenario {}", sc.name),
                host_t0,
                &[
                    ("mapper_searches", num(telemetry.mapper_searches as f64)),
                    ("mapper_rounds", num(telemetry.mapper_rounds as f64)),
                ],
            );
        }
        Ok(EvalReport { scenario: sc.clone(), system, results, telemetry })
    }

    /// Evaluate many scenarios with a shared mapper cache, fanned across
    /// `threads` fixed pool workers. Per-scenario errors are returned in
    /// place, so one bad scenario does not sink the suite.
    pub fn evaluate_suite(
        &self,
        scenarios: &[Scenario],
        threads: usize,
    ) -> Vec<Result<EvalReport, String>> {
        crate::util::pool::parallel_map(scenarios, threads, |sc| self.evaluate(sc))
    }

    /// Like [`Evaluator::evaluate_suite`], but fanned across the
    /// process-wide work-stealing token budget. Combined with a
    /// [`Evaluator::hybrid`] evaluator, a scenario worker that finishes
    /// donates its thread to the mapper candidate loops still running in
    /// the suite's tail.
    pub fn evaluate_suite_shared(
        &self,
        scenarios: &[Scenario],
    ) -> Vec<Result<EvalReport, String>> {
        crate::util::pool::parallel_map_shared(scenarios, |sc| self.evaluate(sc))
    }

    /// The tensor-parallel degree a layer workload runs at: the scenario's
    /// explicit mapping (pipeline-free, since one layer is one stage) or
    /// the historical default of the whole system.
    fn layer_tp_for(
        &self,
        system: &SystemSpec,
        sc: &Scenario,
        model: &ModelConfig,
    ) -> Result<u64, String> {
        let Some(p) = &sc.parallelism else { return Ok(system.device_count) };
        p.validate(system.device_count).map_err(|e| format!("scenario `{}`: {e}", sc.name))?;
        if p.pp != 1 {
            return Err(format!(
                "scenario `{}`: a layer workload is a single pipeline stage (pp must be 1; \
                 use a request or graph workload for pipeline parallelism)",
                sc.name
            ));
        }
        p.validate_heads(model.heads, &model.name)
            .map_err(|e| format!("scenario `{}`: {e}", sc.name))?;
        Ok(p.tp)
    }

    /// End-to-end seconds of a request workload under the scenario's
    /// device mapping (shared by the `latency` and `throughput` outputs).
    /// The layer count resolves through
    /// [`ModelConfig::resolve_layers`] — the single clamp the evaluator
    /// and the graph lowering both use.
    #[allow(clippy::too_many_arguments)]
    fn request_total_s(
        &self,
        system: &SystemSpec,
        sc: &Scenario,
        model: &str,
        batch: u64,
        prefill: u64,
        decode: u64,
        layers: Option<u64>,
    ) -> Result<f64, String> {
        let m = model_by_name(model)?;
        let layers = m.resolve_layers(layers);
        match &sc.parallelism {
            None => Ok(self.sim.e2e_latency(system, &m, batch, prefill, decode, layers)),
            Some(p) => self
                .sim
                .e2e_latency_parallel(system, &m, batch, prefill, decode, layers, p)
                .map_err(|e| format!("scenario `{}`: {e}", sc.name)),
        }
    }

    fn eval_output(
        &self,
        system: &SystemSpec,
        sc: &Scenario,
        out: Output,
        prior: &[EvalResult],
    ) -> Result<EvalResult, String> {
        match out {
            Output::Latency => match &sc.workload {
                // `parallelism` on an op workload is rejected up front in
                // `evaluate`, together with hardware/traffic workloads.
                Workload::Op(op) => Ok(EvalResult::OpLatency {
                    op_name: op.name().to_string(),
                    result: self.sim.op_latency(system, op),
                }),
                Workload::Layer { model, phase } => {
                    let m = model_by_name(model)?;
                    let tp = self.layer_tp_for(system, sc, &m)?;
                    Ok(EvalResult::LayerLatency {
                        layers: m.layers,
                        per_layer: self.sim.layer_tp(system, &m, *phase, tp),
                    })
                }
                Workload::Request { model, batch, prefill, decode, layers } => {
                    let total_s =
                        self.request_total_s(system, sc, model, *batch, *prefill, *decode, *layers)?;
                    Ok(EvalResult::RequestLatency {
                        total_s,
                        tokens_per_s_per_request: *decode as f64 / total_s,
                    })
                }
                Workload::Graph { nodes, edges } => {
                    let base = build_graph(nodes, edges)
                        .map_err(|e| format!("scenario `{}`: {e}", sc.name))?;
                    let g = match &sc.parallelism {
                        None => base,
                        Some(p) => {
                            p.validate(system.device_count)
                                .map_err(|e| format!("scenario `{}`: {e}", sc.name))?;
                            let g = base
                                .tensor_parallel(p.tp)
                                .map_err(|e| format!("scenario `{}`: {e}", sc.name))?;
                            if p.pp > 1 {
                                g.pipeline_parallel(p.pp, p.microbatches)
                                    .map_err(|e| format!("scenario `{}`: {e}", sc.name))?
                            } else {
                                g
                            }
                        }
                    };
                    let schedule = self.sim.schedule_graph(system, &g);
                    if self.sim.recorder.is_enabled() {
                        crate::perf::graph_sched::emit_trace(
                            &self.sim.recorder,
                            &format!("graph {}", sc.name),
                            &schedule,
                        );
                    }
                    Ok(EvalResult::GraphLatency { schedule })
                }
                Workload::Traffic(_) => Err(format!(
                    "scenario `{}`: `latency` needs an op/layer/request/graph workload \
                     (traffic scenarios report `serving`)",
                    sc.name
                )),
                Workload::Hardware => {
                    Err(format!("scenario `{}`: `latency` needs a workload", sc.name))
                }
            },
            Output::Throughput => match &sc.workload {
                Workload::Request { model, batch, prefill, decode, layers } => {
                    // Reuse an already-computed latency result when this
                    // scenario also requested `latency` — identical
                    // simulation, no need to run it twice.
                    let total_s = prior.iter().find_map(|r| match r {
                        EvalResult::RequestLatency { total_s, .. } => Some(*total_s),
                        _ => None,
                    });
                    let total_s = match total_s {
                        Some(t) => t,
                        None => self
                            .request_total_s(system, sc, model, *batch, *prefill, *decode, *layers)?,
                    };
                    Ok(EvalResult::Throughput {
                        tokens_per_s: (*batch * *decode) as f64 / total_s,
                    })
                }
                _ => Err(format!(
                    "scenario `{}`: `throughput` needs a request workload",
                    sc.name
                )),
            },
            Output::Area => Ok(EvalResult::Area(die_breakdown(
                &self.area_params,
                &system.device,
                system.interconnect.link_bandwidth_bytes_per_s,
            ))),
            Output::Cost => Ok(EvalResult::Cost(device_cost(&self.cost_params, &system.device))),
            Output::Serving => match &sc.workload {
                Workload::Traffic(t) => self.eval_serving(system, sc, t),
                _ => Err(format!(
                    "scenario `{}`: `serving` needs a traffic workload",
                    sc.name
                )),
            },
        }
    }

    fn eval_serving(
        &self,
        system: &SystemSpec,
        sc: &Scenario,
        t: &TrafficSpec,
    ) -> Result<EvalResult, String> {
        let model = model_by_name(&t.model)?;
        let cfg = scheduler_config_for(system, &model, t)
            .map_err(|e| format!("scenario `{}`: {e}", sc.name))?;
        let requests = traffic_requests(t)?;
        let fleet = serve::FleetConfig { replicas: t.replicas, balancer: t.balancer };
        serve::validate_fleet(&cfg, system.device_count, &fleet, &requests)
            .map_err(|e| format!("scenario `{}`: {e}", sc.name))?;
        let (report, _) =
            serve::serve_fleet(&self.sim, system, &model, &cfg, &fleet, &requests, &t.slo);
        // A fleet buys the whole cluster once per replica.
        let cluster_cost_usd = device_cost(&self.cost_params, &system.device).total_usd()
            * system.device_count as f64
            * t.replicas as f64;
        let usd_per_mtok =
            serve::sweep::usd_per_mtok_at_slo(cluster_cost_usd, report.summary.goodput_tok_s);
        Ok(EvalResult::Serving(ServingReport {
            summary: report.summary,
            stats: report.stats,
            replica_stats: report.replica_stats,
            kv_capacity_tokens: cfg.kv_capacity_tokens,
            cluster_cost_usd,
            usd_per_mtok,
        }))
    }
}

/// Build the scheduler configuration a traffic workload asks for on a
/// concrete system: derive the KV budget from hardware + model, then apply
/// the spec's knobs (batch cap, execution mode, preemption, KV clamp).
/// Shared by the evaluator, the `serve` CLI, and the integration tests so
/// every surface runs the identical configuration for the same scenario.
pub fn scheduler_config_for(
    system: &SystemSpec,
    model: &ModelConfig,
    t: &TrafficSpec,
) -> Result<serve::SchedulerConfig, String> {
    if t.max_batch == 0 {
        return Err("traffic max_batch must be ≥ 1".to_string());
    }
    let mut cfg = serve::SchedulerConfig::for_system(system, model, t.policy);
    cfg.max_batch = t.max_batch;
    cfg.mode = t.mode.resolved(system.device_count)?;
    cfg.preemption = t.preemption;
    if t.handoff_capacity == Some(0) {
        return Err("traffic handoff_capacity must be ≥ 1".to_string());
    }
    cfg.handoff_capacity = t.handoff_capacity;
    if let Some(clamp) = t.max_kv_tokens {
        if clamp == 0 {
            return Err("traffic max_kv_tokens must be ≥ 1".to_string());
        }
        cfg.kv_capacity_tokens = cfg.kv_capacity_tokens.min(clamp);
    }
    if cfg.kv_capacity_tokens == 0 {
        return Err(format!(
            "model `{}` does not fit `{}` (parameters exceed memory capacity)",
            model.name, system.device.name
        ));
    }
    if let Some(spec) = &t.faults {
        spec.validate()?;
        cfg.faults = Some(Arc::new(spec.clone()));
    }
    Ok(cfg)
}

/// Load every `*.json` scenario in a directory (sorted by file name) as
/// one suite.
pub fn load_suite(dir: &Path) -> Result<Vec<Scenario>, String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("read suite dir {}: {e}", dir.display()))?;
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    paths.sort();
    if paths.is_empty() {
        return Err(format!("no *.json scenario files in {}", dir.display()));
    }
    paths.iter().map(|p| Scenario::load(p)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::Phase;
    use crate::hardware::DType;
    use crate::perf::Op;

    fn small_op() -> Op {
        Op::Matmul { b: 1, m: 256, k: 512, n: 256, dtype: DType::FP16, batched_b: false }
    }

    fn op_scenario(name: &str, hardware: &str) -> Scenario {
        Scenario::new(name, hardware, Workload::Op(small_op()))
    }

    fn traffic_scenario(name: &str, hardware: &str) -> Scenario {
        let mut t = TrafficSpec::poisson("gpt-small", 25.0, 32);
        t.slo = crate::serve::Slo::relaxed();
        t.seed = 7;
        Scenario::new(name, hardware, Workload::Traffic(t)).with_output(Output::Cost)
    }

    #[test]
    fn op_scenario_matches_direct_simulation() {
        let ev = Evaluator::new();
        let rep = ev.evaluate(&op_scenario("op", "a100")).unwrap();
        let EvalResult::OpLatency { op_name, result } = &rep.results[0] else {
            panic!("expected op latency")
        };
        assert_eq!(op_name, "matmul");
        let sys = crate::hardware::presets::system("a100").unwrap();
        let direct = ev.sim.op_latency(&sys, &small_op());
        assert_eq!(result.latency_s, direct.latency_s);
        assert_eq!(result.mapping_desc, direct.mapping_desc);
    }

    #[test]
    fn round_trip_scenario_evaluates_identically() {
        // serialize → parse → evaluate must match evaluating the original.
        let sc = Scenario::new(
            "layer",
            "a100",
            Workload::Layer {
                model: "gpt-small".into(),
                phase: Phase::Prefill { batch: 4, seq: 128 },
            },
        );
        let again = Scenario::parse(&sc.to_json().to_string_pretty()).unwrap();
        assert_eq!(sc, again);
        let ev = Evaluator::new();
        let (a, b) = (ev.evaluate(&sc).unwrap(), ev.evaluate(&again).unwrap());
        let (
            EvalResult::LayerLatency { per_layer: ra, .. },
            EvalResult::LayerLatency { per_layer: rb, .. },
        ) = (&a.results[0], &b.results[0])
        else {
            panic!("expected layer latency")
        };
        assert_eq!(ra.total_s, rb.total_s);
    }

    #[test]
    fn request_latency_and_throughput_consistent() {
        let sc = Scenario::new(
            "req",
            "a100",
            Workload::Request {
                model: "gpt-small".into(),
                batch: 2,
                prefill: 64,
                decode: 16,
                layers: Some(2),
            },
        )
        .with_output(Output::Throughput);
        let ev = Evaluator::new();
        let rep = ev.evaluate(&sc).unwrap();
        let EvalResult::RequestLatency { total_s, tokens_per_s_per_request } = &rep.results[0]
        else {
            panic!("expected request latency")
        };
        let EvalResult::Throughput { tokens_per_s } = &rep.results[1] else {
            panic!("expected throughput")
        };
        assert!(*total_s > 0.0);
        assert!((tokens_per_s_per_request * 2.0 - tokens_per_s).abs() < 1e-9);
    }

    #[test]
    fn hardware_scenario_reports_area_and_cost() {
        let ev = Evaluator::new();
        let rep = ev.evaluate(&Scenario::new("hw", "ga100", Workload::Hardware)).unwrap();
        assert_eq!(rep.results.len(), 2);
        let EvalResult::Area(area) = &rep.results[0] else { panic!("expected area") };
        let EvalResult::Cost(cost) = &rep.results[1] else { panic!("expected cost") };
        assert!(area.total_mm2() > 0.0);
        assert!((cost.die_mm2 - area.total_mm2()).abs() < 1e-9);
        assert!(cost.total_usd() > 0.0);
    }

    #[test]
    fn traffic_scenario_serves_and_prices() {
        let ev = Evaluator::new();
        let rep = ev.evaluate(&traffic_scenario("t", "ga100")).unwrap();
        let EvalResult::Serving(sr) = &rep.results[0] else { panic!("expected serving") };
        assert_eq!(sr.summary.requests, 32);
        assert!(sr.summary.throughput_tok_s > 0.0);
        assert!(sr.kv_capacity_tokens > 0);
        assert!(sr.cluster_cost_usd > 0.0);
        assert!(sr.usd_per_mtok > 0.0);
        let EvalResult::Cost(_) = &rep.results[1] else { panic!("expected cost") };
    }

    #[test]
    fn graph_scenario_schedules_branches_with_overlap() {
        // ln → (left, right) → join: the two branch matmuls are
        // independent, but on a single device (one compute resource) they
        // serialize — the schedule must equal the serial sum. The report
        // carries the full timeline.
        let mm = |m, k, n| Op::Matmul { b: 1, m, k, n, dtype: DType::FP16, batched_b: false };
        let sc = Scenario::parse(
            r#"{"name": "branchy", "hardware": "a100",
                "workload": {"type": "graph", "nodes": [
                    {"name": "ln", "op": "layernorm", "dims": [256, 512]},
                    {"name": "left", "op": "matmul", "dims": [256, 512, 512], "deps": ["ln"]},
                    {"name": "right", "op": "matmul", "dims": [256, 512, 512], "deps": ["ln"]},
                    {"name": "join", "op": "gelu", "dims": [131072], "deps": ["left", "right"]}
                ]}}"#,
        )
        .unwrap();
        let ev = Evaluator::new();
        let rep = ev.evaluate(&sc).unwrap();
        let EvalResult::GraphLatency { schedule } = &rep.results[0] else {
            panic!("expected graph latency")
        };
        assert_eq!(schedule.timings.len(), 4);
        assert_eq!(schedule.total_s.to_bits(), schedule.serial_s.to_bits());
        assert!(schedule.critical_path_s < schedule.serial_s, "branches off the critical path");
        // Spot-check one node against direct simulation.
        let sys = crate::hardware::presets::system("a100").unwrap();
        let direct = ev.sim.op_latency(&sys, &mm(256, 512, 512)).latency_s;
        assert_eq!(schedule.timings[1].latency_s.to_bits(), direct.to_bits());
        // JSON carries the schedule.
        let j = rep.to_json();
        let lat = j.get("results").unwrap().get("latency").unwrap();
        assert_eq!(lat.get("kind").and_then(Json::as_str), Some("graph"));
        assert!(lat.get("nodes").is_some());
        assert!(lat.get("resources").unwrap().get("compute:0").is_some());
    }

    #[test]
    fn parallelism_routes_and_validates() {
        use crate::graph::ir::Parallelism;
        let ev = Evaluator::new();
        // Request with explicit tp == device_count matches the legacy path.
        let req = Scenario::new(
            "req",
            "a100x2",
            Workload::Request {
                model: "gpt-small".into(),
                batch: 4,
                prefill: 64,
                decode: 8,
                layers: Some(2),
            },
        );
        let legacy = ev.evaluate(&req).unwrap();
        let explicit = ev
            .evaluate(&req.clone().with_parallelism(Parallelism { tp: 2, pp: 1, microbatches: 1 }))
            .unwrap();
        let total = |rep: &EvalReport| match &rep.results[0] {
            EvalResult::RequestLatency { total_s, .. } => *total_s,
            _ => panic!("expected request latency"),
        };
        assert_eq!(total(&legacy).to_bits(), total(&explicit).to_bits());
        // A mapping that does not match the system errors.
        let bad = req.clone().with_parallelism(Parallelism { tp: 4, pp: 1, microbatches: 1 });
        assert!(ev.evaluate(&bad).unwrap_err().contains("devices"));
        // Parallelism on traffic workloads is rejected.
        let t = traffic_scenario("t", "ga100")
            .with_parallelism(Parallelism { tp: 1, pp: 1, microbatches: 1 });
        assert!(ev.evaluate(&t).is_err());
        // ... and on op workloads, regardless of the requested outputs.
        let o = op_scenario("op", "a100")
            .with_outputs(&[Output::Area, Output::Cost])
            .with_parallelism(Parallelism { tp: 1, pp: 1, microbatches: 1 });
        assert!(ev.evaluate(&o).is_err());
        // An impossible mapping fails even when only area/cost outputs
        // are requested (nothing would otherwise touch it).
        let l = Scenario::new(
            "l-area",
            "a100",
            Workload::Layer {
                model: "gpt-small".into(),
                phase: Phase::Prefill { batch: 2, seq: 64 },
            },
        )
        .with_outputs(&[Output::Area, Output::Cost])
        .with_parallelism(Parallelism { tp: 3, pp: 5, microbatches: 1 });
        assert!(ev.evaluate(&l).unwrap_err().contains("devices"));
        // Layer workloads accept tp but not pp.
        let layer = Scenario::new(
            "l",
            "a100x2",
            Workload::Layer {
                model: "gpt-small".into(),
                phase: Phase::Prefill { batch: 2, seq: 64 },
            },
        );
        assert!(ev
            .evaluate(&layer.clone().with_parallelism(Parallelism { tp: 2, pp: 1, microbatches: 1 }))
            .is_ok());
        let err = ev
            .evaluate(&layer.with_parallelism(Parallelism { tp: 1, pp: 2, microbatches: 1 }))
            .unwrap_err();
        assert!(err.contains("single pipeline stage"), "{err}");
    }

    #[test]
    fn request_layer_clamp_is_shared() {
        // layers beyond the model depth clamp to the full model — the
        // evaluator and the graph lowering agree by construction because
        // both call ModelConfig::resolve_layers.
        let ev = Evaluator::new();
        let mk = |layers| {
            Scenario::new(
                "req",
                "a100",
                Workload::Request {
                    model: "gpt-small".into(),
                    batch: 1,
                    prefill: 32,
                    decode: 4,
                    layers,
                },
            )
        };
        let full = ev.evaluate(&mk(None)).unwrap();
        let clamped = ev.evaluate(&mk(Some(10_000))).unwrap();
        let total = |rep: &EvalReport| match &rep.results[0] {
            EvalResult::RequestLatency { total_s, .. } => *total_s,
            _ => panic!("expected request latency"),
        };
        assert_eq!(total(&full).to_bits(), total(&clamped).to_bits());
    }

    #[test]
    fn mismatched_outputs_error() {
        let ev = Evaluator::new();
        let bad = op_scenario("op", "a100").with_outputs(&[Output::Serving]);
        assert!(ev.evaluate(&bad).is_err());
        let bad = traffic_scenario("t", "ga100").with_outputs(&[Output::Latency]);
        assert!(ev.evaluate(&bad).is_err());
        let bad = Scenario::new("hw", "a100", Workload::Hardware).with_outputs(&[Output::Latency]);
        assert!(ev.evaluate(&bad).is_err());
        let bad = op_scenario("op", "not-a-device");
        assert!(ev.evaluate(&bad).is_err());
        let bad = Scenario::new(
            "m",
            "a100",
            Workload::Layer {
                model: "gpt-unknown".into(),
                phase: Phase::Prefill { batch: 1, seq: 8 },
            },
        );
        let err = ev.evaluate(&bad).unwrap_err();
        assert!(err.contains("unknown model"), "{err}");
    }

    #[test]
    fn suite_shares_mapper_searches_across_scenarios() {
        // The acceptance criterion of the cross-scenario cache: one shared
        // evaluator performs strictly fewer mapper searches than N
        // independent ones over a suite that revisits the same shapes.
        let suite = vec![
            op_scenario("first", "a100"),
            op_scenario("second", "a100"),
            op_scenario("third", "a100").with_output(Output::Cost),
        ];
        let shared = Evaluator::new();
        for sc in &suite {
            shared.evaluate(sc).unwrap();
        }
        let shared_searches = shared.sim.mapper.searches();
        assert_eq!(shared_searches, 1, "one unique shape → one search");

        let mut independent = 0;
        for sc in &suite {
            let ev = Evaluator::new();
            ev.evaluate(sc).unwrap();
            independent += ev.sim.mapper.searches();
        }
        assert!(
            shared_searches < independent,
            "shared {shared_searches} vs independent {independent}"
        );
    }

    #[test]
    fn parallel_suite_matches_serial() {
        let suite = vec![
            op_scenario("a", "a100"),
            Scenario::new("hw", "ga100", Workload::Hardware),
            op_scenario("b", "ga100"),
        ];
        let serial_ev = Evaluator::new();
        let serial: Vec<_> = suite.iter().map(|sc| serial_ev.evaluate(sc).unwrap()).collect();
        let pooled_ev = Evaluator::new();
        let pooled = pooled_ev.evaluate_suite(&suite, 3);
        assert_eq!(serial.len(), pooled.len());
        for (a, b) in serial.iter().zip(&pooled) {
            let b = b.as_ref().unwrap();
            assert_eq!(a.to_json(), b.to_json());
        }
    }

    #[test]
    fn shared_fanout_matches_serial_results() {
        // The work-stealing hybrid fan-out must produce the identical
        // evaluations (rounds counters may differ under a parallel
        // pruned search — the winners never do).
        let suite = vec![
            op_scenario("a", "a100"),
            op_scenario("b", "ga100"),
            Scenario::new("hw", "ga100", Workload::Hardware),
        ];
        let serial_ev = Evaluator::new();
        let serial: Vec<_> = suite.iter().map(|sc| serial_ev.evaluate(sc).unwrap()).collect();
        let hybrid_ev = Evaluator::hybrid();
        let shared = hybrid_ev.evaluate_suite_shared(&suite);
        assert_eq!(serial.len(), shared.len());
        for (a, b) in serial.iter().zip(&shared) {
            let b = b.as_ref().unwrap();
            match (&a.results[0], &b.results[0]) {
                (
                    EvalResult::OpLatency { result: ra, .. },
                    EvalResult::OpLatency { result: rb, .. },
                ) => {
                    assert_eq!(ra.latency_s.to_bits(), rb.latency_s.to_bits());
                    assert_eq!(ra.mapping_desc, rb.mapping_desc);
                }
                (EvalResult::Area(x), EvalResult::Area(y)) => {
                    assert_eq!(x.total_mm2(), y.total_mm2())
                }
                _ => panic!("result kinds diverged"),
            }
        }
    }

    #[test]
    fn suite_reports_errors_in_place() {
        let suite = vec![op_scenario("good", "a100"), op_scenario("bad", "nope")];
        let ev = Evaluator::new();
        let out = ev.evaluate_suite(&suite, 2);
        assert!(out[0].is_ok());
        assert!(out[1].is_err());
    }

    #[test]
    fn report_json_has_stable_schema() {
        let ev = Evaluator::new();
        let rep = ev.evaluate(&op_scenario("op", "a100").with_output(Output::Area)).unwrap();
        let j = rep.to_json();
        assert_eq!(j.get("schema_version").and_then(Json::as_u64), Some(SCHEMA_VERSION));
        assert_eq!(
            j.get("hardware").unwrap().get("device").and_then(Json::as_str),
            Some("a100")
        );
        let results = j.get("results").unwrap();
        assert!(results.get("latency").unwrap().get("latency_s").is_some());
        assert!(results.get("area").unwrap().get("total").is_some());
        let tel = j.get("telemetry").unwrap();
        assert_eq!(
            tel.get("schema_version").and_then(Json::as_u64),
            Some(TELEMETRY_SCHEMA_VERSION)
        );
        for key in ["searches", "rounds", "candidates", "pruned_candidates", "cache_hits"] {
            assert!(tel.get("mapper").unwrap().get(key).is_some(), "telemetry.mapper lost `{key}`");
        }
        assert!(tel.get("host").unwrap().get("eval_wall_s").is_some());
        // Valid JSON text round trip.
        let text = j.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }
}
