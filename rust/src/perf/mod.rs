//! The operator performance model (paper §III-B).
//!
//! Operators are simulated tile-by-tile across the memory hierarchy:
//! problems are partitioned into global-buffer tiles, then local-buffer
//! sub-tiles scheduled onto cores, then lane-level sub-sub-tiles fed to
//! systolic arrays / vector units. The [`mapper`] parameter-searches the
//! tiling and scheduling space to find the performance-optimal mapping —
//! LLMCompass always reports the *best* mapping found, to "fully
//! demonstrate the hardware capability" of each design.

pub mod matmul;
pub mod mapper;
pub mod vecop;
pub mod comm;
pub mod graph_sched;

use crate::hardware::DType;

/// The dense operators appearing in Transformer graphs, plus the
/// communication primitives needed for parallel inference.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// C[b,m,n] = A[b,m,k] · B[k,n] (+ optional per-batch B: `batched_b`).
    Matmul { b: u64, m: u64, k: u64, n: u64, dtype: DType, batched_b: bool },
    /// Row-wise softmax over an (m × n) view, n is the reduction dim.
    Softmax { m: u64, n: u64, dtype: DType },
    /// Row-wise layer normalization over (m × n).
    LayerNorm { m: u64, n: u64, dtype: DType },
    /// Elementwise GELU over `elements` values.
    Gelu { elements: u64, dtype: DType },
    /// Ring all-reduce of `bytes` across `devices`.
    AllReduce { bytes: u64, devices: u64 },
    /// Point-to-point transfer of `bytes` (pipeline parallelism).
    PeerToPeer { bytes: u64 },
}

impl Op {
    /// Floating-point operations performed (2 per MAC for matmul).
    pub fn flops(&self) -> f64 {
        match *self {
            Op::Matmul { b, m, k, n, .. } => 2.0 * b as f64 * m as f64 * k as f64 * n as f64,
            // online softmax: max, sub, exp, add (pass 1) + sub, exp, div (pass 2) ≈ 7/elt
            Op::Softmax { m, n, .. } => 7.0 * m as f64 * n as f64,
            // mean, var, normalize, scale+shift ≈ 7/elt
            Op::LayerNorm { m, n, .. } => 7.0 * m as f64 * n as f64,
            // tanh-approximated GELU ≈ 12/elt
            Op::Gelu { elements, .. } => 12.0 * elements as f64,
            Op::AllReduce { bytes, devices } => {
                // one add per element per reduce-scatter step, fp16 assumed
                (devices - 1) as f64 * bytes as f64 / 2.0
            }
            Op::PeerToPeer { .. } => 0.0,
        }
    }

    /// Minimum main-memory traffic in bytes (compulsory reads + writes).
    pub fn min_dram_bytes(&self) -> f64 {
        match *self {
            Op::Matmul { b, m, k, n, dtype, batched_b } => {
                let e = dtype.bytes() as f64;
                let bf = b as f64;
                let b_traffic = if batched_b { bf * k as f64 * n as f64 } else { (k * n) as f64 };
                e * (bf * (m * k) as f64 + b_traffic + bf * (m * n) as f64)
            }
            Op::Softmax { m, n, dtype } | Op::LayerNorm { m, n, dtype } => {
                2.0 * (m * n) as f64 * dtype.bytes() as f64
            }
            Op::Gelu { elements, dtype } => 2.0 * elements as f64 * dtype.bytes() as f64,
            Op::AllReduce { bytes, .. } => bytes as f64,
            Op::PeerToPeer { bytes } => bytes as f64,
        }
    }

    /// Bytes of the operator's output tensor — the activation handed to
    /// consumers, which is what moves over the interconnect when a graph
    /// edge crosses a tensor- or pipeline-parallel boundary.
    pub fn out_bytes(&self) -> u64 {
        match *self {
            Op::Matmul { b, m, n, dtype, .. } => b * m * n * dtype.bytes(),
            Op::Softmax { m, n, dtype } | Op::LayerNorm { m, n, dtype } => {
                m * n * dtype.bytes()
            }
            Op::Gelu { elements, dtype } => elements * dtype.bytes(),
            Op::AllReduce { bytes, .. } | Op::PeerToPeer { bytes } => bytes,
        }
    }

    /// Short name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Op::Matmul { .. } => "matmul",
            Op::Softmax { .. } => "softmax",
            Op::LayerNorm { .. } => "layernorm",
            Op::Gelu { .. } => "gelu",
            Op::AllReduce { .. } => "allreduce",
            Op::PeerToPeer { .. } => "p2p",
        }
    }
}

/// Result of simulating one operator on one device (or system, for comms).
#[derive(Debug, Clone)]
pub struct OpResult {
    /// Total latency including kernel-launch overhead, seconds.
    pub latency_s: f64,
    /// Pure-compute roofline bound, seconds.
    pub compute_bound_s: f64,
    /// Pure-memory roofline bound, seconds.
    pub memory_bound_s: f64,
    /// Number of mapper search rounds performed.
    pub mapper_rounds: u64,
    /// Human-readable description of the chosen mapping.
    pub mapping_desc: String,
}

impl OpResult {
    /// Achieved fraction of the binding roofline (1.0 = at roofline).
    pub fn roofline_fraction(&self) -> f64 {
        let bound = self.compute_bound_s.max(self.memory_bound_s);
        if self.latency_s <= 0.0 {
            return 0.0;
        }
        bound / self.latency_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_flops_and_bytes() {
        let op = Op::Matmul { b: 1, m: 128, k: 256, n: 64, dtype: DType::FP16, batched_b: false };
        assert_eq!(op.flops(), 2.0 * 128.0 * 256.0 * 64.0);
        let bytes = op.min_dram_bytes();
        assert_eq!(bytes, 2.0 * (128.0 * 256.0 + 256.0 * 64.0 + 128.0 * 64.0));
    }

    #[test]
    fn batched_b_counts_all_b_matrices() {
        let shared = Op::Matmul { b: 4, m: 8, k: 16, n: 32, dtype: DType::FP16, batched_b: false };
        let batched = Op::Matmul { b: 4, m: 8, k: 16, n: 32, dtype: DType::FP16, batched_b: true };
        assert!(batched.min_dram_bytes() > shared.min_dram_bytes());
        assert_eq!(batched.flops(), shared.flops());
    }

    #[test]
    fn vector_ops_are_two_pass_io() {
        let op = Op::Softmax { m: 100, n: 200, dtype: DType::FP32 };
        assert_eq!(op.min_dram_bytes(), 2.0 * 100.0 * 200.0 * 4.0);
        assert_eq!(op.name(), "softmax");
    }

    #[test]
    fn out_bytes_match_output_tensor() {
        let op = Op::Matmul { b: 2, m: 8, k: 16, n: 4, dtype: DType::FP16, batched_b: true };
        assert_eq!(op.out_bytes(), 2 * 8 * 4 * 2);
        assert_eq!(Op::Softmax { m: 3, n: 5, dtype: DType::FP32 }.out_bytes(), 60);
        assert_eq!(Op::Gelu { elements: 7, dtype: DType::INT8 }.out_bytes(), 7);
        assert_eq!(Op::PeerToPeer { bytes: 99 }.out_bytes(), 99);
    }

    #[test]
    fn roofline_fraction_sane() {
        let r = OpResult {
            latency_s: 2.0,
            compute_bound_s: 1.0,
            memory_bound_s: 0.5,
            mapper_rounds: 1,
            mapping_desc: String::new(),
        };
        assert_eq!(r.roofline_fraction(), 0.5);
    }
}
