//! Vector-operator performance models: Softmax, LayerNorm, GELU
//! (paper §III-B3).
//!
//! These operators have fewer dimensions than matmul (2-D for
//! Softmax/LayerNorm, 1-D for GELU), so the mapping space is small: rows
//! are tiled across cores/lanes and the only real decisions are the row
//! tile and whether a row must be re-read (when one row's working set
//! exceeds the local buffer). They do not use the systolic arrays.
//!
//! * **Softmax** uses the online algorithm [37]: pass 1 streams the row
//!   computing the running max and exp-sum together; pass 2 streams the row
//!   again applying `exp(x−m)/l`. If a whole row tile fits in the local
//!   buffer the second pass hits SRAM, not DRAM.
//! * **LayerNorm** is the same two-pass structure (mean/variance, then
//!   normalize + scale/shift).
//! * **GELU** is one elementwise pass with the tanh approximation [26].

use crate::arch::vector::{elementwise_cycles, gelu_pipeline, reduce_cycles, Prim};
use crate::hardware::{DeviceSpec, DType};
use crate::perf::OpResult;

/// Row-parallel two-pass reduction op (softmax / layernorm commons).
#[derive(Debug, Clone, Copy)]
struct TwoPass {
    /// Vector-issue slots per element for pass 1 (reduction pass).
    pass1_slots: u64,
    /// Slots per element for pass 2 (normalize pass).
    pass2_slots: u64,
    /// Extra per-row scalar work (e.g. 1/l, rsqrt(var)).
    per_row_extra: u64,
}

fn two_pass_latency(dev: &DeviceSpec, m: u64, n: u64, dtype: DType, p: TwoPass) -> OpResult {
    let e = dtype.bytes() as u64;
    let freq = dev.frequency_hz;
    let lanes_total = dev.core_count * dev.core.lane_count;
    let width = dev.core.lane.vector_width;

    // --- compute side -----------------------------------------------------
    // Rows are distributed across all lanes. When rows are scarce (decode:
    // m small), a row is split across the lanes of one core and combined
    // through the local buffer (one extra tree step).
    let (rows_per_lane, row_span, split_penalty) = if m >= lanes_total {
        ((m + lanes_total - 1) / lanes_total, n, 0)
    } else {
        // split each row across the lanes of a core
        let lanes = dev.core.lane_count;
        let chunk = (n + lanes - 1) / lanes;
        (
            (m + dev.core_count - 1) / dev.core_count,
            chunk,
            // cross-lane combine via local buffer: a handful of cycles
            8 + reduce_cycles(lanes, width, Prim::Add),
        )
    };
    let pass1 = reduce_cycles(row_span, width, Prim::Add)
        + elementwise_cycles(row_span, width, Prim::Exp).saturating_mul(0) // structure only
        + (row_span + width - 1) / width * (p.pass1_slots - 1).max(0);
    let pass2 = (row_span + width - 1) / width * p.pass2_slots;
    let per_row = pass1 + pass2 + p.per_row_extra + split_penalty;
    let compute_cycles = rows_per_lane * per_row;
    let compute_s = compute_cycles as f64 / freq;

    // --- memory side --------------------------------------------------------
    // Pass 1 reads the row from DRAM; pass 2 re-reads it from the local
    // buffer if a per-lane row tile fits, else from DRAM again; output is
    // written once.
    let row_tile_bytes = row_span.min(n) * e;
    let refetch = row_tile_bytes * 2 > dev.core.local_buffer_bytes; // tile + output
    let total_elems = (m * n) as f64;
    let dram_bytes = total_elems * e as f64 * if refetch { 3.0 } else { 2.0 };
    let io_s = dram_bytes / dev.memory.bandwidth_bytes_per_s;

    // Global-buffer bandwidth can also bound the streaming.
    let gb_s = total_elems * e as f64 * if refetch { 3.0 } else { 2.0 } / dev.global_buffer_bw();

    let body = compute_s.max(io_s).max(gb_s);
    let latency = dev.launch_overhead_s + body;

    OpResult {
        latency_s: latency,
        compute_bound_s: compute_s,
        memory_bound_s: io_s,
        mapper_rounds: 1,
        mapping_desc: format!(
            "rows/lane={rows_per_lane} span={row_span} refetch={}",
            refetch as u8
        ),
    }
}

/// Softmax over an (m × n) tensor, normalizing along n.
pub fn softmax(dev: &DeviceSpec, m: u64, n: u64, dtype: DType) -> OpResult {
    two_pass_latency(
        dev,
        m,
        n,
        dtype,
        TwoPass {
            // online pass: max, sub, exp, add ≈ 1+1+4+1
            pass1_slots: 7,
            // normalize: sub, exp, mul-by-1/l ≈ 1+4+1
            pass2_slots: 6,
            // 1/l division
            per_row_extra: Prim::Div.cost(),
        },
    )
}

/// LayerNorm over (m × n), normalizing along n.
pub fn layernorm(dev: &DeviceSpec, m: u64, n: u64, dtype: DType) -> OpResult {
    two_pass_latency(
        dev,
        m,
        n,
        dtype,
        TwoPass {
            // sum and sum-of-squares in one pass: add, fma
            pass1_slots: 2,
            // (x − μ)·rsqrt(σ²+ε)·γ + β: sub, mul, fma
            pass2_slots: 3,
            // mean, variance finalize, rsqrt
            per_row_extra: Prim::Div.cost() * 2 + Prim::Sqrt.cost(),
        },
    )
}

/// Elementwise GELU over `elements` values (tanh approximation).
pub fn gelu(dev: &DeviceSpec, elements: u64, dtype: DType) -> OpResult {
    let e = dtype.bytes() as u64;
    let freq = dev.frequency_hz;
    let lanes_total = dev.core_count * dev.core.lane_count;
    let width = dev.core.lane.vector_width;

    let per_lane = (elements + lanes_total - 1) / lanes_total;
    let compute_cycles = gelu_pipeline().cycles(per_lane, width);
    let compute_s = compute_cycles as f64 / freq;

    let dram_bytes = 2.0 * elements as f64 * e as f64;
    let io_s = dram_bytes / dev.memory.bandwidth_bytes_per_s;
    let gb_s = dram_bytes / dev.global_buffer_bw();

    OpResult {
        latency_s: dev.launch_overhead_s + compute_s.max(io_s).max(gb_s),
        compute_bound_s: compute_s,
        memory_bound_s: io_s,
        mapper_rounds: 1,
        mapping_desc: format!("elems/lane={per_lane}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::presets::a100;

    #[test]
    fn softmax_latency_at_least_io_bound() {
        let dev = a100();
        let r = softmax(&dev, 2048, 2048, DType::FP16);
        let io = 2.0 * 2048.0 * 2048.0 * 2.0 / dev.memory.bandwidth_bytes_per_s;
        assert!(r.latency_s >= io);
        assert!(r.latency_s >= dev.launch_overhead_s);
        assert!(r.latency_s < io * 20.0 + dev.launch_overhead_s);
    }

    #[test]
    fn tiny_ops_dominated_by_launch_overhead() {
        // Paper §IV-C: during decode, GELU/LayerNorm/Softmax inputs are
        // small and dominated by kernel-launch overhead.
        let dev = a100();
        let r = gelu(&dev, 8 * 12288, DType::FP16);
        assert!(
            dev.launch_overhead_s / r.latency_s > 0.5,
            "launch {} vs total {}",
            dev.launch_overhead_s,
            r.latency_s
        );
    }

    #[test]
    fn extreme_reduction_dim_degrades_throughput() {
        // Paper Fig. 5d: LayerNorm throughput drops as the reduction
        // dimension grows to an extreme (reduction cost + re-fetch).
        let dev = a100();
        let total = 1u64 << 24; // fixed element count
        let thpt = |n: u64| {
            let m = total / n;
            let r = layernorm(&dev, m, n, DType::FP16);
            total as f64 / r.latency_s
        };
        let mid = thpt(4096);
        let extreme = thpt(1 << 20);
        assert!(
            extreme < mid * 0.8,
            "throughput should droop: mid={mid:.3e} extreme={extreme:.3e}"
        );
    }

    #[test]
    fn more_rows_scale_throughput_until_saturation() {
        let dev = a100();
        let lat_small = softmax(&dev, 8, 4096, DType::FP16).latency_s;
        let lat_big = softmax(&dev, 8192, 4096, DType::FP16).latency_s;
        // 1024x rows should cost much more than 1x but far less than
        // 1024x the launch-dominated small case.
        assert!(lat_big > lat_small * 2.0);
        assert!(lat_big < lat_small * 1024.0);
    }

    #[test]
    fn gelu_compute_reasonable() {
        let dev = a100();
        let r = gelu(&dev, 1 << 26, DType::FP16);
        // Big GELU is IO bound on A100 (12 slots/elt at 19.5 TFLOP-slot/s
        // vs 4 B/elt at 2 TB/s).
        assert!(r.memory_bound_s > r.compute_bound_s);
        assert!(r.roofline_fraction() > 0.5);
    }
}
