//! DAG scheduling for operator graphs ([`crate::graph::ir::Graph`]).
//!
//! [`schedule`] runs greedy list scheduling in topological (insertion)
//! order: each node becomes ready when every predecessor has finished and
//! starts as soon as its execution resource frees up. Compute nodes
//! occupy their pipeline stage's compute resource; communication nodes
//! (`AllReduce` / `PeerToPeer`) occupy a single shared interconnect
//! resource — so compute and communication overlap across microbatches
//! and stages, exactly the overlap pipeline parallelism exists to buy.
//!
//! Two invariants anchor the model (property-tested in this module):
//!
//! * the makespan is never below the **critical-path lower bound** (the
//!   longest dependency chain, ignoring resource contention), and
//! * a **chain graph schedules to exactly the serial sum** of its node
//!   latencies, bit for bit — which is how the pre-IR serial walk over
//!   `layer_ops` stays reproducible: lowering a chain workload onto the
//!   graph path cannot move a single ULP.

use crate::graph::ir::{Graph, Node};
use crate::perf::Op;
use crate::util::json::Json;
use crate::util::telemetry::Recorder;
use std::collections::HashMap;

/// Start/finish of one node in the computed schedule.
#[derive(Debug, Clone)]
pub struct NodeTiming {
    pub name: String,
    /// Pipeline stage (compute resource id) the node ran on.
    pub stage: u64,
    /// True when the node ran on the shared interconnect resource.
    pub comm: bool,
    pub start_s: f64,
    pub finish_s: f64,
    /// The node's own latency (`finish - start` may differ in the last
    /// ULP; this is the exact simulated value).
    pub latency_s: f64,
}

/// The result of scheduling a graph.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Makespan: the latest finish time across all nodes.
    pub total_s: f64,
    /// Longest dependency chain ignoring resource contention — a lower
    /// bound on any legal schedule.
    pub critical_path_s: f64,
    /// Sum of all node latencies in topological order — the latency of
    /// running the graph on one serial resource (equals `total_s` for
    /// chain graphs, bit for bit).
    pub serial_s: f64,
    /// Per-node timings in topological order.
    pub timings: Vec<NodeTiming>,
}

impl Schedule {
    /// Busy seconds per resource, compute stages first (sorted by stage
    /// id) then the shared interconnect.
    pub fn resource_busy(&self) -> Vec<(String, f64)> {
        let mut compute: Vec<(u64, f64)> = Vec::new();
        let mut comm = 0.0;
        let mut any_comm = false;
        for t in &self.timings {
            if t.comm {
                comm += t.latency_s;
                any_comm = true;
            } else {
                match compute.iter_mut().find(|(s, _)| *s == t.stage) {
                    Some((_, b)) => *b += t.latency_s,
                    None => compute.push((t.stage, t.latency_s)),
                }
            }
        }
        compute.sort_by_key(|&(s, _)| s);
        let mut out: Vec<(String, f64)> =
            compute.into_iter().map(|(s, b)| (format!("compute:{s}"), b)).collect();
        if any_comm {
            out.push(("comm".to_string(), comm));
        }
        out
    }
}

fn is_comm(op: &Op) -> bool {
    matches!(op, Op::AllReduce { .. } | Op::PeerToPeer { .. })
}

/// Emit a computed [`Schedule`] onto a telemetry recorder as
/// simulated-time trace tracks: one track per execution resource
/// (`<prefix>/compute:N`, `<prefix>/comm`) with a complete span per
/// node. Resource exclusivity in the schedule means spans on one track
/// never overlap, so pipeline bubbles and comm/compute overlap are
/// directly visible in Perfetto. No-op when the recorder is disabled.
pub fn emit_trace(rec: &Recorder, prefix: &str, sched: &Schedule) {
    if !rec.is_enabled() {
        return;
    }
    for t in &sched.timings {
        let track = if t.comm {
            format!("{prefix}/comm")
        } else {
            format!("{prefix}/compute:{}", t.stage)
        };
        rec.span_sim(
            &track,
            &t.name,
            t.start_s,
            t.finish_s,
            &[("latency_s", Json::Num(t.latency_s))],
        );
    }
}

/// List-schedule `g` with per-node latencies from `lat`, respecting
/// dependency edges and resource exclusivity (one node at a time per
/// compute stage, one at a time on the interconnect).
pub fn schedule<F>(g: &Graph, mut lat: F) -> Schedule
where
    F: FnMut(&Node) -> f64,
{
    let n = g.len();
    let mut finish = vec![0.0f64; n];
    let mut cp = vec![0.0f64; n];
    // (comm, stage) → time the resource frees up. All comm shares stage 0.
    let mut avail: HashMap<(bool, u64), f64> = HashMap::new();
    let mut timings = Vec::with_capacity(n);
    let mut total = 0.0f64;
    let mut cp_max = 0.0f64;
    let mut serial = 0.0f64;
    for i in 0..n {
        let node = g.node(i);
        let l = lat(node);
        serial += l;
        let comm = is_comm(&node.op);
        let key = (comm, if comm { 0 } else { node.stage });
        let mut ready = 0.0f64;
        let mut cp_ready = 0.0f64;
        for &p in g.preds(i) {
            ready = ready.max(finish[p]);
            cp_ready = cp_ready.max(cp[p]);
        }
        let start = ready.max(*avail.get(&key).unwrap_or(&0.0));
        let end = start + l;
        avail.insert(key, end);
        finish[i] = end;
        cp[i] = cp_ready + l;
        total = total.max(end);
        cp_max = cp_max.max(cp[i]);
        timings.push(NodeTiming {
            name: node.name.clone(),
            stage: node.stage,
            comm,
            start_s: start,
            finish_s: end,
            latency_s: l,
        });
    }
    Schedule { total_s: total, critical_path_s: cp_max, serial_s: serial, timings }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::DType;
    use crate::util::quick::forall;

    fn compute_op(tag: u64) -> Op {
        Op::Gelu { elements: tag.max(1), dtype: DType::FP16 }
    }

    fn comm_op(bytes: u64) -> Op {
        Op::PeerToPeer { bytes: bytes.max(1) }
    }

    /// Random DAG with random latencies and stages, built over a `Gen`.
    /// Returns the graph and the latency table.
    fn random_dag(g: &mut crate::util::quick::Gen) -> (Graph, Vec<f64>) {
        let n = g.usize(1, 14);
        let mut graph = Graph::new();
        let mut lats = Vec::with_capacity(n);
        for i in 0..n {
            let stage = g.u64(0, 2);
            let comm = g.bool();
            let op = if comm { comm_op(i as u64 + 1) } else { compute_op(i as u64 + 1) };
            let mut deps = Vec::new();
            if i > 0 {
                // Up to 3 random predecessors among earlier nodes.
                for _ in 0..g.usize(0, 3) {
                    let p = g.usize(0, i - 1);
                    if !deps.contains(&p) {
                        deps.push(p);
                    }
                }
            }
            graph.add_on(stage, format!("n{i}"), op, &deps);
            lats.push(g.f64(0.0, 1.0));
        }
        (graph, lats)
    }

    #[test]
    fn schedule_bounded_by_critical_path_and_serial_sum() {
        forall("cp <= makespan <= serial", 300, |g| {
            let (graph, lats) = random_dag(g);
            let idx = std::cell::Cell::new(0usize);
            let sched = schedule(&graph, |_| {
                let l = lats[idx.get()];
                idx.set(idx.get() + 1);
                l
            });
            let lo_ok = sched.total_s >= sched.critical_path_s - 1e-12;
            let hi_ok = sched.total_s <= sched.serial_s * (1.0 + 1e-12) + 1e-12;
            ((graph.len(), sched.total_s, sched.critical_path_s, sched.serial_s), lo_ok && hi_ok)
        });
    }

    #[test]
    fn chain_schedules_to_exact_serial_sum() {
        forall("chain == serial sum", 300, |g| {
            let n = g.usize(1, 12);
            let mut graph = Graph::new();
            let mut lats = Vec::with_capacity(n);
            for i in 0..n {
                // Mix comm and compute nodes: dependencies alone must
                // serialize a chain regardless of resource classes.
                let op = if g.bool() { comm_op(64) } else { compute_op(i as u64 + 1) };
                let deps: Vec<usize> = if i == 0 { vec![] } else { vec![i - 1] };
                graph.add_on(g.u64(0, 2), format!("n{i}"), op, &deps);
                lats.push(g.f64(0.0, 2.0));
            }
            let mut serial = 0.0f64;
            for &l in &lats {
                serial += l;
            }
            let idx = std::cell::Cell::new(0usize);
            let sched = schedule(&graph, |_| {
                let l = lats[idx.get()];
                idx.set(idx.get() + 1);
                l
            });
            let exact = sched.total_s.to_bits() == serial.to_bits()
                && sched.serial_s.to_bits() == serial.to_bits()
                && sched.critical_path_s.to_bits() == serial.to_bits();
            ((n, sched.total_s, serial), exact)
        });
    }

    #[test]
    fn independent_nodes_on_distinct_stages_overlap() {
        let mut g = Graph::new();
        g.add_on(0, "a", compute_op(1), &[]);
        g.add_on(1, "b", compute_op(2), &[]);
        let sched = schedule(&g, |_| 1.0);
        assert_eq!(sched.total_s, 1.0, "distinct stages run in parallel");
        assert_eq!(sched.serial_s, 2.0);
    }

    #[test]
    fn same_stage_serializes_without_edges() {
        let mut g = Graph::new();
        g.add_on(0, "a", compute_op(1), &[]);
        g.add_on(0, "b", compute_op(2), &[]);
        let sched = schedule(&g, |_| 1.0);
        assert_eq!(sched.total_s, 2.0, "one compute resource per stage");
        assert_eq!(sched.critical_path_s, 1.0, "cp ignores resource contention");
    }

    #[test]
    fn comm_overlaps_compute() {
        // a(compute) -> x(comm), while b(compute, same stage) is
        // independent: b runs during the transfer.
        let mut g = Graph::new();
        let a = g.add_on(0, "a", compute_op(1), &[]);
        g.add_on(0, "x", comm_op(64), &[a]);
        g.add_on(0, "b", compute_op(2), &[]);
        let sched = schedule(&g, |_| 1.0);
        assert_eq!(sched.total_s, 2.0, "transfer hides behind compute");
    }

    #[test]
    fn diamond_respects_both_branches() {
        //    a
        //   / \
        //  b   c     (different stages, so they overlap)
        //   \ /
        //    d
        let mut g = Graph::new();
        let a = g.add_on(0, "a", compute_op(1), &[]);
        let b = g.add_on(0, "b", compute_op(2), &[a]);
        let c = g.add_on(1, "c", compute_op(3), &[a]);
        g.add_on(0, "d", compute_op(4), &[b, c]);
        let lats = [1.0, 1.0, 3.0, 1.0];
        let idx = std::cell::Cell::new(0usize);
        let sched = schedule(&g, |_| {
            let l = lats[idx.get()];
            idx.set(idx.get() + 1);
            l
        });
        // d waits for the slow branch c: 1 + 3 + 1.
        assert_eq!(sched.total_s, 5.0);
        assert_eq!(sched.critical_path_s, 5.0);
        assert_eq!(sched.serial_s, 6.0);
    }

    #[test]
    fn resource_busy_accounts_every_second() {
        let mut g = Graph::new();
        let a = g.add_on(0, "a", compute_op(1), &[]);
        let x = g.add_on(0, "x", comm_op(64), &[a]);
        g.add_on(1, "b", compute_op(2), &[x]);
        let sched = schedule(&g, |_| 1.0);
        let busy = sched.resource_busy();
        assert_eq!(
            busy,
            vec![
                ("compute:0".to_string(), 1.0),
                ("compute:1".to_string(), 1.0),
                ("comm".to_string(), 1.0)
            ]
        );
    }
}
