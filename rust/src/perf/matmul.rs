//! Tile-by-tile matrix-multiplication simulation (paper §III-B1, Fig. 4).
//!
//! A GEMM `C[m,n] = A[m,k] · B[k,n] (+ C)` is simulated in three levels:
//!
//! 1. **Main memory → global buffer.** A/B/C are cut into *global tiles*
//!    small enough for the global buffer. Each step streams one
//!    `A_tile`/`B_tile` in over main memory and writes `C_tile` back;
//!    with the software-pipeline (double-buffering) option, IO of step
//!    *i+1* overlaps compute of step *i*.
//! 2. **Global buffer → local buffers.** The tile is cut into sub-tiles
//!    scheduled onto cores in waves. *Schedule scheme 1* gives each core
//!    its own output sub-tile (reads of a shared `A_sub`/`B_sub` by several
//!    cores in a wave are **merged**, and the Read-After-Write dependency
//!    on `C_sub` is kept core-local so partials never round-trip). *Scheme
//!    2* splits the reduction (k) dimension across cores cooperating on one
//!    output sub-tile and pays a cross-core reduction at the end.
//! 3. **Local buffer → lanes → systolic arrays.** Sub-tiles split across
//!    lanes; per-lane GEMMs go to the systolic-array model
//!    ([`crate::arch::systolic`]), bounded by local-buffer feed bandwidth.

use crate::arch::systolic::{Array, Dataflow, SystolicLut, Tile};
use crate::hardware::{DType, DeviceSpec};

/// Which of the two §III-B1 schedule schemes a mapping uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Scheme 1: cores own distinct output sub-tiles.
    OutputPartitioned,
    /// Scheme 2: cores split the reduction dimension of one sub-tile.
    KSplit,
}

impl Scheme {
    pub fn name(self) -> &'static str {
        match self {
            Scheme::OutputPartitioned => "scheme1",
            Scheme::KSplit => "scheme2",
        }
    }

    pub fn parse(v: &str) -> Option<Scheme> {
        match v {
            "scheme1" => Some(Scheme::OutputPartitioned),
            "scheme2" => Some(Scheme::KSplit),
            _ => None,
        }
    }
}

/// One point in the mapper's search space.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Mapping {
    /// Global-buffer tile (m, k, n).
    pub gt: (u64, u64, u64),
    /// Local-buffer sub-tile (m, k, n).
    pub lt: (u64, u64, u64),
    pub scheme: Scheme,
    /// Software pipeline (double buffering) main-memory ↔ global buffer.
    pub db_global: bool,
    /// Software pipeline global buffer ↔ local buffers.
    pub db_local: bool,
}

impl Mapping {
    pub fn describe(&self) -> String {
        format!(
            "gt={}x{}x{} lt={}x{}x{} {} dbG={} dbL={}",
            self.gt.0,
            self.gt.1,
            self.gt.2,
            self.lt.0,
            self.lt.1,
            self.lt.2,
            self.scheme.name(),
            self.db_global as u8,
            self.db_local as u8
        )
    }
}

/// Problem shape: `b` independent GEMMs (batch). When `batched_b` is false
/// all batch elements share one `B` (the weight matrix — the usual LLM
/// case); when true each batch element has its own `B` (attention).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Shape {
    pub b: u64,
    pub m: u64,
    pub k: u64,
    pub n: u64,
    pub dtype: DType,
    pub batched_b: bool,
}

impl Shape {
    pub fn simple(m: u64, k: u64, n: u64, dtype: DType) -> Shape {
        Shape { b: 1, m, k, n, dtype, batched_b: false }
    }

    pub fn flops(&self) -> f64 {
        2.0 * self.b as f64 * self.m as f64 * self.k as f64 * self.n as f64
    }
}

/// Simulation output for one (shape, mapping) pair.
#[derive(Debug, Clone, Copy)]
pub struct SimOutcome {
    /// Seconds, excluding kernel-launch overhead.
    pub seconds: f64,
    /// Main-memory bytes actually moved.
    pub dram_bytes: f64,
    /// Average systolic-array utilization while computing.
    pub systolic_util: f64,
}

fn ceil_div(a: u64, b: u64) -> u64 {
    (a + b - 1) / b
}

/// Chunk classes for a dimension: (count, size) pairs — `d/e` full chunks
/// of `e` plus an optional ragged remainder.
fn classes(d: u64, e: u64) -> [(u64, u64); 2] {
    [(d / e, e), (u64::from(d % e > 0), d % e)]
}

/// Does the mapping fit the device's buffers? Returns `None` if not.
/// Double buffering at a level doubles the *streamed* operand footprint
/// (A and B), which is exactly the paper's noted downside: enabling the
/// software pipeline halves the maximum usable tile.
pub fn fits(dev: &DeviceSpec, shape: &Shape, map: &Mapping) -> bool {
    let e = shape.dtype.bytes();
    let (gm, gk, gn) = map.gt;
    let (lm, lk, ln) = map.lt;
    if gm == 0 || gk == 0 || gn == 0 || lm == 0 || lk == 0 || ln == 0 {
        return false;
    }
    if lm > gm || lk > gk || ln > gn {
        return false;
    }
    let stream_g = (gm * gk + gk * gn) * e;
    let resident_g = gm * gn * e;
    let g_need = stream_g * if map.db_global { 2 } else { 1 } + resident_g;
    if g_need > dev.global_buffer_bytes {
        return false;
    }
    // Local: A_sub + B_sub streamed, C_sub accumulated in FP32.
    let stream_l = (lm * lk + lk * ln) * e;
    let resident_l = lm * ln * 4;
    let l_need = stream_l * if map.db_local { 2 } else { 1 } + resident_l;
    l_need <= dev.core.local_buffer_bytes
}

/// Level 3: one core executes an (lm × lk × ln) GEMM chunk. Lanes split the
/// wider of the m/n extents; the systolic model gives cycles; the local
/// buffer must also feed operands at `local_buffer_bytes_per_clk`.
fn core_cycles(
    dev: &DeviceSpec,
    dtype: DType,
    lm: u64,
    lk: u64,
    ln: u64,
    lut: &SystolicLut,
) -> u64 {
    let lanes = dev.core.lane_count;
    let lane = &dev.core.lane;
    let array = Array {
        rows: lane.systolic_rows,
        cols: lane.systolic_cols,
        dataflow: Dataflow::WeightStationary,
    };
    // Split across lanes along n (weight columns) if possible, else m.
    let (pm, pn) = if ln >= lanes {
        (lm, ceil_div(ln, lanes))
    } else if lm >= lanes {
        (ceil_div(lm, lanes), ln)
    } else {
        // Few rows *and* few cols: lanes idle; one lane takes the chunk.
        (lm, ln)
    };
    let mut sys = lut.cycles(Tile { m: pm, k: lk, n: pn }, array);
    // Multiple systolic arrays per lane split the k folds.
    if lane.systolic_count > 1 {
        sys = ceil_div(sys, lane.systolic_count);
    }
    // Local-buffer feed: stream A_sub and B_sub once per chunk.
    let bytes = (lm * lk + lk * ln) * dtype.bytes() as u64;
    let feed = ceil_div(bytes, dev.core.local_buffer_bytes_per_clk);
    sys.max(feed)
}

/// Level 2 state for one global tile: how long the cores take, and how many
/// bytes cross the global buffer. Returns (cycles, gb_bytes).
///
/// `gm/gk/gn` are the actual tile extents (ragged tiles at the problem edge
/// are smaller), `pack` is the number of batch elements packed into the
/// tile step (their sub-tiles schedule independently, multiplying the
/// sub-tile count).
fn tile_cycles(
    dev: &DeviceSpec,
    shape: &Shape,
    map: &Mapping,
    gm: u64,
    gk: u64,
    gn: u64,
    pack: u64,
    lut: &SystolicLut,
) -> (u64, u64) {
    let e = shape.dtype.bytes() as u64;
    let (lm, lk, ln) = map.lt;
    let cores = dev.core_count;
    let gb_per_clk = dev.global_buffer_bytes_per_clk.max(1);

    let sub_m = ceil_div(gm, lm);
    let sub_n = ceil_div(gn, ln);
    let k_chunks = ceil_div(gk, lk);

    match map.scheme {
        Scheme::OutputPartitioned => {
            // Sub-tiles are assigned to cores row-major in waves.
            let s_total = sub_m * sub_n * pack;
            let waves = ceil_div(s_total, cores);
            let mut total_cycles = 0u64;
            let mut gb_bytes = 0u64;
            // Full waves repeat with a short pattern (their cost depends on
            // `lo` only through `lo % sub_n` and `lo % (sub_m·sub_n)`), so
            // when there are many, evaluate a window and extrapolate the
            // average — exact for the common aligned cases and within the
            // pattern's jitter otherwise.
            const WAVE_WINDOW: u64 = 64;
            let sampled = waves.min(WAVE_WINDOW);
            for w in 0..sampled {
                let lo = w * cores;
                let hi = (lo + cores).min(s_total); // exclusive
                let active = hi - lo;
                // Distinct row blocks (A_subs) and column blocks (B_subs)
                // touched by this wave — their global-buffer reads merge
                // (paper: "their memory access to the global buffer should
                // be merged"). Sub-tiles are numbered row-major, so a span
                // of `active` consecutive ids touches ⌈(offset+active)/n⌉
                // row blocks; rows in different batch elements are
                // distinct, which the same formula covers.
                let per_elem = sub_m * sub_n;
                let distinct_rows = (active + lo % sub_n + sub_n - 1) / sub_n;
                let cols_per_batch = active.min(sub_n);
                let batches_in_wave = (active + lo % per_elem + per_elem - 1) / per_elem;
                // Shared B merges within a batch element; batched B (e.g.
                // attention) cannot merge across elements.
                let b_blocks = if shape.batched_b {
                    active.min(batches_in_wave * cols_per_batch)
                } else {
                    cols_per_batch
                };
                let mut wave_cycles = 0u64;
                let mut wave_bytes = 0u64;
                for (ck_count, kk) in classes(gk, lk) {
                    if ck_count == 0 {
                        continue;
                    }
                    // Global-buffer traffic for one k-chunk of this wave.
                    let a_bytes = distinct_rows * lm.min(gm) * kk * e;
                    let b_bytes_each = kk * ln.min(gn) * e;
                    let bytes = a_bytes + b_blocks * b_bytes_each;
                    let io = ceil_div(bytes, gb_per_clk);
                    let comp = core_cycles(dev, shape.dtype, lm.min(gm), kk, ln.min(gn), lut);
                    let per_chunk = if map.db_local { io.max(comp) } else { io + comp };
                    wave_cycles += ck_count * per_chunk;
                    wave_bytes += ck_count * bytes;
                }
                // C_sub writeback once per sub-tile after the k loop (RAW
                // dependency stays core-local under scheme 1).
                let c_bytes = active * lm.min(gm) * ln.min(gn) * e;
                wave_cycles += ceil_div(c_bytes, gb_per_clk);
                wave_bytes += c_bytes;
                total_cycles += wave_cycles;
                gb_bytes += wave_bytes;
            }
            if waves > sampled {
                // Scale the sampled window up to the full wave count.
                total_cycles = total_cycles * waves / sampled;
                gb_bytes = gb_bytes * waves / sampled;
            }
            (total_cycles, gb_bytes)
        }
        Scheme::KSplit => {
            // Cores gang up on output sub-tiles: split cores evenly across
            // sub-tiles, each group splits the k chunks.
            let s_total = (sub_m * sub_n * pack).max(1);
            let group = (cores / s_total).max(1).min(k_chunks);
            let groups_in_flight = (cores / group).min(s_total);
            let rounds = ceil_div(s_total, groups_in_flight);

            // Each core streams its own A/B chunks (no merging across
            // different k); all concurrently active groups share the
            // global-buffer bandwidth.
            let mut per_subtile_cycles = 0u64;
            let mut per_subtile_bytes = 0u64;
            for (ck_count, kk) in classes(gk, lk) {
                if ck_count == 0 {
                    continue;
                }
                let bytes = (lm.min(gm) * kk + kk * ln.min(gn)) * e;
                let concurrent = group.min(ck_count) * groups_in_flight;
                let io = ceil_div(bytes * concurrent, gb_per_clk);
                let comp = core_cycles(dev, shape.dtype, lm.min(gm), kk, ln.min(gn), lut);
                let per_chunk = if map.db_local { io.max(comp) } else { io + comp };
                per_subtile_cycles += ceil_div(ck_count, group) * per_chunk;
                per_subtile_bytes += bytes * ck_count;
            }
            // Reduction: group partials combine through the global buffer —
            // each of (group−1) partial C_subs is written (FP32) and read
            // back, then the vector units add them.
            let c_elems = lm.min(gm) * ln.min(gn);
            let red_bytes = (group - 1) * c_elems * 4 * 2;
            let red_io = ceil_div(red_bytes, gb_per_clk);
            let vec_add = crate::arch::vector::elementwise_cycles(
                (group - 1) * c_elems,
                dev.core.lane.vector_width * dev.core.lane_count,
                crate::arch::vector::Prim::Add,
            );
            // Final writeback.
            let c_bytes = c_elems * e;
            let finish = ceil_div(c_bytes, gb_per_clk);
            let per_subtile = per_subtile_cycles + red_io + vec_add + finish;
            let total = rounds * per_subtile;
            let gb_bytes = s_total * (per_subtile_bytes + red_bytes + c_bytes);
            (total, gb_bytes)
        }
    }
}

/// Batch packing: if one batch element's global tile uses only part of
/// the global buffer, pack several batch elements per step so their
/// sub-tiles fill the cores (critical for decode attention, where each
/// per-head GEMM is tiny). Shared by [`simulate`] and [`lower_bound`] —
/// the bound mirrors the model through this one implementation.
fn batch_pack(dev: &DeviceSpec, shape: &Shape, map: &Mapping) -> u64 {
    let e = shape.dtype.bytes();
    let (gm, gk, gn) = map.gt;
    let per_batch = (gm.min(shape.m) * gk.min(shape.k) + gk.min(shape.k) * gn.min(shape.n)) * e
        * if map.db_global { 2 } else { 1 }
        + gm.min(shape.m) * gn.min(shape.n) * e;
    if shape.b > 1 {
        (dev.global_buffer_bytes / per_batch.max(1)).clamp(1, shape.b)
    } else {
        1
    }
}

/// One global-tile class: `steps` equal steps over a (tm × tk × tn) tile
/// with `io_bytes` of main-memory traffic each.
struct TileClass {
    steps: u64,
    tm: u64,
    tk: u64,
    tn: u64,
    /// Per-step main-memory traffic: A and B tiles streamed in (a shared,
    /// non-batched B is still re-read per step — the global buffer only
    /// holds the current tile), plus the C writeback amortized as a
    /// 1/⌈k/gk⌉ share per step to stay closed-form.
    io_bytes: f64,
}

/// Visit the ≤ 8 global-tile classes (full + ragged along each dimension)
/// with their per-step traffic. The single source of the model's
/// stream-traffic accounting, shared by [`simulate`] and [`lower_bound`]
/// so the pruning bound cannot drift from the model. Callback-based (no
/// allocation): this sits on the innermost candidate-evaluation path and
/// runs once per pruning check plus once per surviving simulation.
fn for_each_tile_class(shape: &Shape, map: &Mapping, pack: u64, mut f: impl FnMut(TileClass)) {
    let e = shape.dtype.bytes();
    let (gm, gk, gn) = map.gt;
    let batch_steps = ceil_div(shape.b, pack);
    for (cm, tm) in classes(shape.m, gm) {
        for (cn, tn) in classes(shape.n, gn) {
            for (ck, tk) in classes(shape.k, gk) {
                let count = cm * cn * ck;
                if count == 0 {
                    continue;
                }
                let a_bytes = pack * tm * tk * e;
                let b_bytes = if shape.batched_b { pack * tk * tn * e } else { tk * tn * e };
                let k_tiles_total = ceil_div(shape.k, gk);
                let c_share = (pack * tm * tn * e) as f64 / k_tiles_total as f64;
                f(TileClass {
                    steps: count * batch_steps,
                    tm,
                    tk,
                    tn,
                    io_bytes: (a_bytes + b_bytes) as f64 + c_share,
                });
            }
        }
    }
}

/// Level 1 + 0: full simulation of `shape` under `mapping`. Returns `None`
/// if the mapping does not fit the buffers.
pub fn simulate(
    dev: &DeviceSpec,
    shape: &Shape,
    map: &Mapping,
    lut: &SystolicLut,
) -> Option<SimOutcome> {
    if !fits(dev, shape, map) {
        return None;
    }
    let e = shape.dtype.bytes() as u64;
    let pack = batch_pack(dev, shape, map);

    let freq = dev.frequency_hz;
    let mem_bw = dev.memory.bandwidth_bytes_per_s;

    let mut compute_s_total = 0.0f64;
    let mut io_s_total = 0.0f64;
    let mut max_step_io_s = 0.0f64;
    let mut dram_bytes = 0.0f64;
    let mut steps_total = 0u64;
    let mut pipelined_s = 0.0f64;

    for_each_tile_class(shape, map, pack, |class| {
        let TileClass { steps, tm, tk, tn, io_bytes } = class;
        let (cycles, _gb_bytes) = tile_cycles(dev, shape, map, tm, tk, tn, pack, lut);
        let compute_s = cycles as f64 / freq;
        let io_s = io_bytes / mem_bw;

        compute_s_total += steps as f64 * compute_s;
        io_s_total += steps as f64 * io_s;
        max_step_io_s = max_step_io_s.max(io_s);
        dram_bytes += steps as f64 * io_bytes;
        steps_total += steps;
        pipelined_s += steps as f64 * compute_s.max(io_s);
    });

    let mut seconds = if map.db_global {
        // Software pipeline: steady state is max(io, compute) per step,
        // plus one IO fill at the head.
        pipelined_s + max_step_io_s
    } else {
        compute_s_total + io_s_total
    };

    // Global-buffer-resident fast path: when the whole problem fits in the
    // global buffer, every operand crosses main memory exactly once
    // (compulsory traffic) and subsequent tile passes are served on-chip —
    // the same effect that makes L2-resident GEMMs fast on real GPUs.
    let b_traffic = if shape.batched_b { shape.b } else { 1 };
    let problem_bytes = e
        * (shape.b * shape.m * shape.k
            + b_traffic * shape.k * shape.n
            + shape.b * shape.m * shape.n);
    if problem_bytes <= dev.global_buffer_bytes {
        let io_once = problem_bytes as f64 / mem_bw;
        let resident = compute_s_total.max(io_once);
        if resident < seconds {
            seconds = resident;
            dram_bytes = problem_bytes as f64;
        }
    }

    let _ = steps_total;
    // Utilization relative to systolic peak while the kernel runs.
    let peak = dev.peak_matrix_flops();
    let util = if seconds > 0.0 { shape.flops() / (seconds * peak) } else { 0.0 };

    Some(SimOutcome { seconds, dram_bytes, systolic_util: util.min(1.0) })
}

/// Cheap analytical lower bound on [`simulate`]'s `seconds` for a feasible
/// mapping — the mapper engine's pruning oracle. It is derived from the
/// simulation model itself, not from an independent roofline, so it is a
/// *true* bound: `lower_bound(..) <= simulate(..).seconds` for every
/// mapping that [`fits`] (a `util::quick` property test in
/// `tests/property_model.rs` holds this invariant down).
///
/// Two floors, evaluated in O(#tile classes) ≤ 8 steps instead of the full
/// wave-by-wave simulation:
///
/// * **Memory floor.** The mapping's main-memory stream traffic (A/B tiles
///   re-read once per global-tile pass, C amortized over its k steps) is
///   mirrored from [`simulate`]'s per-step accounting; both the software-
///   pipelined and the serial IO paths take at least `stream / bandwidth`
///   seconds, and the global-buffer-resident fast path takes at least the
///   compulsory `problem / bandwidth`.
/// * **Compute floor.** Every level of the core model costs at least the
///   ideal MAC count over the device's peak MAC rate (the systolic fold
///   equations stream ≥ `m` rows per fold, lanes/cores divide work without
///   speeding the per-MAC rate). The only place the simulation can round
///   *below* that ideal is the wave-window extrapolation's integer
///   division — bounded by one cycle per tile step — so one cycle per
///   step is subtracted to keep the bound sound.
///
/// Kernel-launch overhead is excluded on both sides, matching `simulate`.
/// A final 1e-12 relative shave makes the bound robust to floating-point
/// reassociation between the two computations.
pub fn lower_bound(dev: &DeviceSpec, shape: &Shape, map: &Mapping) -> f64 {
    let e = shape.dtype.bytes();
    let mem_bw = dev.memory.bandwidth_bytes_per_s;

    // Same packing and per-class traffic accounting as `simulate` — the
    // shared helpers are what make the bound a bound. The IO time is also
    // accumulated with the *same association* (per-class divide, then
    // weighted sum) as `simulate`'s `io_s_total`, keeping the two within
    // ulps of each other instead of drifting by summation order.
    let pack = batch_pack(dev, shape, map);
    let mut stream_s = 0.0f64;
    let mut steps_total = 0u64;
    for_each_tile_class(shape, map, pack, |class| {
        stream_s += class.steps as f64 * (class.io_bytes / mem_bw);
        steps_total += class.steps;
    });

    let b_traffic = if shape.batched_b { shape.b } else { 1 };
    let problem_bytes = e
        * (shape.b * shape.m * shape.k
            + b_traffic * shape.k * shape.n
            + shape.b * shape.m * shape.n);
    // The resident fast path can undercut the stream traffic, but only
    // when the whole problem fits the global buffer — and then it still
    // pays the compulsory traffic once.
    let io_floor = if problem_bytes <= dev.global_buffer_bytes {
        (problem_bytes as f64 / mem_bw).min(stream_s)
    } else {
        stream_s
    };

    let compute_floor = (shape.flops() / dev.peak_matrix_flops()
        - steps_total as f64 / dev.frequency_hz)
        .max(0.0);

    let bound = if !map.db_global && problem_bytes > dev.global_buffer_bytes {
        // Without the software pipeline (and with the resident fast path
        // ruled out by capacity) every step *serializes* IO after compute,
        // so the floors add — this is what prunes most non-pipelined
        // candidates of compute-bound GEMMs.
        io_floor + compute_floor
    } else {
        io_floor.max(compute_floor)
    };
    // Shave a relative epsilon so residual floating-point reassociation
    // (a few ulps at most — orders of magnitude below any real pruning
    // margin) can never tip the bound past the simulated time.
    bound * (1.0 - 1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::presets::a100;

    fn lut() -> SystolicLut {
        SystolicLut::new()
    }

    fn map_basic() -> Mapping {
        Mapping {
            gt: (256, 256, 256),
            lt: (128, 32, 64),
            scheme: Scheme::OutputPartitioned,
            db_global: true,
            db_local: true,
        }
    }

    #[test]
    fn fits_respects_capacity() {
        let dev = a100();
        let shape = Shape::simple(4096, 4096, 4096, DType::FP16);
        assert!(fits(&dev, &shape, &map_basic()));
        let huge = Mapping { gt: (8192, 8192, 8192), ..map_basic() };
        assert!(!fits(&dev, &shape, &huge));
        let bad_lt = Mapping { lt: (512, 512, 512), ..map_basic() };
        assert!(!fits(&dev, &shape, &bad_lt));
        let zero = Mapping { gt: (0, 256, 256), ..map_basic() };
        assert!(!fits(&dev, &shape, &zero));
    }

    #[test]
    fn double_buffering_halves_max_tile() {
        let dev = a100();
        let shape = Shape::simple(4096, 4096, 4096, DType::FP16);
        // A tile that fits without the software pipeline but not with it.
        let tight = Mapping {
            gt: (2048, 2048, 3072),
            lt: (128, 32, 64),
            scheme: Scheme::OutputPartitioned,
            db_global: false,
            db_local: true,
        };
        assert!(fits(&dev, &shape, &tight));
        let tight_db = Mapping { db_global: true, ..tight };
        assert!(!fits(&dev, &shape, &tight_db));
    }

    #[test]
    fn simulation_bounded_by_rooflines() {
        let dev = a100();
        let shape = Shape::simple(2048, 2048, 2048, DType::FP16);
        let out = simulate(&dev, &shape, &map_basic(), &lut()).unwrap();
        let compute_bound = shape.flops() / dev.peak_matrix_flops();
        let io_bound = crate::perf::Op::Matmul {
            b: 1,
            m: 2048,
            k: 2048,
            n: 2048,
            dtype: DType::FP16,
            batched_b: false,
        }
        .min_dram_bytes()
            / dev.memory.bandwidth_bytes_per_s;
        assert!(
            out.seconds >= compute_bound.max(io_bound) * 0.999,
            "sim {} vs bounds c={} io={}",
            out.seconds,
            compute_bound,
            io_bound
        );
        // And not absurdly slow either (within 20x of roofline).
        assert!(out.seconds < compute_bound.max(io_bound) * 20.0);
        assert!(out.systolic_util > 0.0 && out.systolic_util <= 1.0);
        assert!(out.dram_bytes >= shape.flops() / 2048.0); // > A bytes alone
    }

    #[test]
    fn narrow_decode_matmul_is_io_bound() {
        let dev = a100();
        // Decode-style: 8×12288×12288 — reading B dominates. A sensible
        // mapping streams the full k extent per tile so compute overlaps
        // the weight stream.
        let shape = Shape::simple(8, 12288, 12288, DType::FP16);
        let map = Mapping {
            gt: (8, 8192, 512),
            lt: (8, 128, 64),
            scheme: Scheme::KSplit,
            db_global: true,
            db_local: true,
        };
        let out = simulate(&dev, &shape, &map, &lut()).unwrap();
        let io_bound = (12288.0 * 12288.0 * 2.0) / dev.memory.bandwidth_bytes_per_s;
        assert!(out.seconds >= io_bound * 0.9);
        assert!(
            out.seconds <= io_bound * 3.0,
            "decode matmul {}x io bound",
            out.seconds / io_bound
        );
    }

    #[test]
    fn decode_matmul_mapper_near_io_bound() {
        // The mapper (not a hand mapping) must get the decode GEMM within
        // ~2x of its IO roofline — paper implication ③ hinges on this.
        let dev = a100();
        let shape = Shape::simple(8, 12288, 12288, DType::FP16);
        let best = crate::perf::mapper::search(
            &dev,
            &shape,
            crate::perf::mapper::SearchBudget::default(),
            &lut(),
        );
        let io_bound = (12288.0 * 12288.0 * 2.0) / dev.memory.bandwidth_bytes_per_s;
        let ratio = best.outcome.seconds / io_bound;
        assert!(ratio < 2.0, "mapper decode GEMM at {ratio:.2}x io bound");
    }

    #[test]
    fn batch_packing_helps_small_batched_gemms() {
        let dev = a100();
        // 96 heads × (1×128 · 128×2048): tiny per-head GEMMs.
        let shape =
            Shape { b: 96, m: 8, k: 128, n: 2048, dtype: DType::FP16, batched_b: true };
        let map = Mapping {
            gt: (8, 128, 2048),
            lt: (8, 128, 64),
            scheme: Scheme::OutputPartitioned,
            db_global: true,
            db_local: true,
        };
        let out = simulate(&dev, &shape, &map, &lut()).unwrap();
        // Without packing this would serialize 96 tile steps; packing must
        // keep it within ~4x of the IO bound.
        let io_bound = shape.b as f64 * (8.0 * 128.0 + 128.0 * 2048.0 + 8.0 * 2048.0) * 2.0
            / dev.memory.bandwidth_bytes_per_s;
        assert!(out.seconds < io_bound * 6.0, "{} vs {}", out.seconds, io_bound);
    }

    #[test]
    fn lower_bound_never_exceeds_simulated_time() {
        // Hand-picked mappings across regimes: compute-bound prefill,
        // IO-bound decode, batched attention, k-split. The exhaustive
        // property version lives in tests/property_model.rs.
        let dev = a100();
        let l = lut();
        let cases = [
            (Shape::simple(2048, 2048, 2048, DType::FP16), map_basic()),
            (
                Shape::simple(8, 12288, 12288, DType::FP16),
                Mapping {
                    gt: (8, 8192, 512),
                    lt: (8, 128, 64),
                    scheme: Scheme::KSplit,
                    db_global: true,
                    db_local: true,
                },
            ),
            (
                Shape { b: 96, m: 8, k: 128, n: 2048, dtype: DType::FP16, batched_b: true },
                Mapping {
                    gt: (8, 128, 2048),
                    lt: (8, 128, 64),
                    scheme: Scheme::OutputPartitioned,
                    db_global: true,
                    db_local: true,
                },
            ),
            (
                Shape::simple(128, 12288, 128, DType::FP16),
                Mapping {
                    gt: (128, 2048, 128),
                    lt: (64, 128, 64),
                    scheme: Scheme::OutputPartitioned,
                    db_global: false,
                    db_local: false,
                },
            ),
        ];
        for (shape, map) in cases {
            let sim = simulate(&dev, &shape, &map, &l).unwrap();
            let lb = lower_bound(&dev, &shape, &map);
            assert!(
                lb <= sim.seconds,
                "lower bound {lb} > simulated {} for {shape:?} {map:?}",
                sim.seconds
            );
            assert!(lb > 0.0, "degenerate bound for {shape:?}");
        }
    }

    #[test]
    fn ksplit_viable_for_few_output_tiles() {
        let dev = a100();
        // m=n=128 but k=12288: scheme 1 can use at most 4 cores (2x2
        // subtiles); scheme 2 should beat it by ganging cores on k.
        let shape = Shape::simple(128, 12288, 128, DType::FP16);
        let s1 = Mapping {
            gt: (128, 2048, 128),
            lt: (64, 128, 64),
            scheme: Scheme::OutputPartitioned,
            db_global: true,
            db_local: true,
        };
        let s2 = Mapping { scheme: Scheme::KSplit, ..s1 };
        let l = lut();
        let t1 = simulate(&dev, &shape, &s1, &l).unwrap().seconds;
        let t2 = simulate(&dev, &shape, &s2, &l).unwrap().seconds;
        assert!(t2 < t1, "k-split {t2} should beat output-partitioned {t1}");
    }
}
