//! Communication-primitive models (paper §III-B2).
//!
//! LLM inference needs only two primitives: **ring all-reduce** for tensor
//! parallelism (two per Transformer layer) and **peer-to-peer** for
//! pipeline parallelism. Both ride on the Eq. 1–2 link model in
//! [`crate::arch::link`].

use crate::arch::link::transfer_time;
use crate::hardware::{InterconnectSpec, SystemSpec};
use crate::perf::OpResult;

/// Ring all-reduce of `bytes` across `devices` (Patarasuk–Yuan [49],
/// bandwidth-optimal): a reduce-scatter phase and an all-gather phase, each
/// `devices − 1` steps moving `bytes / devices` per link per step; all
/// links are active simultaneously, so the wall time is the per-step link
/// time × 2(g−1).
pub fn all_reduce(ic: &InterconnectSpec, bytes: u64, devices: u64) -> OpResult {
    assert!(devices >= 1);
    if devices == 1 || bytes == 0 {
        return OpResult {
            latency_s: 0.0,
            compute_bound_s: 0.0,
            memory_bound_s: 0.0,
            mapper_rounds: 0,
            mapping_desc: "no-op".into(),
        };
    }
    let chunk = (bytes + devices - 1) / devices;
    let steps = 2 * (devices - 1);
    let step_s = transfer_time(ic, chunk);
    let total = steps as f64 * step_s;
    // Lower bound: each byte leaves/enters every device once → the classic
    // 2(g−1)/g · n / B bound.
    let bw_bound =
        2.0 * (devices - 1) as f64 / devices as f64 * bytes as f64 / ic.link_bandwidth_bytes_per_s;
    OpResult {
        latency_s: total,
        compute_bound_s: 0.0,
        memory_bound_s: bw_bound,
        mapper_rounds: 0,
        mapping_desc: format!("ring g={devices} chunk={chunk}B steps={steps}"),
    }
}

/// Point-to-point transfer (pipeline-parallel stage handoff).
pub fn peer_to_peer(ic: &InterconnectSpec, bytes: u64) -> OpResult {
    let t = transfer_time(ic, bytes);
    OpResult {
        latency_s: t,
        compute_bound_s: 0.0,
        memory_bound_s: bytes as f64 / ic.link_bandwidth_bytes_per_s,
        mapper_rounds: 0,
        mapping_desc: format!("p2p {bytes}B"),
    }
}

/// Convenience: all-reduce on a system's interconnect across all devices.
pub fn system_all_reduce(sys: &SystemSpec, bytes: u64) -> OpResult {
    all_reduce(&sys.interconnect, bytes, sys.device_count)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::InterconnectSpec;

    fn nvlink() -> InterconnectSpec {
        InterconnectSpec::nvlink_like(600e9)
    }

    #[test]
    fn single_device_is_free() {
        let r = all_reduce(&nvlink(), 1 << 20, 1);
        assert_eq!(r.latency_s, 0.0);
        assert_eq!(all_reduce(&nvlink(), 0, 8).latency_s, 0.0);
    }

    #[test]
    fn approaches_bandwidth_bound_for_large_messages() {
        let ic = nvlink();
        let r = all_reduce(&ic, 1 << 30, 4);
        // Within framing overhead (~6.25%) + step latencies of the bound.
        assert!(r.latency_s >= r.memory_bound_s);
        assert!(r.latency_s < r.memory_bound_s * 1.15, "{} vs {}", r.latency_s, r.memory_bound_s);
    }

    #[test]
    fn latency_floor_for_small_messages() {
        let ic = nvlink();
        let r = all_reduce(&ic, 1024, 4);
        let floor = 6.0 * (ic.link_latency_s + ic.overhead_s);
        assert!(r.latency_s >= floor);
    }

    #[test]
    fn more_devices_more_steps() {
        let ic = nvlink();
        let small = 64 * 1024;
        let t4 = all_reduce(&ic, small, 4).latency_s;
        let t8 = all_reduce(&ic, small, 8).latency_s;
        assert!(t8 > t4, "latency-dominated all-reduce grows with ring size");
    }

    #[test]
    fn p2p_matches_link_model() {
        let ic = nvlink();
        let r = peer_to_peer(&ic, 1 << 20);
        assert!(r.latency_s > 0.0);
        assert!(r.latency_s >= r.memory_bound_s);
    }
}
