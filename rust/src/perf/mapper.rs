//! The mapper: parameter search over tilings and schedules (paper §III-B).
//!
//! "A parameter search is performed by the mapper to determine the best
//! tiling scheme and schedule scheme … LLMCompass always tries to find the
//! performance-optimal mapping to fully demonstrate the hardware
//! capability."
//!
//! The search enumerates global-tile and local-tile sizes (powers of two
//! aligned to the systolic geometry, plus the problem extents themselves),
//! both schedule schemes, and the software-pipeline (double-buffering)
//! options at each level, simulates every feasible combination through
//! [`super::matmul::simulate`], and keeps the fastest. Results are
//! memoized per (device, shape) — the same matmul shape recurs for every
//! Transformer layer, so a GPT-3 run touches only a handful of unique
//! shapes.

use super::matmul::{fits, simulate, Mapping, Scheme, Shape, SimOutcome};
use crate::arch::systolic::SystolicLut;
use crate::hardware::{DeviceSpec, DType};
use std::collections::{HashMap, HashSet};
use std::sync::{Condvar, Mutex};

/// Search-space budget knobs. The defaults give a few hundred to a couple
/// thousand rounds per unique shape, in line with the paper's 26,400 rounds
/// for a full GPT-3 inference simulation.
#[derive(Debug, Clone, Copy)]
pub struct SearchBudget {
    /// Max candidate sizes per global-tile dimension.
    pub gt_per_dim: usize,
    /// Max candidate sizes per local-tile dimension.
    pub lt_per_dim: usize,
    /// Worker threads for the per-candidate simulation loop (1 = serial).
    /// Keep 1 when the caller already fans out over `util::pool` (the
    /// experiment sweeps do), so thread counts do not multiply.
    pub threads: usize,
}

impl Default for SearchBudget {
    fn default() -> Self {
        SearchBudget { gt_per_dim: 4, lt_per_dim: 3, threads: 1 }
    }
}

impl SearchBudget {
    /// Default budget with the candidate loop fanned across all available
    /// cores — for single-search callers (CLI ops, the serving oracle).
    pub fn pooled() -> Self {
        SearchBudget { threads: crate::util::pool::default_threads(), ..Self::default() }
    }
}

/// Outcome of a mapper search.
#[derive(Debug, Clone)]
pub struct Best {
    pub outcome: SimOutcome,
    pub mapping: Mapping,
    /// Number of (mapping) candidates actually simulated.
    pub rounds: u64,
}

/// Candidate tile sizes for one dimension: descending powers of two capped
/// by `limit` and the problem extent, plus the extent itself, aligned to
/// `align` where possible.
fn candidates(extent: u64, limit: u64, align: u64, max_count: usize) -> Vec<u64> {
    let max_count = max_count.max(1);
    let top = extent.min(limit).max(1);
    let bottom = align.clamp(1, top).max(8).min(top);
    // All powers of two in [bottom, top], plus top itself (the whole dim).
    let mut pool: Vec<u64> = Vec::new();
    pool.push(top);
    let mut p = top.next_power_of_two() / 2;
    while p >= bottom {
        if p < top {
            pool.push(p);
        }
        p /= 2;
    }
    if !pool.contains(&bottom) {
        pool.push(bottom);
    }
    if pool.len() <= max_count {
        return pool;
    }
    // Geometric spread: keep the largest, the smallest, and evenly-spaced
    // (in index space) middles, so tiny buffers and huge ones both get
    // feasible tiles.
    let mut out = Vec::with_capacity(max_count);
    for i in 0..max_count {
        let idx = i * (pool.len() - 1) / (max_count - 1).max(1);
        if !out.contains(&pool[idx]) {
            out.push(pool[idx]);
        }
    }
    out
}

/// Enumerate the feasible candidate mappings for `shape` on `dev`, in the
/// canonical (deterministic) search order.
fn feasible_candidates(dev: &DeviceSpec, shape: &Shape, budget: SearchBudget) -> Vec<Mapping> {
    let sys_r = dev.core.lane.systolic_rows;
    let sys_c = dev.core.lane.systolic_cols;

    // Global tiles: prefer large (maximize reuse); cap extents at 8192 so
    // the capacity filter does the real work.
    let gt_m = candidates(shape.m, 8192, sys_r.min(64), budget.gt_per_dim);
    let gt_k = candidates(shape.k, 8192, sys_r, budget.gt_per_dim);
    let gt_n = candidates(shape.n, 8192, sys_c, budget.gt_per_dim);
    // Local tiles: sized for the local buffer / systolic geometry.
    let lt_m = candidates(shape.m, 256, sys_r.min(16), budget.lt_per_dim);
    let lt_k = candidates(shape.k, 256, sys_r, budget.lt_per_dim);
    let lt_n = candidates(shape.n, 256, sys_c, budget.lt_per_dim);

    let mut out = Vec::new();
    for &gm in &gt_m {
        for &gk in &gt_k {
            for &gn in &gt_n {
                for &lm in &lt_m {
                    if lm > gm {
                        continue;
                    }
                    for &lk in &lt_k {
                        if lk > gk {
                            continue;
                        }
                        for &ln in &lt_n {
                            if ln > gn {
                                continue;
                            }
                            // Scheme 2 only pays off when scheme 1 cannot
                            // fill the cores with output sub-tiles.
                            let sub_tiles =
                                ((gm + lm - 1) / lm) * ((gn + ln - 1) / ln) * shape.b.min(4);
                            let schemes: &[Scheme] = if sub_tiles < 2 * dev.core_count {
                                &[Scheme::OutputPartitioned, Scheme::KSplit]
                            } else {
                                &[Scheme::OutputPartitioned]
                            };
                            for &scheme in schemes {
                                for db_global in [true, false] {
                                    for db_local in [true, false] {
                                        let map = Mapping {
                                            gt: (gm, gk, gn),
                                            lt: (lm, lk, ln),
                                            scheme,
                                            db_global,
                                            db_local,
                                        };
                                        if fits(dev, shape, &map) {
                                            out.push(map);
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Exhaustively search mappings for `shape` on `dev`; returns the fastest
/// feasible mapping. Panics only if no mapping fits (which cannot happen:
/// the minimal systolic-aligned tile always fits any realistic buffer).
///
/// With `budget.threads > 1` the per-candidate simulations fan across a
/// [`crate::util::pool`] scoped pool. The reduction keeps the serial
/// result bit-for-bit: `parallel_map` preserves candidate order and the
/// fold takes the *first* strictly-fastest outcome, so ties resolve the
/// same way in both paths. The [`SystolicLut`] is shared across workers
/// behind its internal `Mutex`.
pub fn search(dev: &DeviceSpec, shape: &Shape, budget: SearchBudget, lut: &SystolicLut) -> Best {
    let cands = feasible_candidates(dev, shape, budget);
    let rounds = cands.len() as u64;

    let outcomes: Vec<Option<SimOutcome>> = if budget.threads > 1 {
        crate::util::pool::parallel_map(&cands, budget.threads, |map| {
            simulate(dev, shape, map, lut)
        })
    } else {
        cands.iter().map(|map| simulate(dev, shape, map, lut)).collect()
    };

    let mut best: Option<(SimOutcome, Mapping)> = None;
    for (map, out) in cands.iter().zip(outcomes) {
        if let Some(out) = out {
            let better = match &best {
                None => true,
                Some((b, _)) => out.seconds < b.seconds,
            };
            if better {
                best = Some((out, *map));
            }
        }
    }

    let (outcome, mapping) = best.unwrap_or_else(|| {
        panic!(
            "no feasible mapping for shape {:?} on {} (local buffer {} B)",
            shape, dev.name, dev.core.local_buffer_bytes
        )
    });
    Best { outcome, mapping, rounds }
}

/// Memoizing front-end to [`search`]. Keyed by device name + shape, so use
/// distinct names for distinct hardware descriptions (presets do).
type CacheKey = (u64, u64, u64, u64, u64, DType, bool);

pub struct Mapper {
    budget: SearchBudget,
    lut: SystolicLut,
    cache: Mutex<HashMap<CacheKey, Best>>,
    /// Keys whose search is currently running on some thread. Concurrent
    /// callers of the same key wait on [`Mapper::search_done`] instead of
    /// duplicating the (expensive) search — this is what keeps the
    /// cross-scenario search count minimal even when `eval` suites fan
    /// out across threads.
    in_flight: Mutex<HashSet<CacheKey>>,
    search_done: Condvar,
    total_rounds: Mutex<u64>,
    searches: Mutex<u64>,
}

impl Default for Mapper {
    fn default() -> Self {
        Self::new(SearchBudget::default())
    }
}

impl Mapper {
    pub fn new(budget: SearchBudget) -> Self {
        Mapper {
            budget,
            lut: SystolicLut::new(),
            cache: Mutex::new(HashMap::new()),
            in_flight: Mutex::new(HashSet::new()),
            search_done: Condvar::new(),
            total_rounds: Mutex::new(0),
            searches: Mutex::new(0),
        }
    }

    /// A mapper whose candidate loop fans across all cores. Memoization is
    /// unchanged — the cache `Mutex` is only held around lookups/inserts,
    /// never across a search; concurrent callers of the same shape
    /// coalesce onto one search via the in-flight set.
    pub fn pooled() -> Self {
        Mapper::new(SearchBudget::pooled())
    }

    pub fn matmul(&self, dev: &DeviceSpec, shape: &Shape) -> Best {
        let key = (
            dev.fingerprint(),
            shape.b,
            shape.m,
            shape.k,
            shape.n,
            shape.dtype,
            shape.batched_b,
        );
        // Fast path / search coalescing. A miss claims the key in
        // `in_flight`; concurrent callers of the same key block on the
        // condvar and re-check the cache instead of duplicating the
        // search. Lock order is safe: the cache guard is always a
        // statement-scoped temporary, never held while acquiring
        // `in_flight`. (If `search` panicked the in-flight marker would
        // leak and waiters would hang, but `search` panics only on an
        // infeasible shape, which the minimal systolic tile rules out.)
        loop {
            if let Some(hit) = self.cache.lock().unwrap().get(&key) {
                return hit.clone();
            }
            let mut in_flight = self.in_flight.lock().unwrap();
            // Re-check: the searcher publishes to the cache before
            // clearing its marker, so miss + no marker ⇒ nobody is on it.
            if self.cache.lock().unwrap().contains_key(&key) {
                continue;
            }
            if in_flight.insert(key) {
                break; // this thread owns the search
            }
            // Someone else is searching this key; wait and re-check.
            drop(self.search_done.wait(in_flight).unwrap());
        }
        let best = search(dev, shape, self.budget, &self.lut);
        *self.total_rounds.lock().unwrap() += best.rounds;
        *self.searches.lock().unwrap() += 1;
        self.cache.lock().unwrap().insert(key, best.clone());
        self.in_flight.lock().unwrap().remove(&key);
        self.search_done.notify_all();
        best
    }

    /// Number of full mapper parameter searches performed (cache misses) —
    /// the quantity cross-scenario caching in `eval` exists to minimize.
    pub fn searches(&self) -> u64 {
        *self.searches.lock().unwrap()
    }

    /// Total mapper rounds across all (non-cached) searches — the paper's
    /// "26,400 rounds of the mapper's parameter search" statistic.
    pub fn total_rounds(&self) -> u64 {
        *self.total_rounds.lock().unwrap()
    }

    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::presets::{a100, design};

    #[test]
    fn candidates_sane() {
        let c = candidates(2048, 8192, 16, 4);
        assert!(c.contains(&2048));
        assert!(c.iter().all(|&v| v <= 2048 && v >= 1));
        assert!(c.len() <= 4);
        // Small extents still produce something.
        let c = candidates(5, 8192, 16, 4);
        assert_eq!(c[0], 5);
    }

    #[test]
    fn search_finds_reasonable_mapping_for_big_gemm() {
        let dev = a100();
        let shape = Shape::simple(2048, 12288, 12288, DType::FP16);
        let best = search(&dev, &shape, SearchBudget::default(), &SystolicLut::new());
        assert!(best.rounds > 10, "searched {} rounds", best.rounds);
        // Prefill-class GEMM on A100 should land within 3x of the
        // compute roofline (paper measures ~50% of roofline on A100).
        let roofline = shape.flops() / dev.peak_matrix_flops();
        let ratio = best.outcome.seconds / roofline;
        assert!(ratio < 3.0, "achieved {ratio:.2}x of compute roofline");
        assert!(best.outcome.systolic_util > 0.3, "util {}", best.outcome.systolic_util);
    }

    #[test]
    fn mapper_caches_by_shape() {
        let mapper = Mapper::default();
        let dev = a100();
        let shape = Shape::simple(256, 512, 256, DType::FP16);
        let a = mapper.matmul(&dev, &shape);
        let rounds_after_first = mapper.total_rounds();
        let b = mapper.matmul(&dev, &shape);
        assert_eq!(mapper.total_rounds(), rounds_after_first, "second hit was cached");
        assert_eq!(a.outcome.seconds, b.outcome.seconds);
        assert_eq!(mapper.cache_len(), 1);
        assert_eq!(mapper.searches(), 1, "one unique shape → one search");
    }

    #[test]
    fn concurrent_matmul_coalesces_to_one_search() {
        // Eight threads racing on a cold cache for the same shape must
        // produce one search, identical results, and one cache entry.
        let mapper = Mapper::default();
        let dev = a100();
        let shape = Shape::simple(256, 512, 256, DType::FP16);
        let items: Vec<u32> = (0..8).collect();
        let outs = crate::util::pool::parallel_map(&items, 8, |_| {
            mapper.matmul(&dev, &shape).outcome.seconds
        });
        assert!(outs.windows(2).all(|w| w[0] == w[1]));
        assert_eq!(mapper.searches(), 1, "racing callers must coalesce");
        assert_eq!(mapper.cache_len(), 1);
    }

    #[test]
    fn tiny_decode_shape_feasible_everywhere() {
        // m=8 decode GEMMs must map onto every Table III design, including
        // E with its 128x128 arrays.
        for l in ['A', 'B', 'C', 'D', 'E'] {
            let dev = design(l).unwrap();
            let shape = Shape::simple(8, 12288, 1024, DType::FP16);
            let best = search(&dev, &shape, SearchBudget::default(), &SystolicLut::new());
            assert!(best.outcome.seconds > 0.0, "design {l}");
        }
    }

    #[test]
    fn pooled_search_matches_serial_exactly() {
        // Same candidates, order-stable reduction → bit-identical winner.
        let dev = a100();
        let lut = SystolicLut::new();
        for shape in [
            Shape::simple(2048, 12288, 12288, DType::FP16),
            Shape::simple(8, 12288, 1024, DType::FP16),
            Shape::simple(5, 300, 7, DType::FP32),
        ] {
            let serial = search(&dev, &shape, SearchBudget::default(), &lut);
            let budget = SearchBudget { threads: 4, ..SearchBudget::default() };
            let pooled = search(&dev, &shape, budget, &lut);
            assert_eq!(serial.rounds, pooled.rounds);
            assert_eq!(serial.outcome.seconds, pooled.outcome.seconds);
            assert_eq!(serial.mapping, pooled.mapping);
        }
    }

    #[test]
    fn more_bandwidth_never_slower() {
        // Monotonicity: doubling memory bandwidth cannot slow the best
        // mapping down (same candidate set, each candidate monotone).
        let mut dev = a100();
        let shape = Shape::simple(8, 12288, 12288, DType::FP16);
        let lut = SystolicLut::new();
        let slow = search(&dev, &shape, SearchBudget::default(), &lut).outcome.seconds;
        dev.memory.bandwidth_bytes_per_s *= 2.0;
        let fast = search(&dev, &shape, SearchBudget::default(), &lut).outcome.seconds;
        assert!(fast <= slow * 1.0001, "2x BW: {fast} vs {slow}");
    }
}
