//! The mapper search **engine**: pruned, work-stealing, persistently
//! cached parameter search over tilings and schedules (paper §III-B).
//!
//! "A parameter search is performed by the mapper to determine the best
//! tiling scheme and schedule scheme … LLMCompass always tries to find the
//! performance-optimal mapping to fully demonstrate the hardware
//! capability."
//!
//! The search enumerates global-tile and local-tile sizes (powers of two
//! aligned to the systolic geometry, plus the problem extents themselves),
//! both schedule schemes, and the software-pipeline (double-buffering)
//! options at each level, and keeps the fastest mapping under
//! [`super::matmul::simulate`]. Four coordinated optimizations make this a
//! search engine rather than a brute-force sweep — each preserves the
//! exhaustive serial path's winner bit for bit:
//!
//! 1. **Lower-bound pruning** ([`SearchBudget::prune`], default on).
//!    Every candidate first gets the O(1) analytical floor from
//!    [`super::matmul::lower_bound`]; candidates whose floor already
//!    exceeds the best simulated time so far are skipped. The best-so-far
//!    lives in an atomic seconds watermark, so the parallel paths prune
//!    too. Because the floor is a *true* lower bound and pruning is
//!    strict (`bound > watermark`), only strictly-suboptimal candidates
//!    are ever skipped — every optimal candidate is simulated, and the
//!    ordered first-strict-minimum reduction returns the identical
//!    winner. Only [`Best::rounds`] (candidates actually simulated)
//!    shrinks.
//! 2. **Work-stealing hybrid parallelism** ([`SearchBudget::hybrid`]).
//!    The candidate loop fans across [`crate::util::pool::parallel_map_shared`],
//!    borrowing workers from the process-wide token budget. Experiment
//!    sweeps and eval suites fan out over the same budget, so both levels
//!    of parallelism (per-cell *and* per-candidate) get used without
//!    thread counts multiplying: a sweep's tail cells donate their idle
//!    workers to the remaining searches.
//! 3. **Lock-light [`SystolicLut`]**. The per-tile timing LUT is sharded
//!    with atomic hit/miss counters, so parallel candidate workers no
//!    longer serialize on the global mutex every simulated candidate used
//!    to take.
//! 4. **Persistent on-disk mapping cache** ([`Mapper::with_cache`]).
//!    Search results are memoized per (device fingerprint, shape, budget)
//!    in a versioned JSON file (CLI `--mapper-cache`, conventionally under
//!    `$LLMCOMPASS_ARTIFACT_DIR`), so repeated CLI runs, eval suites, and
//!    serve sweeps skip whole searches across processes.
//!
//! In-process, results are still memoized per (device, shape) — the same
//! matmul shape recurs for every Transformer layer, so a GPT-3 run touches
//! only a handful of unique shapes.

use super::matmul::{fits, lower_bound, simulate, Mapping, Scheme, Shape, SimOutcome};
use crate::arch::systolic::SystolicLut;
use crate::hardware::{DType, DeviceSpec};
use crate::util::json::{num, obj, s, Json};
use crate::util::telemetry::Recorder;
use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Search-space budget knobs. The defaults give a few hundred to a couple
/// thousand rounds per unique shape, in line with the paper's 26,400 rounds
/// for a full GPT-3 inference simulation.
#[derive(Debug, Clone, Copy)]
pub struct SearchBudget {
    /// Max candidate sizes per global-tile dimension.
    pub gt_per_dim: usize,
    /// Max candidate sizes per local-tile dimension.
    pub lt_per_dim: usize,
    /// Fixed worker threads for the per-candidate simulation loop
    /// (1 = serial). Ignored when `hybrid` is set. Keep 1 when the caller
    /// already fans out over `util::pool` with fixed threads.
    pub threads: usize,
    /// Skip candidates whose analytical lower bound already exceeds the
    /// best simulated time (identical winner, far fewer rounds).
    pub prune: bool,
    /// Fan the candidate loop across the process-wide work-stealing token
    /// budget instead of a fixed thread count — safe under outer sweeps.
    pub hybrid: bool,
}

impl Default for SearchBudget {
    fn default() -> Self {
        SearchBudget { gt_per_dim: 4, lt_per_dim: 3, threads: 1, prune: true, hybrid: false }
    }
}

impl SearchBudget {
    /// Default budget with the candidate loop fanned across all available
    /// cores as a fixed pool — for single-search callers that own the
    /// whole machine (CLI ops, the serving oracle).
    pub fn pooled() -> Self {
        SearchBudget { threads: crate::util::pool::default_threads(), ..Self::default() }
    }

    /// Default budget with the candidate loop in work-stealing hybrid
    /// mode: workers are borrowed from (and returned to) the shared token
    /// budget, so experiment sweeps and eval suites can fan out per-cell
    /// *and* per-candidate without multiplying threads.
    pub fn hybrid() -> Self {
        SearchBudget { hybrid: true, ..Self::default() }
    }

    /// Default budget with pruning disabled — the exhaustive reference
    /// path (benchmarks and the identity tests compare against this).
    pub fn exhaustive() -> Self {
        SearchBudget { prune: false, ..Self::default() }
    }
}

/// Outcome of a mapper search.
#[derive(Debug, Clone)]
pub struct Best {
    pub outcome: SimOutcome,
    pub mapping: Mapping,
    /// Number of candidate mappings actually simulated (pruning shrinks
    /// this; with a parallel budget it can vary run to run — the winner
    /// never does).
    pub rounds: u64,
    /// Number of feasible candidates enumerated (the exhaustive round
    /// count; `rounds / candidates` is the survival rate under pruning).
    pub candidates: u64,
}

/// Candidate tile sizes for one dimension: descending powers of two capped
/// by `limit` and the problem extent, plus the extent itself, aligned to
/// `align` where possible.
fn candidates(extent: u64, limit: u64, align: u64, max_count: usize) -> Vec<u64> {
    let max_count = max_count.max(1);
    let top = extent.min(limit).max(1);
    let bottom = align.clamp(1, top).max(8).min(top);
    // All powers of two in [bottom, top], plus top itself (the whole dim).
    let mut pool: Vec<u64> = Vec::new();
    pool.push(top);
    let mut p = top.next_power_of_two() / 2;
    while p >= bottom {
        if p < top {
            pool.push(p);
        }
        p /= 2;
    }
    if !pool.contains(&bottom) {
        pool.push(bottom);
    }
    if pool.len() <= max_count {
        return pool;
    }
    // Geometric spread: keep the largest, the smallest, and evenly-spaced
    // (in index space) middles, so tiny buffers and huge ones both get
    // feasible tiles.
    let mut out = Vec::with_capacity(max_count);
    for i in 0..max_count {
        let idx = i * (pool.len() - 1) / (max_count - 1).max(1);
        if !out.contains(&pool[idx]) {
            out.push(pool[idx]);
        }
    }
    out
}

/// Enumerate the feasible candidate mappings for `shape` on `dev`, in the
/// canonical (deterministic) search order.
fn feasible_candidates(dev: &DeviceSpec, shape: &Shape, budget: SearchBudget) -> Vec<Mapping> {
    let sys_r = dev.core.lane.systolic_rows;
    let sys_c = dev.core.lane.systolic_cols;

    // Global tiles: prefer large (maximize reuse); cap extents at 8192 so
    // the capacity filter does the real work.
    let gt_m = candidates(shape.m, 8192, sys_r.min(64), budget.gt_per_dim);
    let gt_k = candidates(shape.k, 8192, sys_r, budget.gt_per_dim);
    let gt_n = candidates(shape.n, 8192, sys_c, budget.gt_per_dim);
    // Local tiles: sized for the local buffer / systolic geometry.
    let lt_m = candidates(shape.m, 256, sys_r.min(16), budget.lt_per_dim);
    let lt_k = candidates(shape.k, 256, sys_r, budget.lt_per_dim);
    let lt_n = candidates(shape.n, 256, sys_c, budget.lt_per_dim);

    let mut out = Vec::new();
    for &gm in &gt_m {
        for &gk in &gt_k {
            for &gn in &gt_n {
                for &lm in &lt_m {
                    if lm > gm {
                        continue;
                    }
                    for &lk in &lt_k {
                        if lk > gk {
                            continue;
                        }
                        for &ln in &lt_n {
                            if ln > gn {
                                continue;
                            }
                            // Scheme 2 only pays off when scheme 1 cannot
                            // fill the cores with output sub-tiles.
                            let sub_tiles =
                                ((gm + lm - 1) / lm) * ((gn + ln - 1) / ln) * shape.b.min(4);
                            let schemes: &[Scheme] = if sub_tiles < 2 * dev.core_count {
                                &[Scheme::OutputPartitioned, Scheme::KSplit]
                            } else {
                                &[Scheme::OutputPartitioned]
                            };
                            for &scheme in schemes {
                                for db_global in [true, false] {
                                    for db_local in [true, false] {
                                        let map = Mapping {
                                            gt: (gm, gk, gn),
                                            lt: (lm, lk, ln),
                                            scheme,
                                            db_global,
                                            db_local,
                                        };
                                        if fits(dev, shape, &map) {
                                            out.push(map);
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Search mappings for `shape` on `dev`; returns the fastest feasible
/// mapping. Panics only if no mapping fits (which cannot happen on a
/// realistic device: the minimal systolic-aligned tile always fits).
///
/// All budget modes (serial, fixed-pool, hybrid, pruned or exhaustive)
/// return the identical `(mapping, outcome)`:
///
/// * the candidate list and its order are deterministic;
/// * the parallel maps preserve candidate order, and the reduction takes
///   the *first* strictly-fastest outcome, so ties resolve identically;
/// * pruning skips a candidate only when its [`lower_bound`] strictly
///   exceeds the watermark — an actually-simulated time, so every
///   candidate tied with the optimum is simulated, and only
///   [`Best::rounds`] varies.
pub fn search(dev: &DeviceSpec, shape: &Shape, budget: SearchBudget, lut: &SystolicLut) -> Best {
    let cands = feasible_candidates(dev, shape, budget);
    let candidates = cands.len() as u64;

    // Best-so-far seconds, shared across workers as raw f64 bits. Only
    // ever lowered, and only to values some worker actually simulated.
    let watermark = AtomicU64::new(f64::INFINITY.to_bits());
    let simulated = AtomicU64::new(0);
    let eval = |map: &Mapping| -> Option<SimOutcome> {
        if budget.prune
            && lower_bound(dev, shape, map) > f64::from_bits(watermark.load(Ordering::Relaxed))
        {
            return None;
        }
        let out = simulate(dev, shape, map, lut)?;
        simulated.fetch_add(1, Ordering::Relaxed);
        if budget.prune {
            let mut cur = watermark.load(Ordering::Relaxed);
            while out.seconds < f64::from_bits(cur) {
                match watermark.compare_exchange_weak(
                    cur,
                    out.seconds.to_bits(),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => break,
                    Err(c) => cur = c,
                }
            }
        }
        Some(out)
    };

    let outcomes: Vec<Option<SimOutcome>> = if budget.hybrid {
        crate::util::pool::parallel_map_shared(&cands, eval)
    } else if budget.threads > 1 {
        crate::util::pool::parallel_map(&cands, budget.threads, eval)
    } else {
        cands.iter().map(eval).collect()
    };

    let mut best: Option<(SimOutcome, Mapping)> = None;
    for (map, out) in cands.iter().zip(outcomes) {
        if let Some(out) = out {
            let better = match &best {
                None => true,
                Some((b, _)) => out.seconds < b.seconds,
            };
            if better {
                best = Some((out, *map));
            }
        }
    }

    let (outcome, mapping) = best.unwrap_or_else(|| {
        panic!(
            "no feasible mapping for shape {:?} on {} (local buffer {} B)",
            shape, dev.name, dev.core.local_buffer_bytes
        )
    });
    Best { outcome, mapping, rounds: simulated.load(Ordering::Relaxed), candidates }
}

// ---------------------------------------------------------------------------
// Memoizing front-end + persistent cache
// ---------------------------------------------------------------------------

/// Memoization key: device fingerprint + shape. Distinct hardware
/// descriptions never alias even under one name (the fingerprint hashes
/// every parameter).
type CacheKey = (u64, u64, u64, u64, u64, DType, bool);

/// Version of the on-disk mapping-cache schema ([`Mapper::with_cache`]).
/// Bump on any change to the entry layout; files with another version are
/// rejected on load and replaced on the next persist.
pub const CACHE_VERSION: u64 = 1;

/// One memoized search result plus the device name it was computed for
/// (the name is informational — the key's fingerprint is authoritative).
#[derive(Debug, Clone)]
struct CacheEntry {
    device: String,
    best: Best,
    /// Logical-clock stamp of the entry's last hit or insert, used for
    /// LRU eviction under a [`Mapper::with_cache_capacity`] cap. The
    /// field is additive: pre-cap cache files parse it as 0, so their
    /// entries are evicted first once a cap applies.
    last_used: u64,
}

/// Persistent-cache state: where to save, entries for *other* budgets
/// carried through untouched, and whether anything new needs writing.
struct DiskCache {
    path: PathBuf,
    /// Raw entries from the loaded file whose budget did not match this
    /// mapper's — preserved verbatim by [`Mapper::persist`] so differently
    /// budgeted runs sharing one cache file do not clobber each other.
    foreign: Vec<Json>,
    dirty: AtomicBool,
    loaded: u64,
}

pub struct Mapper {
    budget: SearchBudget,
    lut: SystolicLut,
    cache: Mutex<HashMap<CacheKey, CacheEntry>>,
    /// Keys whose search is currently running on some thread. Concurrent
    /// callers of the same key wait on [`Mapper::search_done`] instead of
    /// duplicating the (expensive) search — this is what keeps the
    /// cross-scenario search count minimal even when `eval` suites fan
    /// out across threads.
    in_flight: Mutex<HashSet<CacheKey>>,
    search_done: Condvar,
    total_rounds: AtomicU64,
    searches: AtomicU64,
    /// Candidates enumerated across all searches (simulated + pruned);
    /// `total_candidates − total_rounds` is the pruning win.
    total_candidates: AtomicU64,
    /// In-memory memoization hits on [`Mapper::matmul`]'s fast path.
    cache_hits: AtomicU64,
    /// Telemetry handle: each cache-missing search emits a host-clock
    /// span plus counter samples. Disabled recorder ⇒ no-op.
    recorder: Arc<Recorder>,
    disk: Option<DiskCache>,
    /// Optional bound on how many of this mapper's *own* entries
    /// [`Mapper::persist`] writes; the least-recently-used entries beyond
    /// the cap are evicted from the file (foreign-budget entries are
    /// never evicted). `None` ⇒ unbounded.
    cache_cap: Option<usize>,
    /// Logical clock for the LRU stamps: bumped on every cache hit and
    /// insert, seeded past the largest stamp loaded from disk so fresh
    /// activity always outranks prior runs.
    clock: AtomicU64,
}

impl Default for Mapper {
    fn default() -> Self {
        Self::new(SearchBudget::default())
    }
}

/// Clears a key's in-flight marker and wakes waiters when dropped — even
/// when `search` panics mid-flight, so no waiter is ever stranded on the
/// condvar (the waiters then re-check the cache, find it cold, and run the
/// search themselves).
struct InFlightGuard<'a> {
    mapper: &'a Mapper,
    key: CacheKey,
}

impl Drop for InFlightGuard<'_> {
    fn drop(&mut self) {
        // Recover the guard even if a panicking thread poisoned the lock;
        // the set's state is a plain membership update, always valid.
        let mut in_flight =
            self.mapper.in_flight.lock().unwrap_or_else(|e| e.into_inner());
        in_flight.remove(&self.key);
        drop(in_flight);
        self.mapper.search_done.notify_all();
    }
}

impl Mapper {
    pub fn new(budget: SearchBudget) -> Self {
        Mapper {
            budget,
            lut: SystolicLut::new(),
            cache: Mutex::new(HashMap::new()),
            in_flight: Mutex::new(HashSet::new()),
            search_done: Condvar::new(),
            total_rounds: AtomicU64::new(0),
            searches: AtomicU64::new(0),
            total_candidates: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            recorder: Arc::new(Recorder::disabled()),
            disk: None,
            cache_cap: None,
            clock: AtomicU64::new(0),
        }
    }

    /// Attach a telemetry recorder; subsequent searches emit host-clock
    /// spans and self-profiling counters into it.
    pub fn set_recorder(&mut self, rec: Arc<Recorder>) {
        self.recorder = rec;
    }

    /// A mapper whose candidate loop fans across all cores as a fixed
    /// pool. Memoization is unchanged — the cache `Mutex` is only held
    /// around lookups/inserts, never across a search; concurrent callers
    /// of the same shape coalesce onto one search via the in-flight set.
    pub fn pooled() -> Self {
        Mapper::new(SearchBudget::pooled())
    }

    /// A mapper in work-stealing hybrid mode (see [`SearchBudget::hybrid`]).
    pub fn hybrid() -> Self {
        Mapper::new(SearchBudget::hybrid())
    }

    /// A mapper backed by a persistent on-disk cache at `path`. Entries
    /// whose `(device fingerprint, shape, budget)` match are pre-loaded
    /// into the in-memory cache, so repeated runs skip those searches
    /// entirely. A missing file is a cold start; a corrupt file or one
    /// with a different [`CACHE_VERSION`] is ignored with a warning (and
    /// replaced on the next [`Mapper::persist`]). New search results are
    /// saved by `persist` — called explicitly by the CLI, and best-effort
    /// on drop.
    pub fn with_cache(budget: SearchBudget, path: &Path) -> Self {
        let mut mapper = Mapper::new(budget);
        let mut foreign = Vec::new();
        let mut loaded = HashMap::new();
        match std::fs::read_to_string(path) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {} // no cache yet
            Err(e) => eprintln!(
                "warning: cannot read mapper cache {}: {e}; starting cold",
                path.display()
            ),
            Ok(text) => match Json::parse(&text) {
                Err(e) => eprintln!(
                    "warning: ignoring corrupt mapper cache {}: {e}",
                    path.display()
                ),
                Ok(doc) => {
                    if doc.get("version").and_then(Json::as_u64) != Some(CACHE_VERSION) {
                        eprintln!(
                            "warning: mapper cache {} is not version {CACHE_VERSION}; starting cold",
                            path.display()
                        );
                    } else if let Some(entries) = doc.get("entries").and_then(Json::as_arr) {
                        for entry in entries {
                            if !budget_matches(entry, &budget) {
                                foreign.push(entry.clone());
                                continue;
                            }
                            match parse_entry(entry) {
                                Some((key, cached)) => {
                                    loaded.insert(key, cached);
                                }
                                None => eprintln!(
                                    "warning: skipping malformed entry in mapper cache {}",
                                    path.display()
                                ),
                            }
                        }
                    }
                }
            },
        }
        let count = loaded.len() as u64;
        let clock = loaded.values().map(|e| e.last_used).max().unwrap_or(0);
        *mapper.cache.get_mut().unwrap() = loaded;
        mapper.clock = AtomicU64::new(clock);
        mapper.disk = Some(DiskCache {
            path: path.to_path_buf(),
            foreign,
            dirty: AtomicBool::new(false),
            loaded: count,
        });
        mapper
    }

    /// [`Mapper::with_cache`] plus an LRU bound: `persist` keeps only the
    /// `cap` most-recently-used of this mapper's own entries, so
    /// long-running suites (or week-long `tune` searches) sharing one
    /// cache file cannot grow it without bound. A cap of 0 is treated as
    /// 1. Entries saved by differently budgeted runs are never evicted.
    pub fn with_cache_capacity(budget: SearchBudget, path: &Path, cap: usize) -> Self {
        let mut mapper = Mapper::with_cache(budget, path);
        mapper.cache_cap = Some(cap.max(1));
        mapper
    }

    /// The LRU entry cap, when one was set via
    /// [`Mapper::with_cache_capacity`].
    pub fn cache_capacity(&self) -> Option<usize> {
        self.cache_cap
    }

    pub fn matmul(&self, dev: &DeviceSpec, shape: &Shape) -> Best {
        let key = (
            dev.fingerprint(),
            shape.b,
            shape.m,
            shape.k,
            shape.n,
            shape.dtype,
            shape.batched_b,
        );
        // Fast path / search coalescing. A miss claims the key in
        // `in_flight`; concurrent callers of the same key block on the
        // condvar and re-check the cache instead of duplicating the
        // search. Lock order is safe: the cache guard is always a
        // statement-scoped temporary, never held while acquiring
        // `in_flight`.
        // The in-flight mutex only guards a membership set (always valid
        // state), so recover from poisoning — a search that panicked on
        // one key must not take down every later call (or waiter) with a
        // PoisonError.
        let lock_in_flight =
            || self.in_flight.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(hit) = self.cache.lock().unwrap().get_mut(&key) {
                hit.last_used = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
                self.cache_hits.fetch_add(1, Ordering::Relaxed);
                // Recency only needs re-persisting when eviction can act
                // on it; an uncapped warm run stays clean (and writes
                // nothing on persist), as before.
                if self.cache_cap.is_some() {
                    if let Some(disk) = &self.disk {
                        disk.dirty.store(true, Ordering::Relaxed);
                    }
                }
                return hit.best.clone();
            }
            let mut in_flight = lock_in_flight();
            // Re-check: the searcher publishes to the cache before
            // clearing its marker, so miss + no marker ⇒ nobody is on it.
            if self.cache.lock().unwrap().contains_key(&key) {
                continue;
            }
            if in_flight.insert(key) {
                break; // this thread owns the search
            }
            // Someone else is searching this key; wait and re-check.
            // While asleep this thread is not a live worker, so donate
            // its core to the shared budget — the searching thread's
            // hybrid candidate loop picks it up instead of running
            // serial while N−1 coalescing callers sleep.
            crate::util::pool::donate_token();
            let woken = self.search_done.wait(in_flight).unwrap_or_else(|e| e.into_inner());
            crate::util::pool::withdraw_token();
            drop(woken);
        }
        // From here the marker is cleared (and waiters woken) even if
        // `search` panics — the guard publishes-then-notifies on drop.
        let _guard = InFlightGuard { mapper: self, key };
        let t0 = self.recorder.host_now_s();
        let best = search(dev, shape, self.budget, &self.lut);
        self.total_rounds.fetch_add(best.rounds, Ordering::Relaxed);
        self.searches.fetch_add(1, Ordering::Relaxed);
        self.total_candidates.fetch_add(best.candidates, Ordering::Relaxed);
        if self.recorder.is_enabled() {
            // Host-clock self-profiling: one span per actual search (the
            // quantity caching exists to minimize) plus running counters.
            self.recorder.span_host(
                "mapper search",
                &format!(
                    "{} b{} m{} k{} n{} {}",
                    dev.name, shape.b, shape.m, shape.k, shape.n, shape.dtype.name()
                ),
                t0,
                &[
                    ("rounds", num(best.rounds as f64)),
                    ("candidates", num(best.candidates as f64)),
                    ("pruned", num(best.candidates.saturating_sub(best.rounds) as f64)),
                ],
            );
            let (lut_hits, lut_misses) = self.lut.stats();
            self.recorder.counter_host("mapper searches", self.searches() as f64);
            self.recorder.counter_host("mapper rounds", self.total_rounds() as f64);
            self.recorder.counter_host("mapper cache hits", self.cache_hits() as f64);
            self.recorder.counter_host("lut hits", lut_hits as f64);
            self.recorder.counter_host("lut misses", lut_misses as f64);
        }
        let stamp = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
        self.cache.lock().unwrap().insert(
            key,
            CacheEntry { device: dev.name.clone(), best: best.clone(), last_used: stamp },
        );
        if let Some(disk) = &self.disk {
            disk.dirty.store(true, Ordering::Relaxed);
        }
        best
    }

    /// Write the cache to its backing file (no-op without one, or when
    /// nothing changed since the last save). Returns the path written.
    /// The file's *current* entries are merged in — only this mapper's own
    /// keys are overwritten — so concurrent processes sharing one cache
    /// path extend rather than clobber each other (the read-merge-rename
    /// window is best-effort, not transactional). Entries are written
    /// sorted by key, via a temp-file rename, so readers never observe a
    /// half-written cache.
    pub fn persist(&self) -> Result<Option<PathBuf>, String> {
        let Some(disk) = &self.disk else { return Ok(None) };
        // Claim the dirty flag *before* snapshotting: a search that lands
        // after the snapshot re-sets it, so the next persist picks the
        // entry up instead of being skipped as clean. Restored on failure.
        if !disk.dirty.swap(false, Ordering::Relaxed) {
            return Ok(None);
        }
        let restore_dirty = || disk.dirty.store(true, Ordering::Relaxed);
        // Snapshot under the lock, then do every file read/parse/serialize
        // lock-free, so concurrent `matmul` cache hits never stall on disk.
        let mut items: Vec<(CacheKey, CacheEntry)> = {
            let cache = self.cache.lock().unwrap();
            cache.iter().map(|(k, e)| (*k, e.clone())).collect()
        };
        items.sort_by_key(|(k, _)| (k.0, k.1, k.2, k.3, k.4, k.5.name(), k.6));
        // `own` covers *every* key this mapper holds — including entries
        // the LRU cap evicts below — so evicted keys are dropped from the
        // file rather than resurrected as foreign entries.
        let own: HashSet<CacheKey> = items.iter().map(|(k, _)| *k).collect();
        if let Some(cap) = self.cache_cap {
            if items.len() > cap {
                // Keep the `cap` most recently used; the stable sort over
                // the key-ordered vector makes ties deterministic.
                items.sort_by_key(|(_, e)| std::cmp::Reverse(e.last_used));
                items.truncate(cap);
                items.sort_by_key(|(k, _)| (k.0, k.1, k.2, k.3, k.4, k.5.name(), k.6));
            }
        }
        // Keep every entry on disk we don't own — other budgets, and
        // shapes another process saved since we loaded. A missing file is
        // a first save; any *other* read error refuses to overwrite
        // rather than clobbering an accumulated cache it cannot see.
        let on_disk = match std::fs::read_to_string(&disk.path) {
            Ok(text) => Some(text),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => None,
            Err(e) => {
                restore_dirty();
                return Err(format!(
                    "read {}: {e} (refusing to overwrite the existing cache)",
                    disk.path.display()
                ));
            }
        };
        // Corrupt or other-version content falls back to the load-time
        // foreign snapshot — replacement is the documented behavior there.
        let parsed = on_disk
            .and_then(|text| Json::parse(&text).ok())
            .filter(|doc| doc.get("version").and_then(Json::as_u64) == Some(CACHE_VERSION));
        let mut entries: Vec<Json> = match parsed.as_ref().and_then(|doc| doc.get("entries")) {
            Some(Json::Arr(es)) => es
                .iter()
                .filter(|entry| {
                    !(budget_matches(entry, &self.budget)
                        && parse_entry(entry).map_or(false, |(key, _)| own.contains(&key)))
                })
                .cloned()
                .collect(),
            _ => disk.foreign.clone(),
        };
        entries.extend(items.iter().map(|(k, e)| entry_to_json(k, e, &self.budget)));
        let doc = obj(vec![
            ("version", num(CACHE_VERSION as f64)),
            ("entries", Json::Arr(entries)),
        ]);
        if let Some(parent) = disk.path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(|e| {
                    restore_dirty();
                    format!("create {}: {e}", parent.display())
                })?;
            }
        }
        let tmp = disk.path.with_extension("json.tmp");
        std::fs::write(&tmp, doc.to_string_pretty()).map_err(|e| {
            restore_dirty();
            format!("write {}: {e}", tmp.display())
        })?;
        std::fs::rename(&tmp, &disk.path).map_err(|e| {
            restore_dirty();
            format!("rename to {}: {e}", disk.path.display())
        })?;
        Ok(Some(disk.path.clone()))
    }

    /// The backing cache file, when this mapper has one.
    pub fn cache_path(&self) -> Option<&Path> {
        self.disk.as_ref().map(|d| d.path.as_path())
    }

    /// How many mappings were pre-loaded from the persistent cache.
    pub fn loaded_from_disk(&self) -> u64 {
        self.disk.as_ref().map(|d| d.loaded).unwrap_or(0)
    }

    /// Number of full mapper parameter searches performed (cache misses) —
    /// the quantity cross-scenario and persistent caching exist to
    /// minimize. Mappings served from the persistent cache count zero.
    pub fn searches(&self) -> u64 {
        self.searches.load(Ordering::Relaxed)
    }

    /// Total mapper rounds across all (non-cached) searches — the paper's
    /// "26,400 rounds of the mapper's parameter search" statistic.
    pub fn total_rounds(&self) -> u64 {
        self.total_rounds.load(Ordering::Relaxed)
    }

    /// Candidates enumerated across all searches, whether simulated or
    /// pruned by the lower bound.
    pub fn total_candidates(&self) -> u64 {
        self.total_candidates.load(Ordering::Relaxed)
    }

    /// Candidates skipped by lower-bound pruning: enumerated minus
    /// simulated (`total_candidates − total_rounds`).
    pub fn pruned_candidates(&self) -> u64 {
        self.total_candidates().saturating_sub(self.total_rounds())
    }

    /// In-memory memoization hits on the [`Mapper::matmul`] fast path.
    pub fn cache_hits(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    /// The systolic-array timing LUT's (hits, misses) counters.
    pub fn lut_stats(&self) -> (u64, u64) {
        self.lut.stats()
    }

    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

impl Drop for Mapper {
    fn drop(&mut self) {
        // Best-effort: CLI paths persist explicitly (and report errors);
        // this catches everything else that used `with_cache`.
        let _ = self.persist();
    }
}

fn entry_to_json(key: &CacheKey, entry: &CacheEntry, budget: &SearchBudget) -> Json {
    let (fp, b, m, k, n, dtype, batched_b) = *key;
    let map = &entry.best.mapping;
    obj(vec![
        ("device", s(&entry.device)),
        ("fingerprint", s(&format!("{fp:016x}"))),
        ("b", num(b as f64)),
        ("m", num(m as f64)),
        ("k", num(k as f64)),
        ("n", num(n as f64)),
        ("dtype", s(dtype.name())),
        ("batched_b", Json::Bool(batched_b)),
        ("last_used", num(entry.last_used as f64)),
        (
            "budget",
            obj(vec![
                ("gt_per_dim", num(budget.gt_per_dim as f64)),
                ("lt_per_dim", num(budget.lt_per_dim as f64)),
            ]),
        ),
        ("seconds", num(entry.best.outcome.seconds)),
        ("dram_bytes", num(entry.best.outcome.dram_bytes)),
        ("systolic_util", num(entry.best.outcome.systolic_util)),
        ("rounds", num(entry.best.rounds as f64)),
        ("candidates", num(entry.best.candidates as f64)),
        (
            "mapping",
            obj(vec![
                ("gt_m", num(map.gt.0 as f64)),
                ("gt_k", num(map.gt.1 as f64)),
                ("gt_n", num(map.gt.2 as f64)),
                ("lt_m", num(map.lt.0 as f64)),
                ("lt_k", num(map.lt.1 as f64)),
                ("lt_n", num(map.lt.2 as f64)),
                ("scheme", s(map.scheme.name())),
                ("db_global", Json::Bool(map.db_global)),
                ("db_local", Json::Bool(map.db_local)),
            ]),
        ),
    ])
}

/// Does a cache entry's recorded budget match this mapper's? Only the
/// knobs that change the candidate set matter — pruning and the thread
/// counts provably do not change the winner, so their cached results are
/// interchangeable.
fn budget_matches(entry: &Json, budget: &SearchBudget) -> bool {
    let Some(b) = entry.get("budget") else { return false };
    b.get("gt_per_dim").and_then(Json::as_u64) == Some(budget.gt_per_dim as u64)
        && b.get("lt_per_dim").and_then(Json::as_u64) == Some(budget.lt_per_dim as u64)
}

fn parse_entry(entry: &Json) -> Option<(CacheKey, CacheEntry)> {
    let fp = u64::from_str_radix(entry.get("fingerprint")?.as_str()?, 16).ok()?;
    let key = (
        fp,
        entry.get("b")?.as_u64()?,
        entry.get("m")?.as_u64()?,
        entry.get("k")?.as_u64()?,
        entry.get("n")?.as_u64()?,
        DType::parse(entry.get("dtype")?.as_str()?)?,
        entry.get("batched_b")?.as_bool()?,
    );
    let map = entry.get("mapping")?;
    let mapping = Mapping {
        gt: (
            map.get("gt_m")?.as_u64()?,
            map.get("gt_k")?.as_u64()?,
            map.get("gt_n")?.as_u64()?,
        ),
        lt: (
            map.get("lt_m")?.as_u64()?,
            map.get("lt_k")?.as_u64()?,
            map.get("lt_n")?.as_u64()?,
        ),
        scheme: Scheme::parse(map.get("scheme")?.as_str()?)?,
        db_global: map.get("db_global")?.as_bool()?,
        db_local: map.get("db_local")?.as_bool()?,
    };
    let best = Best {
        outcome: SimOutcome {
            seconds: entry.get("seconds")?.as_f64()?,
            dram_bytes: entry.get("dram_bytes")?.as_f64()?,
            systolic_util: entry.get("systolic_util")?.as_f64()?,
        },
        mapping,
        rounds: entry.get("rounds")?.as_u64()?,
        candidates: entry.get("candidates")?.as_u64()?,
    };
    let device = entry.get("device")?.as_str()?.to_string();
    // Additive field: caches written before the LRU cap existed have no
    // stamp; 0 ranks them oldest, which is the right eviction order.
    let last_used = entry.get("last_used").and_then(Json::as_u64).unwrap_or(0);
    Some((key, CacheEntry { device, best, last_used }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::presets::{a100, design};

    #[test]
    fn candidates_sane() {
        let c = candidates(2048, 8192, 16, 4);
        assert!(c.contains(&2048));
        assert!(c.iter().all(|&v| v <= 2048 && v >= 1));
        assert!(c.len() <= 4);
        // Small extents still produce something.
        let c = candidates(5, 8192, 16, 4);
        assert_eq!(c[0], 5);
    }

    #[test]
    fn search_finds_reasonable_mapping_for_big_gemm() {
        let dev = a100();
        let shape = Shape::simple(2048, 12288, 12288, DType::FP16);
        let best = search(&dev, &shape, SearchBudget::default(), &SystolicLut::new());
        assert!(best.candidates > 10, "enumerated {} candidates", best.candidates);
        assert!(best.rounds >= 1 && best.rounds <= best.candidates);
        // Prefill-class GEMM on A100 should land within 3x of the
        // compute roofline (paper measures ~50% of roofline on A100).
        let roofline = shape.flops() / dev.peak_matrix_flops();
        let ratio = best.outcome.seconds / roofline;
        assert!(ratio < 3.0, "achieved {ratio:.2}x of compute roofline");
        assert!(best.outcome.systolic_util > 0.3, "util {}", best.outcome.systolic_util);
    }

    #[test]
    fn pruned_and_hybrid_match_exhaustive_on_design_grid() {
        // The engine's core acceptance criterion: every budget mode must
        // return the identical winner on every Table III design and the
        // A100, across prefill-, decode-, and degenerate-class shapes.
        let shapes = [
            Shape::simple(2048, 12288, 12288, DType::FP16), // prefill GEMM
            Shape::simple(8, 12288, 1024, DType::FP16),     // decode GEMM
            Shape::simple(128, 12288, 128, DType::FP16),    // k-heavy (scheme 2 relevant)
            Shape::simple(5, 300, 7, DType::FP32),          // degenerate/ragged
        ];
        let mut devices = vec![a100()];
        for l in ['A', 'B', 'C', 'D', 'E'] {
            devices.push(design(l).unwrap());
        }
        for (di, dev) in devices.iter().enumerate() {
            let lut = SystolicLut::new();
            // The prefill GEMM has the largest candidate set; exercising
            // its exhaustive sweep on the A100 alone keeps the grid fast
            // in debug builds without losing device coverage elsewhere.
            let shapes = if di == 0 { &shapes[..] } else { &shapes[1..] };
            for shape in shapes {
                let exhaustive = search(dev, shape, SearchBudget::exhaustive(), &lut);
                for (mode, budget) in [
                    ("pruned", SearchBudget::default()),
                    ("pruned+pool", SearchBudget { threads: 4, ..SearchBudget::default() }),
                    ("pruned+hybrid", SearchBudget::hybrid()),
                ] {
                    let got = search(dev, shape, budget, &lut);
                    assert_eq!(
                        got.mapping, exhaustive.mapping,
                        "{mode} winner drifted on {} {shape:?}",
                        dev.name
                    );
                    assert_eq!(
                        got.outcome.seconds.to_bits(),
                        exhaustive.outcome.seconds.to_bits(),
                        "{mode} seconds drifted on {} {shape:?}",
                        dev.name
                    );
                    assert_eq!(
                        got.outcome.systolic_util.to_bits(),
                        exhaustive.outcome.systolic_util.to_bits(),
                        "{mode} util drifted on {} {shape:?}",
                        dev.name
                    );
                    assert_eq!(got.candidates, exhaustive.candidates);
                    assert!(got.rounds <= exhaustive.rounds);
                }
            }
        }
    }

    #[test]
    fn pruning_halves_rounds_on_prefill_gemm() {
        // The acceptance bar: ≥ 2x fewer simulated rounds on the
        // prefill-class GEMM (in practice far more survive the axe).
        let dev = a100();
        let shape = Shape::simple(2048, 12288, 12288, DType::FP16);
        let lut = SystolicLut::new();
        let exhaustive = search(&dev, &shape, SearchBudget::exhaustive(), &lut);
        let pruned = search(&dev, &shape, SearchBudget::default(), &lut);
        assert_eq!(exhaustive.rounds, exhaustive.candidates);
        assert!(
            pruned.rounds * 2 <= exhaustive.rounds,
            "pruning only got {} of {} rounds",
            pruned.rounds,
            exhaustive.rounds
        );
    }

    #[test]
    fn mapper_caches_by_shape() {
        let mapper = Mapper::default();
        let dev = a100();
        let shape = Shape::simple(256, 512, 256, DType::FP16);
        let a = mapper.matmul(&dev, &shape);
        let rounds_after_first = mapper.total_rounds();
        let b = mapper.matmul(&dev, &shape);
        assert_eq!(mapper.total_rounds(), rounds_after_first, "second hit was cached");
        assert_eq!(a.outcome.seconds, b.outcome.seconds);
        assert_eq!(mapper.cache_len(), 1);
        assert_eq!(mapper.searches(), 1, "one unique shape → one search");
    }

    #[test]
    fn concurrent_matmul_coalesces_to_one_search() {
        // Eight threads racing on a cold cache for the same shape must
        // produce one search, identical results, and one cache entry.
        let mapper = Mapper::default();
        let dev = a100();
        let shape = Shape::simple(256, 512, 256, DType::FP16);
        let items: Vec<u32> = (0..8).collect();
        let outs = crate::util::pool::parallel_map(&items, 8, |_| {
            mapper.matmul(&dev, &shape).outcome.seconds
        });
        assert!(outs.windows(2).all(|w| w[0] == w[1]));
        assert_eq!(mapper.searches(), 1, "racing callers must coalesce");
        assert_eq!(mapper.cache_len(), 1);
    }

    #[test]
    fn panicking_search_does_not_strand_waiters() {
        // A device nothing fits (1-byte local buffer) makes `search`
        // panic; the in-flight drop-guard must still clear the marker so
        // later callers retry instead of hanging on the condvar.
        let mapper = Mapper::default();
        let mut dev = a100();
        dev.core.local_buffer_bytes = 1;
        let shape = Shape::simple(64, 64, 64, DType::FP16);
        for _ in 0..2 {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                mapper.matmul(&dev, &shape)
            }));
            assert!(r.is_err(), "infeasible device should panic the search");
            assert_eq!(
                mapper.in_flight.lock().unwrap_or_else(|e| e.into_inner()).len(),
                0,
                "in-flight marker leaked"
            );
        }
        assert_eq!(mapper.cache_len(), 0);
        // And the mapper survives: the poisoned-in-unwind in-flight mutex
        // must not take later calls down — a feasible search still works.
        let ok = mapper.matmul(&a100(), &Shape::simple(64, 64, 64, DType::FP16));
        assert!(ok.outcome.seconds > 0.0);
        assert_eq!(mapper.cache_len(), 1);
    }

    #[test]
    fn tiny_decode_shape_feasible_everywhere() {
        // m=8 decode GEMMs must map onto every Table III design, including
        // E with its 128x128 arrays.
        for l in ['A', 'B', 'C', 'D', 'E'] {
            let dev = design(l).unwrap();
            let shape = Shape::simple(8, 12288, 1024, DType::FP16);
            let best = search(&dev, &shape, SearchBudget::default(), &SystolicLut::new());
            assert!(best.outcome.seconds > 0.0, "design {l}");
        }
    }

    #[test]
    fn more_bandwidth_never_slower() {
        // Monotonicity: doubling memory bandwidth cannot slow the best
        // mapping down (same candidate set, each candidate monotone).
        let mut dev = a100();
        let shape = Shape::simple(8, 12288, 12288, DType::FP16);
        let lut = SystolicLut::new();
        let slow = search(&dev, &shape, SearchBudget::default(), &lut).outcome.seconds;
        dev.memory.bandwidth_bytes_per_s *= 2.0;
        let fast = search(&dev, &shape, SearchBudget::default(), &lut).outcome.seconds;
        assert!(fast <= slow * 1.0001, "2x BW: {fast} vs {slow}");
    }

    // --- persistent cache ---------------------------------------------------

    fn temp_cache(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("llmcompass-mapper-{tag}-{}.json", std::process::id()))
    }

    #[test]
    fn persistent_cache_round_trips_across_mappers() {
        let path = temp_cache("roundtrip");
        let _ = std::fs::remove_file(&path);
        let dev = a100();
        let shapes =
            [Shape::simple(256, 512, 256, DType::FP16), Shape::simple(8, 512, 128, DType::FP16)];
        let first = {
            let mapper = Mapper::with_cache(SearchBudget::default(), &path);
            assert_eq!(mapper.loaded_from_disk(), 0);
            let out: Vec<Best> = shapes.iter().map(|sh| mapper.matmul(&dev, sh)).collect();
            assert_eq!(mapper.searches(), 2);
            let written = mapper.persist().unwrap();
            assert_eq!(written.as_deref(), Some(path.as_path()));
            // Not dirty anymore: a second persist is a no-op.
            assert!(mapper.persist().unwrap().is_none());
            out
        };
        let mapper = Mapper::with_cache(SearchBudget::default(), &path);
        assert_eq!(mapper.loaded_from_disk(), 2);
        for (sh, want) in shapes.iter().zip(&first) {
            let got = mapper.matmul(&dev, sh);
            assert_eq!(got.mapping, want.mapping);
            assert_eq!(got.outcome.seconds.to_bits(), want.outcome.seconds.to_bits());
        }
        assert_eq!(mapper.searches(), 0, "warm persistent cache must skip every search");
        // A different budget must NOT reuse these entries (different
        // candidate set) — and must carry them through its own persist.
        let other =
            Mapper::with_cache(SearchBudget { gt_per_dim: 2, ..SearchBudget::default() }, &path);
        assert_eq!(other.loaded_from_disk(), 0);
        other.matmul(&dev, &shapes[0]);
        assert_eq!(other.searches(), 1);
        other.persist().unwrap();
        let merged = Mapper::with_cache(SearchBudget::default(), &path);
        assert_eq!(merged.loaded_from_disk(), 2, "foreign-budget entries were clobbered");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn persistent_cache_rejects_other_versions() {
        let path = temp_cache("version");
        std::fs::write(&path, format!("{{\"version\": {}, \"entries\": []}}", CACHE_VERSION + 1))
            .unwrap();
        let dev = a100();
        let shape = Shape::simple(256, 512, 256, DType::FP16);
        {
            let mapper = Mapper::with_cache(SearchBudget::default(), &path);
            assert_eq!(mapper.loaded_from_disk(), 0, "other-version cache must be rejected");
            mapper.matmul(&dev, &shape);
            assert_eq!(mapper.searches(), 1);
            // Dropping persists (best-effort), replacing the stale file.
        }
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("version").and_then(Json::as_u64), Some(CACHE_VERSION));
        let reloaded = Mapper::with_cache(SearchBudget::default(), &path);
        assert_eq!(reloaded.loaded_from_disk(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn persistent_cache_tolerates_corrupt_files() {
        let path = temp_cache("corrupt");
        std::fs::write(&path, "{ this is not json").unwrap();
        let dev = a100();
        let shape = Shape::simple(256, 512, 256, DType::FP16);
        let mapper = Mapper::with_cache(SearchBudget::default(), &path);
        assert_eq!(mapper.loaded_from_disk(), 0);
        let best = mapper.matmul(&dev, &shape);
        assert!(best.outcome.seconds > 0.0);
        assert_eq!(mapper.searches(), 1);
        mapper.persist().unwrap();
        // The corrupt file was replaced with a valid one.
        let reloaded = Mapper::with_cache(SearchBudget::default(), &path);
        assert_eq!(reloaded.loaded_from_disk(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cache_entry_json_round_trips() {
        let dev = a100();
        let shape = Shape::simple(256, 512, 256, DType::FP16);
        let best = search(&dev, &shape, SearchBudget::default(), &SystolicLut::new());
        let key: CacheKey = (
            dev.fingerprint(),
            shape.b,
            shape.m,
            shape.k,
            shape.n,
            shape.dtype,
            shape.batched_b,
        );
        let entry = CacheEntry { device: dev.name.clone(), best, last_used: 7 };
        let j = entry_to_json(&key, &entry, &SearchBudget::default());
        assert!(budget_matches(&j, &SearchBudget::default()));
        assert!(!budget_matches(&j, &SearchBudget { gt_per_dim: 9, ..Default::default() }));
        let (k2, e2) = parse_entry(&j).unwrap();
        assert_eq!(k2, key);
        assert_eq!(e2.device, entry.device);
        assert_eq!(e2.last_used, 7);
        // A stampless (pre-LRU) entry still parses, ranked oldest.
        let mut stripped = j.clone();
        if let Json::Obj(map) = &mut stripped {
            map.remove("last_used");
        }
        assert_eq!(parse_entry(&stripped).unwrap().1.last_used, 0);
        assert_eq!(e2.best.mapping, entry.best.mapping);
        assert_eq!(e2.best.outcome.seconds.to_bits(), entry.best.outcome.seconds.to_bits());
        assert_eq!(e2.best.rounds, entry.best.rounds);
        assert_eq!(e2.best.candidates, entry.best.candidates);
        // And survives an actual text round trip (f64 precision included).
        let text = j.to_string_pretty();
        let (k3, e3) = parse_entry(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(k3, key);
        assert_eq!(e3.best.outcome.seconds.to_bits(), entry.best.outcome.seconds.to_bits());
    }

    #[test]
    fn lru_cap_evicts_least_recently_used_on_persist() {
        let path = temp_cache("lru");
        let _ = std::fs::remove_file(&path);
        let dev = a100();
        let shapes = [
            Shape::simple(64, 128, 64, DType::FP16),
            Shape::simple(128, 128, 64, DType::FP16),
            Shape::simple(256, 128, 64, DType::FP16),
        ];
        {
            let mapper = Mapper::with_cache_capacity(SearchBudget::default(), &path, 2);
            assert_eq!(mapper.cache_capacity(), Some(2));
            for sh in &shapes {
                mapper.matmul(&dev, sh);
            }
            // Re-touch the first shape: it becomes the most recently
            // used, leaving shapes[1] as the LRU victim.
            mapper.matmul(&dev, &shapes[0]);
            mapper.persist().unwrap();
        }
        let reload = Mapper::with_cache(SearchBudget::default(), &path);
        assert_eq!(reload.loaded_from_disk(), 2, "cap must bound the persisted cache");
        reload.matmul(&dev, &shapes[0]);
        reload.matmul(&dev, &shapes[2]);
        assert_eq!(reload.searches(), 0, "survivors must be served from disk");
        reload.matmul(&dev, &shapes[1]);
        assert_eq!(reload.searches(), 1, "the LRU entry must have been evicted");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn lru_cap_never_evicts_foreign_budget_entries() {
        let path = temp_cache("lru-foreign");
        let _ = std::fs::remove_file(&path);
        let dev = a100();
        let other_budget = SearchBudget { gt_per_dim: 2, ..SearchBudget::default() };
        {
            let other = Mapper::with_cache(other_budget, &path);
            other.matmul(&dev, &Shape::simple(64, 128, 64, DType::FP16));
            other.persist().unwrap();
        }
        {
            let capped = Mapper::with_cache_capacity(SearchBudget::default(), &path, 1);
            capped.matmul(&dev, &Shape::simple(128, 128, 64, DType::FP16));
            capped.matmul(&dev, &Shape::simple(256, 128, 64, DType::FP16));
            capped.persist().unwrap();
        }
        let own = Mapper::with_cache(SearchBudget::default(), &path);
        assert_eq!(own.loaded_from_disk(), 1, "cap keeps exactly one own entry");
        let foreign = Mapper::with_cache(other_budget, &path);
        assert_eq!(foreign.loaded_from_disk(), 1, "foreign entries survived the cap");
        let _ = std::fs::remove_file(&path);
    }
}
