//! The serving coordinator: a batched inference loop over the PJRT
//! runtime.
//!
//! This is the Layer-3 "request path": requests enter a queue, the batcher
//! forms fixed-size batches (the AOT artifacts have static shapes), the
//! loop runs `prefill` once and `decode` per output token with the KV
//! cache held as opaque runtime state, and greedy sampling happens here in
//! Rust. Python is never invoked. The end-to-end example
//! (`examples/e2e_inference.rs`) drives this and reports latency and
//! throughput; integration tests check the token stream against the
//! Python reference generator.

pub mod queue;

use crate::runtime::{HostTensor, Runtime};
use anyhow::{anyhow, Result};
use std::time::Instant;

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub n_tokens: usize,
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Wall time from batch start to this request's last token.
    pub latency_s: f64,
    /// Time spent waiting in the queue before its batch started.
    pub wait_s: f64,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub completions: Vec<Completion>,
    pub total_s: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
    pub tokens_generated: u64,
}

impl ServeReport {
    pub fn tokens_per_s(&self) -> f64 {
        if self.total_s > 0.0 {
            self.tokens_generated as f64 / self.total_s
        } else {
            0.0
        }
    }

    pub fn latency_percentile(&self, p: f64) -> f64 {
        let lats: Vec<f64> = self.completions.iter().map(|c| c.latency_s).collect();
        crate::util::stats::percentile(&lats, p)
    }
}

/// Greedy argmax over a (batch, vocab) logits tensor; returns one token
/// per row.
pub fn argmax_tokens(logits: &HostTensor) -> Result<Vec<i32>> {
    let data = logits.f32().ok_or_else(|| anyhow!("logits not f32"))?;
    let shape = logits.shape();
    if shape.len() != 2 {
        return Err(anyhow!("logits shape {shape:?} is not 2-D"));
    }
    let (b, v) = (shape[0], shape[1]);
    let mut out = Vec::with_capacity(b);
    for row in 0..b {
        let slice = &data[row * v..(row + 1) * v];
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &x) in slice.iter().enumerate() {
            if x > best_v {
                best_v = x;
                best = i;
            }
        }
        out.push(best as i32);
    }
    Ok(out)
}

/// Pad or truncate a prompt to exactly `len` tokens (static artifact
/// shapes). Shorter prompts are left-padded by cycling the prompt, so the
/// semantically meaningful tokens stay at the end (nearest to generation).
pub fn fit_prompt(prompt: &[i32], len: usize) -> Vec<i32> {
    assert!(len > 0);
    if prompt.is_empty() {
        return vec![0; len];
    }
    if prompt.len() >= len {
        return prompt[prompt.len() - len..].to_vec();
    }
    let mut out = Vec::with_capacity(len);
    let pad = len - prompt.len();
    for i in 0..pad {
        out.push(prompt[i % prompt.len()]);
    }
    out.extend_from_slice(prompt);
    out
}

/// The coordinator: owns the runtime, the compiled model artifacts, and
/// the (one-time-initialized) parameter vector.
pub struct Coordinator {
    rt: Runtime,
    params: HostTensor,
    pub batch: usize,
    pub prefill_seq: usize,
    pub max_seq: usize,
    prefill_name: String,
    decode_name: String,
}

impl Coordinator {
    /// Build a coordinator over an artifact directory: loads the manifest,
    /// runs `init` once to materialize weights, and locates the
    /// prefill/decode artifacts.
    pub fn new(artifact_dir: &std::path::Path) -> Result<Coordinator> {
        let mut rt = Runtime::new(artifact_dir)?;
        let prefill_name = rt
            .manifest()
            .artifacts
            .iter()
            .find(|a| a.name.starts_with("prefill_"))
            .ok_or_else(|| anyhow!("no prefill artifact"))?
            .name
            .clone();
        let decode_name = rt
            .manifest()
            .artifacts
            .iter()
            .find(|a| a.name.starts_with("decode_"))
            .ok_or_else(|| anyhow!("no decode artifact"))?
            .name
            .clone();
        // prefill args: (params, tokens[b, s]).
        let meta = rt.manifest().find(&prefill_name).unwrap();
        let (batch, prefill_seq) = (meta.args[1].shape[0], meta.args[1].shape[1]);
        let max_seq = rt.manifest().model.max_seq as usize;
        let params = rt
            .run("init", &[])?
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("init returned nothing"))?;
        Ok(Coordinator { rt, params, batch, prefill_seq, max_seq, prefill_name, decode_name })
    }

    pub fn vocab(&self) -> usize {
        self.rt.manifest().model.vocab as usize
    }

    /// Serve a closed set of requests with fixed-size batching. Returns a
    /// report with per-request latencies and aggregate throughput.
    pub fn serve(&mut self, requests: &[Request]) -> Result<ServeReport> {
        let t0 = Instant::now();
        let mut report = ServeReport::default();
        for chunk in requests.chunks(self.batch) {
            let wait_s = t0.elapsed().as_secs_f64();
            let bstart = Instant::now();

            // Assemble the (b, s) prompt block, padding the ragged tail
            // batch by repeating the last request.
            let mut tokens: Vec<i32> = Vec::with_capacity(self.batch * self.prefill_seq);
            for i in 0..self.batch {
                let req = &chunk[i.min(chunk.len() - 1)];
                tokens.extend(fit_prompt(&req.prompt, self.prefill_seq));
            }
            let token_t = HostTensor::I32(tokens, vec![self.batch, self.prefill_seq]);

            // Prefill.
            let pstart = Instant::now();
            let mut out = self.rt.run(&self.prefill_name, &[self.params.clone(), token_t])?;
            report.prefill_s += pstart.elapsed().as_secs_f64();
            let (logits, kv_k, kv_v) = take3(&mut out)?;
            let mut kv_k = kv_k;
            let mut kv_v = kv_v;
            let mut next = argmax_tokens(&logits)?;

            // Decode loop.
            let n_steps = chunk.iter().map(|r| r.n_tokens).max().unwrap_or(0);
            let budget = self.max_seq - self.prefill_seq;
            let n_steps = n_steps.min(budget);
            let mut generated: Vec<Vec<i32>> = vec![Vec::new(); chunk.len()];
            let mut done_at: Vec<Option<f64>> = vec![None; chunk.len()];
            let mut pos = self.prefill_seq;
            for step in 0..n_steps {
                for (i, g) in generated.iter_mut().enumerate() {
                    if g.len() < chunk[i].n_tokens.min(budget) {
                        g.push(next[i.min(self.batch - 1)]);
                        if g.len() == chunk[i].n_tokens.min(budget) {
                            done_at[i] = Some(bstart.elapsed().as_secs_f64());
                        }
                    }
                }
                if step + 1 == n_steps {
                    break;
                }
                let dstart = Instant::now();
                let tok_t = HostTensor::I32(next.clone(), vec![self.batch]);
                let mut out = self.rt.run(
                    &self.decode_name,
                    &[
                        self.params.clone(),
                        tok_t,
                        kv_k,
                        kv_v,
                        HostTensor::scalar_i32(pos as i32),
                    ],
                )?;
                report.decode_s += dstart.elapsed().as_secs_f64();
                let (logits, k2, v2) = take3(&mut out)?;
                kv_k = k2;
                kv_v = v2;
                next = argmax_tokens(&logits)?;
                pos += 1;
            }

            for (i, req) in chunk.iter().enumerate() {
                report.tokens_generated += generated[i].len() as u64;
                report.completions.push(Completion {
                    id: req.id,
                    tokens: std::mem::take(&mut generated[i]),
                    latency_s: done_at[i].unwrap_or_else(|| bstart.elapsed().as_secs_f64()),
                    wait_s,
                });
            }
        }
        report.total_s = t0.elapsed().as_secs_f64();
        Ok(report)
    }
}

fn take3(out: &mut Vec<HostTensor>) -> Result<(HostTensor, HostTensor, HostTensor)> {
    if out.len() != 3 {
        return Err(anyhow!("expected 3 outputs, got {}", out.len()));
    }
    let v = std::mem::take(out);
    let mut it = v.into_iter();
    Ok((it.next().unwrap(), it.next().unwrap(), it.next().unwrap()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max_per_row() {
        let t = HostTensor::F32(vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0], vec![2, 3]);
        assert_eq!(argmax_tokens(&t).unwrap(), vec![1, 0]);
        let bad = HostTensor::F32(vec![0.0; 4], vec![4]);
        assert!(argmax_tokens(&bad).is_err());
    }

    #[test]
    fn fit_prompt_pads_and_truncates() {
        assert_eq!(fit_prompt(&[1, 2, 3], 5), vec![1, 2, 1, 2, 3]);
        assert_eq!(fit_prompt(&[1, 2, 3, 4, 5, 6], 4), vec![3, 4, 5, 6]);
        assert_eq!(fit_prompt(&[], 3), vec![0, 0, 0]);
        assert_eq!(fit_prompt(&[7], 1), vec![7]);
    }

    fn completion(latency_s: f64) -> Completion {
        Completion { id: 0, tokens: vec![], latency_s, wait_s: 0.0 }
    }

    #[test]
    fn latency_percentile_empty_report_is_zero() {
        let rep = ServeReport::default();
        assert_eq!(rep.latency_percentile(50.0), 0.0);
        assert_eq!(rep.latency_percentile(0.0), 0.0);
        assert_eq!(rep.latency_percentile(100.0), 0.0);
        assert_eq!(rep.tokens_per_s(), 0.0);
    }

    #[test]
    fn latency_percentile_single_completion() {
        let mut rep = ServeReport::default();
        rep.completions.push(completion(1.5));
        for p in [0.0, 50.0, 95.0, 100.0] {
            assert_eq!(rep.latency_percentile(p), 1.5);
        }
    }

    #[test]
    fn latency_percentile_interpolates_unsorted_completions() {
        let mut rep = ServeReport::default();
        for l in [4.0, 1.0, 3.0, 2.0] {
            rep.completions.push(completion(l));
        }
        assert_eq!(rep.latency_percentile(0.0), 1.0);
        assert_eq!(rep.latency_percentile(100.0), 4.0);
        assert!((rep.latency_percentile(50.0) - 2.5).abs() < 1e-12);
        assert!(rep.latency_percentile(95.0) <= 4.0);
    }
}
