//! Request queue + batcher: synthetic workload generation and batch
//! formation policy for the serving coordinator.
//!
//! The AOT artifacts have static shapes, so batching is fixed-size; the
//! policy decisions left are *ordering* (FIFO vs shortest-job-first) and
//! *padding waste* accounting, both of which the e2e example reports.

use super::Request;
use crate::util::prng::Rng;

/// Batch-formation policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// First-in first-out.
    Fifo,
    /// Shortest-job-first by requested output tokens — reduces padded
    /// decode steps when jobs are heterogeneous.
    ShortestFirst,
}

/// Generate a synthetic request trace: prompt lengths uniform in
/// [1, max_prompt], output lengths skewed-small in [1, max_out] (typical
/// interactive traces are short-output heavy).
pub fn synthetic_trace(
    n: usize,
    vocab: i32,
    max_prompt: usize,
    max_out: usize,
    seed: u64,
) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let plen = rng.range(1, max_prompt as u64) as usize;
            let prompt = (0..plen).map(|_| rng.below(vocab as u64) as i32).collect();
            let n_tokens = (rng.skewed(max_out as u64) + 1) as usize;
            Request { id: i as u64, prompt, n_tokens }
        })
        .collect()
}

/// Order requests according to the policy (stable within equal keys).
pub fn order(mut requests: Vec<Request>, policy: Policy) -> Vec<Request> {
    match policy {
        Policy::Fifo => requests,
        Policy::ShortestFirst => {
            requests.sort_by_key(|r| r.n_tokens);
            requests
        }
    }
}

/// Padded-step waste of a batch split: Σ over batches of
/// (batch·max_steps − Σ steps) — decode iterations spent on finished rows.
pub fn padding_waste(requests: &[Request], batch: usize) -> u64 {
    requests
        .chunks(batch)
        .map(|chunk| {
            let max = chunk.iter().map(|r| r.n_tokens as u64).max().unwrap_or(0);
            chunk.iter().map(|r| max - r.n_tokens as u64).sum::<u64>()
                + max * (batch - chunk.len()) as u64
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_bounded() {
        let a = synthetic_trace(20, 100, 16, 8, 7);
        let b = synthetic_trace(20, 100, 16, 8, 7);
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.n_tokens, y.n_tokens);
            assert!((1..=16).contains(&x.prompt.len()));
            assert!((1..=8).contains(&x.n_tokens));
            assert!(x.prompt.iter().all(|&t| (0..100).contains(&t)));
        }
    }

    #[test]
    fn shortest_first_reduces_waste() {
        let reqs = synthetic_trace(64, 100, 8, 32, 3);
        let fifo_waste = padding_waste(&order(reqs.clone(), Policy::Fifo), 4);
        let sjf_waste = padding_waste(&order(reqs, Policy::ShortestFirst), 4);
        assert!(
            sjf_waste <= fifo_waste,
            "sjf waste {sjf_waste} should not exceed fifo {fifo_waste}"
        );
    }

    #[test]
    fn padding_waste_counts_ragged_batches() {
        let reqs = vec![
            Request { id: 0, prompt: vec![1], n_tokens: 4 },
            Request { id: 1, prompt: vec![1], n_tokens: 2 },
            Request { id: 2, prompt: vec![1], n_tokens: 4 },
        ];
        // batch=2: [4,2] wastes 2; ragged [4] wastes 4 (one empty slot).
        assert_eq!(padding_waste(&reqs, 2), 2 + 4);
    }
}
