//! Multi-replica data-parallel serving: N replica engines (each a full
//! prefill/decode pool pair running the existing [`super::scheduler`]
//! engine) behind a pluggable load balancer, with cross-replica
//! re-dispatch of crash losses.
//!
//! # Dispatch
//!
//! Arrivals are assigned to replicas up front, in arrival order, by the
//! configured [`Balancer`]:
//!
//! * `round_robin` — a rotating counter; ignores request shape.
//! * `least_kv_pressure` — the replica with the least *cumulative
//!   assigned KV-token load* (Σ `prompt + output` of everything sent its
//!   way so far). A deterministic stand-in for instantaneous-KV routing:
//!   it balances the memory bill each replica will foot, without the
//!   balancer needing a latency oracle of its own.
//! * `session_affinity` — a stable hash of the request id picks the
//!   replica, so repeat sessions land where their (future, PR-carried)
//!   prefix KV would live; re-dispatch offsets the hash by attempt.
//!
//! # Re-dispatch
//!
//! Each replica runs with its fault spec projected through
//! [`FaultSpec::for_replica`] and its *replica-level* retry budget zeroed:
//! a crash victim surfaces immediately as a loss, and the fleet owns the
//! retry budget. Losses are committed in global loss-time order through
//! the same [`EventHeap`] that drives the engine clocks: repeatedly, the
//! earliest crash loss with budget left is re-dispatched — once, with
//! exponential backoff — to a balancer-chosen replica *other than* the
//! one that lost it, and only the receiving replica is re-simulated.
//! This is stable because an engine is causal (an arrival at time `t`
//! cannot change anything before `t`) and every re-dispatched arrival is
//! at or after the committed loss time: decisions already taken never
//! invalidate. The lost instance stays in the losing replica's trace —
//! the work it wasted there is real and stays modeled — but the
//! request's *authoritative* outcome is wherever it was sent last.
//! Queue-timeout losses and shed arrivals are final (re-dispatching a
//! request that already blew its deadline would just blow it again).
//!
//! # Reporting
//!
//! The fleet aggregate [`RunStats`] sums work counters across replicas,
//! takes maxima for peaks, uses the slowest replica's makespan as the
//! fleet makespan, and defines availability as
//! `1 − Σ downtime_i / (N · makespan)` — replica-hours lost over
//! replica-hours offered (1.0 for a zero-span run). Request counters
//! (`requests_lost`/`shed`/`retried`) count *global* requests by final
//! outcome, not per-instance events. Per-replica stats ride along in
//! [`ServeReport::replica_stats`]. A completed re-dispatched request
//! keeps its original arrival time in the metrics (the user waited the
//! whole saga) and is fault-marked. With `--trace`, every replica gets
//! its own "replica N …" track set plus a fleet-level "redispatch"
//! instant per committed retry.
//!
//! `replicas = 1` delegates to [`serve_once`] untouched — the fleet path
//! reproduces the single-pool `ServeReport` byte for byte.

use super::events::EventHeap;
use super::metrics::{self, RequestMetrics, Slo};
use super::scheduler::{self, Outcome, RunStats, SchedulerConfig};
use super::workload::Request;
use super::{serve_once, ServeReport};
use crate::graph::inference::Simulator;
use crate::graph::ModelConfig;
use crate::hardware::SystemSpec;
use crate::util::json::num;
use crate::util::telemetry::{Recorder, ScopedRecorder};
use std::sync::Arc;

#[cfg(doc)]
use super::fault::FaultSpec;

/// Load-balancing policy assigning arrivals (and re-dispatches) to
/// replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Balancer {
    RoundRobin,
    LeastKvPressure,
    SessionAffinity,
}

impl Balancer {
    pub fn parse(v: &str) -> Option<Balancer> {
        match v {
            "round_robin" | "round-robin" | "rr" => Some(Balancer::RoundRobin),
            "least_kv_pressure" | "least-kv-pressure" | "least_kv" => {
                Some(Balancer::LeastKvPressure)
            }
            "session_affinity" | "session-affinity" | "affinity" => Some(Balancer::SessionAffinity),
            _ => None,
        }
    }

    /// Canonical name, accepted back by [`Balancer::parse`].
    pub fn name(self) -> &'static str {
        match self {
            Balancer::RoundRobin => "round_robin",
            Balancer::LeastKvPressure => "least_kv_pressure",
            Balancer::SessionAffinity => "session_affinity",
        }
    }
}

/// Fleet shape: how many replica engines, and how arrivals are routed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FleetConfig {
    /// Replica count. Each replica is a full copy of the configured
    /// system (same devices, same `SchedulerConfig`); 1 is the plain
    /// single-pool path.
    pub replicas: u64,
    pub balancer: Balancer,
}

impl FleetConfig {
    /// The degenerate single-replica fleet (balancer is irrelevant).
    pub fn single() -> FleetConfig {
        FleetConfig { replicas: 1, balancer: Balancer::RoundRobin }
    }
}

/// Validate a fleet configuration the way [`scheduler::validate`] guards
/// the single-pool path: callers evaluating user input get an error here
/// instead of a panic from [`serve_fleet`].
pub fn validate_fleet(
    cfg: &SchedulerConfig,
    device_count: u64,
    fleet: &FleetConfig,
    requests: &[Request],
) -> Result<(), String> {
    if fleet.replicas == 0 {
        return Err("replicas must be ≥ 1".to_string());
    }
    if let Some(spec) = &cfg.faults {
        if let Some(r) = spec.max_replica_target() {
            if r >= fleet.replicas {
                return Err(format!(
                    "fault target replica:{r} is out of range for a {}-replica fleet",
                    fleet.replicas
                ));
            }
        }
    }
    // Every replica trace is a subset of the full request set, so one
    // pass over it covers all of them.
    scheduler::validate(cfg, device_count, requests)
}

/// 64-bit finalizer (MurmurHash3 fmix64): the stable session hash behind
/// `session_affinity`.
fn fmix64(mut h: u64) -> u64 {
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^= h >> 33;
    h = h.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    h ^= h >> 33;
    h
}

/// Balancer state threaded through initial dispatch and re-dispatch.
struct Dispatcher {
    balancer: Balancer,
    n: usize,
    rr_next: u64,
    /// Cumulative assigned KV-token load per replica (`least_kv_pressure`).
    kv_load: Vec<u64>,
}

impl Dispatcher {
    fn new(balancer: Balancer, n: usize) -> Dispatcher {
        Dispatcher { balancer, n, rr_next: 0, kv_load: vec![0; n] }
    }

    /// Pick a replica for `req` (re-dispatch attempt `attempt`; 0 for
    /// the initial assignment), avoiding the replica that just lost it.
    fn assign(&mut self, req: &Request, attempt: u64, avoid: Option<usize>) -> usize {
        let pick = match self.balancer {
            Balancer::RoundRobin => {
                let mut p = (self.rr_next % self.n as u64) as usize;
                self.rr_next += 1;
                if Some(p) == avoid && self.n > 1 {
                    p = (self.rr_next % self.n as u64) as usize;
                    self.rr_next += 1;
                }
                p
            }
            Balancer::LeastKvPressure => {
                let mut best = None;
                for i in 0..self.n {
                    if Some(i) == avoid && self.n > 1 {
                        continue;
                    }
                    let better = match best {
                        None => true,
                        Some(b) => self.kv_load[i] < self.kv_load[b],
                    };
                    if better {
                        best = Some(i);
                    }
                }
                best.expect("at least one replica to assign to")
            }
            Balancer::SessionAffinity => {
                let mut p = (fmix64(req.id).wrapping_add(attempt) % self.n as u64) as usize;
                if Some(p) == avoid && self.n > 1 {
                    p = (p + 1) % self.n;
                }
                p
            }
        };
        self.kv_load[pick] += req.total_tokens();
        pick
    }
}

/// One replica's slice of the fleet: its trace (in arrival order), the
/// instance id of every trace entry, and the cached engine result —
/// invalidated whenever the trace gains a re-dispatched arrival.
#[derive(Default)]
struct Replica {
    trace: Vec<Request>,
    instance: Vec<u64>,
    result: Option<(Vec<RequestMetrics>, RunStats, Vec<Outcome>)>,
}

impl Replica {
    fn insert(&mut self, req: Request, instance: u64) {
        let pos = self.trace.partition_point(|r| r.arrival_s <= req.arrival_s);
        self.trace.insert(pos, req);
        self.instance.insert(pos, instance);
        self.result = None;
    }

    /// Local trace index of an instance id (linear scan; traces are
    /// simulation-sized).
    fn local_idx(&self, instance: u64) -> Option<usize> {
        self.instance.iter().position(|&x| x == instance)
    }
}

/// One global request's routing state: where its live instance currently
/// is and how much fleet retry budget it has burned.
struct Tracked {
    req: Request,
    replica: usize,
    instance: u64,
    attempts: u64,
}

/// Serve one workload on an N-replica fleet end to end. `replicas ≤ 1`
/// is exactly [`serve_once`]. Panics on configurations
/// [`validate_fleet`] rejects — callers evaluating user input should
/// validate first.
pub fn serve_fleet(
    sim: &Simulator,
    sys: &SystemSpec,
    model: &ModelConfig,
    cfg: &SchedulerConfig,
    fleet: &FleetConfig,
    requests: &[Request],
    slo: &Slo,
) -> (ServeReport, Vec<RequestMetrics>) {
    if fleet.replicas <= 1 {
        return serve_once(sim, sys, model, cfg, requests, slo);
    }
    if let Err(e) = validate_fleet(cfg, sys.device_count, fleet, requests) {
        panic!("{e}");
    }
    let n = fleet.replicas as usize;

    // The fleet owns the retry budget; each replica engine surfaces
    // crash victims immediately (max_retries = 0) under its projected
    // fault spec.
    let (max_retries, retry_backoff_s) = cfg
        .faults
        .as_ref()
        .map(|s| (s.recovery.max_retries, s.recovery.retry_backoff_s))
        .unwrap_or((0, 0.0));
    let cfgs: Vec<SchedulerConfig> = (0..n)
        .map(|r| {
            let faults = cfg.faults.as_ref().map(|s| {
                let mut proj = s.for_replica(r as u64, fleet.replicas);
                proj.recovery.max_retries = 0;
                Arc::new(proj)
            });
            SchedulerConfig { faults, ..cfg.clone() }
        })
        .collect();

    // Initial dispatch, in arrival order (the input is sorted, so every
    // per-replica trace comes out sorted too).
    let mut dispatcher = Dispatcher::new(fleet.balancer, n);
    let mut replicas: Vec<Replica> = (0..n).map(|_| Replica::default()).collect();
    let mut tracked: Vec<Tracked> = Vec::with_capacity(requests.len());
    for (gi, r) in requests.iter().enumerate() {
        let pick = dispatcher.assign(r, 0, None);
        replicas[pick].trace.push(r.clone());
        replicas[pick].instance.push(gi as u64);
        tracked.push(Tracked { req: r.clone(), replica: pick, instance: gi as u64, attempts: 0 });
    }
    let mut next_instance = requests.len() as u64;

    // Working runs are quiet — traces mutate during re-dispatch, so
    // telemetry is emitted in one authoritative pass at the end.
    let quiet = Recorder::disabled();
    let quiet_scope = ScopedRecorder::new(&quiet, "");
    let run = |cfg_r: &SchedulerConfig, trace: &[Request]| {
        scheduler::simulate_scoped(sim, sys, model, cfg_r, trace, &quiet_scope)
    };

    let mut retry_tokens = 0u64;
    // (loss time, request id) per committed re-dispatch, for telemetry.
    let mut redispatches: Vec<(f64, u64)> = Vec::new();
    loop {
        for (r, rep) in replicas.iter_mut().enumerate() {
            if rep.result.is_none() {
                rep.result = Some(run(&cfgs[r], &rep.trace));
            }
        }
        // Commit the globally earliest pending crash loss with budget
        // left. Causality makes everything earlier than it stable, so
        // committing one loss per pass (and re-running only the replica
        // that receives the retry) converges deterministically.
        let mut pending: EventHeap<usize> = EventHeap::new();
        for (gi, tr) in tracked.iter().enumerate() {
            if tr.attempts >= max_retries {
                continue;
            }
            let rep = &replicas[tr.replica];
            let (_, _, outcomes) = rep.result.as_ref().expect("replica result cached");
            let Some(li) = rep.local_idx(tr.instance) else { continue };
            if let Outcome::Lost { at_s, crash_kv: Some(_) } = outcomes[li] {
                pending.push(at_s, 0, gi);
            }
        }
        let Some((loss_at, gi)) = pending.pop() else { break };
        let (from, crash_kv) = {
            let tr = &tracked[gi];
            let rep = &replicas[tr.replica];
            let li = rep.local_idx(tr.instance).expect("live instance in its replica");
            match rep.result.as_ref().expect("replica result cached").2[li] {
                Outcome::Lost { crash_kv: Some(kv), .. } => (tr.replica, kv),
                _ => unreachable!("pending loss vanished between scans"),
            }
        };
        tracked[gi].attempts += 1;
        let attempts = tracked[gi].attempts;
        let backoff = retry_backoff_s * (1u64 << (attempts - 1).min(62)) as f64;
        let dest = dispatcher.assign(&tracked[gi].req, attempts, Some(from));
        let instance = next_instance;
        next_instance += 1;
        let req = Request { arrival_s: loss_at + backoff, ..tracked[gi].req.clone() };
        replicas[dest].insert(req, instance);
        tracked[gi].replica = dest;
        tracked[gi].instance = instance;
        retry_tokens += crash_kv;
        redispatches.push((loss_at, tracked[gi].req.id));
    }

    // Aggregate: sum the work, max the peaks, slowest replica sets the
    // fleet makespan.
    let replica_stats: Vec<RunStats> =
        replicas.iter().map(|r| r.result.as_ref().unwrap().1.clone()).collect();
    let mut agg = RunStats::default();
    let mut downtime_sum = 0.0;
    for st in &replica_stats {
        agg.prefill_iterations += st.prefill_iterations;
        agg.decode_iterations += st.decode_iterations;
        agg.mixed_iterations += st.mixed_iterations;
        agg.prefill_busy_s += st.prefill_busy_s;
        agg.decode_busy_s += st.decode_busy_s;
        agg.mixed_busy_s += st.mixed_busy_s;
        agg.idle_s += st.idle_s;
        agg.peak_kv_tokens = agg.peak_kv_tokens.max(st.peak_kv_tokens);
        agg.prefill_peak_kv_tokens = agg.prefill_peak_kv_tokens.max(st.prefill_peak_kv_tokens);
        agg.peak_batch = agg.peak_batch.max(st.peak_batch);
        agg.preemptions += st.preemptions;
        agg.preempted_requests += st.preempted_requests;
        agg.recompute_tokens += st.recompute_tokens;
        agg.transfer_total_s += st.transfer_total_s;
        agg.handoff_wait_s += st.handoff_wait_s;
        agg.handoff_stall_s += st.handoff_stall_s;
        agg.faults_injected += st.faults_injected;
        downtime_sum += st.fault_downtime_s;
        agg.makespan_s = agg.makespan_s.max(st.makespan_s);
    }
    agg.fault_downtime_s = downtime_sum;
    // Replica-hours lost over replica-hours offered; a zero-span fleet
    // was never unavailable.
    agg.availability = if agg.makespan_s > 0.0 {
        (1.0 - downtime_sum / (fleet.replicas as f64 * agg.makespan_s)).clamp(0.0, 1.0)
    } else {
        1.0
    };

    // Request counters by *final* outcome (per global request, not per
    // instance — a re-dispatched-then-completed request is not lost).
    let mut metrics_out: Vec<RequestMetrics> = Vec::new();
    for tr in &tracked {
        let rep = &replicas[tr.replica];
        let (mets, _, outcomes) = rep.result.as_ref().unwrap();
        let li = rep.local_idx(tr.instance).expect("live instance in its replica");
        match outcomes[li] {
            Outcome::Completed => {
                // A replica's metrics keep one entry per completed
                // instance, and only one instance of a request ever
                // completes, so lookup by id is unambiguous.
                let mut m =
                    mets.iter().find(|m| m.id == tr.req.id).expect("completed metrics").clone();
                if tr.attempts > 0 {
                    // The user waited from the *original* arrival.
                    m.arrival_s = tr.req.arrival_s;
                    m.faulted = true;
                }
                metrics_out.push(m);
            }
            Outcome::Lost { .. } => agg.requests_lost += 1,
            Outcome::Shed { .. } => agg.requests_shed += 1,
        }
    }
    agg.requests_retried = tracked.iter().filter(|t| t.attempts > 0).count() as u64;
    agg.retry_tokens_recomputed = retry_tokens;
    debug_assert_eq!(
        metrics_out.len() as u64 + agg.requests_lost + agg.requests_shed,
        requests.len() as u64,
        "fleet request accounting does not conserve"
    );

    // Authoritative telemetry pass: each replica's final trace once,
    // under its own track prefix, plus fleet-level re-dispatch markers.
    if sim.recorder.is_enabled() {
        for (r, rep) in replicas.iter().enumerate() {
            let scope = ScopedRecorder::new(&sim.recorder, &format!("replica {r} "));
            let _ = scheduler::simulate_scoped(sim, sys, model, &cfgs[r], &rep.trace, &scope);
        }
        for &(at, id) in &redispatches {
            sim.recorder.instant_sim("fleet", "redispatch", at, &[("req", num(id as f64))]);
        }
    }

    let summary = metrics::summarize(&metrics_out, slo, agg.makespan_s);
    (ServeReport { summary, stats: agg, replica_stats }, metrics_out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::presets;
    use crate::serve::fault::{FaultEvent, FaultKind, FaultSpec, FaultTarget};
    use crate::serve::scheduler::Policy;
    use crate::serve::workload::{generate, WorkloadSpec};
    use crate::serve::ServeMode;

    fn setup() -> (Simulator, SystemSpec, crate::graph::ModelConfig) {
        let model = crate::graph::ModelConfig::gpt_small();
        (Simulator::new(), presets::system("a100x2").unwrap(), model)
    }

    #[test]
    fn balancer_names_round_trip() {
        for b in [Balancer::RoundRobin, Balancer::LeastKvPressure, Balancer::SessionAffinity] {
            assert_eq!(Balancer::parse(b.name()), Some(b));
        }
        assert_eq!(Balancer::parse("rr"), Some(Balancer::RoundRobin));
        assert_eq!(Balancer::parse("nope"), None);
    }

    #[test]
    fn single_replica_fleet_is_exactly_serve_once() {
        let (sim, sys, model) = setup();
        let reqs = generate(&WorkloadSpec::poisson(20.0, 40, 3));
        for mode in [
            ServeMode::Monolithic,
            ServeMode::Chunked { chunk_tokens: 512 },
            ServeMode::Disaggregated { prefill_devices: 1, transfer_base_s: 0.002 },
        ] {
            let mut cfg = SchedulerConfig::for_system(&sys, &model, Policy::Fcfs);
            cfg.mode = mode;
            let (single, per_single) =
                serve_once(&sim, &sys, &model, &cfg, &reqs, &Slo::relaxed());
            let (fleet, per_fleet) = serve_fleet(
                &sim,
                &sys,
                &model,
                &cfg,
                &FleetConfig::single(),
                &reqs,
                &Slo::relaxed(),
            );
            assert_eq!(
                single.to_json().to_string_pretty(),
                fleet.to_json().to_string_pretty(),
                "replicas=1 must reproduce the single-pool report byte for byte ({})",
                mode.name()
            );
            assert_eq!(per_single.len(), per_fleet.len());
        }
    }

    #[test]
    fn fleet_splits_load_and_conserves_requests() {
        let (sim, sys, model) = setup();
        let cfg = SchedulerConfig::for_system(&sys, &model, Policy::Fcfs);
        let reqs = generate(&WorkloadSpec::poisson(30.0, 60, 5));
        for balancer in [Balancer::RoundRobin, Balancer::LeastKvPressure, Balancer::SessionAffinity]
        {
            let fleet = FleetConfig { replicas: 3, balancer };
            let (report, per_req) =
                serve_fleet(&sim, &sys, &model, &cfg, &fleet, &reqs, &Slo::relaxed());
            assert_eq!(per_req.len(), reqs.len(), "no faults: everything completes");
            assert_eq!(report.replica_stats.len(), 3);
            assert_eq!(report.stats.requests_lost, 0);
            assert_eq!(report.stats.availability, 1.0);
            // Work landed on more than one replica.
            let active = report
                .replica_stats
                .iter()
                .filter(|s| s.decode_iterations + s.prefill_iterations > 0)
                .count();
            assert!(active >= 2, "{balancer:?} routed everything to one replica");
            // The report carries the per-replica stats only for fleets.
            let j = report.to_json();
            assert!(j.get("replicas").is_some());
        }
    }

    #[test]
    fn replica_crash_redispatches_to_survivors() {
        let (sim, sys, model) = setup();
        let mut cfg = SchedulerConfig::for_system(&sys, &model, Policy::Fcfs);
        let mut spec = FaultSpec::none();
        // Replica 1 crashes mid-trace, once decode queues have built up;
        // retries land elsewhere.
        spec.events.push(FaultEvent {
            kind: FaultKind::Crash,
            at_s: 0.5,
            duration_s: 5.0,
            target: FaultTarget::Replica(1),
        });
        spec.recovery.max_retries = 2;
        spec.recovery.retry_backoff_s = 0.05;
        cfg.faults = Some(Arc::new(spec));
        let reqs = generate(&WorkloadSpec::poisson(40.0, 60, 9));
        let fleet = FleetConfig { replicas: 3, balancer: Balancer::RoundRobin };
        let (report, per_req) =
            serve_fleet(&sim, &sys, &model, &cfg, &fleet, &reqs, &Slo::relaxed());
        let stats = &report.stats;
        assert_eq!(
            per_req.len() as u64 + stats.requests_lost + stats.requests_shed,
            reqs.len() as u64,
            "conservation"
        );
        assert!(stats.requests_retried > 0, "the crash re-dispatched nobody");
        assert!(stats.retry_tokens_recomputed > 0);
        assert!(stats.availability < 1.0, "a replica outage must dent availability");
        assert!(stats.availability > 0.0, "two of three replicas stayed up");
        assert!(
            per_req.iter().any(|m| m.faulted),
            "re-dispatched completions carry the fault mark"
        );
        // Determinism: the whole pipeline replays bit for bit.
        let (replay, _) = serve_fleet(&sim, &sys, &model, &cfg, &fleet, &reqs, &Slo::relaxed());
        assert_eq!(
            report.to_json().to_string_pretty(),
            replay.to_json().to_string_pretty()
        );
    }

    #[test]
    fn validate_fleet_rejects_bad_shapes() {
        let (_, sys, model) = setup();
        let cfg = SchedulerConfig::for_system(&sys, &model, Policy::Fcfs);
        let fleet = FleetConfig { replicas: 0, balancer: Balancer::RoundRobin };
        assert!(validate_fleet(&cfg, sys.device_count, &fleet, &[]).is_err());
        // A replica target beyond the fleet size is a config error.
        let mut faulty = cfg.clone();
        let mut spec = FaultSpec::none();
        spec.events.push(FaultEvent {
            kind: FaultKind::Crash,
            at_s: 1.0,
            duration_s: 1.0,
            target: FaultTarget::Replica(7),
        });
        faulty.faults = Some(Arc::new(spec));
        let fleet = FleetConfig { replicas: 4, balancer: Balancer::RoundRobin };
        let err = validate_fleet(&faulty, sys.device_count, &fleet, &[]).unwrap_err();
        assert!(err.contains("replica:7"), "unhelpful error: {err}");
        assert!(validate_fleet(&cfg, sys.device_count, &fleet, &[]).is_ok());
    }
}
