//! SLO-aware cost sweep: serve the same workload on a ladder of arrival
//! rates across hardware presets — and, since scheduler v2, across
//! scheduler execution modes — and report **$ / 1M output tokens at
//! SLO** — the serving-economics figure of merit that combines the
//! performance model (via the scheduler) with the cost model.
//!
//! This is the traffic-scale generalization of the paper's Table IV
//! performance/cost rows: instead of normalizing isolated-batch latency by
//! die+memory cost, it normalizes *goodput under an SLO* — so a design
//! with cheap capacious DRAM (the throughput-oriented proposal) wins at
//! relaxed SLOs even though its per-iteration decode is slower, exactly
//! the Fig. 10–12 trade the paper argues for. Sweeping `modes` on one
//! system isolates the scheduler's contribution: monolithic vs. chunked
//! prefill vs. disaggregated pools on identical hardware and traffic.

use super::fault::{FaultSpec, DEFAULT_MTTR_S};
use super::fleet::{serve_fleet, validate_fleet, Balancer, FleetConfig};
use super::metrics::{Slo, Summary};
use super::scheduler::{Policy, Preemption, SchedulerConfig, ServeMode};
use super::workload::{generate, WorkloadSpec};
use crate::cost::{device_cost, CostParams};
use crate::graph::inference::Simulator;
use crate::graph::ModelConfig;
use crate::hardware::presets;
use std::sync::Arc;

/// Hardware amortization window for $/token: a 3-year depreciation of the
/// die + memory cost (hosting, power, and interconnect excluded, as the
/// paper's cost model excludes IP/masks/packaging).
pub const AMORT_SECONDS: f64 = 3.0 * 365.0 * 24.0 * 3600.0;

/// $ per million output tokens at the SLO for a cluster costing
/// `cluster_cost_usd`, amortized over [`AMORT_SECONDS`]; infinite when
/// nothing met the SLO. Shared by the sweep and `eval` serving reports so
/// the two surfaces can never diverge.
pub fn usd_per_mtok_at_slo(cluster_cost_usd: f64, goodput_tok_s: f64) -> f64 {
    if goodput_tok_s > 0.0 {
        cluster_cost_usd / AMORT_SECONDS / goodput_tok_s * 1e6
    } else {
        f64::INFINITY
    }
}

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// System preset names (`<device>x<count>` or bare device).
    pub systems: Vec<String>,
    /// Scheduler execution modes to compare on every system (disaggregated
    /// entries are skipped on single-device systems rather than erroring).
    pub modes: Vec<ServeMode>,
    pub preemption: Preemption,
    /// Poisson arrival rates to sweep, requests/second.
    pub rates: Vec<f64>,
    pub requests: usize,
    pub slo: Slo,
    pub policy: Policy,
    pub seed: u64,
    /// SLO-under-fault axis: MTBF values (hours) to sweep in addition to
    /// the implicit fault-free point. Each value serves every (system,
    /// mode, rate) point under a seeded MTBF crash process, answering
    /// "goodput and $/1M-token at the SLO given an MTBF of X hours".
    /// Empty: fault-free sweep only.
    pub fault_mtbf_hours: Vec<f64>,
    /// Downtime per MTBF-generated crash, seconds.
    pub fault_mttr_s: f64,
    /// Fleet-size axis: replica counts to sweep (round-robin balanced;
    /// each replica is a full copy of the system, so the cluster cost
    /// scales with it). `vec![1]` is the single-engine sweep.
    pub fleet_sizes: Vec<u64>,
}

impl SweepConfig {
    /// The paper-comparison default: GPT-3-class traffic on 8-device
    /// nodes of the A100, full GA100, and the Table IV proposals,
    /// monolithic scheduling.
    pub fn paper_default(requests: usize, slo: Slo) -> SweepConfig {
        SweepConfig {
            systems: vec![
                "a100x8".into(),
                "ga100x8".into(),
                "latency-orientedx8".into(),
                "throughput-orientedx8".into(),
            ],
            modes: vec![ServeMode::Monolithic],
            preemption: Preemption::Conservative,
            rates: vec![0.5, 1.0, 2.0, 4.0],
            requests,
            slo,
            policy: Policy::Fcfs,
            seed: 42,
            fault_mtbf_hours: Vec::new(),
            fault_mttr_s: DEFAULT_MTTR_S,
            fleet_sizes: vec![1],
        }
    }

    /// Compare the three scheduler modes on the same hardware and traffic
    /// — the phase-splitting study (chunk 2048 tokens, half the devices
    /// on prefill, 1 ms transfer base).
    pub fn mode_comparison(system: &str, requests: usize, slo: Slo) -> SweepConfig {
        SweepConfig {
            systems: vec![system.to_string()],
            modes: vec![
                ServeMode::Monolithic,
                ServeMode::Chunked { chunk_tokens: 2048 },
                ServeMode::Disaggregated { prefill_devices: 0, transfer_base_s: 1e-3 },
            ],
            preemption: Preemption::Conservative,
            rates: vec![1.0, 2.0, 4.0],
            requests,
            slo,
            policy: Policy::Fcfs,
            seed: 42,
            fault_mtbf_hours: Vec::new(),
            fault_mttr_s: DEFAULT_MTTR_S,
            fleet_sizes: vec![1],
        }
    }
}

/// One (system, mode, rate, MTBF) sweep point.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub system: String,
    /// Canonical scheduler-mode name ([`ServeMode::name`]).
    pub mode: &'static str,
    pub rate_per_s: f64,
    pub cluster_cost_usd: f64,
    pub summary: Summary,
    /// Preemption events of this run (0 under conservative admission).
    pub preemptions: u64,
    /// $ per million output tokens at the SLO (hardware amortized over
    /// [`AMORT_SECONDS`]); infinite when nothing met the SLO.
    pub usd_per_mtok: f64,
    /// MTBF of this point's crash process, hours; `None` for the
    /// fault-free point.
    pub mtbf_hours: Option<f64>,
    /// Data-parallel replica count of this point (1: single engine).
    pub replicas: u64,
    /// Fraction of the makespan with every pool up (1.0 fault-free).
    pub availability: f64,
    /// Requests dropped for good at this point (crashes past the retry
    /// budget + queue timeouts).
    pub requests_lost: u64,
}

/// The sweep's fault axis, parsed once up front: the implicit fault-free
/// point, then one seeded MTBF crash process per requested value. Every
/// (system, mode, rate) cell shares the same `Arc`'d spec instead of
/// rebuilding and deep-cloning it per cell.
fn fault_axis(cfg: &SweepConfig) -> Result<Vec<(Option<f64>, Option<Arc<FaultSpec>>)>, String> {
    let mut points: Vec<(Option<f64>, Option<Arc<FaultSpec>>)> = vec![(None, None)];
    for &h in &cfg.fault_mtbf_hours {
        if !(h > 0.0) || !h.is_finite() {
            return Err(format!("sweep fault MTBF must be finite and > 0 hours, got {h}"));
        }
        let spec = FaultSpec::mtbf(cfg.seed, h * 3600.0, cfg.fault_mttr_s);
        points.push((Some(h), Some(Arc::new(spec))));
    }
    Ok(points)
}

/// One cell's scheduler configuration: the (system, mode) base with the
/// cell's shared fault spec swapped in — the only per-cell divergence,
/// made explicit here instead of scattered mutation of the base config.
fn cell_config(base: &SchedulerConfig, faults: Option<&Arc<FaultSpec>>) -> SchedulerConfig {
    SchedulerConfig { faults: faults.cloned(), ..base.clone() }
}

/// Run the sweep for one model across all (system, mode, rate) points. The
/// `sim`'s mapper caches *and* its shared latency-oracle cache persist
/// across points (shapes and hardware recur), which is what makes a full
/// sweep take seconds: every cell over unchanged hardware+model replays
/// the same warm oracle instead of re-simulating its buckets.
pub fn run_sweep(
    sim: &Simulator,
    model: &ModelConfig,
    cfg: &SweepConfig,
) -> Result<Vec<SweepRow>, String> {
    let cost_params = CostParams::default();
    // The fault axis is cell-independent — parse and validate it once.
    let fault_points = fault_axis(cfg)?;
    let mut rows = Vec::new();
    for name in &cfg.systems {
        let sys = presets::system(name)
            .ok_or_else(|| format!("unknown system preset `{name}`"))?;
        let cluster_cost_usd =
            device_cost(&cost_params, &sys.device).total_usd() * sys.device_count as f64;
        for &mode in &cfg.modes {
            let Ok(resolved) = mode.resolved(sys.device_count) else {
                continue; // e.g. disaggregation on a single device
            };
            let mut base = SchedulerConfig::for_system(&sys, model, cfg.policy);
            base.mode = resolved;
            base.preemption = cfg.preemption;
            if base.kv_capacity_tokens == 0 {
                return Err(format!(
                    "model `{}` does not fit `{name}` (parameters exceed memory capacity)",
                    model.name
                ));
            }
            for &replicas in &cfg.fleet_sizes {
                if replicas == 0 {
                    return Err("sweep fleet_sizes entries must be ≥ 1".to_string());
                }
                let fleet = FleetConfig { replicas, balancer: Balancer::RoundRobin };
                // A fleet buys the whole cluster once per replica.
                let fleet_cost_usd = cluster_cost_usd * replicas as f64;
                for &rate in &cfg.rates {
                    // Same seed across systems, modes, and rates: identical
                    // request lengths, only the arrival spacing scales.
                    let requests = generate(&WorkloadSpec::poisson(rate, cfg.requests, cfg.seed));
                    for (mtbf_hours, spec) in &fault_points {
                        let mtbf_hours = *mtbf_hours;
                        let sched = cell_config(&base, spec.as_ref());
                        validate_fleet(&sched, sys.device_count, &fleet, &requests)?;
                        let (report, _) =
                            serve_fleet(sim, &sys, model, &sched, &fleet, &requests, &cfg.slo);
                        let usd_per_mtok =
                            usd_per_mtok_at_slo(fleet_cost_usd, report.summary.goodput_tok_s);
                        rows.push(SweepRow {
                            system: name.clone(),
                            mode: resolved.name(),
                            rate_per_s: rate,
                            cluster_cost_usd: fleet_cost_usd,
                            summary: report.summary,
                            preemptions: report.stats.preemptions,
                            usd_per_mtok,
                            mtbf_hours,
                            replicas,
                            availability: report.stats.availability,
                            requests_lost: report.stats.requests_lost,
                        });
                    }
                }
            }
        }
    }
    Ok(rows)
}

/// Best (cheapest $/1M-tokens-at-SLO) row per (system, mode, fleet size,
/// MTBF point), preserving the sweep's order. Fault-free and each MTBF
/// value group separately, so the under-fault economics never hide behind
/// the best-case row; fleet sizes likewise, so the sweep surfaces the
/// cost of buying N clusters rather than silently preferring one.
pub fn best_per_system(rows: &[SweepRow]) -> Vec<&SweepRow> {
    let key =
        |r: &SweepRow| (r.system.clone(), r.mode, r.replicas, r.mtbf_hours.map(f64::to_bits));
    let mut order: Vec<(String, &str, u64, Option<u64>)> = Vec::new();
    for r in rows {
        if !order.contains(&key(r)) {
            order.push(key(r));
        }
    }
    order
        .into_iter()
        .map(|k| {
            rows.iter()
                .filter(|r| key(r) == k)
                // total_cmp: rows where nothing met the SLO carry an
                // infinite (or, before the summarize guards, NaN) price —
                // ordering must not panic on them.
                .min_by(|a, b| a.usd_per_mtok.total_cmp(&b.usd_per_mtok))
                .unwrap()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> SweepConfig {
        SweepConfig {
            systems: vec!["ga100".into(), "throughput-oriented".into()],
            modes: vec![ServeMode::Monolithic],
            preemption: Preemption::Conservative,
            rates: vec![20.0, 60.0],
            requests: 48,
            slo: Slo::relaxed(),
            policy: Policy::Fcfs,
            seed: 3,
            fault_mtbf_hours: Vec::new(),
            fault_mttr_s: DEFAULT_MTTR_S,
            fleet_sizes: vec![1],
        }
    }

    #[test]
    fn sweep_produces_rows_and_finite_costs() {
        let sim = Simulator::new();
        let rows = run_sweep(&sim, &ModelConfig::gpt_small(), &quick_cfg()).unwrap();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.cluster_cost_usd > 0.0);
            assert!(r.summary.requests == 48);
            assert!(r.summary.throughput_tok_s > 0.0);
            assert!(r.usd_per_mtok > 0.0);
            assert_eq!(r.mode, "monolithic");
            assert_eq!(r.preemptions, 0);
        }
        let best = best_per_system(&rows);
        assert_eq!(best.len(), 2);
        assert_eq!(best[0].system, "ga100");
    }

    #[test]
    fn mode_comparison_covers_all_three_modes_on_one_system() {
        let sim = Simulator::new();
        let mut cfg = SweepConfig::mode_comparison("a100x2", 24, Slo::relaxed());
        cfg.rates = vec![30.0];
        let rows = run_sweep(&sim, &ModelConfig::gpt_small(), &cfg).unwrap();
        let modes: Vec<&str> = rows.iter().map(|r| r.mode).collect();
        assert_eq!(modes, vec!["monolithic", "chunked", "disaggregated"]);
        for r in &rows {
            assert_eq!(r.summary.requests, 24);
            assert!(r.summary.throughput_tok_s > 0.0, "{} produced nothing", r.mode);
        }
        // Identical traffic in every row: same total output tokens.
        assert!(rows.windows(2).all(|w| w[0].summary.output_tokens == w[1].summary.output_tokens));
        // On a single device the disaggregated entry is skipped, not fatal.
        let mut single = cfg.clone();
        single.systems = vec!["a100".into()];
        let rows = run_sweep(&sim, &ModelConfig::gpt_small(), &single).unwrap();
        assert_eq!(rows.len(), 2, "mono + chunked only");
    }

    #[test]
    fn fault_axis_adds_mtbf_points_with_degraded_availability() {
        let sim = Simulator::new();
        let mut cfg = quick_cfg();
        cfg.systems = vec!["ga100".into()];
        cfg.rates = vec![40.0];
        // Absurdly low MTBF (one crash every ~0.1s of simulated time) so
        // the short smoke trace is statistically certain to be struck.
        cfg.fault_mtbf_hours = vec![0.1 / 3600.0];
        cfg.fault_mttr_s = 0.5;
        let rows = run_sweep(&sim, &ModelConfig::gpt_small(), &cfg).unwrap();
        assert_eq!(rows.len(), 2, "fault-free point + one MTBF point");
        let free = &rows[0];
        let faulty = &rows[1];
        assert_eq!(free.mtbf_hours, None);
        assert_eq!(free.availability, 1.0);
        assert_eq!(free.requests_lost, 0);
        assert!(faulty.mtbf_hours.is_some());
        assert!(faulty.availability < 1.0, "0.1s MTBF never degraded availability");
        // Under faults the same hardware serves fewer good tokens, so the
        // $/1M-token at SLO can only get worse (or stay equal).
        assert!(faulty.usd_per_mtok >= free.usd_per_mtok);
        // Both points group separately in the best-per-system view.
        assert_eq!(best_per_system(&rows).len(), 2);
        // Determinism: the same sweep reproduces byte-identical numbers.
        let again = run_sweep(&sim, &ModelConfig::gpt_small(), &cfg).unwrap();
        assert_eq!(rows[1].availability.to_bits(), again[1].availability.to_bits());
        assert_eq!(
            rows[1].summary.goodput_tok_s.to_bits(),
            again[1].summary.goodput_tok_s.to_bits()
        );
    }

    #[test]
    fn fleet_axis_adds_rows_and_scales_cost() {
        let sim = Simulator::new();
        let mut cfg = quick_cfg();
        cfg.systems = vec!["ga100".into()];
        cfg.rates = vec![40.0];
        cfg.fleet_sizes = vec![1, 2];
        let rows = run_sweep(&sim, &ModelConfig::gpt_small(), &cfg).unwrap();
        assert_eq!(rows.len(), 2, "one rate × two fleet sizes");
        let (single, fleet) = (&rows[0], &rows[1]);
        assert_eq!(single.replicas, 1);
        assert_eq!(fleet.replicas, 2);
        assert!(
            (fleet.cluster_cost_usd - 2.0 * single.cluster_cost_usd).abs() < 1e-9,
            "two replicas cost two clusters"
        );
        // Same traffic either way; the fleet just splits it.
        assert_eq!(single.summary.requests, fleet.summary.requests);
        assert_eq!(single.summary.output_tokens, fleet.summary.output_tokens);
        // Fleet sizes group separately in the best-per-system view.
        assert_eq!(best_per_system(&rows).len(), 2);
        // Zero is a config error, not a hang.
        cfg.fleet_sizes = vec![0];
        assert!(run_sweep(&sim, &ModelConfig::gpt_small(), &cfg).is_err());
    }

    #[test]
    fn unknown_system_errors() {
        let sim = Simulator::new();
        let mut cfg = quick_cfg();
        cfg.systems = vec!["bogusx9".into()];
        assert!(run_sweep(&sim, &ModelConfig::gpt_small(), &cfg).is_err());
    }

    #[test]
    fn model_too_big_for_system_errors() {
        let sim = Simulator::new();
        let mut cfg = quick_cfg();
        cfg.systems = vec!["a100".into()]; // 80 GB < 350 GB of weights
        let err = run_sweep(&sim, &ModelConfig::gpt3_175b(), &cfg).unwrap_err();
        assert!(err.contains("does not fit"), "{err}");
    }

    #[test]
    fn cheap_capacious_design_wins_at_relaxed_slo() {
        // The throughput-oriented design costs 296$ vs 711$ (GA100) and
        // holds 6.4x the memory; at a relaxed SLO its $/1M-tokens must be
        // no worse — the Table IV / Fig. 12 ordering, now under traffic.
        let sim = Simulator::new();
        let rows = run_sweep(&sim, &ModelConfig::gpt_small(), &quick_cfg()).unwrap();
        let best = best_per_system(&rows);
        let ga = best.iter().find(|r| r.system == "ga100").unwrap();
        let thr = best.iter().find(|r| r.system == "throughput-oriented").unwrap();
        assert!(
            thr.usd_per_mtok <= ga.usd_per_mtok * 1.05,
            "throughput ${:.4}/Mtok vs GA100 ${:.4}/Mtok",
            thr.usd_per_mtok,
            ga.usd_per_mtok
        );
    }
}
