//! The serving scheduler: a discrete-event simulation of one inference
//! cluster, with [`crate::graph::inference::Simulator`] as the latency
//! oracle.
//!
//! The engine models iteration-level (Orca/vLLM-style) scheduling in three
//! execution modes ([`ServeMode`]):
//!
//! * **Monolithic** — an iteration is either a whole-prompt **prefill** of
//!   the just-admitted requests (padded to the longest prompt, emitting
//!   each request's first token) or one **decode** step of the running
//!   batch; prefills take priority, which bounds TTFT under load at some
//!   cost to time-between-tokens.
//! * **Chunked** — Sarathi-style mixed iterations under a per-iteration
//!   token budget: every iteration decodes the whole running batch (one
//!   token each) and spends the remaining budget advancing waiting
//!   prompts in fixed-token chunks. No padding (chunks are exact token
//!   counts summed across requests) and decodes never stall behind long
//!   prefills. The fused iteration is modeled as
//!   `max(prefill(1, chunk_tokens), decode(batch, kv))`: one weight pass
//!   serves both the chunk's compute and the decode batch's bandwidth
//!   demand, so the iteration pays the greater of the two.
//! * **Disaggregated** — Splitwise-style phase splitting: a prefill pool
//!   and a decode pool of devices run their own iteration clocks, coupled
//!   by a *bounded* handoff queue whose entries become decodable only
//!   after a KV-transfer latency (LogGP peer-to-peer of the prompt KV
//!   bytes over the system interconnect, plus a fixed base). When the
//!   queue hits [`SchedulerConfig::handoff_capacity`] the prefill pool
//!   stalls (decode-pool backpressure) instead of queueing unboundedly;
//!   stall time is reported as [`RunStats::handoff_stall_s`].
//!
//! Orthogonally, [`Preemption`] picks the admission strategy:
//! `Conservative` reserves a request's full `prompt + output` KV footprint
//! up front (no preemption is ever needed); `Evict` admits optimistically
//! on the current footprint and, under KV pressure, evicts the
//! youngest-admitted sequence (vLLM-style recompute-on-resume: its KV is
//! dropped and the whole context is re-prefilled when capacity frees up).
//! Preemption counters are surfaced in [`RunStats`] and therefore in every
//! `ServeReport`/`EvalReport`.
//!
//! Iteration latencies come from the analytical simulator through the
//! quantizing [`SharedOracle`](super::oracle::SharedOracle) (resolved via
//! the simulator's [`OracleCache`](super::oracle::OracleCache), so fleet
//! replicas and sweep cells over unchanged hardware+model share one warm
//! cache), so a million-token trace touches only a handful of unique
//! mapper shapes, and the clock only ever advances by iteration
//! latencies, transfer completions, or idle gaps to the next arrival.

use super::events::EventHeap;
use super::fault::{FaultSpec, Faults, RecoveryPolicy, POOL_DECODE, POOL_PREFILL};
use super::metrics::RequestMetrics;
use super::oracle::SharedOracle;
use super::workload::Request;
use crate::graph::inference::Simulator;
use crate::graph::ModelConfig;
use crate::hardware::SystemSpec;
use crate::util::json::num;
use crate::util::telemetry::ScopedRecorder;
use std::collections::VecDeque;
use std::sync::Arc;

/// Admission-ordering policy for the waiting queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// First-come first-served (arrival order).
    Fcfs,
    /// Shortest-prompt-first: cheapest prefills jump the queue, trading
    /// worst-case fairness for lower mean TTFT under prefill pressure.
    ShortestPromptFirst,
}

impl Policy {
    pub fn parse(v: &str) -> Option<Policy> {
        match v {
            "fcfs" | "fifo" => Some(Policy::Fcfs),
            "spf" | "shortest-prompt-first" => Some(Policy::ShortestPromptFirst),
            _ => None,
        }
    }

    /// Canonical name, accepted back by [`Policy::parse`].
    pub fn name(self) -> &'static str {
        match self {
            Policy::Fcfs => "fcfs",
            Policy::ShortestPromptFirst => "spf",
        }
    }
}

/// Execution mode of the serving engine (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ServeMode {
    /// Whole-prompt prefill iterations, prefill-prioritized (v1 behavior).
    Monolithic,
    /// Mixed prefill+decode iterations under a per-iteration token budget
    /// of `chunk_tokens` (decode tokens consume the budget first; the
    /// remainder advances prompts in chunks).
    Chunked { chunk_tokens: u64 },
    /// Separate prefill and decode device pools coupled by a
    /// transfer-latency-modeled handoff queue. `prefill_devices == 0`
    /// means "half the system" (resolved by [`ServeMode::resolved`]);
    /// `transfer_base_s` is added to the modeled KV-transfer time.
    Disaggregated { prefill_devices: u64, transfer_base_s: f64 },
}

impl ServeMode {
    /// Canonical mode name (the scenario/CLI `mode` value).
    pub fn name(self) -> &'static str {
        match self {
            ServeMode::Monolithic => "monolithic",
            ServeMode::Chunked { .. } => "chunked",
            ServeMode::Disaggregated { .. } => "disaggregated",
        }
    }

    /// Resolve defaults against a concrete system: a zero
    /// `prefill_devices` becomes half the devices. Errors on configs that
    /// can never run (disaggregation needs ≥ 2 devices and at least one
    /// device per pool; chunked needs a positive budget).
    pub fn resolved(self, device_count: u64) -> Result<ServeMode, String> {
        match self {
            ServeMode::Monolithic => Ok(self),
            ServeMode::Chunked { chunk_tokens } => {
                if chunk_tokens == 0 {
                    return Err("chunked mode needs chunk_tokens ≥ 1".to_string());
                }
                Ok(self)
            }
            ServeMode::Disaggregated { prefill_devices, transfer_base_s } => {
                if device_count < 2 {
                    return Err(format!(
                        "disaggregated mode needs ≥ 2 devices, system has {device_count}"
                    ));
                }
                if !transfer_base_s.is_finite() || transfer_base_s < 0.0 {
                    return Err(format!(
                        "disaggregated transfer_base_s must be finite and ≥ 0, got {transfer_base_s}"
                    ));
                }
                let p = if prefill_devices == 0 { device_count / 2 } else { prefill_devices };
                if p >= device_count {
                    return Err(format!(
                        "disaggregated prefill_devices {p} leaves no decode devices \
                         (system has {device_count})"
                    ));
                }
                Ok(ServeMode::Disaggregated { prefill_devices: p, transfer_base_s })
            }
        }
    }
}

/// Admission strategy for KV-cache memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preemption {
    /// Reserve the full `prompt + output` footprint at admission; nothing
    /// is ever preempted (v1 behavior).
    Conservative,
    /// Admit on the current footprint and evict the youngest-admitted
    /// sequence under KV pressure; evicted sequences are re-prefilled over
    /// their whole context when re-admitted (recompute-on-resume).
    Evict,
}

impl Preemption {
    pub fn parse(v: &str) -> Option<Preemption> {
        match v {
            "conservative" | "none" => Some(Preemption::Conservative),
            "evict" | "recompute" => Some(Preemption::Evict),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Preemption::Conservative => "conservative",
            Preemption::Evict => "evict",
        }
    }
}

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Maximum concurrent sequences in the running batch (chunked mode
    /// counts in-progress prefills against this too).
    pub max_batch: u64,
    /// Cluster-wide KV-cache budget in tokens (see [`kv_capacity_tokens`]).
    /// Disaggregated mode splits it across the pools
    /// ([`SchedulerConfig::pool_budgets`]).
    pub kv_capacity_tokens: u64,
    pub policy: Policy,
    /// Maximum requests prefilled in one iteration (bounds padded prefill
    /// cost per iteration; in chunked mode, bounds the concurrent
    /// partial-prefill set).
    pub max_prefill_batch: u64,
    pub mode: ServeMode,
    pub preemption: Preemption,
    /// Disaggregated mode: bound on sequences sitting in the KV-handoff
    /// queue (prefilled, not yet admitted to the decode pool). When the
    /// queue is full the prefill pool *stalls* instead of racing ahead of
    /// the decode pool unboundedly; stall time is surfaced as
    /// [`RunStats::handoff_stall_s`]. `None` derives the decode pool's KV
    /// budget measured in mean-trace-length sequences.
    pub handoff_capacity: Option<u64>,
    /// Fault-injection schedule + recovery policy (`None`: a perfect
    /// fleet — identical behavior to a zero-event [`FaultSpec`]). Behind
    /// an `Arc` so fleet replicas and sweep cells share one parsed spec
    /// instead of deep-cloning it per engine run.
    pub faults: Option<Arc<FaultSpec>>,
}

impl SchedulerConfig {
    /// Derive a configuration from hardware + model: KV budget from memory
    /// capacity, batch cap from a target per-iteration concurrency.
    /// Defaults to monolithic execution with conservative admission.
    pub fn for_system(sys: &SystemSpec, model: &ModelConfig, policy: Policy) -> SchedulerConfig {
        SchedulerConfig {
            max_batch: 64,
            kv_capacity_tokens: kv_capacity_tokens(sys, model),
            policy,
            max_prefill_batch: 8,
            mode: ServeMode::Monolithic,
            preemption: Preemption::Conservative,
            handoff_capacity: None,
            faults: None,
        }
    }

    /// (prefill pool, decode pool) KV budgets in disaggregated mode: the
    /// cluster budget split proportionally to the pool device counts.
    /// (This ignores that each pool replicates the weights — a deliberate
    /// simplification so caller-set budgets keep meaning something; the
    /// error is ≤ the weight share of one pool's memory.) For other modes
    /// both slots are the whole budget.
    pub fn pool_budgets(&self, device_count: u64) -> (u64, u64) {
        match self.mode {
            ServeMode::Disaggregated { prefill_devices, .. } => {
                let p = prefill_devices.min(device_count.saturating_sub(1)).max(1);
                let pre = self.kv_capacity_tokens * p / device_count.max(1);
                (pre, self.kv_capacity_tokens - pre)
            }
            _ => (self.kv_capacity_tokens, self.kv_capacity_tokens),
        }
    }
}

/// Cluster-wide KV-cache token budget under tensor parallelism: every
/// device holds `params / tp` resident weight bytes and `kv_per_token / tp`
/// per cached token, so the binding constraint is per-device free memory:
///
/// `tokens = tp · (capacity − params/tp) / kv_bytes_per_token`.
///
/// Returns 0 when the shard of parameters alone overflows a device.
pub fn kv_capacity_tokens(sys: &SystemSpec, model: &ModelConfig) -> u64 {
    let tp = sys.device_count.max(1);
    let cap = sys.device.memory.capacity_bytes as f64;
    let params_per_dev = model.param_bytes(model.layers) as f64 / tp as f64;
    if params_per_dev >= cap {
        return 0;
    }
    let kv_per_token = (model.kv_bytes_per_token_per_layer() * model.layers) as f64;
    ((cap - params_per_dev) * tp as f64 / kv_per_token).floor() as u64
}

/// Validate a configuration against a trace before simulating. The
/// simulator asserts the same conditions; callers that load user input
/// (scenario files, CLI flags) should call this first to get an error
/// instead of a panic.
pub fn validate(
    cfg: &SchedulerConfig,
    device_count: u64,
    requests: &[Request],
) -> Result<(), String> {
    if cfg.max_batch == 0 {
        return Err("max_batch must be ≥ 1".to_string());
    }
    if cfg.max_prefill_batch == 0 {
        return Err("max_prefill_batch must be ≥ 1".to_string());
    }
    if cfg.handoff_capacity == Some(0) {
        return Err("handoff_capacity must be ≥ 1".to_string());
    }
    if let Some(spec) = &cfg.faults {
        spec.validate()?;
    }
    let mode = cfg.mode.resolved(device_count)?;
    let (pre_cap, dec_cap) = SchedulerConfig { mode, ..cfg.clone() }.pool_budgets(device_count);
    for r in requests {
        if r.total_tokens() > dec_cap {
            return Err(format!(
                "request {} needs {} KV tokens but the {} budget is {} — \
                 it can never be admitted",
                r.id,
                r.total_tokens(),
                if matches!(mode, ServeMode::Disaggregated { .. }) {
                    "decode pool"
                } else {
                    "cluster"
                },
                dec_cap
            ));
        }
        if matches!(mode, ServeMode::Disaggregated { .. }) {
            // Under eviction a preempted request recomputes its whole
            // context (up to `total − 1` tokens) on the prefill pool, so
            // the pool must fit the final footprint, not just the prompt.
            let pre_need = match cfg.preemption {
                Preemption::Conservative => r.prompt_tokens + 1,
                Preemption::Evict => r.total_tokens(),
            };
            if pre_need > pre_cap {
                return Err(format!(
                    "request {} needs {} prefill KV tokens but the prefill pool budget is {} — \
                     it can never be admitted",
                    r.id, pre_need, pre_cap
                ));
            }
        }
    }
    Ok(())
}

/// Per-iteration accounting of the simulated run. All fields are part of
/// the stable serving-report schema (golden-locked): new fields may be
/// appended, existing ones keep their meaning.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Pure-prefill iterations (monolithic prefills, decode-free chunk
    /// iterations, prefill-pool iterations in disaggregated mode).
    pub prefill_iterations: u64,
    /// Pure-decode iterations.
    pub decode_iterations: u64,
    /// Chunked-mode iterations that carried both prefill and decode work.
    pub mixed_iterations: u64,
    pub prefill_busy_s: f64,
    pub decode_busy_s: f64,
    /// Busy time of mixed (chunk + decode) iterations.
    pub mixed_busy_s: f64,
    pub idle_s: f64,
    /// Peak KV tokens reserved at any point (decode pool in disaggregated
    /// mode), sampled at the per-iteration high-water mark.
    pub peak_kv_tokens: u64,
    /// Peak KV tokens held by the prefill pool (0 outside disaggregated
    /// mode).
    pub prefill_peak_kv_tokens: u64,
    /// Peak concurrent sequences in flight (running + just admitted).
    pub peak_batch: u64,
    /// Preemption events (a sequence evicted under KV pressure).
    pub preemptions: u64,
    /// Distinct requests preempted at least once.
    pub preempted_requests: u64,
    /// Context tokens dropped at preemption that must be re-prefilled on
    /// resume (the recompute bill of `Preemption::Evict`).
    pub recompute_tokens: u64,
    /// Total modeled KV handoff time in disaggregated mode (sum over
    /// requests; transfers overlap, so this is work, not wall-clock).
    pub transfer_total_s: f64,
    /// Time requests spent transfer-complete but not yet admitted to the
    /// decode pool (handoff queueing).
    pub handoff_wait_s: f64,
    /// Time the prefill pool spent stalled because the bounded handoff
    /// queue was full (decode-pool backpressure; 0 outside disaggregated
    /// mode or when the queue never fills).
    pub handoff_stall_s: f64,
    /// Wall-clock of the simulated run (last completion time).
    pub makespan_s: f64,
    /// Fault events whose window opened during the run (explicit
    /// [`FaultSpec`] events + MTBF-generated crashes).
    pub faults_injected: u64,
    /// Requests dropped for good: a crash exhausted their retry budget,
    /// or they exceeded the recovery policy's queue timeout.
    pub requests_lost: u64,
    /// Distinct requests re-dispatched at least once after losing KV
    /// state to a crash.
    pub requests_retried: u64,
    /// Fresh arrivals refused by admission shedding.
    pub requests_shed: u64,
    /// Context tokens dropped by crashes that retries must re-prefill
    /// (the fault twin of `recompute_tokens`).
    pub retry_tokens_recomputed: u64,
    /// Wall-clock with at least one pool inside a crash or drain window
    /// (union of outage windows, clipped to the makespan).
    pub fault_downtime_s: f64,
    /// `1 − fault_downtime_s / makespan_s` — exactly 1.0 in fault-free
    /// runs.
    pub availability: f64,
}

impl RunStats {
    /// Stable JSON rendering (part of the `eval` report schema).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num, obj};
        obj(vec![
            ("prefill_iterations", num(self.prefill_iterations as f64)),
            ("decode_iterations", num(self.decode_iterations as f64)),
            ("mixed_iterations", num(self.mixed_iterations as f64)),
            ("prefill_busy_s", num(self.prefill_busy_s)),
            ("decode_busy_s", num(self.decode_busy_s)),
            ("mixed_busy_s", num(self.mixed_busy_s)),
            ("idle_s", num(self.idle_s)),
            ("peak_kv_tokens", num(self.peak_kv_tokens as f64)),
            ("prefill_peak_kv_tokens", num(self.prefill_peak_kv_tokens as f64)),
            ("peak_batch", num(self.peak_batch as f64)),
            ("preemptions", num(self.preemptions as f64)),
            ("preempted_requests", num(self.preempted_requests as f64)),
            ("recompute_tokens", num(self.recompute_tokens as f64)),
            ("transfer_total_s", num(self.transfer_total_s)),
            ("handoff_wait_s", num(self.handoff_wait_s)),
            ("handoff_stall_s", num(self.handoff_stall_s)),
            ("makespan_s", num(self.makespan_s)),
            ("faults_injected", num(self.faults_injected as f64)),
            ("requests_lost", num(self.requests_lost as f64)),
            ("requests_retried", num(self.requests_retried as f64)),
            ("requests_shed", num(self.requests_shed as f64)),
            ("retry_tokens_recomputed", num(self.retry_tokens_recomputed as f64)),
            ("fault_downtime_s", num(self.fault_downtime_s)),
            ("availability", num(self.availability)),
        ])
    }
}

/// How one request ended, as seen by the engine that ran it. The fleet
/// layer consumes these to decide which losses to re-dispatch to a
/// surviving replica; the public [`simulate`] entry point discards them
/// (its per-request story is told by which [`RequestMetrics`] survive).
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum Outcome {
    /// Generated all its tokens.
    Completed,
    /// Dropped for good at `at_s`. `crash_kv` is `Some(kv_built)` when a
    /// crash killed it (the KV tokens it had built, i.e. what a re-dispatch
    /// must re-prefill); `None` when it exceeded the queue timeout.
    Lost { at_s: f64, crash_kv: Option<u64> },
    /// Refused at arrival by admission shedding.
    Shed { at_s: f64 },
}

/// The decode-side in-flight set, in SoA layout: parallel columns keyed
/// by position, so the hot per-iteration scans (KV totals, youngest-
/// serial eviction, completion sweeps) stream dense `u64` vectors
/// instead of striding through structs. Mutators keep the columns in
/// lockstep; position-based `remove`/`swap_remove` mirror the `Vec`
/// methods the AoS version used, byte for byte in iteration order.
#[derive(Default)]
struct RunningSet {
    /// Request index into the trace.
    idx: Vec<usize>,
    /// Current KV footprint in tokens.
    kv_tokens: Vec<u64>,
    /// Monotone admission serial — eviction targets the youngest.
    serial: Vec<u64>,
}

impl RunningSet {
    fn len(&self) -> usize {
        self.idx.len()
    }

    fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    fn push(&mut self, idx: usize, kv_tokens: u64, serial: u64) {
        self.idx.push(idx);
        self.kv_tokens.push(kv_tokens);
        self.serial.push(serial);
    }

    /// Remove position `j` preserving order, returning its columns.
    fn remove(&mut self, j: usize) -> (usize, u64, u64) {
        (self.idx.remove(j), self.kv_tokens.remove(j), self.serial.remove(j))
    }

    /// O(1) removal for completion sweeps (matches the AoS
    /// `Vec::swap_remove` scan order exactly).
    fn swap_remove(&mut self, j: usize) -> (usize, u64, u64) {
        (self.idx.swap_remove(j), self.kv_tokens.swap_remove(j), self.serial.swap_remove(j))
    }

    fn clear(&mut self) {
        self.idx.clear();
        self.kv_tokens.clear();
        self.serial.clear();
    }

    fn kv_total(&self) -> u64 {
        self.kv_tokens.iter().sum()
    }

    /// Position and serial of the youngest-admitted sequence. Ties keep
    /// the *last* maximum, mirroring `Iterator::max_by_key` (serials are
    /// unique in practice, but the tie-break is part of the byte-identity
    /// contract).
    fn youngest_with_serial(&self) -> Option<(usize, u64)> {
        let mut best: Option<(usize, u64)> = None;
        for (j, &s) in self.serial.iter().enumerate() {
            if best.map_or(true, |(_, bs)| s >= bs) {
                best = Some((j, s));
            }
        }
        best
    }

    fn youngest(&self) -> Option<usize> {
        self.youngest_with_serial().map(|(j, _)| j)
    }
}

/// Requests part-way through a chunked prefill, in the same SoA layout.
#[derive(Default)]
struct PrefillSet {
    idx: Vec<usize>,
    /// Context tokens processed so far (target: `prompt + generated`).
    done: Vec<u64>,
    serial: Vec<u64>,
}

impl PrefillSet {
    fn len(&self) -> usize {
        self.idx.len()
    }

    fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    fn push(&mut self, idx: usize, done: u64, serial: u64) {
        self.idx.push(idx);
        self.done.push(done);
        self.serial.push(serial);
    }

    fn remove(&mut self, j: usize) -> (usize, u64, u64) {
        (self.idx.remove(j), self.done.remove(j), self.serial.remove(j))
    }

    fn clear(&mut self) {
        self.idx.clear();
        self.done.clear();
        self.serial.clear();
    }

    /// Last-max-serial position, mirroring `max_by_key` (see
    /// [`RunningSet::youngest_with_serial`]).
    fn youngest_with_serial(&self) -> Option<(usize, u64)> {
        let mut best: Option<(usize, u64)> = None;
        for (j, &s) in self.serial.iter().enumerate() {
            if best.map_or(true, |(_, bs)| s >= bs) {
                best = Some((j, s));
            }
        }
        best
    }
}

/// Shared per-run state: request-indexed progress that survives
/// preemption, plus the output accumulators.
struct RunState<'a> {
    cfg: &'a SchedulerConfig,
    requests: &'a [Request],
    /// Telemetry recorder (no-op when disabled). Lifecycle spans and
    /// preemption instants are emitted here so all three engines share
    /// one instrumentation vocabulary. Scoped so a fleet replica's
    /// tracks carry a "replica N " prefix; single-pool runs use an
    /// empty prefix (byte-identical to recording directly).
    rec: &'a ScopedRecorder<'a>,
    metrics: Vec<RequestMetrics>,
    stats: RunStats,
    /// Tokens generated so far per request (survives preemption).
    generated: Vec<u64>,
    preempted_ever: Vec<bool>,
    /// When the request last became runnable — its arrival, or the
    /// moment it was preempted back to the queue. Start of the current
    /// "queued" trace span.
    queued_since: Vec<f64>,
    /// When the request last became decodable (prefill completion, or
    /// decode-pool admission in disaggregated mode). Start of the
    /// "decode" trace span.
    decode_from: Vec<f64>,
    completed: usize,
    serial: u64,
    /// Crash re-dispatches consumed per request (bounded by the recovery
    /// policy's `max_retries`).
    retries: Vec<u64>,
    /// Dropped for good: retry budget exhausted or queue timeout.
    lost: Vec<bool>,
    /// When the request was lost (NaN while not lost), and the KV it had
    /// built if a crash (rather than a timeout) killed it — the fleet
    /// layer re-dispatches crash losses and bills the rebuilt KV.
    lost_at: Vec<f64>,
    lost_crash_kv: Vec<Option<u64>>,
    /// Refused at arrival by admission shedding.
    shed: Vec<bool>,
    /// When the request was shed (NaN while not shed).
    shed_at: Vec<f64>,
    /// `lost.count(true) + shed.count(true)` — settled-without-finishing.
    lost_or_shed: usize,
}

impl<'a> RunState<'a> {
    fn new(
        cfg: &'a SchedulerConfig,
        requests: &'a [Request],
        rec: &'a ScopedRecorder<'a>,
    ) -> Self {
        let metrics = requests
            .iter()
            .map(|r| RequestMetrics {
                id: r.id,
                arrival_s: r.arrival_s,
                prompt_tokens: r.prompt_tokens,
                output_tokens: r.output_tokens,
                first_token_s: f64::NAN,
                finish_s: f64::NAN,
                faulted: false,
            })
            .collect();
        RunState {
            cfg,
            requests,
            rec,
            metrics,
            stats: RunStats::default(),
            generated: vec![0; requests.len()],
            preempted_ever: vec![false; requests.len()],
            queued_since: requests.iter().map(|r| r.arrival_s).collect(),
            decode_from: vec![0.0; requests.len()],
            completed: 0,
            serial: 0,
            retries: vec![0; requests.len()],
            lost: vec![false; requests.len()],
            lost_at: vec![f64::NAN; requests.len()],
            lost_crash_kv: vec![None; requests.len()],
            shed: vec![false; requests.len()],
            shed_at: vec![f64::NAN; requests.len()],
            lost_or_shed: 0,
        }
    }

    /// Requests that need no further work: finished, lost, or shed. The
    /// engines loop until every request is settled.
    fn settled(&self) -> usize {
        self.completed + self.lost_or_shed
    }

    /// Per-request trace track name.
    fn track(&self, i: usize) -> String {
        format!("req {}", self.requests[i].id)
    }

    /// Trace the "queued" lifecycle span ending at admission time `t`
    /// (start: arrival, or the preemption that re-queued the request).
    fn emit_admitted(&self, i: usize, t: f64) {
        if self.rec.is_enabled() {
            self.rec.span_sim(
                &self.track(i),
                "queued",
                self.queued_since[i].min(t),
                t,
                &[
                    ("prompt_tokens", num(self.requests[i].prompt_tokens as f64)),
                    ("output_tokens", num(self.requests[i].output_tokens as f64)),
                ],
            );
        }
    }

    /// Trace a prefill-work span (`name`: "prefill" or "chunk") covering
    /// `tokens` context tokens between `t0` and `t1`.
    fn emit_prefill_span(&self, i: usize, name: &str, t0: f64, t1: f64, tokens: u64) {
        if self.rec.is_enabled() {
            self.rec.span_sim(
                &self.track(i),
                name,
                t0,
                t1,
                &[("tokens", num(tokens as f64))],
            );
        }
    }

    /// Trace the "decode" lifecycle span at completion time `t`.
    fn emit_done(&self, i: usize, t: f64) {
        if self.rec.is_enabled() {
            let track = self.track(i);
            self.rec.span_sim(
                &track,
                "decode",
                self.decode_from[i].min(t),
                t,
                &[("generated", num(self.generated[i] as f64))],
            );
            self.rec.instant_sim(&track, "done", t, &[]);
        }
    }

    fn next_serial(&mut self) -> u64 {
        self.serial += 1;
        self.serial
    }

    /// The context length a (re-)prefill of request `i` must process.
    fn prefill_target(&self, i: usize) -> u64 {
        self.requests[i].prompt_tokens + self.generated[i]
    }

    /// KV tokens reserved when admitting request `i` under the preemption
    /// strategy (conservative: final footprint; evict: post-prefill
    /// footprint only).
    fn admit_need(&self, i: usize) -> u64 {
        match self.cfg.preemption {
            Preemption::Conservative => self.requests[i].total_tokens(),
            Preemption::Evict => self.prefill_target(i) + 1,
        }
    }

    /// Record a prefill completion at time `t`: emits one token, returns
    /// `Some(kv_tokens)` when the request continues into decode, `None`
    /// when it finished (prefill's own logits were the whole answer).
    fn finish_prefill(&mut self, i: usize, t: f64) -> Option<u64> {
        if self.generated[i] == 0 {
            self.metrics[i].first_token_s = t;
            if self.rec.is_enabled() {
                self.rec.instant_sim(&self.track(i), "first_token", t, &[]);
            }
        }
        self.generated[i] += 1;
        self.decode_from[i] = t;
        let kv = self.prefill_target(i); // prompt + generated
        if self.generated[i] >= self.requests[i].output_tokens {
            self.metrics[i].finish_s = t;
            self.completed += 1;
            if self.rec.is_enabled() {
                self.rec.instant_sim(&self.track(i), "done", t, &[]);
            }
            None
        } else {
            Some(kv)
        }
    }

    /// Record a preemption at time `t` of a sequence holding `kv` tokens.
    fn note_preemption(&mut self, idx: usize, kv: u64, t: f64) {
        self.stats.preemptions += 1;
        self.stats.recompute_tokens += kv;
        if !self.preempted_ever[idx] {
            self.preempted_ever[idx] = true;
            self.stats.preempted_requests += 1;
        }
        if self.rec.is_enabled() {
            self.rec.instant_sim(
                &self.track(idx),
                "preempt",
                t,
                &[("kv_tokens", num(kv as f64))],
            );
        }
        self.queued_since[idx] = t;
    }

    /// KV released when a request completes (mirror of the reservation).
    fn release_on_completion(&self, i: usize) -> u64 {
        match self.cfg.preemption {
            Preemption::Conservative => self.requests[i].total_tokens(),
            Preemption::Evict => self.prefill_target(i), // == current kv
        }
    }

    /// A crash dropped request `i`'s built KV (`kv_built` tokens) at
    /// time `t`; the pool rejoins at `rejoin`. While retry budget
    /// remains the request is re-dispatched through the retry queue with
    /// exponential backoff (and must re-prefill from scratch — all
    /// generation progress is gone); beyond the budget it is lost.
    fn crash_request(
        &mut self,
        i: usize,
        kv_built: u64,
        t: f64,
        rejoin: f64,
        recovery: &RecoveryPolicy,
        retry_q: &mut Vec<(f64, usize)>,
    ) {
        self.metrics[i].faulted = true;
        self.metrics[i].first_token_s = f64::NAN;
        self.generated[i] = 0;
        if self.rec.is_enabled() {
            self.rec.instant_sim(
                &self.track(i),
                "crash",
                t,
                &[("kv_tokens", num(kv_built as f64))],
            );
        }
        if self.retries[i] < recovery.max_retries {
            self.retries[i] += 1;
            if self.retries[i] == 1 {
                self.stats.requests_retried += 1;
            }
            self.stats.retry_tokens_recomputed += kv_built;
            let backoff =
                recovery.retry_backoff_s * (1u64 << (self.retries[i] - 1).min(62)) as f64;
            let ready = rejoin.max(t) + backoff;
            self.queued_since[i] = ready;
            retry_q.push((ready, i));
        } else {
            self.lost[i] = true;
            self.lost_at[i] = t;
            self.lost_crash_kv[i] = Some(kv_built);
            self.lost_or_shed += 1;
            self.stats.requests_lost += 1;
            if self.rec.is_enabled() {
                self.rec.instant_sim(&self.track(i), "lost", t, &[]);
            }
        }
    }

    /// Admission shedding refused fresh arrival `i` at time `t`.
    fn shed_request(&mut self, i: usize, t: f64) {
        self.shed[i] = true;
        self.shed_at[i] = t;
        self.lost_or_shed += 1;
        self.stats.requests_shed += 1;
        if self.rec.is_enabled() {
            self.rec.instant_sim(&self.track(i), "shed", t, &[]);
        }
    }

    /// Request `i` exceeded the recovery policy's queue deadline at `t`.
    fn lose_to_timeout(&mut self, i: usize, t: f64) {
        self.lost[i] = true;
        self.lost_at[i] = t;
        self.lost_or_shed += 1;
        self.stats.requests_lost += 1;
        if self.rec.is_enabled() {
            self.rec.instant_sim(&self.track(i), "timeout", t, &[]);
        }
    }

    /// Close out fault accounting against the final makespan and build
    /// the report: lost/shed requests are dropped from the metrics (they
    /// produced no tokens) and live on only in the stats counters and
    /// the per-request [`Outcome`] list (in input order).
    fn into_results(self, f: &mut Faults) -> (Vec<RequestMetrics>, RunStats, Vec<Outcome>) {
        let mut stats = self.stats;
        let makespan = stats.makespan_s;
        stats.faults_injected = f.injected_count(makespan);
        stats.fault_downtime_s = f.downtime_in(makespan);
        // A zero-span run (no requests, or nothing ever started) had no
        // window to be unavailable in: report availability 1.0, never
        // 0/0 = NaN.
        stats.availability = if makespan > 0.0 {
            ((makespan - stats.fault_downtime_s) / makespan).max(0.0)
        } else {
            1.0
        };
        debug_assert_eq!(
            self.completed + self.lost_or_shed,
            self.requests.len(),
            "request accounting does not conserve"
        );
        let outcomes = (0..self.requests.len())
            .map(|i| {
                if self.lost[i] {
                    Outcome::Lost { at_s: self.lost_at[i], crash_kv: self.lost_crash_kv[i] }
                } else if self.shed[i] {
                    Outcome::Shed { at_s: self.shed_at[i] }
                } else {
                    Outcome::Completed
                }
            })
            .collect();
        let metrics = self
            .metrics
            .into_iter()
            .zip(self.lost.iter().zip(self.shed.iter()))
            .filter(|(_, (&l, &s))| !l && !s)
            .map(|(m, _)| m)
            .collect();
        (metrics, stats, outcomes)
    }
}

/// Policy-ordered waiting queue of request indices. Preempted requests
/// resume through a separate FIFO that admission always drains first.
/// Both lanes are ring buffers: `pop` is O(1) instead of the O(n)
/// front-shift a `Vec::remove(0)` pays on every admission.
struct WaitQueue {
    policy: Policy,
    waiting: VecDeque<usize>,
    resume: VecDeque<usize>,
}

impl WaitQueue {
    fn new(policy: Policy) -> Self {
        WaitQueue { policy, waiting: VecDeque::new(), resume: VecDeque::new() }
    }

    /// Enqueue a fresh arrival, keeping `waiting` in policy order as it
    /// grows: FCFS appends (arrival order), SPF inserts at the
    /// (prompt, id)-sorted position — same order a stable sort by that key
    /// would give, without re-sorting the backlog every iteration.
    fn arrive(&mut self, idx: usize, requests: &[Request]) {
        match self.policy {
            Policy::Fcfs => self.waiting.push_back(idx),
            Policy::ShortestPromptFirst => {
                let key = (requests[idx].prompt_tokens, idx);
                let pos =
                    self.waiting.partition_point(|&i| (requests[i].prompt_tokens, i) < key);
                self.waiting.insert(pos, idx);
            }
        }
    }

    fn requeue_preempted(&mut self, idx: usize) {
        self.resume.push_back(idx);
    }

    fn is_empty(&self) -> bool {
        self.waiting.is_empty() && self.resume.is_empty()
    }

    /// Depth of the backlog (waiting + resume) — the admission-shedding
    /// pressure signal.
    fn len(&self) -> usize {
        self.waiting.len() + self.resume.len()
    }

    /// Drop every queued request whose time since arrival exceeds
    /// `timeout` (the recovery policy's per-request deadline), appending
    /// the dropped indices to `dropped` (a caller-owned buffer reused
    /// across iterations instead of a fresh allocation per call).
    fn drop_timed_out(
        &mut self,
        t: f64,
        timeout: f64,
        requests: &[Request],
        dropped: &mut Vec<usize>,
    ) {
        self.waiting.retain(|&i| {
            let keep = t - requests[i].arrival_s <= timeout;
            if !keep {
                dropped.push(i);
            }
            keep
        });
        self.resume.retain(|&i| {
            let keep = t - requests[i].arrival_s <= timeout;
            if !keep {
                dropped.push(i);
            }
            keep
        });
    }

    fn peek(&self) -> Option<usize> {
        self.resume.front().copied().or_else(|| self.waiting.front().copied())
    }

    fn pop(&mut self) -> Option<usize> {
        self.resume.pop_front().or_else(|| self.waiting.pop_front())
    }
}

/// Move crash retries whose backoff has elapsed back into the waiting
/// queue, through the resume lane — a retried request was admitted once
/// already, so it outranks fresh arrivals.
fn drain_retries(retry_q: &mut Vec<(f64, usize)>, t: f64, queue: &mut WaitQueue) {
    let mut k = 0;
    while k < retry_q.len() {
        if retry_q[k].0 <= t {
            let (_, idx) = retry_q.remove(k);
            queue.requeue_preempted(idx);
        } else {
            k += 1;
        }
    }
}

/// Evict the youngest-admitted sequences until the batch's decode growth
/// (+1 KV token per surviving sequence) fits `capacity`, leaving at least
/// one sequence running. The growth re-shrinks with every eviction, so
/// the bound is recomputed each pass. Evicted indices are appended to
/// `evicted` (a caller-owned buffer; the caller pushes them to the
/// resume queue).
fn evict_for(
    state: &mut RunState<'_>,
    running: &mut RunningSet,
    kv_reserved: &mut u64,
    capacity: u64,
    t: f64,
    evicted: &mut Vec<usize>,
) {
    while *kv_reserved + running.len() as u64 > capacity && running.len() > 1 {
        let j = running.youngest().unwrap();
        let (idx, kv, _) = running.remove(j);
        *kv_reserved -= kv;
        state.note_preemption(idx, kv, t);
        evicted.push(idx);
    }
}

/// Simulate serving `requests` (sorted by arrival) on the cluster.
/// Returns per-request metrics (in input order) plus run statistics.
/// Panics on configurations [`validate`] rejects — callers evaluating
/// user input should validate first.
pub fn simulate(
    sim: &Simulator,
    sys: &SystemSpec,
    model: &ModelConfig,
    cfg: &SchedulerConfig,
    requests: &[Request],
) -> (Vec<RequestMetrics>, RunStats) {
    let rec = ScopedRecorder::new(&sim.recorder, "");
    let (metrics, stats, _) = simulate_scoped(sim, sys, model, cfg, requests, &rec);
    (metrics, stats)
}

/// [`simulate`] with an explicit (possibly track-prefixed) recorder, also
/// returning each request's [`Outcome`]. The fleet layer runs replica
/// engines through here: probe runs against a disabled recorder, the
/// final authoritative pass against the real one under a "replica N "
/// prefix. `simulate` itself is this with the simulator's own recorder
/// and an empty prefix.
pub(crate) fn simulate_scoped(
    sim: &Simulator,
    sys: &SystemSpec,
    model: &ModelConfig,
    cfg: &SchedulerConfig,
    requests: &[Request],
    rec: &ScopedRecorder<'_>,
) -> (Vec<RequestMetrics>, RunStats, Vec<Outcome>) {
    if let Err(e) = validate(cfg, sys.device_count, requests) {
        panic!("{e}");
    }
    let mode = cfg.mode.resolved(sys.device_count).unwrap();
    // Scheduled fault windows go on their own trace track up front; MTBF
    // crashes are emitted as they land (they are generated lazily).
    if rec.is_enabled() {
        if let Some(spec) = &cfg.faults {
            for e in &spec.events {
                let target_name = e.target.name();
                rec.span_sim(
                    "faults",
                    e.kind.name(),
                    e.at_s,
                    e.at_s + e.duration_s,
                    &[("target", crate::util::json::s(&target_name))],
                );
            }
        }
    }
    match mode {
        ServeMode::Monolithic => {
            let oracle = sim.oracles.for_system(sys, model);
            run_monolithic(sim, &oracle, cfg, requests, rec)
        }
        ServeMode::Chunked { chunk_tokens } => {
            let oracle = sim.oracles.for_system(sys, model);
            run_chunked(sim, &oracle, cfg, requests, chunk_tokens, rec)
        }
        ServeMode::Disaggregated { prefill_devices, transfer_base_s } => run_disaggregated(
            sim,
            sys,
            model,
            cfg,
            requests,
            prefill_devices,
            transfer_base_s,
            rec,
        ),
    }
}

/// A sub-pool of the system: same device and interconnect, fewer of them.
fn sub_system(sys: &SystemSpec, device_count: u64) -> SystemSpec {
    SystemSpec { device: sys.device.clone(), device_count, interconnect: sys.interconnect.clone() }
}

// ---------------------------------------------------------------------------
// Monolithic engine (v1 semantics + optional eviction)
// ---------------------------------------------------------------------------

fn run_monolithic(
    sim: &Simulator,
    oracle: &SharedOracle,
    cfg: &SchedulerConfig,
    requests: &[Request],
    rec: &ScopedRecorder<'_>,
) -> (Vec<RequestMetrics>, RunStats, Vec<Outcome>) {
    // Borrow the fault spec through the Arc instead of deep-cloning the
    // whole schedule per engine run (fleets run one engine per replica).
    let no_faults = FaultSpec::none();
    let spec: &FaultSpec = cfg.faults.as_deref().unwrap_or(&no_faults);
    let mut f = Faults::new(spec, true);
    let mut retry_q: Vec<(f64, usize)> = Vec::new();
    let mut state = RunState::new(cfg, requests, rec);
    let mut queue = WaitQueue::new(cfg.policy);
    let mut running = RunningSet::default();
    let mut kv_reserved = 0u64;
    let mut t = 0.0f64;
    let mut next_arrival = 0usize;
    // Scratch buffers reused across iterations (cleared, never
    // reallocated on the hot path).
    let mut admitted: Vec<usize> = Vec::new();
    let mut dropped: Vec<usize> = Vec::new();
    let mut evicted: Vec<usize> = Vec::new();

    while state.settled() < requests.len() {
        // 0. Faults: crashes land at iteration boundaries — the in-flight
        //    iteration (modeled atomically) finishes, then the pool loses
        //    its KV state and admits nothing until the window ends.
        let mut crashed = false;
        while let Some((tc, rec_end)) = f.pending_crash(t, POOL_PREFILL) {
            if rec.is_enabled() {
                rec.instant_sim("faults", "crash", tc, &[]);
                rec.span_sim("faults", "downtime", tc, rec_end, &[]);
            }
            for j in 0..running.len() {
                state.crash_request(
                    running.idx[j],
                    running.kv_tokens[j],
                    tc,
                    rec_end,
                    &f.recovery,
                    &mut retry_q,
                );
            }
            running.clear();
            kv_reserved = 0;
            state.stats.idle_s += (rec_end - t).max(0.0);
            t = t.max(rec_end);
            crashed = true;
        }
        if crashed {
            continue;
        }

        // 1. Ingest arrivals up to the current clock (shedding fresh
        //    arrivals while the backlog is over the pressure bound), plus
        //    crashed requests whose retry backoff has elapsed; then drop
        //    whatever has overstayed the queue deadline.
        while next_arrival < requests.len() && requests[next_arrival].arrival_s <= t {
            let over = f
                .recovery
                .shed_queue_depth
                .map(|d| queue.len() as u64 >= d)
                .unwrap_or(false);
            if over {
                state.shed_request(next_arrival, requests[next_arrival].arrival_s);
            } else {
                queue.arrive(next_arrival, requests);
            }
            next_arrival += 1;
        }
        drain_retries(&mut retry_q, t, &mut queue);
        if let Some(timeout) = f.recovery.request_timeout_s {
            dropped.clear();
            queue.drop_timed_out(t, timeout, requests, &mut dropped);
            for &idx in &dropped {
                state.lose_to_timeout(idx, t);
            }
        }

        // 2. Admit from the waiting queue under the KV budget + batch cap.
        //    Admission is greedy in queue order (no skipping ahead past a
        //    request that does not fit — FCFS head-of-line blocking is
        //    part of what the policy choice is about). Preempted requests
        //    resume first. A crash/drain window suspends admission.
        let can_admit = f.admitting(t, POOL_PREFILL);
        admitted.clear();
        while can_admit
            && admitted.len() < cfg.max_prefill_batch as usize
            && running.len() + admitted.len() < cfg.max_batch as usize
        {
            let Some(cand) = queue.peek() else { break };
            let need = state.admit_need(cand);
            if kv_reserved + need > cfg.kv_capacity_tokens {
                break;
            }
            kv_reserved += need;
            admitted.push(cand);
            queue.pop();
        }

        // Peaks are sampled here — reservations for this iteration are all
        // taken and nothing has completed yet, so this is the true
        // high-water mark (completions release KV later in the loop).
        state.stats.peak_kv_tokens = state.stats.peak_kv_tokens.max(kv_reserved);
        state.stats.peak_batch =
            state.stats.peak_batch.max((running.len() + admitted.len()) as u64);
        rec.counter_sim("kv_tokens", t, kv_reserved as f64);
        rec.counter_sim("batch", t, (running.len() + admitted.len()) as f64);

        if !admitted.is_empty() {
            // 3a. Prefill iteration for the admitted requests (padded to
            // the longest context — a resumed request re-prefills its
            // whole prompt + generated prefix). Emits each one's next
            // token.
            let batch = admitted.len() as u64;
            let max_ctx = admitted.iter().map(|&i| state.prefill_target(i)).max().unwrap();
            let t0 = t;
            let dt = oracle.prefill(sim, batch, max_ctx) * f.latency_mult(t0, POOL_PREFILL);
            t += dt;
            state.stats.prefill_iterations += 1;
            state.stats.prefill_busy_s += dt;
            if rec.is_enabled() {
                rec.span_sim(
                    "engine",
                    "prefill",
                    t0,
                    t,
                    &[("batch", num(batch as f64)), ("max_ctx", num(max_ctx as f64))],
                );
            }
            for &i in &admitted {
                let reserved = state.admit_need(i);
                state.emit_admitted(i, t0);
                state.emit_prefill_span(i, "prefill", t0, t, state.prefill_target(i));
                match state.finish_prefill(i, t) {
                    Some(kv_tokens) => {
                        debug_assert!(
                            cfg.preemption == Preemption::Conservative || reserved == kv_tokens
                        );
                        let serial = state.next_serial();
                        running.push(i, kv_tokens, serial);
                    }
                    None => kv_reserved -= reserved.min(kv_reserved),
                }
            }
        } else if !running.is_empty() {
            // 3b. One decode step of the whole running batch. Under
            // eviction, first make room for this step's +1-token-per-
            // sequence KV growth by preempting the youngest sequences.
            if cfg.preemption == Preemption::Evict {
                evicted.clear();
                evict_for(
                    &mut state,
                    &mut running,
                    &mut kv_reserved,
                    cfg.kv_capacity_tokens,
                    t,
                    &mut evicted,
                );
                for &idx in &evicted {
                    queue.requeue_preempted(idx);
                }
            }
            let batch = running.len() as u64;
            let mean_kv = running.kv_total() / batch;
            let t0 = t;
            let dt = oracle.decode(sim, batch, mean_kv) * f.latency_mult(t0, POOL_PREFILL);
            t += dt;
            state.stats.decode_iterations += 1;
            state.stats.decode_busy_s += dt;
            if rec.is_enabled() {
                rec.span_sim(
                    "engine",
                    "decode",
                    t0,
                    t,
                    &[("batch", num(batch as f64)), ("mean_kv", num(mean_kv as f64))],
                );
            }
            if cfg.preemption == Preemption::Evict {
                kv_reserved += batch;
                state.stats.peak_kv_tokens = state.stats.peak_kv_tokens.max(kv_reserved);
            }
            let mut i = 0;
            while i < running.len() {
                let idx = running.idx[i];
                state.generated[idx] += 1;
                running.kv_tokens[i] += 1;
                if state.generated[idx] >= requests[idx].output_tokens {
                    let (done_idx, _, _) = running.swap_remove(i);
                    state.metrics[done_idx].finish_s = t;
                    state.completed += 1;
                    state.emit_done(done_idx, t);
                    kv_reserved -= state.release_on_completion(done_idx).min(kv_reserved);
                } else {
                    i += 1;
                }
            }
        } else {
            // 3c. Idle: nothing running and nothing admittable. Wake at
            // the next arrival, the next retry re-dispatch, or — when the
            // backlog is fault-blocked — the moment the pool rejoins
            // (`validate` guarantees a queue head always fits an empty,
            // healthy cluster, so a non-empty queue here means admission
            // is inside a crash/drain window).
            let mut wake = f64::INFINITY;
            if next_arrival < requests.len() {
                wake = wake.min(requests[next_arrival].arrival_s);
            }
            for &(at, _) in &retry_q {
                wake = wake.min(at);
            }
            if !queue.is_empty() {
                debug_assert!(
                    !f.admitting(t, POOL_PREFILL),
                    "waiting requests with an idle, healthy cluster"
                );
                wake = wake.min(f.next_admit_time(t, POOL_PREFILL));
            }
            if !wake.is_finite() {
                break; // nothing in flight and nothing left to happen
            }
            // Step 1 ingested/drained everything ≤ t, and a non-empty
            // queue implies a blocking window ending after t.
            debug_assert!(wake > t, "idle wake did not advance the clock");
            state.stats.idle_s += wake - t;
            t = wake;
        }
    }

    state.stats.makespan_s = t;
    state.into_results(&mut f)
}

// ---------------------------------------------------------------------------
// Chunked engine (mixed iterations under a token budget)
// ---------------------------------------------------------------------------

fn run_chunked(
    sim: &Simulator,
    oracle: &SharedOracle,
    cfg: &SchedulerConfig,
    requests: &[Request],
    chunk_tokens: u64,
    rec: &ScopedRecorder<'_>,
) -> (Vec<RequestMetrics>, RunStats, Vec<Outcome>) {
    let no_faults = FaultSpec::none();
    let spec: &FaultSpec = cfg.faults.as_deref().unwrap_or(&no_faults);
    let mut f = Faults::new(spec, true);
    let mut retry_q: Vec<(f64, usize)> = Vec::new();
    let mut state = RunState::new(cfg, requests, rec);
    let mut queue = WaitQueue::new(cfg.policy);
    let mut prefilling = PrefillSet::default();
    let mut running = RunningSet::default();
    let mut kv_reserved = 0u64;
    let mut t = 0.0f64;
    let mut next_arrival = 0usize;
    // Scratch buffers reused across iterations.
    let mut dropped: Vec<usize> = Vec::new();
    let mut advanced: Vec<(usize, u64)> = Vec::new();

    while state.settled() < requests.len() {
        // Faults: crashes land at iteration boundaries and wipe both the
        // running batch and every partial prefill.
        let mut crashed = false;
        while let Some((tc, rec_end)) = f.pending_crash(t, POOL_PREFILL) {
            if rec.is_enabled() {
                rec.instant_sim("faults", "crash", tc, &[]);
                rec.span_sim("faults", "downtime", tc, rec_end, &[]);
            }
            for j in 0..running.len() {
                state.crash_request(
                    running.idx[j],
                    running.kv_tokens[j],
                    tc,
                    rec_end,
                    &f.recovery,
                    &mut retry_q,
                );
            }
            running.clear();
            for j in 0..prefilling.len() {
                state.crash_request(
                    prefilling.idx[j],
                    prefilling.done[j],
                    tc,
                    rec_end,
                    &f.recovery,
                    &mut retry_q,
                );
            }
            prefilling.clear();
            kv_reserved = 0;
            state.stats.idle_s += (rec_end - t).max(0.0);
            t = t.max(rec_end);
            crashed = true;
        }
        if crashed {
            continue;
        }

        while next_arrival < requests.len() && requests[next_arrival].arrival_s <= t {
            let over = f
                .recovery
                .shed_queue_depth
                .map(|d| queue.len() as u64 >= d)
                .unwrap_or(false);
            if over {
                state.shed_request(next_arrival, requests[next_arrival].arrival_s);
            } else {
                queue.arrive(next_arrival, requests);
            }
            next_arrival += 1;
        }
        drain_retries(&mut retry_q, t, &mut queue);
        if let Some(timeout) = f.recovery.request_timeout_s {
            dropped.clear();
            queue.drop_timed_out(t, timeout, requests, &mut dropped);
            for &idx in &dropped {
                state.lose_to_timeout(idx, t);
            }
        }

        // Admit into the partial-prefill set (resumed requests first).
        // Under eviction, admission also leaves headroom for this
        // iteration's +1-per-running-sequence decode growth — otherwise
        // every admission near capacity would be immediately undone by
        // the evict pass below (admit/evict churn). A crash/drain window
        // suspends admission.
        let can_admit = f.admitting(t, POOL_PREFILL);
        while can_admit
            && prefilling.len() < cfg.max_prefill_batch as usize
            && running.len() + prefilling.len() < cfg.max_batch as usize
        {
            let Some(cand) = queue.peek() else { break };
            let headroom = match cfg.preemption {
                Preemption::Conservative => 0,
                Preemption::Evict => running.len() as u64,
            };
            let need = state.admit_need(cand);
            if kv_reserved + need + headroom > cfg.kv_capacity_tokens {
                break;
            }
            kv_reserved += need;
            queue.pop();
            let serial = state.next_serial();
            state.emit_admitted(cand, t);
            prefilling.push(cand, 0, serial);
        }

        state.stats.peak_kv_tokens = state.stats.peak_kv_tokens.max(kv_reserved);
        state.stats.peak_batch =
            state.stats.peak_batch.max((running.len() + prefilling.len()) as u64);
        rec.counter_sim("kv_tokens", t, kv_reserved as f64);
        rec.counter_sim("batch", t, (running.len() + prefilling.len()) as f64);

        if prefilling.is_empty() && running.is_empty() {
            // Idle: wake at the next arrival, retry re-dispatch, or — for
            // a fault-blocked backlog — the end of the blocking window.
            let mut wake = f64::INFINITY;
            if next_arrival < requests.len() {
                wake = wake.min(requests[next_arrival].arrival_s);
            }
            for &(at, _) in &retry_q {
                wake = wake.min(at);
            }
            if !queue.is_empty() {
                debug_assert!(
                    !f.admitting(t, POOL_PREFILL),
                    "waiting requests with an idle, healthy cluster"
                );
                wake = wake.min(f.next_admit_time(t, POOL_PREFILL));
            }
            if !wake.is_finite() {
                break;
            }
            debug_assert!(wake > t, "idle wake did not advance the clock");
            state.stats.idle_s += wake - t;
            t = wake;
            continue;
        }

        // Under eviction, make room for this iteration's +1-per-sequence
        // decode growth *before* spending any chunk budget, by evicting
        // the youngest admitted work — partial prefills release their
        // whole reservation, running sequences their KV. At least one
        // running sequence is kept when no prefills are left: a lone
        // sequence always fits its own growth (its KV is < total ≤
        // capacity). Evicting first means a doomed sequence never
        // consumes chunk tokens or inflates this iteration's latency.
        if cfg.preemption == Preemption::Evict && !running.is_empty() {
            loop {
                if kv_reserved + running.len() as u64 <= cfg.kv_capacity_tokens
                    || (running.len() <= 1 && prefilling.is_empty())
                {
                    break;
                }
                let run_j = running.youngest_with_serial();
                let pf_j = prefilling.youngest_with_serial();
                let take_pf = running.len() <= 1
                    || match (run_j, pf_j) {
                        (Some((_, rs)), Some((_, ps))) => ps > rs,
                        (None, Some(_)) => true,
                        _ => false,
                    };
                if take_pf {
                    let (j, _) = pf_j.unwrap();
                    let (pf_idx, pf_done, _) = prefilling.remove(j);
                    kv_reserved -= state.admit_need(pf_idx).min(kv_reserved);
                    state.note_preemption(pf_idx, pf_done, t);
                    queue.requeue_preempted(pf_idx);
                } else {
                    let (j, _) = run_j.unwrap();
                    let (v_idx, v_kv, _) = running.remove(j);
                    kv_reserved -= v_kv.min(kv_reserved);
                    state.note_preemption(v_idx, v_kv, t);
                    queue.requeue_preempted(v_idx);
                }
            }
        }

        // Build the iteration: every running sequence decodes one token;
        // the remaining budget advances prompts in admission order.
        // Degraded mode caps the budget while any fault window is active
        // (keep decode pace, slow prefill progress).
        let decode_b = running.len() as u64;
        let iter_budget = match f.recovery.degraded_chunk_tokens {
            Some(d) if f.degraded(t, POOL_PREFILL) => chunk_tokens.min(d),
            _ => chunk_tokens,
        };
        let mut budget = iter_budget.saturating_sub(decode_b);
        let mut chunk = 0u64;
        // (request, tokens) advanced this iteration — for the chunk trace
        // spans, which can only be emitted once the latency is known.
        advanced.clear();
        for j in 0..prefilling.len() {
            if budget == 0 {
                break;
            }
            let idx = prefilling.idx[j];
            let need = state.requests[idx].prompt_tokens + state.generated[idx]
                - prefilling.done[j];
            let give = need.min(budget);
            prefilling.done[j] += give;
            budget -= give;
            chunk += give;
            if rec.is_enabled() && give > 0 {
                advanced.push((idx, give));
            }
        }

        // Fused-iteration latency: the chunk's compute and the decode
        // batch's weight/KV traffic share one pass, so the iteration pays
        // the greater of the two legs.
        let lat_p = if chunk > 0 { oracle.prefill(sim, 1, chunk) } else { 0.0 };
        let lat_d = if decode_b > 0 {
            let mean_kv = running.kv_total() / decode_b;
            oracle.decode(sim, decode_b, mean_kv)
        } else {
            0.0
        };
        let dt = lat_p.max(lat_d) * f.latency_mult(t, POOL_PREFILL);
        let t0 = t;
        t += dt;
        let kind = match (chunk > 0, decode_b > 0) {
            (true, true) => {
                state.stats.mixed_iterations += 1;
                state.stats.mixed_busy_s += dt;
                "mixed"
            }
            (true, false) => {
                state.stats.prefill_iterations += 1;
                state.stats.prefill_busy_s += dt;
                "prefill"
            }
            (false, true) => {
                state.stats.decode_iterations += 1;
                state.stats.decode_busy_s += dt;
                "decode"
            }
            // prefilling/running non-empty ⇒ at least one leg has work.
            (false, false) => unreachable!("iteration with no work"),
        };
        if rec.is_enabled() {
            rec.span_sim(
                "engine",
                kind,
                t0,
                t,
                &[("chunk_tokens", num(chunk as f64)), ("decode_batch", num(decode_b as f64))],
            );
            for &(idx, give) in &advanced {
                state.emit_prefill_span(idx, "chunk", t0, t, give);
            }
        }

        // Decode completions and KV growth.
        if cfg.preemption == Preemption::Evict {
            kv_reserved += decode_b;
            state.stats.peak_kv_tokens = state.stats.peak_kv_tokens.max(kv_reserved);
        }
        let mut i = 0;
        while i < running.len() {
            let idx = running.idx[i];
            state.generated[idx] += 1;
            running.kv_tokens[i] += 1;
            if state.generated[idx] >= requests[idx].output_tokens {
                let (done_idx, _, _) = running.swap_remove(i);
                state.metrics[done_idx].finish_s = t;
                state.completed += 1;
                state.emit_done(done_idx, t);
                kv_reserved -= state.release_on_completion(done_idx).min(kv_reserved);
            } else {
                i += 1;
            }
        }

        // Prefill completions: emit the first token, move into decode.
        let mut j = 0;
        while j < prefilling.len() {
            let idx = prefilling.idx[j];
            let target = state.requests[idx].prompt_tokens + state.generated[idx];
            if prefilling.done[j] >= target {
                let (pf_idx, _, pf_serial) = prefilling.remove(j);
                let reserved = state.admit_need(pf_idx);
                match state.finish_prefill(pf_idx, t) {
                    Some(kv_tokens) => running.push(pf_idx, kv_tokens, pf_serial),
                    None => kv_reserved -= reserved.min(kv_reserved),
                }
            } else {
                j += 1;
            }
        }
    }

    state.stats.makespan_s = t;
    state.into_results(&mut f)
}

// ---------------------------------------------------------------------------
// Disaggregated engine (prefill pool + decode pool + handoff queue)
// ---------------------------------------------------------------------------

/// A request whose prefill finished, in flight (or queued) to the decode
/// pool.
struct Handoff {
    idx: usize,
    ready_at: f64,
    serial: u64,
}

/// Default bound on the handoff queue: the decode pool's KV budget
/// measured in mean-trace-length sequences (at least 1). Queueing more
/// than fits the decode pool is pure backlog — the prefill pool should
/// stall instead.
fn default_handoff_capacity(dec_cap: u64, requests: &[Request]) -> u64 {
    if requests.is_empty() {
        return 1;
    }
    let mean =
        (requests.iter().map(|r| r.total_tokens()).sum::<u64>() / requests.len() as u64).max(1);
    (dec_cap / mean).max(1)
}

/// Which pool a scheduled event wakes. Prefill carries the lower event
/// priority so a time tie pops the prefill pool first — the same pick the
/// two-clock `if next_prefill_work <= next_decode_work` comparison made
/// before the event heap existed (byte-identity depends on it).
#[derive(Debug, Clone, Copy, PartialEq)]
enum PoolStep {
    Prefill,
    Decode,
}

const PRIO_PREFILL: u8 = 0;
const PRIO_DECODE: u8 = 1;

#[allow(clippy::too_many_arguments)]
fn run_disaggregated(
    sim: &Simulator,
    sys: &SystemSpec,
    model: &ModelConfig,
    cfg: &SchedulerConfig,
    requests: &[Request],
    prefill_devices: u64,
    transfer_base_s: f64,
    rec: &ScopedRecorder<'_>,
) -> (Vec<RequestMetrics>, RunStats, Vec<Outcome>) {
    let sys_p = sub_system(sys, prefill_devices);
    let sys_d = sub_system(sys, sys.device_count - prefill_devices);
    // Sub-pool oracles key apart by device_count, so every run (and every
    // sweep cell) at the same pool split shares the same two warm caches.
    let oracle_p = sim.oracles.for_system(&sys_p, model);
    let oracle_d = sim.oracles.for_system(&sys_d, model);
    let resolved = SchedulerConfig {
        mode: ServeMode::Disaggregated { prefill_devices, transfer_base_s },
        ..cfg.clone()
    };
    let (pre_cap, dec_cap) = resolved.pool_budgets(sys.device_count);
    let kv_bytes_per_token = model.kv_bytes_per_token_per_layer() * model.layers;
    // Bounded handoff queue: default is the decode pool's KV budget in
    // mean-trace-length sequences — beyond that, prefilled-but-undecodable
    // KV cannot even fit the decode pool, so racing further ahead is pure
    // queue growth.
    let handoff_cap = cfg
        .handoff_capacity
        .unwrap_or_else(|| default_handoff_capacity(dec_cap, requests))
        .max(1);

    // Borrow the fault spec through the Arc instead of deep-cloning the
    // whole schedule per engine run (fleets run one engine per replica).
    let no_faults = FaultSpec::none();
    let spec: &FaultSpec = cfg.faults.as_deref().unwrap_or(&no_faults);
    // Two pools: `prefill`/`decode` fault targets strike one of them,
    // `all` (and every MTBF crash) strikes both.
    let mut f = Faults::new(spec, false);
    // The global event heap orders the two pool clocks: each pass
    // schedules both pools' next useful-work times and pops the earliest
    // (prefill priority wins ties, as the old clock comparison did).
    let mut events: EventHeap<PoolStep> = EventHeap::new();
    let mut retry_q: Vec<(f64, usize)> = Vec::new();
    let mut state = RunState::new(cfg, requests, rec);
    // Prefill side. Preempted requests carry the decode-pool time they
    // became available again.
    let mut queue = WaitQueue::new(cfg.policy);
    let mut resume_avail: Vec<(usize, f64)> = Vec::new();
    let mut t_p = 0.0f64;
    let mut next_arrival = 0usize;
    // Decode side.
    let mut handoff: Vec<Handoff> = Vec::new();
    let mut running = RunningSet::default();
    let mut kv_d = 0u64;
    let mut t_d = 0.0f64;
    let mut last_finish = 0.0f64;
    // Scratch buffers reused across iterations.
    let mut admitted: Vec<usize> = Vec::new();
    let mut dropped: Vec<usize> = Vec::new();
    let mut evicted: Vec<usize> = Vec::new();
    // Time since when the prefill pool has been blocked on a full handoff
    // queue (None: not blocked).
    let mut blocked_since: Option<f64> = None;

    while state.settled() < requests.len() {
        // Earliest time each pool could do useful work (INFINITY: never).
        // A pool blocked by a crash/drain window wakes when it rejoins.
        let raw_prefill_work = {
            let retry = retry_q.iter().map(|&(at, _)| at).fold(f64::INFINITY, f64::min);
            let base = if !queue.is_empty() {
                t_p
            } else {
                let arr = if next_arrival < requests.len() {
                    requests[next_arrival].arrival_s
                } else {
                    f64::INFINITY
                };
                let res = resume_avail
                    .iter()
                    .map(|&(_, at)| at)
                    .fold(f64::INFINITY, f64::min);
                t_p.max(arr.min(res).min(retry))
            };
            if base.is_finite() && !f.admitting(base, POOL_PREFILL) {
                f.next_admit_time(base, POOL_PREFILL)
            } else {
                base
            }
        };
        // Backpressure: a full handoff queue blocks the prefill pool until
        // the decode pool drains a slot. (The queue holds work for the
        // decode side, so the decode pool always has a finite next step
        // here — no deadlock.)
        let next_prefill_work = if handoff.len() as u64 >= handoff_cap {
            if blocked_since.is_none() && raw_prefill_work.is_finite() {
                blocked_since = Some(raw_prefill_work);
            }
            f64::INFINITY
        } else {
            raw_prefill_work
        };
        let next_decode_work = if !running.is_empty() {
            t_d
        } else {
            let ready = handoff.iter().map(|h| h.ready_at).fold(f64::INFINITY, f64::min);
            let base = t_d.max(ready);
            if base.is_finite() && !f.admitting(base, POOL_DECODE) {
                f.next_admit_time(base, POOL_DECODE)
            } else {
                base
            }
        };
        events.clear();
        if next_prefill_work.is_finite() {
            events.push(next_prefill_work, PRIO_PREFILL, PoolStep::Prefill);
        }
        if next_decode_work.is_finite() {
            events.push(next_decode_work, PRIO_DECODE, PoolStep::Decode);
        }
        let Some((_, step)) = events.pop() else {
            // Neither pool will ever have work again.
            debug_assert!(state.settled() == requests.len(), "stalled with work remaining");
            break;
        };

        if step == PoolStep::Prefill {
            // ---- Prefill-pool step ----
            t_p = next_prefill_work;
            while next_arrival < requests.len() && requests[next_arrival].arrival_s <= t_p {
                let over = f
                    .recovery
                    .shed_queue_depth
                    .map(|d| queue.len() as u64 >= d)
                    .unwrap_or(false);
                if over {
                    state.shed_request(next_arrival, requests[next_arrival].arrival_s);
                } else {
                    queue.arrive(next_arrival, requests);
                }
                next_arrival += 1;
            }
            let mut k = 0;
            while k < resume_avail.len() {
                if resume_avail[k].1 <= t_p {
                    let (idx, _) = resume_avail.remove(k);
                    queue.requeue_preempted(idx);
                } else {
                    k += 1;
                }
            }
            drain_retries(&mut retry_q, t_p, &mut queue);
            if let Some(timeout) = f.recovery.request_timeout_s {
                dropped.clear();
                queue.drop_timed_out(t_p, timeout, requests, &mut dropped);
                for &idx in &dropped {
                    state.lose_to_timeout(idx, t_p);
                }
            }
            if queue.is_empty() {
                // Everything this wake-up materialized was shed or timed
                // out — nothing to admit, re-evaluate the next event.
                continue;
            }
            // Admit a prefill batch under the prefill-pool KV budget (the
            // pool holds a batch's context KV only for the duration of
            // its iteration + transfer, modeled as iteration-scoped).
            admitted.clear();
            let mut kv_p = 0u64;
            while admitted.len() < cfg.max_prefill_batch as usize
                && (handoff.len() + admitted.len()) < handoff_cap as usize
            {
                let Some(cand) = queue.peek() else { break };
                let need = state.prefill_target(cand) + 1;
                if kv_p + need > pre_cap {
                    break;
                }
                kv_p += need;
                admitted.push(cand);
                queue.pop();
            }
            // The head always fits an empty pool (`validate` bounds every
            // request's prefill footprint by the pool budget), and the
            // ingest above materialized whatever made this the next work
            // time — an empty admission would loop forever, so fail loud.
            assert!(!admitted.is_empty(), "prefill pool woke with nothing admittable");
            state.stats.prefill_peak_kv_tokens = state.stats.prefill_peak_kv_tokens.max(kv_p);
            rec.counter_sim("kv_tokens (prefill pool)", t_p, kv_p as f64);
            rec.counter_sim("batch (prefill pool)", t_p, admitted.len() as f64);
            let batch = admitted.len() as u64;
            let max_ctx = admitted.iter().map(|&i| state.prefill_target(i)).max().unwrap();
            let t_p0 = t_p;
            let dt = oracle_p.prefill(sim, batch, max_ctx) * f.latency_mult(t_p0, POOL_PREFILL);
            t_p += dt;
            state.stats.prefill_iterations += 1;
            state.stats.prefill_busy_s += dt;
            if rec.is_enabled() {
                rec.span_sim(
                    "prefill pool",
                    "prefill",
                    t_p0,
                    t_p,
                    &[("batch", num(batch as f64)), ("max_ctx", num(max_ctx as f64))],
                );
            }
            for &i in &admitted {
                let ctx = state.prefill_target(i);
                state.emit_admitted(i, t_p0);
                state.emit_prefill_span(i, "prefill", t_p0, t_p, ctx);
                match state.finish_prefill(i, t_p) {
                    Some(_) => {
                        // KV handoff: LogGP peer-to-peer of the context KV
                        // over one interconnect link, plus the base.
                        let bytes = ctx * kv_bytes_per_token;
                        // Link degradation stretches the whole transfer
                        // (base + modeled fabric time).
                        let xfer = (transfer_base_s
                            + crate::perf::comm::peer_to_peer(&sys.interconnect, bytes).latency_s)
                            * f.link_mult(t_p);
                        state.stats.transfer_total_s += xfer;
                        let serial = state.next_serial();
                        if rec.is_enabled() {
                            rec.span_sim(
                                &state.track(i),
                                "handoff",
                                t_p,
                                t_p + xfer,
                                &[("kv_bytes", num(bytes as f64))],
                            );
                        }
                        handoff.push(Handoff { idx: i, ready_at: t_p + xfer, serial });
                    }
                    None => last_finish = last_finish.max(t_p),
                }
            }
            handoff.sort_by(|a, b| a.ready_at.total_cmp(&b.ready_at).then(a.serial.cmp(&b.serial)));
        } else {
            // ---- Decode-pool step ----
            if next_decode_work > t_d {
                state.stats.idle_s += next_decode_work - t_d;
                t_d = next_decode_work;
            }
            // Crashes strike the decode pool at its iteration boundary:
            // running sequences and handoffs that were in flight before
            // the pool rejoined lose their KV and go back through the
            // prefill pool as retries.
            let mut crashed = false;
            while let Some((tc, rec_end)) = f.pending_crash(t_d, POOL_DECODE) {
                if rec.is_enabled() {
                    rec.instant_sim("faults", "crash", tc, &[]);
                    rec.span_sim("faults", "downtime", tc, rec_end, &[]);
                }
                for j in 0..running.len() {
                    state.crash_request(
                        running.idx[j],
                        running.kv_tokens[j],
                        tc,
                        rec_end,
                        &f.recovery,
                        &mut retry_q,
                    );
                }
                running.clear();
                let mut k = 0;
                while k < handoff.len() {
                    if handoff[k].ready_at < rec_end {
                        let h = handoff.remove(k);
                        let kv = state.prefill_target(h.idx);
                        state.crash_request(h.idx, kv, tc, rec_end, &f.recovery, &mut retry_q);
                    } else {
                        k += 1;
                    }
                }
                kv_d = 0;
                state.stats.idle_s += (rec_end - t_d).max(0.0);
                t_d = t_d.max(rec_end);
                crashed = true;
            }
            if crashed {
                // The drained handoff queue may release a stalled
                // prefill pool.
                if (handoff.len() as u64) < handoff_cap {
                    if let Some(since) = blocked_since.take() {
                        state.stats.handoff_stall_s += (t_d - since).max(0.0);
                        if rec.is_enabled() && t_d > since {
                            rec.span_sim("prefill pool", "handoff_stall", since, t_d, &[]);
                        }
                        t_p = t_p.max(t_d);
                    }
                }
                continue;
            }
            // Admit transfer-complete requests in ready order. A drain
            // window suspends admission (in-flight decodes continue).
            let can_admit = f.admitting(t_d, POOL_DECODE);
            let mut k = 0;
            while k < handoff.len() {
                if !can_admit || running.len() >= cfg.max_batch as usize {
                    break;
                }
                if handoff[k].ready_at > t_d {
                    break; // sorted: nothing later is ready either
                }
                let idx = handoff[k].idx;
                // Current footprint is `prompt + generated` (the same
                // post-prefill convention the other engines use); decode
                // growth is reserved iteration-by-iteration below.
                let need = match cfg.preemption {
                    Preemption::Conservative => requests[idx].total_tokens(),
                    Preemption::Evict => state.prefill_target(idx),
                };
                if kv_d + need > dec_cap {
                    break; // greedy in ready order, no skip-ahead
                }
                let h = handoff.remove(k);
                state.stats.handoff_wait_s += t_d - h.ready_at;
                if rec.is_enabled() && t_d > h.ready_at {
                    rec.span_sim(&state.track(idx), "handoff_wait", h.ready_at, t_d, &[]);
                }
                state.decode_from[idx] = t_d;
                kv_d += need;
                running.push(idx, state.prefill_target(idx), h.serial);
                // `remove(k)` slid the next entry into position k.
            }
            // Draining below the bound releases the prefill pool; it lost
            // the whole window from when it wanted to run until now.
            if (handoff.len() as u64) < handoff_cap {
                if let Some(since) = blocked_since.take() {
                    state.stats.handoff_stall_s += (t_d - since).max(0.0);
                    if rec.is_enabled() && t_d > since {
                        rec.span_sim("prefill pool", "handoff_stall", since, t_d, &[]);
                    }
                    t_p = t_p.max(t_d);
                }
            }
            state.stats.peak_kv_tokens = state.stats.peak_kv_tokens.max(kv_d);
            state.stats.peak_batch = state.stats.peak_batch.max(running.len() as u64);
            rec.counter_sim("kv_tokens (decode pool)", t_d, kv_d as f64);
            rec.counter_sim("batch (decode pool)", t_d, running.len() as f64);
            // The head of a ready handoff always fits an empty pool
            // (`validate` bounds every total by the decode budget), so an
            // empty batch here would loop forever — fail loud instead.
            assert!(!running.is_empty(), "decode pool woke with nothing admittable");
            if cfg.preemption == Preemption::Evict {
                evicted.clear();
                evict_for(&mut state, &mut running, &mut kv_d, dec_cap, t_d, &mut evicted);
                for &idx in &evicted {
                    // Recompute happens back on the prefill pool.
                    resume_avail.push((idx, t_d));
                }
            }
            let batch = running.len() as u64;
            let mean_kv = running.kv_total() / batch;
            let t_d0 = t_d;
            let dt = oracle_d.decode(sim, batch, mean_kv) * f.latency_mult(t_d0, POOL_DECODE);
            t_d += dt;
            state.stats.decode_iterations += 1;
            state.stats.decode_busy_s += dt;
            if rec.is_enabled() {
                rec.span_sim(
                    "decode pool",
                    "decode",
                    t_d0,
                    t_d,
                    &[("batch", num(batch as f64)), ("mean_kv", num(mean_kv as f64))],
                );
            }
            if cfg.preemption == Preemption::Evict {
                kv_d += batch;
                state.stats.peak_kv_tokens = state.stats.peak_kv_tokens.max(kv_d);
            }
            let mut i = 0;
            while i < running.len() {
                let idx = running.idx[i];
                state.generated[idx] += 1;
                running.kv_tokens[i] += 1;
                if state.generated[idx] >= requests[idx].output_tokens {
                    let (done_idx, _, _) = running.swap_remove(i);
                    state.metrics[done_idx].finish_s = t_d;
                    state.completed += 1;
                    last_finish = last_finish.max(t_d);
                    state.emit_done(done_idx, t_d);
                    kv_d -= state.release_on_completion(done_idx).min(kv_d);
                } else {
                    i += 1;
                }
            }
        }
    }

    state.stats.makespan_s = last_finish;
    state.into_results(&mut f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::presets;
    use crate::serve::workload::{generate, Request, WorkloadSpec};

    fn small_setup() -> (Simulator, SystemSpec, ModelConfig) {
        (Simulator::new(), presets::system("a100").unwrap(), ModelConfig::gpt_small())
    }

    fn cfg_for(sys: &SystemSpec, model: &ModelConfig, policy: Policy) -> SchedulerConfig {
        SchedulerConfig::for_system(sys, model, policy)
    }

    #[test]
    fn kv_capacity_matches_hand_calculation() {
        let m = ModelConfig::gpt3_175b();
        let sys = presets::system("a100x8").unwrap();
        let tokens = kv_capacity_tokens(&sys, &m);
        // 8 × 80 GB − 350 GB of weights ≈ 290 GB free; 4.5 MiB/token KV.
        let free = 8.0 * 80e9 - m.param_bytes(m.layers) as f64;
        let expect = free / (m.kv_bytes_per_token_per_layer() * m.layers) as f64;
        assert!((tokens as f64 - expect).abs() < 2.0, "{tokens} vs {expect:.0}");
        // One A100 cannot even hold the weights.
        assert_eq!(kv_capacity_tokens(&presets::system("a100").unwrap(), &m), 0);
    }

    #[test]
    fn mode_resolution_and_validation() {
        assert_eq!(ServeMode::Monolithic.resolved(1).unwrap(), ServeMode::Monolithic);
        assert!(ServeMode::Chunked { chunk_tokens: 0 }.resolved(1).is_err());
        let d = ServeMode::Disaggregated { prefill_devices: 0, transfer_base_s: 0.001 };
        assert_eq!(
            d.resolved(8).unwrap(),
            ServeMode::Disaggregated { prefill_devices: 4, transfer_base_s: 0.001 }
        );
        assert!(d.resolved(1).is_err(), "single device cannot disaggregate");
        assert!(ServeMode::Disaggregated { prefill_devices: 4, transfer_base_s: 0.001 }
            .resolved(4)
            .is_err());
        assert!(ServeMode::Disaggregated { prefill_devices: 1, transfer_base_s: f64::NAN }
            .resolved(4)
            .is_err());
        // Parse round trips.
        for p in [Preemption::Conservative, Preemption::Evict] {
            assert_eq!(Preemption::parse(p.name()), Some(p));
        }
        assert_eq!(Preemption::parse("nope"), None);
    }

    #[test]
    fn pool_budgets_split_proportionally() {
        let (_, sys, model) = small_setup();
        let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
        cfg.kv_capacity_tokens = 1000;
        cfg.mode = ServeMode::Disaggregated { prefill_devices: 1, transfer_base_s: 0.0 };
        let (p, d) = cfg.pool_budgets(4);
        assert_eq!((p, d), (250, 750));
        assert_eq!(p + d, cfg.kv_capacity_tokens, "nothing lost to rounding");
        cfg.mode = ServeMode::Monolithic;
        assert_eq!(cfg.pool_budgets(4), (1000, 1000));
    }

    #[test]
    fn all_requests_complete_with_sane_timelines() {
        let (sim, sys, model) = small_setup();
        let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
        cfg.max_batch = 16;
        cfg.max_prefill_batch = 4;
        let reqs = generate(&WorkloadSpec::poisson(20.0, 200, 5));
        let (metrics, stats) = simulate(&sim, &sys, &model, &cfg, &reqs);
        assert_eq!(metrics.len(), 200);
        for m in &metrics {
            assert!(m.first_token_s.is_finite(), "request {} never prefetched", m.id);
            assert!(m.finish_s.is_finite(), "request {} never finished", m.id);
            assert!(m.first_token_s > m.arrival_s);
            assert!(m.finish_s >= m.first_token_s);
        }
        assert!(stats.prefill_iterations > 0 && stats.decode_iterations > 0);
        assert_eq!(stats.preemptions, 0, "conservative admission never preempts");
        assert!(stats.makespan_s >= reqs.last().unwrap().arrival_s);
        assert!(stats.peak_batch <= 16);
        assert!(stats.peak_kv_tokens <= cfg.kv_capacity_tokens);
    }

    #[test]
    fn deterministic_for_same_inputs() {
        let (sim, sys, model) = small_setup();
        for mode in [
            ServeMode::Monolithic,
            ServeMode::Chunked { chunk_tokens: 512 },
        ] {
            let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
            cfg.mode = mode;
            let reqs = generate(&WorkloadSpec::poisson(10.0, 64, 9));
            let (a, _) = simulate(&sim, &sys, &model, &cfg, &reqs);
            let (b, _) = simulate(&sim, &sys, &model, &cfg, &reqs);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.first_token_s, y.first_token_s);
                assert_eq!(x.finish_s, y.finish_s);
            }
        }
    }

    #[test]
    fn spf_prefers_short_prompts_under_backlog() {
        let (sim, sys, model) = small_setup();
        // Everything arrives at t=0: a long-prompt request first, then
        // short ones. SPF should give the short ones earlier first tokens.
        let mut reqs = vec![Request {
            id: 0,
            arrival_s: 0.0,
            prompt_tokens: 2048,
            output_tokens: 4,
        }];
        for i in 1..6u64 {
            reqs.push(Request {
                id: i,
                arrival_s: 0.0,
                prompt_tokens: 32,
                output_tokens: 4,
            });
        }
        let mk = |policy| {
            let mut c = cfg_for(&sys, &model, policy);
            c.max_batch = 2;
            c.max_prefill_batch = 1;
            c
        };
        let (fcfs, _) = simulate(&sim, &sys, &model, &mk(Policy::Fcfs), &reqs);
        let (spf, _) = simulate(&sim, &sys, &model, &mk(Policy::ShortestPromptFirst), &reqs);
        let mean_short_ttft = |ms: &[RequestMetrics]| {
            ms.iter().skip(1).map(|m| m.first_token_s - m.arrival_s).sum::<f64>() / 5.0
        };
        assert!(
            mean_short_ttft(&spf) < mean_short_ttft(&fcfs),
            "SPF {:.4} vs FCFS {:.4}",
            mean_short_ttft(&spf),
            mean_short_ttft(&fcfs)
        );
        // FCFS serves the long prompt first.
        assert!(fcfs[0].first_token_s <= spf[0].first_token_s);
    }

    #[test]
    #[should_panic(expected = "never be admitted")]
    fn oversized_request_panics_up_front() {
        let (sim, sys, model) = small_setup();
        let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
        cfg.max_batch = 4;
        cfg.kv_capacity_tokens = 100;
        let reqs = vec![Request {
            id: 0,
            arrival_s: 0.0,
            prompt_tokens: 200,
            output_tokens: 10,
        }];
        simulate(&sim, &sys, &model, &cfg, &reqs);
    }

    #[test]
    fn chunked_runs_mixed_iterations_without_padding_waste() {
        let (sim, sys, model) = small_setup();
        let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
        cfg.mode = ServeMode::Chunked { chunk_tokens: 512 };
        cfg.max_batch = 16;
        // Overlapping arrivals so decodes are live while prompts prefill.
        let reqs: Vec<Request> = (0..24u64)
            .map(|i| Request {
                id: i,
                arrival_s: i as f64 * 0.002,
                prompt_tokens: 700 + 37 * i, // not pow2-friendly on purpose
                output_tokens: 32,
            })
            .collect();
        let (metrics, stats) = simulate(&sim, &sys, &model, &cfg, &reqs);
        for m in &metrics {
            assert!(m.finish_s.is_finite(), "request {} unfinished", m.id);
        }
        assert!(stats.mixed_iterations > 0, "no mixed iterations under overlap");
        assert!(stats.mixed_busy_s > 0.0);
        assert!(stats.peak_kv_tokens <= cfg.kv_capacity_tokens);
        // A chunked prompt takes ≥ ceil(prompt/chunk) iterations, so TTFT
        // of the first request spans at least two iterations' latency.
        assert!(metrics[0].first_token_s > 0.0);
    }

    #[test]
    fn evict_mode_preempts_under_pressure_and_still_completes() {
        let (sim, sys, model) = small_setup();
        let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
        cfg.max_batch = 8;
        cfg.max_prefill_batch = 8;
        cfg.kv_capacity_tokens = 500;
        cfg.preemption = Preemption::Evict;
        // Four requests, each 100-prompt + 100-output = 200 final tokens.
        // Evict admits all four on their 101-token prefill footprint
        // (404 ≤ 500) but total demand is 800 — preemption must kick in,
        // and everything must still finish.
        let reqs: Vec<Request> = (0..4u64)
            .map(|i| Request { id: i, arrival_s: 0.0, prompt_tokens: 100, output_tokens: 100 })
            .collect();
        let (metrics, stats) = simulate(&sim, &sys, &model, &cfg, &reqs);
        assert!(stats.preemptions > 0, "no preemption under 1.6x oversubscription");
        assert!(stats.preempted_requests >= 1);
        assert!(stats.recompute_tokens > 0);
        assert!(stats.peak_kv_tokens <= cfg.kv_capacity_tokens, "KV overflow");
        for m in &metrics {
            assert!(m.finish_s.is_finite(), "request {} lost to preemption", m.id);
        }
        // Conservative on the same trace admits fewer but never preempts.
        cfg.preemption = Preemption::Conservative;
        let (m2, s2) = simulate(&sim, &sys, &model, &cfg, &reqs);
        assert_eq!(s2.preemptions, 0);
        assert!(m2.iter().all(|m| m.finish_s.is_finite()));
        let sum = |ms: &[RequestMetrics]| ms.iter().map(|m| m.output_tokens).sum::<u64>();
        assert_eq!(sum(&metrics), sum(&m2), "tokens not conserved across admission modes");
    }

    #[test]
    fn disaggregated_pools_serve_with_transfer_latency() {
        let sim = Simulator::new();
        let sys = presets::system("a100x4").unwrap();
        let model = ModelConfig::gpt_small();
        let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
        cfg.mode = ServeMode::Disaggregated { prefill_devices: 2, transfer_base_s: 0.002 };
        cfg.max_batch = 16;
        let reqs = generate(&WorkloadSpec::poisson(40.0, 48, 3));
        let (metrics, stats) = simulate(&sim, &sys, &model, &cfg, &reqs);
        for m in &metrics {
            assert!(m.first_token_s.is_finite() && m.finish_s.is_finite());
            assert!(m.finish_s >= m.first_token_s);
        }
        assert!(stats.prefill_iterations > 0 && stats.decode_iterations > 0);
        // Every multi-token request paid at least the base transfer.
        let multi = reqs.iter().filter(|r| r.output_tokens > 1).count() as f64;
        assert!(
            stats.transfer_total_s >= 0.002 * multi,
            "transfer_total_s {} below base × {multi}",
            stats.transfer_total_s
        );
        assert!(stats.prefill_peak_kv_tokens > 0);
        let (pre_cap, dec_cap) = cfg.pool_budgets(sys.device_count);
        assert!(stats.prefill_peak_kv_tokens <= pre_cap);
        assert!(stats.peak_kv_tokens <= dec_cap);
        // TPOT includes the handoff, so it is ≥ the pure decode pace for
        // at least the earliest request (no queueing at t≈0).
        assert!(stats.makespan_s >= metrics.iter().fold(0.0f64, |a, m| a.max(m.finish_s)) - 1e-12);
    }

    #[test]
    fn bounded_handoff_queue_stalls_prefill_pool() {
        let sim = Simulator::new();
        let sys = presets::system("a100x2").unwrap();
        let model = ModelConfig::gpt_small();
        let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
        cfg.mode = ServeMode::Disaggregated { prefill_devices: 1, transfer_base_s: 0.0 };
        cfg.max_prefill_batch = 8;
        // Long outputs: the decode pool drains far slower than the prefill
        // pool produces, so an unbounded queue would race ahead.
        let reqs: Vec<Request> = (0..12u64)
            .map(|i| Request { id: i, arrival_s: 0.0, prompt_tokens: 128, output_tokens: 256 })
            .collect();
        cfg.handoff_capacity = Some(1);
        let (tight_m, tight) = simulate(&sim, &sys, &model, &cfg, &reqs);
        assert!(tight.handoff_stall_s > 0.0, "capacity-1 queue never stalled");
        assert!(tight_m.iter().all(|m| m.finish_s.is_finite()));
        // Unbounded-ish capacity on the same trace: no stalls, identical
        // token output.
        cfg.handoff_capacity = Some(1_000);
        let (wide_m, wide) = simulate(&sim, &sys, &model, &cfg, &reqs);
        assert_eq!(wide.handoff_stall_s, 0.0);
        let sum = |ms: &[RequestMetrics]| ms.iter().map(|m| m.output_tokens).sum::<u64>();
        assert_eq!(sum(&tight_m), sum(&wide_m));
        // Backpressure delays prefill work, it cannot invent any: the
        // stalled run prefills no earlier.
        assert!(tight.prefill_busy_s >= wide.prefill_busy_s * 0.99);

        // The derived default equals dec_cap / mean total tokens.
        assert_eq!(default_handoff_capacity(10_000, &reqs), 10_000 / (128 + 256));
        assert_eq!(default_handoff_capacity(10, &reqs), 1, "floor of one slot");
        assert_eq!(default_handoff_capacity(100, &[]), 1);

        // Zero capacity is rejected up front.
        cfg.handoff_capacity = Some(0);
        assert!(validate(&cfg, sys.device_count, &reqs).is_err());
    }

    #[test]
    fn disaggregated_first_token_comes_from_prefill_pool() {
        // A single request: TTFT must not include the transfer, but the
        // finish time must (transfer happens before any decode step).
        let sim = Simulator::new();
        let sys = presets::system("a100x2").unwrap();
        let model = ModelConfig::gpt_small();
        let base = 0.5; // exaggerated transfer base to make the gap visible
        let mut mono = cfg_for(&sys, &model, Policy::Fcfs);
        let mut disagg = mono.clone();
        disagg.mode = ServeMode::Disaggregated { prefill_devices: 1, transfer_base_s: base };
        let reqs =
            vec![Request { id: 0, arrival_s: 0.0, prompt_tokens: 256, output_tokens: 8 }];
        let (dm, ds) = simulate(&sim, &sys, &model, &disagg, &reqs);
        mono.mode = ServeMode::Monolithic;
        let (mm, _) = simulate(&sim, &sys, &model, &mono, &reqs);
        assert!(dm[0].first_token_s < base, "TTFT should not pay the transfer");
        assert!(
            dm[0].finish_s - dm[0].first_token_s > base,
            "decode tail must include the handoff"
        );
        assert!(ds.transfer_total_s >= base);
        // Same tokens produced either way.
        assert_eq!(mm[0].output_tokens, dm[0].output_tokens);
    }

    // ---------------- fault injection ----------------

    use crate::serve::fault::{FaultEvent, FaultKind, FaultTarget};

    fn all_modes() -> [ServeMode; 3] {
        [
            ServeMode::Monolithic,
            ServeMode::Chunked { chunk_tokens: 512 },
            ServeMode::Disaggregated { prefill_devices: 1, transfer_base_s: 0.002 },
        ]
    }

    fn event(kind: FaultKind, at_s: f64, duration_s: f64) -> FaultEvent {
        FaultEvent { kind, at_s, duration_s, target: FaultTarget::All }
    }

    #[test]
    fn empty_workload_reports_full_availability_in_all_modes() {
        // Regression: a zero-request run has makespan 0; availability must
        // come out 1.0 (never 0/0 = NaN or a spurious 0.0).
        let sim = Simulator::new();
        let sys = presets::system("a100x2").unwrap();
        let model = ModelConfig::gpt_small();
        for mode in all_modes() {
            let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
            cfg.mode = mode;
            let (metrics, stats) = simulate(&sim, &sys, &model, &cfg, &[]);
            assert!(metrics.is_empty());
            assert_eq!(stats.makespan_s, 0.0);
            assert_eq!(stats.availability, 1.0, "zero-span run must be fully available");
            assert_eq!(stats.fault_downtime_s, 0.0);
        }
        // Even with scheduled fault windows on the books: no requests ⇒
        // no span for the outage to overlap.
        let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
        let mut spec = FaultSpec::none();
        spec.events.push(event(FaultKind::Crash, 1.0, 5.0));
        cfg.faults = Some(std::sync::Arc::new(spec));
        let (_, stats) = simulate(&sim, &sys, &model, &cfg, &[]);
        assert_eq!(stats.availability, 1.0);
        assert!(stats.availability.is_finite());
    }

    #[test]
    fn all_requests_lost_still_reports_finite_availability() {
        // Regression companion: when a crash wipes out every request the
        // run still has a positive makespan and a well-defined (< 1.0)
        // availability — nothing divides by zero or goes NaN.
        let sim = Simulator::new();
        let sys = presets::system("a100x2").unwrap();
        let model = ModelConfig::gpt_small();
        let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
        let mut spec = FaultSpec::none();
        spec.events.push(event(FaultKind::Crash, 0.05, 2.0));
        spec.recovery.max_retries = 0;
        spec.recovery.request_timeout_s = Some(0.5);
        cfg.faults = Some(std::sync::Arc::new(spec));
        let reqs: Vec<Request> = (0..6u64)
            .map(|i| Request { id: i, arrival_s: 0.0, prompt_tokens: 64, output_tokens: 400 })
            .collect();
        let (metrics, stats) = simulate(&sim, &sys, &model, &cfg, &reqs);
        assert!(metrics.is_empty(), "every request should be lost");
        assert_eq!(stats.requests_lost, reqs.len() as u64);
        assert!(stats.makespan_s > 0.0);
        assert!(stats.availability.is_finite());
        assert!(stats.availability < 1.0, "downtime overlapped the whole run");
        assert!(stats.availability >= 0.0);
    }

    #[test]
    fn zero_fault_spec_matches_no_spec_baseline_in_all_modes() {
        let sim = Simulator::new();
        let sys = presets::system("a100x2").unwrap();
        let model = ModelConfig::gpt_small();
        for mode in all_modes() {
            let mut base = cfg_for(&sys, &model, Policy::Fcfs);
            base.mode = mode;
            let mut zero = base.clone();
            zero.faults = Some(std::sync::Arc::new(FaultSpec::none()));
            let reqs = generate(&WorkloadSpec::poisson(15.0, 60, 11));
            let (am, astats) = simulate(&sim, &sys, &model, &base, &reqs);
            let (bm, bstats) = simulate(&sim, &sys, &model, &zero, &reqs);
            assert_eq!(
                astats.to_json().to_string_pretty(),
                bstats.to_json().to_string_pretty(),
                "zero-fault stats diverged in {mode:?}"
            );
            for (x, y) in am.iter().zip(&bm) {
                assert_eq!(x.first_token_s.to_bits(), y.first_token_s.to_bits());
                assert_eq!(x.finish_s.to_bits(), y.finish_s.to_bits());
                assert!(!y.faulted);
            }
            assert_eq!(bstats.availability, 1.0);
            assert_eq!(bstats.faults_injected, 0);
        }
    }

    #[test]
    fn crash_without_retry_loses_inflight_requests() {
        let (sim, sys, model) = small_setup();
        let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
        cfg.max_batch = 8;
        let mut spec = FaultSpec::none();
        spec.events.push(event(FaultKind::Crash, 0.05, 2.0));
        spec.recovery.max_retries = 0;
        cfg.faults = Some(std::sync::Arc::new(spec));
        // Everything in flight at t=0.05 with long decodes: the crash hits.
        let reqs: Vec<Request> = (0..8u64)
            .map(|i| Request { id: i, arrival_s: 0.0, prompt_tokens: 64, output_tokens: 400 })
            .collect();
        let (metrics, stats) = simulate(&sim, &sys, &model, &cfg, &reqs);
        assert!(stats.requests_lost > 0, "crash at t=0.05 lost nothing");
        assert_eq!(stats.requests_retried, 0);
        assert_eq!(
            metrics.len() as u64 + stats.requests_lost + stats.requests_shed,
            reqs.len() as u64,
            "accounting does not conserve"
        );
        assert!(stats.availability < 1.0, "downtime not reflected in availability");
        assert!(stats.fault_downtime_s > 0.0);
        assert_eq!(stats.faults_injected, 1);
        // Survivors (late retries disabled ⇒ only never-admitted ones) finish.
        assert!(metrics.iter().all(|m| m.finish_s.is_finite()));
    }

    #[test]
    fn crash_with_retry_recomputes_and_completes_everything() {
        let (sim, sys, model) = small_setup();
        let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
        cfg.max_batch = 8;
        let mut spec = FaultSpec::none();
        spec.events.push(event(FaultKind::Crash, 0.05, 0.5));
        spec.recovery.max_retries = 3;
        spec.recovery.retry_backoff_s = 0.1;
        cfg.faults = Some(std::sync::Arc::new(spec));
        let reqs: Vec<Request> = (0..8u64)
            .map(|i| Request { id: i, arrival_s: 0.0, prompt_tokens: 64, output_tokens: 64 })
            .collect();
        let (metrics, stats) = simulate(&sim, &sys, &model, &cfg, &reqs);
        assert_eq!(metrics.len(), reqs.len(), "retries should recover every request");
        assert_eq!(stats.requests_lost, 0);
        assert!(stats.requests_retried > 0, "no request was retried");
        assert!(stats.retry_tokens_recomputed > 0, "retried prefills recompute KV");
        assert!(metrics.iter().any(|m| m.faulted), "retried requests are fault-marked");
        assert!(metrics.iter().all(|m| m.finish_s.is_finite()));
        let tokens: u64 = metrics.iter().map(|m| m.output_tokens).sum();
        assert_eq!(tokens, 8 * 64, "token output not conserved across retries");
    }

    #[test]
    fn drain_pauses_admission_but_loses_nothing() {
        let (sim, sys, model) = small_setup();
        let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
        let mut spec = FaultSpec::none();
        spec.events.push(event(FaultKind::Drain, 0.0, 1.0));
        cfg.faults = Some(std::sync::Arc::new(spec));
        let reqs = generate(&WorkloadSpec::poisson(30.0, 24, 7));
        let (metrics, stats) = simulate(&sim, &sys, &model, &cfg, &reqs);
        assert_eq!(metrics.len(), reqs.len());
        assert_eq!(stats.requests_lost, 0);
        assert_eq!(stats.requests_retried, 0);
        // Nothing admits inside [0, 1): every first token lands after rejoin.
        assert!(metrics.iter().all(|m| m.first_token_s >= 1.0));
        assert!(stats.availability < 1.0);
        // Baseline without the drain starts strictly earlier.
        cfg.faults = None;
        let (base, _) = simulate(&sim, &sys, &model, &cfg, &reqs);
        assert!(base.iter().any(|m| m.first_token_s < 1.0));
    }

    #[test]
    fn slowdown_window_stretches_the_makespan() {
        let (sim, sys, model) = small_setup();
        let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
        // Everything at t=0 so the makespan is service-dominated (an
        // arrival-limited trace would hide the slowdown in idle time).
        let reqs: Vec<Request> = (0..16u64)
            .map(|i| Request { id: i, arrival_s: 0.0, prompt_tokens: 256, output_tokens: 64 })
            .collect();
        let (_, base) = simulate(&sim, &sys, &model, &cfg, &reqs);
        let mut spec = FaultSpec::none();
        spec.events
            .push(event(FaultKind::Slowdown { multiplier: 4.0 }, 0.0, 1e9));
        cfg.faults = Some(std::sync::Arc::new(spec));
        let (metrics, slow) = simulate(&sim, &sys, &model, &cfg, &reqs);
        assert_eq!(metrics.len(), reqs.len());
        assert!(
            slow.makespan_s > base.makespan_s * 1.5,
            "4x slowdown barely moved makespan: {} vs {}",
            slow.makespan_s,
            base.makespan_s
        );
        // A slowdown is degradation, not downtime.
        assert_eq!(slow.availability, 1.0);
    }

    #[test]
    fn link_degradation_inflates_disagg_transfer_time() {
        let sim = Simulator::new();
        let sys = presets::system("a100x2").unwrap();
        let model = ModelConfig::gpt_small();
        let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
        cfg.mode = ServeMode::Disaggregated { prefill_devices: 1, transfer_base_s: 0.002 };
        let reqs = generate(&WorkloadSpec::poisson(30.0, 32, 5));
        let (_, base) = simulate(&sim, &sys, &model, &cfg, &reqs);
        let mut spec = FaultSpec::none();
        spec.events
            .push(event(FaultKind::LinkDegrade { factor: 8.0 }, 0.0, 1e9));
        cfg.faults = Some(std::sync::Arc::new(spec));
        let (metrics, degraded) = simulate(&sim, &sys, &model, &cfg, &reqs);
        assert_eq!(metrics.len(), reqs.len());
        assert!(
            degraded.transfer_total_s > base.transfer_total_s * 4.0,
            "8x link cut should multiply transfer time: {} vs {}",
            degraded.transfer_total_s,
            base.transfer_total_s
        );
    }

    #[test]
    fn shedding_and_timeouts_bound_the_queue() {
        let (sim, sys, model) = small_setup();
        let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
        cfg.max_batch = 2;
        cfg.max_prefill_batch = 1;
        let mut spec = FaultSpec::none();
        // Long drain builds a backlog; a tiny shed threshold rejects the
        // overflow at arrival, and a timeout reaps stale waiters.
        spec.events.push(event(FaultKind::Drain, 0.0, 5.0));
        spec.recovery.shed_queue_depth = Some(4);
        spec.recovery.request_timeout_s = Some(2.0);
        cfg.faults = Some(std::sync::Arc::new(spec));
        let reqs: Vec<Request> = (0..30u64)
            .map(|i| Request {
                id: i,
                arrival_s: i as f64 * 0.01,
                prompt_tokens: 64,
                output_tokens: 16,
            })
            .collect();
        let (metrics, stats) = simulate(&sim, &sys, &model, &cfg, &reqs);
        assert!(stats.requests_shed > 0, "queue depth 4 under 30 arrivals never shed");
        assert!(stats.requests_lost > 0, "2s timeout under a 5s drain never fired");
        assert_eq!(
            metrics.len() as u64 + stats.requests_lost + stats.requests_shed,
            reqs.len() as u64
        );
    }

    #[test]
    fn mtbf_fault_runs_are_byte_identical_across_replays() {
        let sim = Simulator::new();
        let sys = presets::system("a100x2").unwrap();
        let model = ModelConfig::gpt_small();
        for mode in all_modes() {
            let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
            cfg.mode = mode;
            // Aggressive MTBF so the run is statistically certain to be
            // struck several times within its few-second makespan.
            let mut spec = FaultSpec::mtbf(33, 0.5, 0.2);
            spec.recovery.max_retries = 2;
            cfg.faults = Some(std::sync::Arc::new(spec));
            let reqs = generate(&WorkloadSpec::poisson(15.0, 60, 13));
            let (am, astats) = simulate(&sim, &sys, &model, &cfg, &reqs);
            let (bm, bstats) = simulate(&sim, &sys, &model, &cfg, &reqs);
            assert_eq!(
                astats.to_json().to_string_pretty(),
                bstats.to_json().to_string_pretty(),
                "MTBF replay diverged in {mode:?}"
            );
            assert_eq!(am.len(), bm.len());
            for (x, y) in am.iter().zip(&bm) {
                assert_eq!(x.first_token_s.to_bits(), y.first_token_s.to_bits());
                assert_eq!(x.finish_s.to_bits(), y.finish_s.to_bits());
                assert_eq!(x.faulted, y.faulted);
            }
            assert!(astats.faults_injected > 0, "4s MTBF over a long run never struck");
            assert_eq!(
                am.len() as u64 + astats.requests_lost + astats.requests_shed,
                reqs.len() as u64
            );
        }
    }

    #[test]
    fn degraded_chunk_budget_shrinks_chunked_iterations() {
        let (sim, sys, model) = small_setup();
        let mut cfg = cfg_for(&sys, &model, Policy::Fcfs);
        cfg.mode = ServeMode::Chunked { chunk_tokens: 512 };
        let reqs: Vec<Request> = (0..8u64)
            .map(|i| Request { id: i, arrival_s: 0.0, prompt_tokens: 1024, output_tokens: 8 })
            .collect();
        let (_, base) = simulate(&sim, &sys, &model, &cfg, &reqs);
        let mut spec = FaultSpec::none();
        spec.events
            .push(event(FaultKind::Slowdown { multiplier: 1.0 }, 0.0, 1e9));
        spec.recovery.degraded_chunk_tokens = Some(64);
        cfg.faults = Some(std::sync::Arc::new(spec));
        let (metrics, deg) = simulate(&sim, &sys, &model, &cfg, &reqs);
        assert_eq!(metrics.len(), reqs.len());
        assert!(
            deg.prefill_iterations + deg.mixed_iterations
                > base.prefill_iterations + base.mixed_iterations,
            "64-token degraded chunks should take more iterations"
        );
    }
}
