//! The continuous-batching scheduler: a discrete-event simulation of one
//! inference cluster, with [`crate::graph::inference::Simulator`] as the
//! latency oracle.
//!
//! The engine models iteration-level (Orca/vLLM-style) scheduling:
//!
//! * Requests arrive on an open-loop trace and wait in an admission queue.
//! * Between iterations the scheduler admits waiting requests into the
//!   running batch, reserving KV-cache memory for their full
//!   `prompt + output` footprint against the cluster budget (derived from
//!   device memory capacity minus resident parameters) — conservative
//!   admission means no preemption/eviction is ever needed.
//! * An iteration is either a **prefill** of the just-admitted requests
//!   (which also emits their first output token) or one **decode** step of
//!   the whole running batch; prefills take priority, which is what keeps
//!   TTFT bounded under load at some cost to time-between-tokens.
//! * Iteration latency comes from the analytical simulator through a
//!   quantizing [`IterOracle`], so a million-token trace touches only a
//!   handful of unique mapper shapes.
//!
//! The clock only ever advances by iteration latencies or idle gaps to the
//! next arrival, so simulating thousands of requests is dominated by the
//! (cached) oracle calls, not by the event loop.

use super::metrics::RequestMetrics;
use super::workload::Request;
use crate::graph::inference::Simulator;
use crate::graph::ModelConfig;
use crate::hardware::SystemSpec;
use std::collections::HashMap;
use std::sync::Mutex;

/// Admission-ordering policy for the waiting queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// First-come first-served (arrival order).
    Fcfs,
    /// Shortest-prompt-first: cheapest prefills jump the queue, trading
    /// worst-case fairness for lower mean TTFT under prefill pressure.
    ShortestPromptFirst,
}

impl Policy {
    pub fn parse(v: &str) -> Option<Policy> {
        match v {
            "fcfs" | "fifo" => Some(Policy::Fcfs),
            "spf" | "shortest-prompt-first" => Some(Policy::ShortestPromptFirst),
            _ => None,
        }
    }

    /// Canonical name, accepted back by [`Policy::parse`].
    pub fn name(self) -> &'static str {
        match self {
            Policy::Fcfs => "fcfs",
            Policy::ShortestPromptFirst => "spf",
        }
    }
}

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Maximum concurrent sequences in the running batch.
    pub max_batch: u64,
    /// Cluster-wide KV-cache budget in tokens (see [`kv_capacity_tokens`]).
    pub kv_capacity_tokens: u64,
    pub policy: Policy,
    /// Maximum requests prefilled in one iteration (bounds padded prefill
    /// cost per iteration).
    pub max_prefill_batch: u64,
}

impl SchedulerConfig {
    /// Derive a configuration from hardware + model: KV budget from memory
    /// capacity, batch cap from a target per-iteration concurrency.
    pub fn for_system(sys: &SystemSpec, model: &ModelConfig, policy: Policy) -> SchedulerConfig {
        SchedulerConfig {
            max_batch: 64,
            kv_capacity_tokens: kv_capacity_tokens(sys, model),
            policy,
            max_prefill_batch: 8,
        }
    }
}

/// Cluster-wide KV-cache token budget under tensor parallelism: every
/// device holds `params / tp` resident weight bytes and `kv_per_token / tp`
/// per cached token, so the binding constraint is per-device free memory:
///
/// `tokens = tp · (capacity − params/tp) / kv_bytes_per_token`.
///
/// Returns 0 when the shard of parameters alone overflows a device.
pub fn kv_capacity_tokens(sys: &SystemSpec, model: &ModelConfig) -> u64 {
    let tp = sys.device_count.max(1);
    let cap = sys.device.memory.capacity_bytes as f64;
    let params_per_dev = model.param_bytes(model.layers) as f64 / tp as f64;
    if params_per_dev >= cap {
        return 0;
    }
    let kv_per_token = (model.kv_bytes_per_token_per_layer() * model.layers) as f64;
    ((cap - params_per_dev) * tp as f64 / kv_per_token).floor() as u64
}

/// Quantizing latency oracle over the analytical simulator.
///
/// Decode latency is affine in the KV length at fixed batch (weights
/// dominate, attention reads grow linearly), so per power-of-two batch
/// bucket the oracle samples two KV points and interpolates. Prefill is
/// cached per (batch bucket, power-of-two sequence bucket). This bounds
/// the number of distinct mapper searches for an arbitrarily long trace.
pub struct IterOracle<'a> {
    sim: &'a Simulator,
    sys: &'a SystemSpec,
    model: &'a ModelConfig,
    /// batch bucket → (latency at KV_LO, slope per KV token).
    decode_fit: Mutex<HashMap<u64, (f64, f64)>>,
    /// (batch bucket, seq bucket) → prefill seconds.
    prefill_cache: Mutex<HashMap<(u64, u64), f64>>,
}

/// KV sample points for the affine decode fit.
const KV_LO: u64 = 64;
const KV_HI: u64 = 4096;

fn pow2_bucket(v: u64) -> u64 {
    v.max(1).next_power_of_two()
}

impl<'a> IterOracle<'a> {
    pub fn new(sim: &'a Simulator, sys: &'a SystemSpec, model: &'a ModelConfig) -> Self {
        IterOracle {
            sim,
            sys,
            model,
            decode_fit: Mutex::new(HashMap::new()),
            prefill_cache: Mutex::new(HashMap::new()),
        }
    }

    /// Latency of one decode iteration for `batch` sequences at mean KV
    /// length `kv_len`.
    pub fn decode(&self, batch: u64, kv_len: u64) -> f64 {
        let b = pow2_bucket(batch);
        // Take the guard in its own statement so it drops before the
        // (slow) simulator calls and before re-locking to insert.
        let cached = self.decode_fit.lock().unwrap().get(&b).copied();
        let (lo, slope) = match cached {
            Some(fit) => fit,
            None => {
                let l_lo = self.sim.decode(self.sys, self.model, b, KV_LO, self.model.layers);
                let l_hi = self.sim.decode(self.sys, self.model, b, KV_HI, self.model.layers);
                let fit = (l_lo, (l_hi - l_lo) / (KV_HI - KV_LO) as f64);
                self.decode_fit.lock().unwrap().insert(b, fit);
                fit
            }
        };
        (lo + slope * (kv_len.max(KV_LO) - KV_LO) as f64).max(0.0)
    }

    /// Latency of one prefill iteration: `batch` prompts padded to the
    /// bucketed `seq` length.
    pub fn prefill(&self, batch: u64, seq: u64) -> f64 {
        let key = (pow2_bucket(batch), pow2_bucket(seq));
        if let Some(&s) = self.prefill_cache.lock().unwrap().get(&key) {
            return s;
        }
        let s = self.sim.prefill(self.sys, self.model, key.0, key.1, self.model.layers);
        self.prefill_cache.lock().unwrap().insert(key, s);
        s
    }

    /// Number of unique (batch, seq/kv) points simulated so far.
    pub fn cached_points(&self) -> usize {
        self.decode_fit.lock().unwrap().len() * 2 + self.prefill_cache.lock().unwrap().len()
    }
}

/// Per-iteration accounting of the simulated run.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub prefill_iterations: u64,
    pub decode_iterations: u64,
    pub prefill_busy_s: f64,
    pub decode_busy_s: f64,
    pub idle_s: f64,
    /// Peak KV tokens reserved at any point (sampled at the per-iteration
    /// high-water mark, before completions release their reservations).
    pub peak_kv_tokens: u64,
    /// Peak concurrent sequences in flight (running + just admitted).
    pub peak_batch: u64,
    /// Wall-clock of the simulated run (last completion time).
    pub makespan_s: f64,
}

impl RunStats {
    /// Stable JSON rendering (part of the `eval` report schema).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num, obj};
        obj(vec![
            ("prefill_iterations", num(self.prefill_iterations as f64)),
            ("decode_iterations", num(self.decode_iterations as f64)),
            ("prefill_busy_s", num(self.prefill_busy_s)),
            ("decode_busy_s", num(self.decode_busy_s)),
            ("idle_s", num(self.idle_s)),
            ("peak_kv_tokens", num(self.peak_kv_tokens as f64)),
            ("peak_batch", num(self.peak_batch as f64)),
            ("makespan_s", num(self.makespan_s)),
        ])
    }
}

/// One request in flight.
struct Running {
    idx: usize,
    /// Tokens generated so far (first one comes from prefill).
    generated: u64,
    /// Current KV footprint in tokens.
    kv_tokens: u64,
}

/// Simulate serving `requests` (sorted by arrival) on the cluster.
/// Returns per-request metrics (in input order) plus run statistics.
pub fn simulate(
    oracle: &IterOracle<'_>,
    cfg: &SchedulerConfig,
    requests: &[Request],
) -> (Vec<RequestMetrics>, RunStats) {
    assert!(cfg.max_batch > 0, "max_batch must be ≥ 1");
    assert!(cfg.max_prefill_batch > 0, "max_prefill_batch must be ≥ 1");
    for r in requests {
        assert!(
            r.total_tokens() <= cfg.kv_capacity_tokens,
            "request {} needs {} KV tokens but the cluster budget is {} — \
             it can never be admitted",
            r.id,
            r.total_tokens(),
            cfg.kv_capacity_tokens
        );
    }

    let mut metrics: Vec<RequestMetrics> = requests
        .iter()
        .map(|r| RequestMetrics {
            id: r.id,
            arrival_s: r.arrival_s,
            prompt_tokens: r.prompt_tokens,
            output_tokens: r.output_tokens,
            first_token_s: f64::NAN,
            finish_s: f64::NAN,
        })
        .collect();
    let mut stats = RunStats::default();

    let mut t = 0.0f64;
    let mut next_arrival = 0usize; // index into `requests`
    let mut waiting: Vec<usize> = Vec::new();
    let mut running: Vec<Running> = Vec::new();
    let mut kv_reserved = 0u64;
    let mut completed = 0usize;

    while completed < requests.len() {
        // 1. Ingest arrivals up to the current clock, keeping `waiting` in
        //    policy order as it grows: FCFS appends (arrival order), SPF
        //    inserts at the (prompt, id)-sorted position — same order a
        //    stable sort by that key would give, without re-sorting the
        //    backlog every iteration.
        while next_arrival < requests.len() && requests[next_arrival].arrival_s <= t {
            match cfg.policy {
                Policy::Fcfs => waiting.push(next_arrival),
                Policy::ShortestPromptFirst => {
                    let key = (requests[next_arrival].prompt_tokens, next_arrival);
                    let pos = waiting
                        .partition_point(|&i| (requests[i].prompt_tokens, i) < key);
                    waiting.insert(pos, next_arrival);
                }
            }
            next_arrival += 1;
        }

        // 2. Admit from the waiting queue under the KV budget + batch cap.
        //    Admission is greedy in queue order (no skipping ahead past a
        //    request that does not fit — FCFS head-of-line blocking is
        //    part of what the policy choice is about).
        let mut admitted: Vec<usize> = Vec::new();
        while admitted.len() < cfg.max_prefill_batch as usize
            && !waiting.is_empty()
            && running.len() + admitted.len() < cfg.max_batch as usize
        {
            let cand = waiting[0];
            let need = requests[cand].total_tokens();
            if kv_reserved + need > cfg.kv_capacity_tokens {
                break;
            }
            kv_reserved += need;
            admitted.push(cand);
            waiting.remove(0);
        }

        // Peaks are sampled here — reservations for this iteration are all
        // taken and nothing has completed yet, so this is the true
        // high-water mark (completions release KV later in the loop).
        stats.peak_kv_tokens = stats.peak_kv_tokens.max(kv_reserved);
        stats.peak_batch = stats.peak_batch.max((running.len() + admitted.len()) as u64);

        if !admitted.is_empty() {
            // 3a. Prefill iteration for the admitted requests (padded to
            // the longest prompt). Emits each request's first token.
            let batch = admitted.len() as u64;
            let max_prompt =
                admitted.iter().map(|&i| requests[i].prompt_tokens).max().unwrap();
            let dt = oracle.prefill(batch, max_prompt);
            t += dt;
            stats.prefill_iterations += 1;
            stats.prefill_busy_s += dt;
            for &i in &admitted {
                metrics[i].first_token_s = t;
                if requests[i].output_tokens <= 1 {
                    // Prefill's own logits were the whole answer.
                    metrics[i].finish_s = t;
                    kv_reserved -= requests[i].total_tokens();
                    completed += 1;
                } else {
                    running.push(Running {
                        idx: i,
                        generated: 1,
                        kv_tokens: requests[i].prompt_tokens + 1,
                    });
                }
            }
        } else if !running.is_empty() {
            // 3b. One decode step of the whole running batch at its mean
            // KV length (attention cost is linear in KV, so the mean gives
            // the right batch total).
            let batch = running.len() as u64;
            let mean_kv =
                running.iter().map(|r| r.kv_tokens).sum::<u64>() / batch;
            let dt = oracle.decode(batch, mean_kv);
            t += dt;
            stats.decode_iterations += 1;
            stats.decode_busy_s += dt;
            let mut i = 0;
            while i < running.len() {
                running[i].generated += 1;
                running[i].kv_tokens += 1;
                if running[i].generated >= requests[running[i].idx].output_tokens {
                    let done = running.swap_remove(i);
                    metrics[done.idx].finish_s = t;
                    kv_reserved -= requests[done.idx].total_tokens();
                    completed += 1;
                } else {
                    i += 1;
                }
            }
        } else {
            // 3c. Idle: nothing running and nothing admittable. If
            // requests are waiting but over budget, that is a permanent
            // stall only if nothing is running — guarded by the assert
            // above (every request fits an empty cluster).
            debug_assert!(waiting.is_empty(), "waiting requests with an idle cluster");
            if next_arrival >= requests.len() {
                break; // all requests ingested and completed
            }
            // Step 1 ingested everything with arrival ≤ t, so the gap is
            // strictly positive here.
            stats.idle_s += requests[next_arrival].arrival_s - t;
            t = requests[next_arrival].arrival_s;
        }
    }

    stats.makespan_s = t;
    (metrics, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::presets;
    use crate::serve::workload::{generate, Request, WorkloadSpec};

    fn small_setup() -> (Simulator, SystemSpec, ModelConfig) {
        (Simulator::new(), presets::system("a100").unwrap(), ModelConfig::gpt_small())
    }

    #[test]
    fn kv_capacity_matches_hand_calculation() {
        let m = ModelConfig::gpt3_175b();
        let sys = presets::system("a100x8").unwrap();
        let tokens = kv_capacity_tokens(&sys, &m);
        // 8 × 80 GB − 350 GB of weights ≈ 290 GB free; 4.5 MiB/token KV.
        let free = 8.0 * 80e9 - m.param_bytes(m.layers) as f64;
        let expect = free / (m.kv_bytes_per_token_per_layer() * m.layers) as f64;
        assert!((tokens as f64 - expect).abs() < 2.0, "{tokens} vs {expect:.0}");
        // One A100 cannot even hold the weights.
        assert_eq!(kv_capacity_tokens(&presets::system("a100").unwrap(), &m), 0);
    }

    #[test]
    fn oracle_decode_affine_and_monotone_in_kv() {
        let (sim, sys, model) = small_setup();
        let oracle = IterOracle::new(&sim, &sys, &model);
        let l1 = oracle.decode(8, 256);
        let l2 = oracle.decode(8, 1024);
        let l3 = oracle.decode(8, 4096);
        assert!(l1 > 0.0);
        assert!(l2 >= l1 && l3 >= l2, "decode not monotone: {l1} {l2} {l3}");
        // Affine: midpoint interpolates exactly.
        let mid = oracle.decode(8, (256 + 4096) / 2);
        let lin = l1 + (l3 - l1) * ((256 + 4096) / 2 - 256) as f64 / (4096 - 256) as f64;
        assert!((mid - lin).abs() < 1e-12);
        // Bucketing: batches 5..8 share a fit.
        assert_eq!(oracle.decode(5, 1024), oracle.decode(8, 1024));
    }

    #[test]
    fn all_requests_complete_with_sane_timelines() {
        let (sim, sys, model) = small_setup();
        let oracle = IterOracle::new(&sim, &sys, &model);
        let cfg = SchedulerConfig {
            max_batch: 16,
            kv_capacity_tokens: kv_capacity_tokens(&sys, &model),
            policy: Policy::Fcfs,
            max_prefill_batch: 4,
        };
        let reqs = generate(&WorkloadSpec::poisson(20.0, 200, 5));
        let (metrics, stats) = simulate(&oracle, &cfg, &reqs);
        assert_eq!(metrics.len(), 200);
        for m in &metrics {
            assert!(m.first_token_s.is_finite(), "request {} never prefetched", m.id);
            assert!(m.finish_s.is_finite(), "request {} never finished", m.id);
            assert!(m.first_token_s > m.arrival_s);
            assert!(m.finish_s >= m.first_token_s);
        }
        assert!(stats.prefill_iterations > 0 && stats.decode_iterations > 0);
        assert!(stats.makespan_s >= reqs.last().unwrap().arrival_s);
        assert!(stats.peak_batch <= 16);
        assert!(stats.peak_kv_tokens <= cfg.kv_capacity_tokens);
        // Oracle quantization keeps the simulated shape set tiny.
        assert!(oracle.cached_points() < 64, "{} oracle points", oracle.cached_points());
    }

    #[test]
    fn deterministic_for_same_inputs() {
        let (sim, sys, model) = small_setup();
        let oracle = IterOracle::new(&sim, &sys, &model);
        let cfg = SchedulerConfig::for_system(&sys, &model, Policy::Fcfs);
        let reqs = generate(&WorkloadSpec::poisson(10.0, 64, 9));
        let (a, _) = simulate(&oracle, &cfg, &reqs);
        let (b, _) = simulate(&oracle, &cfg, &reqs);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.first_token_s, y.first_token_s);
            assert_eq!(x.finish_s, y.finish_s);
        }
    }

    #[test]
    fn spf_prefers_short_prompts_under_backlog() {
        let (sim, sys, model) = small_setup();
        let oracle = IterOracle::new(&sim, &sys, &model);
        // Everything arrives at t=0: a long-prompt request first, then
        // short ones. SPF should give the short ones earlier first tokens.
        let mut reqs = vec![Request {
            id: 0,
            arrival_s: 0.0,
            prompt_tokens: 2048,
            output_tokens: 4,
        }];
        for i in 1..6u64 {
            reqs.push(Request {
                id: i,
                arrival_s: 0.0,
                prompt_tokens: 32,
                output_tokens: 4,
            });
        }
        let mk = |policy| SchedulerConfig {
            max_batch: 2,
            kv_capacity_tokens: kv_capacity_tokens(&sys, &model),
            policy,
            max_prefill_batch: 1,
        };
        let (fcfs, _) = simulate(&oracle, &mk(Policy::Fcfs), &reqs);
        let (spf, _) = simulate(&oracle, &mk(Policy::ShortestPromptFirst), &reqs);
        let mean_short_ttft = |ms: &[RequestMetrics]| {
            ms.iter().skip(1).map(|m| m.first_token_s - m.arrival_s).sum::<f64>() / 5.0
        };
        assert!(
            mean_short_ttft(&spf) < mean_short_ttft(&fcfs),
            "SPF {:.4} vs FCFS {:.4}",
            mean_short_ttft(&spf),
            mean_short_ttft(&fcfs)
        );
        // FCFS serves the long prompt first.
        assert!(fcfs[0].first_token_s <= spf[0].first_token_s);
    }

    #[test]
    #[should_panic(expected = "never be admitted")]
    fn oversized_request_panics_up_front() {
        let (sim, sys, model) = small_setup();
        let oracle = IterOracle::new(&sim, &sys, &model);
        let cfg = SchedulerConfig {
            max_batch: 4,
            kv_capacity_tokens: 100,
            policy: Policy::Fcfs,
            max_prefill_batch: 4,
        };
        let reqs = vec![Request {
            id: 0,
            arrival_s: 0.0,
            prompt_tokens: 200,
            output_tokens: 10,
        }];
        simulate(&oracle, &cfg, &reqs);
    }
}
