//! Workload generation for the cluster serving simulator: open-loop
//! arrival processes (Poisson, bursty/Markov-modulated) and trace replay,
//! with configurable prompt/output-length distributions.
//!
//! Serving-oriented benchmarks (LLM-Inference-Bench and the production
//! traces they draw on) show that *when* requests arrive matters as much
//! as what they ask for: the same aggregate rate delivered smoothly or in
//! bursts produces very different queueing delay and tail latency. All
//! generators here are driven by [`crate::util::prng::Rng`], so a seed
//! fully determines a trace and experiments replay bit-identically.

use crate::util::prng::Rng;

/// One serving request in the open-loop trace.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time, seconds from trace start.
    pub arrival_s: f64,
    /// Prompt (input) length in tokens.
    pub prompt_tokens: u64,
    /// Requested output length in tokens (≥ 1; the first token comes from
    /// prefill itself).
    pub output_tokens: u64,
}

impl Request {
    /// KV-cache tokens this request holds when fully generated.
    pub fn total_tokens(&self) -> u64 {
        self.prompt_tokens + self.output_tokens
    }
}

/// Arrival process of the open-loop workload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// Memoryless arrivals at `rate_per_s`: exponential inter-arrival gaps.
    Poisson { rate_per_s: f64 },
    /// Two-state Markov-modulated Poisson process: a calm state at
    /// `rate_per_s` and a burst state at `burst_multiplier × rate_per_s`,
    /// with geometric dwell times of `mean_phase_requests` requests per
    /// state. Models diurnal spikes and thundering herds.
    Bursty {
        rate_per_s: f64,
        burst_multiplier: f64,
        mean_phase_requests: f64,
    },
}

/// Request-length distribution (used for both prompt and output lengths).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LengthDist {
    Fixed(u64),
    /// Uniform over `[lo, hi]` inclusive.
    Uniform { lo: u64, hi: u64 },
    /// Skewed toward short requests over `[1, max]` (quadratic-inverse CDF
    /// via [`Rng::skewed`]) — the shape of interactive chat traces, where
    /// most turns are short and a heavy tail is long.
    Skewed { max: u64 },
}

impl LengthDist {
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        match *self {
            LengthDist::Fixed(n) => n.max(1),
            LengthDist::Uniform { lo, hi } => rng.range(lo.max(1), hi.max(lo.max(1))),
            LengthDist::Skewed { max } => rng.skewed(max.max(1)) + 1,
        }
    }

    /// Largest value the distribution can produce (for KV reservations).
    pub fn max_value(&self) -> u64 {
        match *self {
            LengthDist::Fixed(n) => n.max(1),
            LengthDist::Uniform { lo, hi } => hi.max(lo.max(1)),
            LengthDist::Skewed { max } => max.max(1),
        }
    }
}

/// Smooth day/night swing of the arrival rate: a raised-cosine cycle of
/// `period_s` seconds that multiplies the base rate by 1.0 at the trough
/// (t = 0) and by `peak_multiplier` at the crest (t = period/2). Composes
/// multiplicatively with the base process and with [`FlashCrowd`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Diurnal {
    pub period_s: f64,
    pub peak_multiplier: f64,
}

/// A one-off traffic spike (launch event, viral moment): the arrival rate
/// is multiplied by `multiplier` inside `[at_s, at_s + duration_s)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlashCrowd {
    pub at_s: f64,
    pub duration_s: f64,
    pub multiplier: f64,
}

/// A complete workload specification.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub arrival: Arrival,
    pub prompt: LengthDist,
    pub output: LengthDist,
    pub requests: usize,
    pub seed: u64,
    /// Optional diurnal rate modulation on top of the base process.
    pub diurnal: Option<Diurnal>,
    /// Optional flash-crowd spike on top of the base process.
    pub flash_crowd: Option<FlashCrowd>,
}

impl WorkloadSpec {
    /// A Poisson workload with chat-shaped lengths — the default for the
    /// `serve` CLI and the SLO sweep.
    pub fn poisson(rate_per_s: f64, requests: usize, seed: u64) -> WorkloadSpec {
        WorkloadSpec {
            arrival: Arrival::Poisson { rate_per_s },
            prompt: LengthDist::Uniform { lo: 128, hi: 2048 },
            output: LengthDist::Skewed { max: 512 },
            requests,
            seed,
            diurnal: None,
            flash_crowd: None,
        }
    }

    /// Rate multiplier contributed by diurnal/flash-crowd modulation at
    /// trace time `t` (1.0 when no modulation is configured).
    pub fn rate_multiplier_at(&self, t: f64) -> f64 {
        let mut m = 1.0;
        if let Some(d) = self.diurnal {
            let phase = t / d.period_s.max(1e-9) * std::f64::consts::TAU;
            m *= 1.0 + (d.peak_multiplier.max(1.0) - 1.0) * 0.5 * (1.0 - phase.cos());
        }
        if let Some(f) = self.flash_crowd {
            if t >= f.at_s && t < f.at_s + f.duration_s {
                m *= f.multiplier.max(1.0);
            }
        }
        m
    }
}

/// Generate the request trace for a spec. Arrivals are monotone in time
/// and ids are assigned in arrival order.
pub fn generate(spec: &WorkloadSpec) -> Vec<Request> {
    let mut rng = Rng::new(spec.seed);
    let mut t = 0.0f64;
    let mut in_burst = false;
    let mut out = Vec::with_capacity(spec.requests);
    for id in 0..spec.requests as u64 {
        let rate = match spec.arrival {
            Arrival::Poisson { rate_per_s } => rate_per_s,
            Arrival::Bursty { rate_per_s, burst_multiplier, mean_phase_requests } => {
                // Geometric phase dwell: leave the current state with
                // probability 1/mean_phase_requests per request.
                if rng.chance(1.0 / mean_phase_requests.max(1.0)) {
                    in_burst = !in_burst;
                }
                if in_burst {
                    rate_per_s * burst_multiplier.max(1.0)
                } else {
                    rate_per_s
                }
            }
        };
        assert!(rate > 0.0, "arrival rate must be positive");
        // Diurnal/flash modulation: piecewise-constant approximation — the
        // effective rate is evaluated at the previous arrival's timestamp,
        // so the no-modulation path stays bit-identical to older traces
        // (no extra RNG draws, no multiply by 1.0).
        let rate = if spec.diurnal.is_some() || spec.flash_crowd.is_some() {
            rate * spec.rate_multiplier_at(t)
        } else {
            rate
        };
        // Exponential inter-arrival gap: −ln(1−u)/λ, u ∈ [0,1).
        t += -(1.0 - rng.f64()).ln() / rate;
        out.push(Request {
            id,
            arrival_s: t,
            prompt_tokens: spec.prompt.sample(&mut rng),
            output_tokens: spec.output.sample(&mut rng),
        });
    }
    out
}

/// Parse a replay trace: one request per line, `arrival_s,prompt,output`,
/// `#`-prefixed comment lines and blank lines ignored. Lines may arrive
/// unsorted; the result is sorted by arrival time with ids reassigned in
/// arrival order.
pub fn parse_trace(text: &str) -> Result<Vec<Request>, String> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        if fields.len() != 3 {
            return Err(format!(
                "trace line {}: expected `arrival_s,prompt,output`, got `{line}`",
                lineno + 1
            ));
        }
        let arrival_s: f64 = fields[0]
            .parse()
            .map_err(|_| format!("trace line {}: bad arrival `{}`", lineno + 1, fields[0]))?;
        let prompt_tokens: u64 = fields[1]
            .parse()
            .map_err(|_| format!("trace line {}: bad prompt length `{}`", lineno + 1, fields[1]))?;
        let output_tokens: u64 = fields[2]
            .parse()
            .map_err(|_| format!("trace line {}: bad output length `{}`", lineno + 1, fields[2]))?;
        if !arrival_s.is_finite() || arrival_s < 0.0 || prompt_tokens == 0 || output_tokens == 0 {
            return Err(format!(
                "trace line {}: arrival must be finite and ≥ 0, lengths ≥ 1",
                lineno + 1
            ));
        }
        out.push(Request { id: 0, arrival_s, prompt_tokens, output_tokens });
    }
    out.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    for (i, r) in out.iter_mut().enumerate() {
        r.id = i as u64;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn poisson_rate_is_respected() {
        let spec = WorkloadSpec::poisson(4.0, 4000, 11);
        let reqs = generate(&spec);
        assert_eq!(reqs.len(), 4000);
        let span = reqs.last().unwrap().arrival_s;
        let rate = reqs.len() as f64 / span;
        assert!((rate - 4.0).abs() / 4.0 < 0.1, "empirical rate {rate:.2}");
        // Monotone arrivals, ids in order.
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
            assert_eq!(w[1].id, w[0].id + 1);
        }
    }

    #[test]
    fn trace_is_deterministic_per_seed() {
        let spec = WorkloadSpec::poisson(2.0, 100, 7);
        assert_eq!(generate(&spec), generate(&spec));
        let other = WorkloadSpec { seed: 8, ..spec };
        assert_ne!(generate(&spec), generate(&other));
    }

    #[test]
    fn bursty_has_higher_variance_than_poisson_at_same_rate() {
        let n = 4000;
        let poisson = generate(&WorkloadSpec::poisson(4.0, n, 3));
        let bursty = generate(&WorkloadSpec {
            arrival: Arrival::Bursty {
                rate_per_s: 4.0,
                burst_multiplier: 8.0,
                mean_phase_requests: 50.0,
            },
            ..WorkloadSpec::poisson(4.0, n, 3)
        });
        let gaps = |rs: &[Request]| -> Vec<f64> {
            rs.windows(2).map(|w| w[1].arrival_s - w[0].arrival_s).collect()
        };
        // Burstiness shows up as a higher coefficient of variation of the
        // inter-arrival gaps (Poisson has CV ≈ 1).
        let cv = |g: &[f64]| stats::stddev(g) / stats::mean(g);
        let cv_p = cv(&gaps(&poisson));
        let cv_b = cv(&gaps(&bursty));
        assert!((cv_p - 1.0).abs() < 0.15, "poisson CV {cv_p:.2}");
        assert!(cv_b > cv_p, "bursty CV {cv_b:.2} vs poisson {cv_p:.2}");
    }

    #[test]
    fn diurnal_modulation_concentrates_arrivals_near_the_crest() {
        let base = WorkloadSpec::poisson(4.0, 4000, 9);
        let period = 100.0;
        let spec = WorkloadSpec {
            diurnal: Some(Diurnal { period_s: period, peak_multiplier: 8.0 }),
            ..base.clone()
        };
        let flat = generate(&base);
        let waved = generate(&spec);
        // The unmodulated path is untouched by the (None, None) fields.
        assert_eq!(flat, generate(&WorkloadSpec { diurnal: None, ..base.clone() }));
        assert_ne!(flat, waved);
        // Crest half of each cycle ([P/4, 3P/4), cosine minimum at P/2)
        // must hold clearly more arrivals than the trough half.
        let (mut crest, mut trough) = (0usize, 0usize);
        for r in &waved {
            let phase = (r.arrival_s / period).fract();
            if (0.25..0.75).contains(&phase) {
                crest += 1;
            } else {
                trough += 1;
            }
        }
        assert!(
            crest as f64 > trough as f64 * 2.0,
            "diurnal peak 8x left crest/trough at {crest}/{trough}"
        );
        // Multiplier is exact at the landmarks: 1.0 at trough, peak at crest.
        assert!((spec.rate_multiplier_at(0.0) - 1.0).abs() < 1e-9);
        assert!((spec.rate_multiplier_at(period / 2.0) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn flash_crowd_spikes_local_density() {
        let base = WorkloadSpec::poisson(2.0, 2000, 21);
        let spec = WorkloadSpec {
            flash_crowd: Some(FlashCrowd { at_s: 50.0, duration_s: 20.0, multiplier: 10.0 }),
            ..base
        };
        let reqs = generate(&spec);
        let count_in = |lo: f64, hi: f64| {
            reqs.iter().filter(|r| r.arrival_s >= lo && r.arrival_s < hi).count()
        };
        let inside = count_in(50.0, 70.0);
        let before = count_in(20.0, 40.0);
        assert!(
            inside as f64 > before as f64 * 3.0,
            "10x flash crowd barely moved density: {inside} in-window vs {before} before"
        );
        // Outside the window the multiplier is exactly 1.
        assert_eq!(spec.rate_multiplier_at(49.9), 1.0);
        assert_eq!(spec.rate_multiplier_at(70.0), 1.0);
        assert_eq!(spec.rate_multiplier_at(55.0), 10.0);
    }

    #[test]
    fn length_dists_bounded() {
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let u = LengthDist::Uniform { lo: 10, hi: 20 }.sample(&mut rng);
            assert!((10..=20).contains(&u));
            let s = LengthDist::Skewed { max: 64 }.sample(&mut rng);
            assert!((1..=64).contains(&s));
            assert_eq!(LengthDist::Fixed(0).sample(&mut rng), 1);
        }
        assert_eq!(LengthDist::Uniform { lo: 10, hi: 20 }.max_value(), 20);
        assert_eq!(LengthDist::Skewed { max: 64 }.max_value(), 64);
    }

    #[test]
    fn parse_trace_roundtrip_and_errors() {
        let text = "# t,prompt,output\n0.5, 128, 32\n0.1,64,8\n\n1.0,256,1\n";
        let reqs = parse_trace(text).unwrap();
        assert_eq!(reqs.len(), 3);
        // Sorted by arrival with ids reassigned.
        assert_eq!(reqs[0].arrival_s, 0.1);
        assert_eq!(reqs[0].prompt_tokens, 64);
        assert_eq!(reqs[0].id, 0);
        assert_eq!(reqs[2].arrival_s, 1.0);
        assert_eq!(reqs[2].id, 2);
        assert!(parse_trace("1.0,2").is_err());
        assert!(parse_trace("x,2,3").is_err());
        assert!(parse_trace("1.0,0,3").is_err());
        assert!(parse_trace("nan,2,3").is_err());
        assert!(parse_trace("inf,2,3").is_err());
        assert!(parse_trace("-1.0,2,3").is_err());
    }
}
