//! Fault injection for the serving simulator: seeded, deterministic
//! replica/pool failures plus the recovery policy that reacts to them.
//!
//! A [`FaultSpec`] schedules four kinds of events against the
//! discrete-event engines in [`super::scheduler`]:
//!
//! * **crash** — the pool goes down for a window: every in-flight request
//!   on it loses its KV state and progress (re-dispatched or lost per the
//!   [`RecoveryPolicy`]); no admission until the window ends.
//! * **drain** — the pool stops admitting for a window but finishes its
//!   in-flight work, then rejoins (a maintenance restart).
//! * **slowdown** — iteration latencies on the pool are multiplied for a
//!   window (thermal throttle, a degraded HBM stack).
//! * **link degradation** — the modeled interconnect transfer time is
//!   multiplied for a window (a cut fabric lane), stressing the
//!   disaggregated KV-handoff path.
//!
//! Events come from an explicit list and/or an MTBF process: with
//! [`FaultSpec::mtbf_s`] set, whole-pool crashes recur with exponential
//! inter-arrival gaps drawn from a **dedicated seeded RNG stream**
//! ([`FaultSpec::seed`]), generated lazily but monotonically so replay is
//! byte-identical regardless of how the engines interleave their pool
//! clocks. A spec with no events, no MTBF, and no recovery pressure knobs
//! is completely inert: the engines take the exact same float path as a
//! run with no spec at all (multiplying a latency by `1.0` is bit-exact),
//! which the tests assert as byte-identical `ServeReport` JSON.
//!
//! The [`Faults`] runtime answers the engines' questions (`admitting?`,
//! `pending crash?`, `latency multiplier?`) against a pool identity:
//! single-pool engines (monolithic, chunked) match every target;
//! disaggregated matches `prefill`/`decode` targets to the corresponding
//! pool. Window membership is half-open `[start, end)` — at `end` the
//! pool is back.

use crate::util::prng::Rng;

/// Default mean-time-to-repair for MTBF-generated crashes, seconds.
pub const DEFAULT_MTTR_S: f64 = 30.0;
/// Default retry budget of the recovery policy.
pub const DEFAULT_MAX_RETRIES: u64 = 2;
/// Default base backoff before a crashed request is re-dispatched,
/// seconds (doubles per retry).
pub const DEFAULT_RETRY_BACKOFF_S: f64 = 0.5;

/// Which pool (or fleet replica) a fault event strikes. Single-pool
/// engines treat every pool target as "this engine"; disaggregated mode
/// routes `Prefill`/`Decode` to the matching pool and `All` to both.
/// `Replica(i)` pins the event to replica `i` of a fleet
/// ([`FaultSpec::for_replica`] rewrites it to `All` inside that replica
/// and drops it everywhere else); outside a fleet only replica 0 exists,
/// so `replica:0` behaves like `all` and other indices are inert.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultTarget {
    All,
    Prefill,
    Decode,
    Replica(u64),
}

impl FaultTarget {
    pub fn name(self) -> String {
        match self {
            FaultTarget::All => "all".to_string(),
            FaultTarget::Prefill => "prefill".to_string(),
            FaultTarget::Decode => "decode".to_string(),
            FaultTarget::Replica(i) => format!("replica:{i}"),
        }
    }

    pub fn parse(v: &str) -> Option<FaultTarget> {
        match v {
            "all" => Some(FaultTarget::All),
            "prefill" => Some(FaultTarget::Prefill),
            "decode" => Some(FaultTarget::Decode),
            _ => v
                .strip_prefix("replica:")
                .and_then(|i| i.parse().ok())
                .map(FaultTarget::Replica),
        }
    }
}

/// The kind of a scheduled fault event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Pool down for the window; in-flight requests lose their KV state.
    Crash,
    /// Pool stops admitting for the window, finishes in-flight work.
    Drain,
    /// Iteration latency × `multiplier` for the window (must be > 0;
    /// values < 1 model a speedup, which is allowed but unusual).
    Slowdown { multiplier: f64 },
    /// Interconnect transfer latency × `factor` for the window (a
    /// bandwidth cut by `factor`; must be ≥ 1).
    LinkDegrade { factor: f64 },
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Crash => "crash",
            FaultKind::Drain => "drain",
            FaultKind::Slowdown { .. } => "slowdown",
            FaultKind::LinkDegrade { .. } => "link_degrade",
        }
    }
}

/// One scheduled fault: a kind, a start time, a duration, and a target
/// pool.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    pub kind: FaultKind,
    pub at_s: f64,
    pub duration_s: f64,
    pub target: FaultTarget,
}

/// How the scheduler reacts to faults (and, for the pressure knobs, to
/// overload generally — shedding and timeouts act even without a fault
/// window when configured).
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryPolicy {
    /// Re-dispatch budget per request after a crash loses it; beyond
    /// this the request is counted lost.
    pub max_retries: u64,
    /// Base delay before a crashed request re-enters the queue, seconds;
    /// doubles with each retry (exponential backoff).
    pub retry_backoff_s: f64,
    /// Drop requests that have waited in the queue longer than this
    /// since arrival (counted lost). `None`: never.
    pub request_timeout_s: Option<f64>,
    /// Refuse fresh arrivals while the waiting queue is at least this
    /// deep (admission shedding; counted shed). `None`: never.
    pub shed_queue_depth: Option<u64>,
    /// Chunked mode only: cap the per-iteration token budget at this
    /// while any fault window is active on the pool (degraded-mode
    /// chunking keeps decode pace at the cost of prefill progress).
    pub degraded_chunk_tokens: Option<u64>,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy {
            max_retries: DEFAULT_MAX_RETRIES,
            retry_backoff_s: DEFAULT_RETRY_BACKOFF_S,
            request_timeout_s: None,
            shed_queue_depth: None,
            degraded_chunk_tokens: None,
        }
    }
}

/// A seeded, deterministic fault schedule plus its recovery policy —
/// the declarative form carried by `TrafficSpec` / scenario JSON.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Seed of the dedicated fault RNG stream (MTBF gap draws). Never
    /// shared with the workload generator, so adding faults does not
    /// change the trace.
    pub seed: u64,
    /// Explicitly scheduled events.
    pub events: Vec<FaultEvent>,
    /// Mean time between whole-pool crashes, seconds; `None` disables
    /// the random crash process.
    pub mtbf_s: Option<f64>,
    /// Downtime per MTBF-generated crash, seconds.
    pub mttr_s: f64,
    /// Fleet correlation of pool-targeted events, in [0, 1]: each
    /// `all`/`prefill`/`decode` event strikes a seeded subset of
    /// `max(1, round(fraction × N))` replicas. 0 (the default) models
    /// independent single-replica incidents; 1 a fleet-wide outage
    /// (shared switch, bad rollout). Ignored outside fleets.
    pub correlated_fraction: f64,
    pub recovery: RecoveryPolicy,
}

impl FaultSpec {
    /// A spec that injects nothing and pressures nothing — guaranteed to
    /// reproduce the no-spec report byte-for-byte.
    pub fn none() -> FaultSpec {
        FaultSpec {
            seed: 0,
            events: Vec::new(),
            mtbf_s: None,
            mttr_s: DEFAULT_MTTR_S,
            correlated_fraction: 0.0,
            recovery: RecoveryPolicy::default(),
        }
    }

    /// MTBF-only crashes: mean `mtbf_s` between crashes, `mttr_s` down
    /// per crash, default recovery.
    pub fn mtbf(seed: u64, mtbf_s: f64, mttr_s: f64) -> FaultSpec {
        FaultSpec { mtbf_s: Some(mtbf_s), mttr_s, seed, ..FaultSpec::none() }
    }

    /// Reject physically meaningless specs with a message instead of
    /// letting the engines mis-simulate. Mirrors `scheduler::validate`'s
    /// role for the rest of the config.
    pub fn validate(&self) -> Result<(), String> {
        for (i, e) in self.events.iter().enumerate() {
            if !e.at_s.is_finite() || e.at_s < 0.0 {
                return Err(format!("fault event {i}: at_s must be finite and ≥ 0"));
            }
            if !e.duration_s.is_finite() || e.duration_s < 0.0 {
                return Err(format!("fault event {i}: duration_s must be finite and ≥ 0"));
            }
            match e.kind {
                FaultKind::Slowdown { multiplier } => {
                    if !multiplier.is_finite() || multiplier <= 0.0 {
                        return Err(format!(
                            "fault event {i}: slowdown multiplier must be finite and > 0"
                        ));
                    }
                }
                FaultKind::LinkDegrade { factor } => {
                    if !factor.is_finite() || factor < 1.0 {
                        return Err(format!(
                            "fault event {i}: link_degrade factor must be finite and ≥ 1"
                        ));
                    }
                }
                FaultKind::Crash | FaultKind::Drain => {}
            }
        }
        if let Some(m) = self.mtbf_s {
            if !m.is_finite() || m <= 0.0 {
                return Err("fault mtbf_s must be finite and > 0".to_string());
            }
            if !self.mttr_s.is_finite() || self.mttr_s <= 0.0 {
                return Err("fault mttr_s must be finite and > 0 when mtbf_s is set".to_string());
            }
        } else if !self.mttr_s.is_finite() || self.mttr_s < 0.0 {
            return Err("fault mttr_s must be finite and ≥ 0".to_string());
        }
        let r = &self.recovery;
        if !r.retry_backoff_s.is_finite() || r.retry_backoff_s < 0.0 {
            return Err("fault recovery retry_backoff_s must be finite and ≥ 0".to_string());
        }
        if let Some(t) = r.request_timeout_s {
            if !t.is_finite() || t <= 0.0 {
                return Err("fault recovery request_timeout_s must be finite and > 0".to_string());
            }
        }
        if r.shed_queue_depth == Some(0) {
            return Err("fault recovery shed_queue_depth must be ≥ 1".to_string());
        }
        if r.degraded_chunk_tokens == Some(0) {
            return Err("fault recovery degraded_chunk_tokens must be ≥ 1".to_string());
        }
        if !self.correlated_fraction.is_finite()
            || !(0.0..=1.0).contains(&self.correlated_fraction)
        {
            return Err("fault correlated_fraction must be in [0, 1]".to_string());
        }
        Ok(())
    }

    /// Highest replica index named by a `replica:<i>` event target, if any
    /// — the fleet validates it against its size.
    pub fn max_replica_target(&self) -> Option<u64> {
        self.events
            .iter()
            .filter_map(|e| match e.target {
                FaultTarget::Replica(i) => Some(i),
                _ => None,
            })
            .max()
    }

    /// Project this fleet-level spec onto replica `replica` of an
    /// N-replica fleet:
    ///
    /// * `replica:<i>` events land only on replica `i`, rewritten to
    ///   target `all` pools of that replica's engine;
    /// * pool-targeted events strike a seeded deterministic subset of
    ///   `max(1, round(correlated_fraction × N))` replicas, drawn per
    ///   event from the spec seed so replay is byte-identical;
    /// * the MTBF crash process becomes an independent per-replica stream
    ///   under a replica-derived seed.
    ///
    /// With `fleet_size ≤ 1` the spec passes through unchanged, so the
    /// fleet path reproduces the single-engine run byte for byte.
    pub fn for_replica(&self, replica: u64, fleet_size: u64) -> FaultSpec {
        if fleet_size <= 1 {
            return self.clone();
        }
        let strike =
            ((self.correlated_fraction * fleet_size as f64).round() as u64).clamp(1, fleet_size);
        let mut events = Vec::new();
        for (j, e) in self.events.iter().enumerate() {
            match e.target {
                FaultTarget::Replica(r) => {
                    if r == replica {
                        events.push(FaultEvent { target: FaultTarget::All, ..e.clone() });
                    }
                }
                _ => {
                    if struck_replicas(self.seed, j as u64, fleet_size, strike)
                        .contains(&replica)
                    {
                        events.push(e.clone());
                    }
                }
            }
        }
        FaultSpec {
            seed: self
                .seed
                .wrapping_add(replica.wrapping_add(1).wrapping_mul(0x9e37_79b9_7f4a_7c15)),
            events,
            mtbf_s: self.mtbf_s,
            mttr_s: self.mttr_s,
            correlated_fraction: 0.0,
            recovery: self.recovery.clone(),
        }
    }
}

/// The `strike`-sized replica subset hit by pool-targeted event
/// `event_idx`: a partial Fisher–Yates draw from a per-event RNG stream,
/// so the subset depends only on (seed, event index, fleet size).
fn struck_replicas(seed: u64, event_idx: u64, fleet_size: u64, strike: u64) -> Vec<u64> {
    let mut rng = Rng::new(
        seed ^ event_idx.wrapping_mul(0xd1b5_4a32_d192_ed03).wrapping_add(0x2545_f491_4f6c_dd1d),
    );
    let mut ids: Vec<u64> = (0..fleet_size).collect();
    let k = strike.min(fleet_size) as usize;
    for i in 0..k {
        let j = i + rng.below((ids.len() - i) as u64) as usize;
        ids.swap(i, j);
    }
    ids.truncate(k);
    ids
}

/// Pool index used by the engines: single-pool engines and the
/// disaggregated prefill pool.
pub const POOL_PREFILL: usize = 0;
/// Pool index of the disaggregated decode pool.
pub const POOL_DECODE: usize = 1;

/// One resolved fault window.
struct Win {
    kind: FaultKind,
    target: FaultTarget,
    start: f64,
    end: f64,
}

/// Per-run fault state: the resolved explicit windows, the lazily
/// generated MTBF crash windows, and per-pool "crash applied" marks.
///
/// All methods take `&mut self` only because the MTBF process extends
/// lazily; extension is monotone and independent of which pool asks
/// first, so disaggregated mode's interleaved pool clocks cannot perturb
/// the draw sequence.
pub struct Faults {
    events: Vec<Win>,
    /// Per explicit event, per pool: crash already applied there.
    event_applied: Vec<[bool; 2]>,
    /// MTBF crash windows `(start, end)`, monotone in start.
    auto: Vec<(f64, f64)>,
    auto_applied: Vec<[bool; 2]>,
    rng: Rng,
    mtbf_s: Option<f64>,
    mttr_s: f64,
    /// Monolithic/chunked: one pool matches every target.
    single_pool: bool,
    pub recovery: RecoveryPolicy,
}

impl Faults {
    pub fn new(spec: &FaultSpec, single_pool: bool) -> Faults {
        let mut events: Vec<Win> = spec
            .events
            .iter()
            .map(|e| Win {
                kind: e.kind,
                target: e.target,
                start: e.at_s,
                end: e.at_s + e.duration_s,
            })
            .collect();
        events.sort_by(|a, b| a.start.total_cmp(&b.start));
        let n = events.len();
        Faults {
            events,
            event_applied: vec![[false; 2]; n],
            auto: Vec::new(),
            auto_applied: Vec::new(),
            rng: Rng::new(spec.seed),
            mtbf_s: spec.mtbf_s,
            mttr_s: spec.mttr_s,
            single_pool,
            recovery: spec.recovery.clone(),
        }
    }

    fn matches(&self, target: FaultTarget, pool: usize) -> bool {
        // Replica targets reaching an engine directly (no fleet projection)
        // mean "this single replica" — index 0 — and are inert otherwise.
        if let FaultTarget::Replica(r) = target {
            return r == 0;
        }
        if self.single_pool {
            return true;
        }
        match target {
            FaultTarget::All | FaultTarget::Replica(_) => true,
            FaultTarget::Prefill => pool == POOL_PREFILL,
            FaultTarget::Decode => pool == POOL_DECODE,
        }
    }

    /// Extend the MTBF crash sequence until at least one window starts
    /// strictly after `t`. Exponential inter-arrival gaps; each window
    /// lasts `mttr_s`. No-op (and no RNG draw) without `mtbf_s`.
    fn ensure(&mut self, t: f64) {
        let Some(mtbf) = self.mtbf_s else { return };
        while self.auto.last().map(|&(s, _)| s <= t).unwrap_or(true) {
            let from = self.auto.last().map(|&(_, e)| e).unwrap_or(0.0);
            // Inverse-CDF exponential draw; `1 - f64()` is in (0, 1], so
            // the log is finite and the gap non-negative.
            let gap = -mtbf * (1.0 - self.rng.f64()).ln();
            let start = from + gap;
            self.auto.push((start, start + self.mttr_s));
            self.auto_applied.push([false; 2]);
        }
    }

    /// The earliest not-yet-applied crash on `pool` with start ≤ `t`,
    /// marked applied. Engines call this in a loop at each iteration
    /// boundary and drop the pool's in-flight state for each hit (the
    /// discretization: an iteration spanning a crash instant completes
    /// first, then the crash lands).
    pub fn pending_crash(&mut self, t: f64, pool: usize) -> Option<(f64, f64)> {
        self.ensure(t);
        let mut best: Option<(f64, f64, bool, usize)> = None;
        for (i, w) in self.events.iter().enumerate() {
            if matches!(w.kind, FaultKind::Crash)
                && w.start <= t
                && !self.event_applied[i][pool]
                && self.matches(w.target, pool)
                && best.map(|(s, ..)| w.start < s).unwrap_or(true)
            {
                best = Some((w.start, w.end, false, i));
            }
        }
        for (i, &(s, e)) in self.auto.iter().enumerate() {
            if s <= t
                && !self.auto_applied[i][pool]
                && best.map(|(bs, ..)| s < bs).unwrap_or(true)
            {
                best = Some((s, e, true, i));
            }
        }
        best.map(|(s, e, is_auto, i)| {
            if is_auto {
                self.auto_applied[i][pool] = true;
            } else {
                self.event_applied[i][pool] = true;
            }
            (s, e)
        })
    }

    /// Whether `pool` admits new work at `t`: outside every crash and
    /// drain window that matches it.
    pub fn admitting(&mut self, t: f64, pool: usize) -> bool {
        self.ensure(t);
        let blocked = self.events.iter().any(|w| {
            matches!(w.kind, FaultKind::Crash | FaultKind::Drain)
                && self.matches(w.target, pool)
                && w.start <= t
                && t < w.end
        });
        !blocked && !self.auto.iter().any(|&(s, e)| s <= t && t < e)
    }

    /// Earliest time ≥ `t` at which `pool` admits again. Jumps window
    /// end to window end, so chained/overlapping outages resolve to the
    /// final rejoin time.
    pub fn next_admit_time(&mut self, t: f64, pool: usize) -> f64 {
        let mut at = t;
        loop {
            self.ensure(at);
            let mut covering_end: Option<f64> = None;
            for w in &self.events {
                if matches!(w.kind, FaultKind::Crash | FaultKind::Drain)
                    && self.matches(w.target, pool)
                    && w.start <= at
                    && at < w.end
                {
                    covering_end =
                        Some(covering_end.map(|e: f64| e.max(w.end)).unwrap_or(w.end));
                }
            }
            for &(s, e) in &self.auto {
                if s <= at && at < e {
                    covering_end = Some(covering_end.map(|x: f64| x.max(e)).unwrap_or(e));
                }
            }
            match covering_end {
                Some(e) => at = e,
                None => return at,
            }
        }
    }

    /// Iteration-latency multiplier on `pool` at `t`: the product of
    /// active slowdown windows (1.0 outside any — bit-exact no-op).
    pub fn latency_mult(&mut self, t: f64, pool: usize) -> f64 {
        self.ensure(t);
        let mut m = 1.0;
        for w in &self.events {
            if let FaultKind::Slowdown { multiplier } = w.kind {
                if self.matches(w.target, pool) && w.start <= t && t < w.end {
                    m *= multiplier;
                }
            }
        }
        m
    }

    /// Interconnect-transfer multiplier at `t`: the product of active
    /// link-degradation factors (pool targets are ignored — the fabric is
    /// shared; replica targets still only bind to this replica).
    pub fn link_mult(&mut self, t: f64) -> f64 {
        let mut m = 1.0;
        for w in &self.events {
            if let FaultKind::LinkDegrade { factor } = w.kind {
                if matches!(w.target, FaultTarget::Replica(r) if r != 0) {
                    continue;
                }
                if w.start <= t && t < w.end {
                    m *= factor;
                }
            }
        }
        m
    }

    /// Whether any fault window matching `pool` is active at `t` (the
    /// degraded-mode trigger for `degraded_chunk_tokens`).
    pub fn degraded(&mut self, t: f64, pool: usize) -> bool {
        self.ensure(t);
        self.events
            .iter()
            .any(|w| self.matches(w.target, pool) && w.start <= t && t < w.end)
            || self.auto.iter().any(|&(s, e)| s <= t && t < e)
    }

    /// Earliest retry-ready / window-edge time strictly after `t` that
    /// could unblock `pool` (window starts matter for degraded-mode
    /// re-evaluation, ends for admission). INFINITY when none.
    pub fn next_change_after(&mut self, t: f64, pool: usize) -> f64 {
        self.ensure(t);
        let mut next = f64::INFINITY;
        for w in &self.events {
            if !self.matches(w.target, pool) {
                continue;
            }
            if w.start > t {
                next = next.min(w.start);
            }
            if w.end > t {
                next = next.min(w.end);
            }
        }
        for &(s, e) in &self.auto {
            if s > t {
                next = next.min(s);
            }
            if e > t {
                next = next.min(e);
            }
        }
        next
    }

    /// Total wall-clock in `[0, makespan]` with at least one pool inside
    /// a crash or drain window: the union of outage windows (explicit
    /// crash/drain events + MTBF crashes), clipped to the run. Slowdown
    /// and link windows degrade service but do not count as downtime.
    pub fn downtime_in(&mut self, makespan: f64) -> f64 {
        self.ensure(makespan);
        let mut wins: Vec<(f64, f64)> = self
            .events
            .iter()
            .filter(|w| matches!(w.kind, FaultKind::Crash | FaultKind::Drain))
            .map(|w| (w.start, w.end))
            .chain(self.auto.iter().copied())
            .map(|(s, e)| (s.max(0.0), e.min(makespan)))
            .filter(|&(s, e)| e > s)
            .collect();
        wins.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
        let mut total = 0.0;
        let mut cur: Option<(f64, f64)> = None;
        for (s, e) in wins {
            match cur {
                Some((cs, ce)) if s <= ce => cur = Some((cs, ce.max(e))),
                Some((cs, ce)) => {
                    total += ce - cs;
                    cur = Some((s, e));
                }
                None => cur = Some((s, e)),
            }
        }
        if let Some((cs, ce)) = cur {
            total += ce - cs;
        }
        total
    }

    /// Number of fault events whose window started by `makespan` — the
    /// `faults_injected` report counter. Extends the MTBF sequence to the
    /// makespan so late crashes are counted deterministically.
    pub fn injected_count(&mut self, makespan: f64) -> u64 {
        self.ensure(makespan);
        let explicit = self.events.iter().filter(|w| w.start <= makespan).count();
        let auto = self.auto.iter().filter(|&&(s, _)| s <= makespan).count();
        (explicit + auto) as u64
    }

    /// The explicit windows, for upfront telemetry span emission:
    /// `(kind name, target name, start, end)`.
    pub fn event_windows(&self) -> Vec<(&'static str, String, f64, f64)> {
        self.events
            .iter()
            .map(|w| (w.kind.name(), w.target.name(), w.start, w.end))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// JSON (scenario / CLI `--fault-spec` format)
// ---------------------------------------------------------------------------

use crate::util::json::{num, obj, s, Json};

/// Keys accepted at each level of the fault JSON — shared with the
/// scenario parser's unknown-field rejection.
pub const FAULT_SPEC_KEYS: &[&str] = &[
    "seed",
    "events",
    "mtbf_s",
    "mtbf_hours",
    "mttr_s",
    "correlated_fraction",
    "recovery",
];
pub const FAULT_EVENT_KEYS: &[&str] =
    &["kind", "at_s", "duration_s", "target", "multiplier", "factor"];
pub const RECOVERY_KEYS: &[&str] = &[
    "max_retries",
    "retry_backoff_s",
    "request_timeout_s",
    "shed_queue_depth",
    "degraded_chunk_tokens",
];

fn opt_u64(v: &Json, key: &str) -> Result<Option<u64>, String> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => x
            .as_u64()
            .map(Some)
            .ok_or_else(|| format!("fault `{key}` must be a non-negative integer")),
    }
}

fn opt_f64(v: &Json, key: &str) -> Result<Option<f64>, String> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => x.as_f64().map(Some).ok_or_else(|| format!("fault `{key}` must be a number")),
    }
}

/// Reject keys outside `allowed` so a typo'd fault knob fails loudly.
fn check_known(v: &Json, allowed: &[&str], ctx: &str) -> Result<(), String> {
    if let Some(m) = v.as_obj() {
        for k in m.keys() {
            if !allowed.contains(&k.as_str()) {
                return Err(format!(
                    "unknown {ctx} field `{k}` (allowed: {})",
                    allowed.join(", ")
                ));
            }
        }
        Ok(())
    } else {
        Err(format!("{ctx} must be an object"))
    }
}

impl FaultSpec {
    /// Stable JSON rendering (round-trips through [`FaultSpec::from_json`]).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("seed", num(self.seed as f64))];
        if let Some(m) = self.mtbf_s {
            fields.push(("mtbf_s", num(m)));
        }
        fields.push(("mttr_s", num(self.mttr_s)));
        if self.correlated_fraction > 0.0 {
            fields.push(("correlated_fraction", num(self.correlated_fraction)));
        }
        if !self.events.is_empty() {
            fields.push((
                "events",
                Json::Arr(
                    self.events
                        .iter()
                        .map(|e| {
                            let mut ef = vec![
                                ("kind", s(e.kind.name())),
                                ("at_s", num(e.at_s)),
                                ("duration_s", num(e.duration_s)),
                            ];
                            match e.kind {
                                FaultKind::Slowdown { multiplier } => {
                                    ef.push(("multiplier", num(multiplier)))
                                }
                                FaultKind::LinkDegrade { factor } => {
                                    ef.push(("factor", num(factor)))
                                }
                                _ => {}
                            }
                            let target_name = e.target.name();
                            if e.target != FaultTarget::All {
                                ef.push(("target", s(&target_name)));
                            }
                            obj(ef)
                        })
                        .collect(),
                ),
            ));
        }
        if self.recovery != RecoveryPolicy::default() {
            let r = &self.recovery;
            let mut rf = vec![
                ("max_retries", num(r.max_retries as f64)),
                ("retry_backoff_s", num(r.retry_backoff_s)),
            ];
            if let Some(t) = r.request_timeout_s {
                rf.push(("request_timeout_s", num(t)));
            }
            if let Some(d) = r.shed_queue_depth {
                rf.push(("shed_queue_depth", num(d as f64)));
            }
            if let Some(c) = r.degraded_chunk_tokens {
                rf.push(("degraded_chunk_tokens", num(c as f64)));
            }
            fields.push(("recovery", obj(rf)));
        }
        obj(fields)
    }

    /// Parse the scenario/CLI fault object. Unknown keys at any level are
    /// rejected by name; `mtbf_hours` is accepted as sugar for
    /// `mtbf_s = hours × 3600` (`to_json` always emits `mtbf_s`).
    pub fn from_json(v: &Json) -> Result<FaultSpec, String> {
        check_known(v, FAULT_SPEC_KEYS, "fault spec")?;
        let mtbf_s = match (opt_f64(v, "mtbf_s")?, opt_f64(v, "mtbf_hours")?) {
            (Some(_), Some(_)) => {
                return Err("fault spec sets both `mtbf_s` and `mtbf_hours`".to_string())
            }
            (Some(sv), None) => Some(sv),
            (None, Some(h)) => Some(h * 3600.0),
            (None, None) => None,
        };
        let mut events = Vec::new();
        match v.get("events") {
            None => {}
            Some(Json::Arr(items)) => {
                for item in items {
                    check_known(item, FAULT_EVENT_KEYS, "fault event")?;
                    let kind_name = item
                        .get("kind")
                        .and_then(Json::as_str)
                        .ok_or_else(|| "fault event needs a string `kind`".to_string())?;
                    let kind = match kind_name {
                        "crash" => FaultKind::Crash,
                        "drain" => FaultKind::Drain,
                        "slowdown" => FaultKind::Slowdown {
                            multiplier: opt_f64(item, "multiplier")?.ok_or_else(|| {
                                "slowdown fault event needs `multiplier`".to_string()
                            })?,
                        },
                        "link_degrade" => FaultKind::LinkDegrade {
                            factor: opt_f64(item, "factor")?.ok_or_else(|| {
                                "link_degrade fault event needs `factor`".to_string()
                            })?,
                        },
                        other => {
                            return Err(format!(
                                "unknown fault kind `{other}` (crash | drain | slowdown | \
                                 link_degrade)"
                            ))
                        }
                    };
                    let target = match item.get("target") {
                        None => FaultTarget::All,
                        Some(t) => {
                            let t = t
                                .as_str()
                                .ok_or_else(|| "fault event `target` must be a string".to_string())?;
                            FaultTarget::parse(t).ok_or_else(|| {
                                format!(
                                    "unknown fault target `{t}` (all | prefill | decode | \
                                     replica:<i>)"
                                )
                            })?
                        }
                    };
                    events.push(FaultEvent {
                        kind,
                        at_s: opt_f64(item, "at_s")?
                            .ok_or_else(|| "fault event needs `at_s`".to_string())?,
                        duration_s: opt_f64(item, "duration_s")?
                            .ok_or_else(|| "fault event needs `duration_s`".to_string())?,
                        target,
                    });
                }
            }
            Some(_) => return Err("fault `events` must be an array".to_string()),
        }
        let recovery = match v.get("recovery") {
            None => RecoveryPolicy::default(),
            Some(r) => {
                check_known(r, RECOVERY_KEYS, "fault recovery")?;
                let d = RecoveryPolicy::default();
                RecoveryPolicy {
                    max_retries: opt_u64(r, "max_retries")?.unwrap_or(d.max_retries),
                    retry_backoff_s: opt_f64(r, "retry_backoff_s")?.unwrap_or(d.retry_backoff_s),
                    request_timeout_s: opt_f64(r, "request_timeout_s")?,
                    shed_queue_depth: opt_u64(r, "shed_queue_depth")?,
                    degraded_chunk_tokens: opt_u64(r, "degraded_chunk_tokens")?,
                }
            }
        };
        let spec = FaultSpec {
            seed: opt_u64(v, "seed")?.unwrap_or(0),
            events,
            mtbf_s,
            mttr_s: opt_f64(v, "mttr_s")?.unwrap_or(DEFAULT_MTTR_S),
            correlated_fraction: opt_f64(v, "correlated_fraction")?.unwrap_or(0.0),
            recovery,
        };
        spec.validate()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_spec_is_inert() {
        let mut f = Faults::new(&FaultSpec::none(), true);
        assert!(f.admitting(0.0, POOL_PREFILL));
        assert!(f.pending_crash(1e9, POOL_PREFILL).is_none());
        assert_eq!(f.latency_mult(5.0, POOL_PREFILL), 1.0);
        assert_eq!(f.link_mult(5.0), 1.0);
        assert!(!f.degraded(5.0, POOL_PREFILL));
        assert_eq!(f.next_change_after(0.0, POOL_PREFILL), f64::INFINITY);
        assert_eq!(f.injected_count(1e9), 0);
    }

    #[test]
    fn windows_gate_admission_and_multiply_latency() {
        let spec = FaultSpec {
            seed: 1,
            events: vec![
                FaultEvent {
                    kind: FaultKind::Drain,
                    at_s: 1.0,
                    duration_s: 2.0,
                    target: FaultTarget::Prefill,
                },
                FaultEvent {
                    kind: FaultKind::Slowdown { multiplier: 3.0 },
                    at_s: 0.5,
                    duration_s: 1.0,
                    target: FaultTarget::All,
                },
                FaultEvent {
                    kind: FaultKind::LinkDegrade { factor: 4.0 },
                    at_s: 0.0,
                    duration_s: 10.0,
                    target: FaultTarget::All,
                },
            ],
            mtbf_s: None,
            mttr_s: 0.0,
            correlated_fraction: 0.0,
            recovery: RecoveryPolicy::default(),
        };
        spec.validate().unwrap();
        let mut f = Faults::new(&spec, false);
        assert!(f.admitting(0.9, POOL_PREFILL));
        assert!(!f.admitting(1.0, POOL_PREFILL), "window start is inclusive");
        assert!(!f.admitting(2.9, POOL_PREFILL));
        assert!(f.admitting(3.0, POOL_PREFILL), "window end is exclusive");
        assert!(f.admitting(2.0, POOL_DECODE), "prefill drain leaves decode admitting");
        assert_eq!(f.next_admit_time(1.5, POOL_PREFILL), 3.0);
        assert_eq!(f.next_admit_time(1.5, POOL_DECODE), 1.5);
        assert_eq!(f.latency_mult(1.0, POOL_DECODE), 3.0);
        assert_eq!(f.latency_mult(1.6, POOL_DECODE), 1.0);
        assert_eq!(f.link_mult(5.0), 4.0);
        assert_eq!(f.link_mult(11.0), 1.0);
        assert!(f.degraded(2.5, POOL_PREFILL));
        assert!(!f.degraded(2.5, POOL_DECODE), "only the link window covers decode at 2.5");
        assert_eq!(f.injected_count(100.0), 3);
    }

    #[test]
    fn crash_applies_once_per_pool_and_counts() {
        let spec = FaultSpec {
            seed: 9,
            events: vec![FaultEvent {
                kind: FaultKind::Crash,
                at_s: 2.0,
                duration_s: 1.0,
                target: FaultTarget::All,
            }],
            mtbf_s: None,
            mttr_s: 0.0,
            correlated_fraction: 0.0,
            recovery: RecoveryPolicy::default(),
        };
        let mut f = Faults::new(&spec, false);
        assert!(f.pending_crash(1.0, POOL_PREFILL).is_none(), "not yet struck");
        assert_eq!(f.pending_crash(2.5, POOL_PREFILL), Some((2.0, 3.0)));
        assert!(f.pending_crash(2.5, POOL_PREFILL).is_none(), "applied once per pool");
        assert_eq!(f.pending_crash(9.0, POOL_DECODE), Some((2.0, 3.0)));
        assert!(!f.admitting(2.5, POOL_DECODE), "crash window blocks admission");
    }

    #[test]
    fn mtbf_sequence_is_deterministic_and_order_independent() {
        let spec = FaultSpec::mtbf(7, 100.0, 5.0);
        let mut a = Faults::new(&spec, false);
        let mut b = Faults::new(&spec, false);
        // Interleave queries differently; the generated windows must agree.
        a.ensure(1000.0);
        let _ = b.pending_crash(50.0, POOL_DECODE);
        let _ = b.admitting(400.0, POOL_PREFILL);
        b.ensure(1000.0);
        assert_eq!(a.auto, b.auto, "MTBF windows depend only on the seed");
        assert!(a.auto.iter().all(|&(s, e)| e - s == 5.0));
        assert!(
            a.auto.windows(2).all(|w| w[1].0 >= w[0].1),
            "windows are sequential (downtime separates crashes)"
        );
        // Different seed, different schedule.
        let mut c = Faults::new(&FaultSpec::mtbf(8, 100.0, 5.0), false);
        c.ensure(1000.0);
        assert_ne!(a.auto, c.auto);
    }

    #[test]
    fn spec_json_round_trips() {
        let mut spec = FaultSpec {
            seed: 11,
            events: vec![
                FaultEvent {
                    kind: FaultKind::Crash,
                    at_s: 1.5,
                    duration_s: 0.5,
                    target: FaultTarget::Decode,
                },
                FaultEvent {
                    kind: FaultKind::Slowdown { multiplier: 2.0 },
                    at_s: 0.25,
                    duration_s: 4.0,
                    target: FaultTarget::All,
                },
                FaultEvent {
                    kind: FaultKind::Crash,
                    at_s: 3.0,
                    duration_s: 1.0,
                    target: FaultTarget::Replica(2),
                },
            ],
            mtbf_s: Some(7200.0),
            mttr_s: 12.0,
            correlated_fraction: 0.5,
            recovery: RecoveryPolicy {
                max_retries: 3,
                retry_backoff_s: 0.25,
                request_timeout_s: Some(30.0),
                shed_queue_depth: Some(64),
                degraded_chunk_tokens: Some(256),
            },
        };
        let text = spec.to_json().to_string_pretty();
        let again = FaultSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(spec, again, "round trip changed the spec:\n{text}");
        // Default recovery and empty events stay implicit.
        spec.events.clear();
        spec.recovery = RecoveryPolicy::default();
        let text = spec.to_json().to_string_pretty();
        assert!(!text.contains("recovery") && !text.contains("events"));
        assert_eq!(spec, FaultSpec::from_json(&Json::parse(&text).unwrap()).unwrap());
        // mtbf_hours sugar.
        let sugared = Json::parse(r#"{"seed": 2, "mtbf_hours": 2.0, "mttr_s": 30.0}"#).unwrap();
        assert_eq!(FaultSpec::from_json(&sugared).unwrap().mtbf_s, Some(7200.0));
    }

    #[test]
    fn bad_specs_error_by_name() {
        for (text, needle) in [
            (r#"{"seed": 1, "mtbf": 10.0}"#, "unknown fault spec field `mtbf`"),
            (r#"{"events": [{"kind": "crash", "at_s": 1.0}]}"#, "duration_s"),
            (r#"{"events": [{"kind": "explode", "at_s": 1.0, "duration_s": 1.0}]}"#, "explode"),
            (
                r#"{"events": [{"kind": "slowdown", "at_s": 1.0, "duration_s": 1.0}]}"#,
                "multiplier",
            ),
            (
                r#"{"events": [{"kind": "crash", "at_s": 1.0, "duration_s": 1.0, "oops": 1}]}"#,
                "unknown fault event field `oops`",
            ),
            (r#"{"recovery": {"max_retry": 3}}"#, "unknown fault recovery field `max_retry`"),
            (r#"{"mtbf_s": 10.0, "mttr_s": 0.0}"#, "mttr_s"),
            (r#"{"mtbf_s": 1.0, "mtbf_hours": 1.0, "mttr_s": 1.0}"#, "both"),
            (
                r#"{"events": [{"kind": "link_degrade", "at_s": 0.0, "duration_s": 1.0,
                    "factor": 0.5}]}"#,
                "factor",
            ),
            (r#"{"recovery": {"shed_queue_depth": 0}}"#, "shed_queue_depth"),
            (r#"{"correlated_fraction": 1.5}"#, "correlated_fraction"),
            (
                r#"{"events": [{"kind": "crash", "at_s": 0.0, "duration_s": 1.0,
                    "target": "replica:x"}]}"#,
                "unknown fault target",
            ),
        ] {
            let v = Json::parse(text).unwrap();
            let err = FaultSpec::from_json(&v).unwrap_err();
            assert!(err.contains(needle), "`{text}` → `{err}` (wanted `{needle}`)");
        }
    }

    #[test]
    fn replica_targets_parse_and_bind_to_replica_zero_outside_fleets() {
        assert_eq!(FaultTarget::parse("replica:3"), Some(FaultTarget::Replica(3)));
        assert_eq!(FaultTarget::Replica(3).name(), "replica:3");
        assert_eq!(FaultTarget::parse("replica:"), None);
        // Outside a fleet only replica 0 exists: replica:0 gates, others
        // are inert.
        let mk = |r: u64| FaultSpec {
            events: vec![FaultEvent {
                kind: FaultKind::Drain,
                at_s: 0.0,
                duration_s: 5.0,
                target: FaultTarget::Replica(r),
            }],
            ..FaultSpec::none()
        };
        let mut hit = Faults::new(&mk(0), true);
        assert!(!hit.admitting(1.0, POOL_PREFILL));
        let mut miss = Faults::new(&mk(4), true);
        assert!(miss.admitting(1.0, POOL_PREFILL));
        assert_eq!(mk(4).max_replica_target(), Some(4));
        assert_eq!(FaultSpec::none().max_replica_target(), None);
    }

    #[test]
    fn for_replica_projects_targets_and_correlation_deterministically() {
        let mut spec = FaultSpec::none();
        spec.seed = 13;
        spec.events = vec![
            FaultEvent {
                kind: FaultKind::Crash,
                at_s: 1.0,
                duration_s: 0.5,
                target: FaultTarget::Replica(2),
            },
            FaultEvent {
                kind: FaultKind::Drain,
                at_s: 2.0,
                duration_s: 1.0,
                target: FaultTarget::All,
            },
        ];
        // Fleet of 1: pass-through, byte for byte.
        assert_eq!(spec.for_replica(0, 1), spec);

        let n = 4;
        let per: Vec<FaultSpec> = (0..n).map(|r| spec.for_replica(r, n)).collect();
        // The replica:2 crash lands only on replica 2, rewritten to `all`.
        for (r, p) in per.iter().enumerate() {
            let has_crash = p.events.iter().any(|e| matches!(e.kind, FaultKind::Crash));
            assert_eq!(has_crash, r == 2, "crash leaked to replica {r}");
            if r == 2 {
                let crash = p.events.iter().find(|e| matches!(e.kind, FaultKind::Crash));
                assert_eq!(crash.unwrap().target, FaultTarget::All);
            }
        }
        // correlated_fraction 0 ⇒ the pool-targeted drain strikes exactly
        // one replica; which one is seed-stable.
        let drained: Vec<usize> = (0..n as usize)
            .filter(|&r| per[r].events.iter().any(|e| matches!(e.kind, FaultKind::Drain)))
            .collect();
        assert_eq!(drained.len(), 1, "c=0 must strike exactly one replica");
        let again: Vec<FaultSpec> = (0..n).map(|r| spec.for_replica(r, n)).collect();
        assert_eq!(per, again, "projection must be deterministic");
        // Per-replica MTBF streams get distinct derived seeds.
        let seeds: std::collections::BTreeSet<u64> = per.iter().map(|p| p.seed).collect();
        assert_eq!(seeds.len(), n as usize);

        // correlated_fraction 1 ⇒ everyone is hit.
        spec.correlated_fraction = 1.0;
        for r in 0..n {
            let p = spec.for_replica(r, n);
            assert!(
                p.events.iter().any(|e| matches!(e.kind, FaultKind::Drain)),
                "c=1 drain missing on replica {r}"
            );
            assert_eq!(p.correlated_fraction, 0.0, "projection is already resolved");
        }
    }
}
