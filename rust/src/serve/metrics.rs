//! Serving metrics: per-request TTFT / TPOT / end-to-end latency, tail
//! percentiles, throughput, and goodput under a service-level objective.
//!
//! These are the quantities serving-oriented hardware comparisons actually
//! rank on (LLM-Inference-Bench): a design that wins on isolated-batch
//! latency can still lose under load once queueing delay and
//! time-between-tokens are accounted for. Aggregation reuses
//! [`crate::util::stats`].

use crate::util::stats;

/// Timeline of one served request (all times in seconds from trace start).
#[derive(Debug, Clone)]
pub struct RequestMetrics {
    pub id: u64,
    pub arrival_s: f64,
    pub prompt_tokens: u64,
    pub output_tokens: u64,
    /// When the first output token was emitted (end of its prefill
    /// iteration); NaN until served.
    pub first_token_s: f64,
    /// When the last output token was emitted; NaN until finished.
    pub finish_s: f64,
    /// Whether a fault ever hit this request (its KV state was lost to a
    /// crash and it was re-dispatched). Fault-conditioned tail
    /// percentiles aggregate over exactly these requests.
    pub faulted: bool,
}

impl RequestMetrics {
    /// Time to first token: queueing delay + prefill.
    pub fn ttft_s(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }

    /// Time per output token after the first (the inter-token pace a
    /// streaming client observes). Zero-decode requests report 0.
    pub fn tpot_s(&self) -> f64 {
        if self.output_tokens <= 1 {
            0.0
        } else {
            (self.finish_s - self.first_token_s) / (self.output_tokens - 1) as f64
        }
    }

    /// End-to-end latency from arrival to last token.
    pub fn e2e_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }
}

/// A service-level objective on the per-request experience.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slo {
    /// Maximum acceptable time to first token, seconds.
    pub ttft_s: f64,
    /// Maximum acceptable time per output token, seconds.
    pub tpot_s: f64,
}

impl Slo {
    /// An interactive chat SLO: first token within 2 s, then ≥ 10 tok/s.
    pub fn interactive() -> Slo {
        Slo { ttft_s: 2.0, tpot_s: 0.1 }
    }

    /// A relaxed batch/offline SLO: first token within 30 s, ≥ 2 tok/s.
    pub fn relaxed() -> Slo {
        Slo { ttft_s: 30.0, tpot_s: 0.5 }
    }

    pub fn met_by(&self, m: &RequestMetrics) -> bool {
        m.ttft_s() <= self.ttft_s && m.tpot_s() <= self.tpot_s
    }
}

/// Aggregate summary of one serving run.
#[derive(Debug, Clone)]
pub struct Summary {
    pub requests: usize,
    pub output_tokens: u64,
    pub makespan_s: f64,
    /// Mean time to first token (the chunked-vs-monolithic figure of
    /// merit: padding waste shows up here before it shows in the tails).
    pub ttft_mean_s: f64,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    pub tpot_mean_s: f64,
    pub tpot_p50_s: f64,
    pub tpot_p99_s: f64,
    pub e2e_p50_s: f64,
    pub e2e_p99_s: f64,
    /// Output tokens per second over the makespan.
    pub throughput_tok_s: f64,
    /// Output tokens per second counting only SLO-meeting requests.
    pub goodput_tok_s: f64,
    /// Fraction of requests meeting the SLO.
    pub slo_attainment: f64,
    /// Completed requests that were hit by a fault along the way
    /// (crashed and re-dispatched; lost/shed requests never reach the
    /// summary).
    pub faulted_requests: usize,
    /// p99 TTFT over the faulted subset only (0 when none) — how bad the
    /// first-token experience gets for the requests that had to retry.
    pub ttft_p99_faulted_s: f64,
    /// p99 TPOT over the faulted subset only (0 when none).
    pub tpot_p99_faulted_s: f64,
}

/// Summarize per-request metrics under an SLO. `makespan_s` should be the
/// scheduler's reported run length (last completion time).
pub fn summarize(metrics: &[RequestMetrics], slo: &Slo, makespan_s: f64) -> Summary {
    let ttft: Vec<f64> = metrics.iter().map(RequestMetrics::ttft_s).collect();
    let tpot: Vec<f64> = metrics.iter().map(RequestMetrics::tpot_s).collect();
    let e2e: Vec<f64> = metrics.iter().map(RequestMetrics::e2e_s).collect();
    let output_tokens: u64 = metrics.iter().map(|m| m.output_tokens).sum();
    let good: Vec<&RequestMetrics> = metrics.iter().filter(|m| slo.met_by(m)).collect();
    let good_tokens: u64 = good.iter().map(|m| m.output_tokens).sum();
    let faulted: Vec<&RequestMetrics> = metrics.iter().filter(|m| m.faulted).collect();
    let ttft_faulted: Vec<f64> = faulted.iter().map(|m| m.ttft_s()).collect();
    let tpot_faulted: Vec<f64> = faulted.iter().map(|m| m.tpot_s()).collect();
    let span = makespan_s.max(f64::MIN_POSITIVE);
    Summary {
        requests: metrics.len(),
        output_tokens,
        makespan_s,
        ttft_mean_s: stats::mean(&ttft),
        tpot_mean_s: stats::mean(&tpot),
        ttft_p50_s: stats::percentile(&ttft, 50.0),
        ttft_p99_s: stats::percentile(&ttft, 99.0),
        tpot_p50_s: stats::percentile(&tpot, 50.0),
        tpot_p99_s: stats::percentile(&tpot, 99.0),
        e2e_p50_s: stats::percentile(&e2e, 50.0),
        e2e_p99_s: stats::percentile(&e2e, 99.0),
        throughput_tok_s: output_tokens as f64 / span,
        goodput_tok_s: good_tokens as f64 / span,
        slo_attainment: if metrics.is_empty() {
            0.0
        } else {
            good.len() as f64 / metrics.len() as f64
        },
        faulted_requests: faulted.len(),
        ttft_p99_faulted_s: stats::percentile(&ttft_faulted, 99.0),
        tpot_p99_faulted_s: stats::percentile(&tpot_faulted, 99.0),
    }
}

impl Summary {
    /// Stable JSON rendering (part of the `eval` report schema).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num, obj};
        obj(vec![
            ("requests", num(self.requests as f64)),
            ("output_tokens", num(self.output_tokens as f64)),
            ("makespan_s", num(self.makespan_s)),
            ("ttft_mean_s", num(self.ttft_mean_s)),
            ("ttft_p50_s", num(self.ttft_p50_s)),
            ("ttft_p99_s", num(self.ttft_p99_s)),
            ("tpot_mean_s", num(self.tpot_mean_s)),
            ("tpot_p50_s", num(self.tpot_p50_s)),
            ("tpot_p99_s", num(self.tpot_p99_s)),
            ("e2e_p50_s", num(self.e2e_p50_s)),
            ("e2e_p99_s", num(self.e2e_p99_s)),
            ("throughput_tok_s", num(self.throughput_tok_s)),
            ("goodput_tok_s", num(self.goodput_tok_s)),
            ("slo_attainment", num(self.slo_attainment)),
            ("faulted_requests", num(self.faulted_requests as f64)),
            ("ttft_p99_faulted_s", num(self.ttft_p99_faulted_s)),
            ("tpot_p99_faulted_s", num(self.tpot_p99_faulted_s)),
        ])
    }

    /// Multi-line human-readable rendering for the CLI.
    pub fn render(&self) -> String {
        format!(
            "requests {} | output tokens {} | makespan {:.2} s\n\
             TTFT mean {} p50 {} p99 {} | TPOT mean {} p50 {} p99 {} | e2e p50 {} p99 {}\n\
             throughput {:.1} tok/s | goodput {:.1} tok/s | SLO attainment {:.1}%",
            self.requests,
            self.output_tokens,
            self.makespan_s,
            crate::util::fmt_seconds(self.ttft_mean_s),
            crate::util::fmt_seconds(self.ttft_p50_s),
            crate::util::fmt_seconds(self.ttft_p99_s),
            crate::util::fmt_seconds(self.tpot_mean_s),
            crate::util::fmt_seconds(self.tpot_p50_s),
            crate::util::fmt_seconds(self.tpot_p99_s),
            crate::util::fmt_seconds(self.e2e_p50_s),
            crate::util::fmt_seconds(self.e2e_p99_s),
            self.throughput_tok_s,
            self.goodput_tok_s,
            self.slo_attainment * 100.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(arrival: f64, first: f64, finish: f64, out: u64) -> RequestMetrics {
        RequestMetrics {
            id: 0,
            arrival_s: arrival,
            prompt_tokens: 128,
            output_tokens: out,
            first_token_s: first,
            finish_s: finish,
            faulted: false,
        }
    }

    #[test]
    fn per_request_quantities() {
        let m = req(1.0, 1.5, 2.5, 11);
        assert!((m.ttft_s() - 0.5).abs() < 1e-12);
        assert!((m.tpot_s() - 0.1).abs() < 1e-12);
        assert!((m.e2e_s() - 1.5).abs() < 1e-12);
        // Single-token request: everything came from prefill.
        let one = req(0.0, 0.4, 0.4, 1);
        assert_eq!(one.tpot_s(), 0.0);
        assert!((one.e2e_s() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn slo_gating() {
        let slo = Slo { ttft_s: 1.0, tpot_s: 0.2 };
        assert!(slo.met_by(&req(0.0, 0.9, 1.9, 11))); // tpot 0.1
        assert!(!slo.met_by(&req(0.0, 1.1, 2.0, 11))); // ttft miss
        assert!(!slo.met_by(&req(0.0, 0.5, 3.5, 11))); // tpot 0.3 miss
    }

    #[test]
    fn summary_splits_goodput_from_throughput() {
        let metrics = vec![
            req(0.0, 0.5, 1.5, 11),  // meets
            req(0.0, 5.0, 6.0, 11),  // ttft miss
            req(0.0, 0.5, 30.5, 11), // tpot miss (3 s/token)
        ];
        let slo = Slo { ttft_s: 1.0, tpot_s: 0.2 };
        let s = summarize(&metrics, &slo, 30.5);
        assert_eq!(s.requests, 3);
        assert_eq!(s.output_tokens, 33);
        assert!((s.slo_attainment - 1.0 / 3.0).abs() < 1e-12);
        assert!((s.throughput_tok_s - 33.0 / 30.5).abs() < 1e-12);
        assert!((s.goodput_tok_s - 11.0 / 30.5).abs() < 1e-12);
        assert!(s.goodput_tok_s < s.throughput_tok_s);
        assert!(s.ttft_p50_s <= s.ttft_p99_s);
        // Means: TTFT (0.5 + 5.0 + 0.5)/3 = 2.0; TPOT (0.1 + 0.1 + 3.0)/3.
        assert!((s.ttft_mean_s - 2.0).abs() < 1e-12);
        assert!((s.tpot_mean_s - 3.2 / 3.0).abs() < 1e-12);
        assert!(s.render().contains("SLO attainment"));
    }

    #[test]
    fn empty_summary_is_defined() {
        let s = summarize(&[], &Slo::interactive(), 0.0);
        assert_eq!(s.requests, 0);
        assert_eq!(s.slo_attainment, 0.0);
        assert_eq!(s.ttft_p50_s, 0.0);
        assert_eq!(s.ttft_mean_s, 0.0);
        assert_eq!(s.goodput_tok_s, 0.0);
        assert_eq!(s.faulted_requests, 0);
        assert_eq!(s.ttft_p99_faulted_s, 0.0);
    }

    #[test]
    fn fault_conditioned_percentiles_cover_only_faulted_requests() {
        let mut slow = req(0.0, 8.0, 10.0, 11); // retried after a crash
        slow.faulted = true;
        let metrics = vec![req(0.0, 0.5, 1.5, 11), req(0.0, 0.6, 1.6, 11), slow];
        let s = summarize(&metrics, &Slo::interactive(), 10.0);
        assert_eq!(s.faulted_requests, 1);
        assert!((s.ttft_p99_faulted_s - 8.0).abs() < 1e-12);
        assert!((s.tpot_p99_faulted_s - 0.2).abs() < 1e-12);
        // The overall p50 is still dominated by the healthy requests.
        assert!(s.ttft_p50_s < 1.0);
        // Without faulted requests the conditioned tails stay zero.
        let healthy = summarize(&metrics[..2], &Slo::interactive(), 10.0);
        assert_eq!(healthy.faulted_requests, 0);
        assert_eq!(healthy.ttft_p99_faulted_s, 0.0);
    }
}
