//! The shared, lock-light quantizing latency oracle.
//!
//! Iteration latencies in the serving engines come from the analytical
//! simulator, quantized so an arbitrarily long trace touches only a
//! handful of unique mapper shapes: decode latency is affine in the KV
//! length at fixed batch (weights dominate, attention reads grow
//! linearly), so per power-of-two batch bucket the oracle samples two KV
//! points and interpolates; prefill is cached per (batch bucket,
//! power-of-two sequence bucket).
//!
//! Historically every engine run built its own cold oracle, so a
//! 4-replica fleet inside a 6-cell sweep recomputed the same expensive
//! mapper-backed points ~24×. [`OracleCache`] (one per
//! [`Simulator`], shared by everything the simulator drives) dedupes
//! oracles by a (device, device_count, interconnect, model) fingerprint
//! and hands out [`Arc<SharedOracle>`] handles, so fleet replicas and
//! sweep cells over unchanged hardware+model hit the underlying
//! simulator once. Sharing cannot change results: a bucket's value is a
//! pure deterministic function of the key, so a point computed in one
//! cell is bit-identical to what any other cell would have computed —
//! the shared-vs-private property tests lock this.
//!
//! Internally each oracle shards its bucket maps 16 ways (the
//! `SystolicLut` idiom) so concurrent engines rarely contend, and the
//! miss path *reserves* a bucket before filling it: the first caller
//! publishes a slot it already holds locked, simulates outside the shard
//! lock, then writes the value — a racing second caller finds the slot
//! and blocks on it instead of simulating the same bucket twice. That
//! keeps the hit/miss/simulator-call counters deterministic, which the
//! CI sweep smoke and the speedup integration test assert on.

use crate::graph::inference::Simulator;
use crate::graph::ModelConfig;
use crate::hardware::SystemSpec;
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// KV sample points for the affine decode fit.
const KV_LO: u64 = 64;
const KV_HI: u64 = 4096;

/// Shard count for the bucket maps (matches the mapper's `SystolicLut`).
const SHARDS: usize = 16;

fn pow2_bucket(v: u64) -> u64 {
    v.max(1).next_power_of_two()
}

fn shard_of<K: Hash>(key: &K) -> usize {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() as usize) % SHARDS
}

/// One bucket entry: either a published value or a slot reserved by the
/// caller currently simulating it (waiters block on the slot's lock).
enum BucketSlot<V> {
    Filling(Arc<Mutex<Option<V>>>),
    Ready(V),
}

/// Cache-activity counters, shared by every oracle a cache hands out
/// (including private baseline oracles), so fleet- and sweep-wide totals
/// read as one coherent set of numbers.
#[derive(Default)]
pub struct OracleCounters {
    hits: AtomicU64,
    misses: AtomicU64,
    /// Underlying analytical-simulator calls (2 per decode fit, 1 per
    /// prefill point) — the deterministic "work actually done" metric the
    /// shared-oracle speedup is asserted on.
    sim_calls: AtomicU64,
    decode_fits: AtomicU64,
    prefill_points: AtomicU64,
}

/// An immutable, coherent view of the counters — what
/// `IterOracle::cached_points()` should have been (that method took its
/// two mutexes back to back, so a mid-run reader could see a decode fit
/// without its prefill sibling). All fields are read from monotone
/// atomics bumped at publish time, never from the maps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OracleSnapshot {
    /// Unique (batch bucket, seq bucket) prefill points simulated.
    pub prefill_points: u64,
    /// Unique per-batch-bucket affine decode fits computed (2 simulator
    /// calls each).
    pub decode_fits: u64,
    pub hits: u64,
    pub misses: u64,
    /// Total underlying simulator calls: `2·decode_fits + prefill_points`.
    pub sim_calls: u64,
}

impl OracleCounters {
    fn snapshot(&self) -> OracleSnapshot {
        OracleSnapshot {
            prefill_points: self.prefill_points.load(Ordering::Relaxed),
            decode_fits: self.decode_fits.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            sim_calls: self.sim_calls.load(Ordering::Relaxed),
        }
    }
}

/// A quantizing latency oracle for one (system, model) pair, shareable
/// across engine runs, fleet replicas, and sweep cells. Owns clones of
/// the system and model so its lifetime is independent of any one run
/// (disaggregated sub-pool specs are run-local); the simulator is passed
/// per call instead of borrowed, which is what lets the cache outlive
/// every run that populated it.
pub struct SharedOracle {
    sys: SystemSpec,
    model: ModelConfig,
    /// batch bucket → (latency at `KV_LO`, slope per KV token), sharded.
    decode_fits: Vec<Mutex<HashMap<u64, BucketSlot<(f64, f64)>>>>,
    /// (batch bucket, seq bucket) → prefill seconds, sharded.
    prefill_points: Vec<Mutex<HashMap<(u64, u64), BucketSlot<f64>>>>,
    counters: Arc<OracleCounters>,
}

impl SharedOracle {
    /// A standalone oracle with its own counters (prefer
    /// [`OracleCache::for_system`], which dedupes and aggregates).
    pub fn new(sys: &SystemSpec, model: &ModelConfig) -> Self {
        Self::with_counters(sys, model, Arc::new(OracleCounters::default()))
    }

    fn with_counters(
        sys: &SystemSpec,
        model: &ModelConfig,
        counters: Arc<OracleCounters>,
    ) -> Self {
        SharedOracle {
            sys: sys.clone(),
            model: model.clone(),
            decode_fits: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            prefill_points: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            counters,
        }
    }

    /// Latency of one decode iteration for `batch` sequences at mean KV
    /// length `kv_len`.
    pub fn decode(&self, sim: &Simulator, batch: u64, kv_len: u64) -> f64 {
        let b = pow2_bucket(batch);
        let (lo, slope) = get_or_fill(
            &self.decode_fits[shard_of(&b)],
            b,
            &self.counters,
            || {
                self.counters.sim_calls.fetch_add(2, Ordering::Relaxed);
                self.counters.decode_fits.fetch_add(1, Ordering::Relaxed);
                let l_lo = sim.decode(&self.sys, &self.model, b, KV_LO, self.model.layers);
                let l_hi = sim.decode(&self.sys, &self.model, b, KV_HI, self.model.layers);
                (l_lo, (l_hi - l_lo) / (KV_HI - KV_LO) as f64)
            },
        );
        (lo + slope * (kv_len.max(KV_LO) - KV_LO) as f64).max(0.0)
    }

    /// Latency of one prefill iteration: `batch` prompts padded to the
    /// bucketed `seq` length.
    pub fn prefill(&self, sim: &Simulator, batch: u64, seq: u64) -> f64 {
        let key = (pow2_bucket(batch), pow2_bucket(seq));
        get_or_fill(&self.prefill_points[shard_of(&key)], key, &self.counters, || {
            self.counters.sim_calls.fetch_add(1, Ordering::Relaxed);
            self.counters.prefill_points.fetch_add(1, Ordering::Relaxed);
            sim.prefill(&self.sys, &self.model, key.0, key.1, self.model.layers)
        })
    }

    /// Coherent counter snapshot (cache-wide when the oracle came from an
    /// [`OracleCache`] — every sibling oracle shares the counters).
    pub fn snapshot(&self) -> OracleSnapshot {
        self.counters.snapshot()
    }
}

/// Read-mostly lookup with a reserve-then-fill miss path: the value for
/// `key` is computed exactly once cache-wide, and hits take one shard
/// lock. The `fill` closure runs outside the shard lock, so other keys
/// (and other shards) proceed while the simulator works.
fn get_or_fill<K, V>(
    shard: &Mutex<HashMap<K, BucketSlot<V>>>,
    key: K,
    counters: &OracleCounters,
    fill: impl FnOnce() -> V,
) -> V
where
    K: Hash + Eq + Copy,
    V: Copy,
{
    // Fast path: published value, or a slot someone is already filling.
    let waiter = {
        let map = shard.lock().unwrap();
        match map.get(&key) {
            Some(BucketSlot::Ready(v)) => {
                counters.hits.fetch_add(1, Ordering::Relaxed);
                return *v;
            }
            Some(BucketSlot::Filling(slot)) => Some(slot.clone()),
            None => None,
        }
    };
    if let Some(slot) = waiter {
        counters.hits.fetch_add(1, Ordering::Relaxed);
        return slot.lock().unwrap().expect("oracle slot abandoned by its filler");
    }
    // Reserve: publish a slot we already hold locked, so a racing caller
    // blocks on it instead of simulating the same bucket.
    let slot = Arc::new(Mutex::new(None));
    let mut publish = slot.lock().unwrap();
    {
        let mut map = shard.lock().unwrap();
        match map.entry(key) {
            Entry::Occupied(e) => {
                // Raced between our two shard locks: defer to the winner.
                let winner = match e.get() {
                    BucketSlot::Ready(v) => {
                        counters.hits.fetch_add(1, Ordering::Relaxed);
                        return *v;
                    }
                    BucketSlot::Filling(s) => s.clone(),
                };
                drop(map);
                drop(publish);
                counters.hits.fetch_add(1, Ordering::Relaxed);
                return winner.lock().unwrap().expect("oracle slot abandoned by its filler");
            }
            Entry::Vacant(e) => {
                e.insert(BucketSlot::Filling(slot.clone()));
            }
        }
    }
    counters.misses.fetch_add(1, Ordering::Relaxed);
    let v = fill();
    *publish = Some(v);
    drop(publish);
    // Swap the slot for the plain value so every later hit is one lock.
    shard.lock().unwrap().insert(key, BucketSlot::Ready(v));
    v
}

/// FNV-1a fingerprint of everything the oracle's values depend on. The
/// device fingerprint already folds in every structural parameter;
/// `device_count` keys disaggregated sub-pools apart from the full
/// system, and the model's `Debug` form folds in layer/width/dtype.
fn fingerprint(sys: &SystemSpec, model: &ModelConfig) -> u64 {
    let repr = format!(
        "{:x}|{}|{:?}|{:?}",
        sys.device.fingerprint(),
        sys.device_count,
        sys.interconnect,
        model
    );
    let mut h = 0xcbf29ce484222325u64;
    for b in repr.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The process-level oracle registry, one per [`Simulator`]: dedupes
/// [`SharedOracle`]s by hardware+model fingerprint so every consumer of
/// the same simulator — fleet replicas, sweep cells, experiment
/// sections — reuses one warm cache, and aggregates their counters for
/// the `eval` telemetry section and the `serve` stderr summary.
pub struct OracleCache {
    oracles: Mutex<HashMap<u64, Arc<SharedOracle>>>,
    counters: Arc<OracleCounters>,
    /// Test-only escape hatch: when `false`, [`OracleCache::for_system`]
    /// returns a fresh private oracle per call — the per-engine cold
    /// baseline the shared cache is measured against. Counters still
    /// aggregate, so baseline simulator-call totals stay comparable.
    shared: AtomicBool,
}

impl Default for OracleCache {
    fn default() -> Self {
        Self::new()
    }
}

impl OracleCache {
    pub fn new() -> Self {
        OracleCache {
            oracles: Mutex::new(HashMap::new()),
            counters: Arc::new(OracleCounters::default()),
            shared: AtomicBool::new(true),
        }
    }

    /// The shared oracle for this (system, model) pair, created on first
    /// use. Identical fingerprints — all replicas of a fleet, every sweep
    /// cell over unchanged hardware+model, both disaggregated pools at
    /// matching sizes — get the same `Arc`.
    pub fn for_system(&self, sys: &SystemSpec, model: &ModelConfig) -> Arc<SharedOracle> {
        if !self.shared.load(Ordering::Relaxed) {
            return Arc::new(SharedOracle::with_counters(sys, model, self.counters.clone()));
        }
        let key = fingerprint(sys, model);
        let mut map = self.oracles.lock().unwrap();
        map.entry(key)
            .or_insert_with(|| {
                Arc::new(SharedOracle::with_counters(sys, model, self.counters.clone()))
            })
            .clone()
    }

    /// Disable (or re-enable) cross-run sharing — the private-oracle
    /// baseline mode of the byte-identity property tests and the
    /// simulator-call-count comparisons.
    pub fn set_shared(&self, shared: bool) {
        self.shared.store(shared, Ordering::Relaxed);
    }

    /// Aggregate counter snapshot across every oracle this cache handed
    /// out (shared and private alike).
    pub fn snapshot(&self) -> OracleSnapshot {
        self.counters.snapshot()
    }

    /// Distinct (system, model) oracles currently cached.
    pub fn len(&self) -> usize {
        self.oracles.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::presets;

    fn setup() -> (Simulator, SystemSpec, ModelConfig) {
        (Simulator::new(), presets::system("a100").unwrap(), ModelConfig::gpt_small())
    }

    #[test]
    fn decode_affine_monotone_and_bucketed_with_exact_counters() {
        let (sim, sys, model) = setup();
        let oracle = SharedOracle::new(&sys, &model);
        let l1 = oracle.decode(&sim, 8, 256);
        let l2 = oracle.decode(&sim, 8, 1024);
        let l3 = oracle.decode(&sim, 8, 4096);
        assert!(l1 > 0.0);
        assert!(l2 >= l1 && l3 >= l2, "decode not monotone: {l1} {l2} {l3}");
        // Affine: midpoint interpolates exactly.
        let mid = oracle.decode(&sim, 8, (256 + 4096) / 2);
        let lin = l1 + (l3 - l1) * ((256 + 4096) / 2 - 256) as f64 / (4096 - 256) as f64;
        assert!((mid - lin).abs() < 1e-12);
        // Bucketing: batches 5..8 share a fit.
        assert_eq!(oracle.decode(&sim, 5, 1024), oracle.decode(&sim, 8, 1024));
        // All six calls landed in one pow2 batch bucket: one fit, two
        // simulator calls, and every later call a hit — exactly.
        let snap = oracle.snapshot();
        assert_eq!(snap.decode_fits, 1);
        assert_eq!(snap.prefill_points, 0);
        assert_eq!(snap.misses, 1);
        assert_eq!(snap.hits, 5);
        assert_eq!(snap.sim_calls, 2);
    }

    #[test]
    fn prefill_caches_per_bucket_pair() {
        let (sim, sys, model) = setup();
        let oracle = SharedOracle::new(&sys, &model);
        let a = oracle.prefill(&sim, 3, 700);
        // Same buckets (pow2(3)=4, pow2(700)=1024) — cached, identical.
        let b = oracle.prefill(&sim, 4, 1024);
        assert_eq!(a.to_bits(), b.to_bits());
        // A different seq bucket is a new point.
        let c = oracle.prefill(&sim, 4, 2048);
        assert!(c > 0.0 && c.to_bits() != a.to_bits());
        let snap = oracle.snapshot();
        assert_eq!(snap.prefill_points, 2);
        assert_eq!(snap.decode_fits, 0);
        assert_eq!(snap.hits, 1);
        assert_eq!(snap.misses, 2);
        assert_eq!(snap.sim_calls, 2);
    }

    #[test]
    fn cache_dedupes_by_hardware_and_model() {
        let (sim, sys, model) = setup();
        let a = sim.oracles.for_system(&sys, &model);
        let b = sim.oracles.for_system(&sys, &model);
        assert!(Arc::ptr_eq(&a, &b), "same fingerprint must share one oracle");
        assert_eq!(sim.oracles.len(), 1);
        // A different device count (a disaggregated sub-pool) keys apart.
        let mut sub = sys.clone();
        sub.device_count = 2;
        let c = sim.oracles.for_system(&sub, &model);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(sim.oracles.len(), 2);
        // A different model keys apart too.
        let other = ModelConfig::gpt3_175b();
        let d = sim.oracles.for_system(&sys, &other);
        assert!(!Arc::ptr_eq(&a, &d));
        assert_eq!(sim.oracles.len(), 3);
    }

    #[test]
    fn private_mode_returns_cold_oracles_but_aggregates_counters() {
        let (sim, sys, model) = setup();
        sim.oracles.set_shared(false);
        let a = sim.oracles.for_system(&sys, &model);
        let b = sim.oracles.for_system(&sys, &model);
        assert!(!Arc::ptr_eq(&a, &b), "private mode must not share");
        assert_eq!(sim.oracles.len(), 0, "private oracles are not retained");
        let v1 = a.decode(&sim, 4, 512);
        let v2 = b.decode(&sim, 4, 512);
        assert_eq!(v1.to_bits(), v2.to_bits(), "values are key-deterministic");
        // Both cold oracles simulated the same fit: 2 fits, 4 sim calls,
        // 0 hits — visible in the cache-wide aggregate.
        let snap = sim.oracles.snapshot();
        assert_eq!(snap.decode_fits, 2);
        assert_eq!(snap.sim_calls, 4);
        assert_eq!(snap.hits, 0);
        assert_eq!(snap.misses, 2);
        // Back in shared mode the same bucket costs nothing new per user.
        sim.oracles.set_shared(true);
        let c = sim.oracles.for_system(&sys, &model);
        let v3 = c.decode(&sim, 4, 512);
        assert_eq!(v1.to_bits(), v3.to_bits());
        assert_eq!(sim.oracles.snapshot().decode_fits, 3);
    }

    #[test]
    fn shared_reuse_across_consumers_hits_instead_of_simulating() {
        let (sim, sys, model) = setup();
        // Two independent consumers (two fleet replicas, or two sweep
        // cells) resolve the same oracle and replay the same buckets.
        let first = sim.oracles.for_system(&sys, &model);
        first.prefill(&sim, 4, 700);
        first.decode(&sim, 4, 900);
        let cold = sim.oracles.snapshot();
        assert_eq!(cold.sim_calls, 3); // 1 prefill point + 1 decode fit
        let second = sim.oracles.for_system(&sys, &model);
        second.prefill(&sim, 4, 700);
        second.decode(&sim, 4, 900);
        let warm = sim.oracles.snapshot();
        assert_eq!(warm.sim_calls, cold.sim_calls, "reuse must not re-simulate");
        assert_eq!(warm.hits, cold.hits + 2);
        assert_eq!(warm.prefill_points, 1);
        assert_eq!(warm.decode_fits, 1);
    }
}
