//! Cluster serving simulator: discrete-event simulation of an inference
//! cluster under realistic traffic, with the analytical performance model
//! ([`crate::graph::inference::Simulator`]) as the latency oracle.
//!
//! The paper evaluates hardware with static prefill/decode latencies at
//! fixed batch sizes; this subsystem turns those latencies into
//! *serving-level* quantities — time-to-first-token, time-per-output-token,
//! tail percentiles, and goodput under an SLO — by simulating request
//! arrivals, queueing, continuous batching, and KV-cache memory pressure:
//!
//! * [`workload`] — Poisson / bursty arrival processes and trace replay
//!   with configurable prompt/output-length distributions.
//! * [`scheduler`] — the iteration-level engine in three execution modes
//!   ([`ServeMode`]): monolithic prefill-prioritized batching, chunked
//!   prefill piggybacked onto decode iterations (Sarathi/Orca-style mixed
//!   iterations under a token budget), and disaggregated prefill/decode
//!   device pools coupled by a transfer-latency-modeled, bounded handoff
//!   queue (Splitwise-style; `handoff_capacity` backpressure stalls the
//!   prefill pool, surfaced as `handoff_stall_s`) — each with
//!   conservative or eviction-based ([`Preemption`]) KV admission. All
//!   iteration latencies come from the graph-lowered layer costs of the
//!   analytical simulator through the quantizing [`SharedOracle`].
//! * [`oracle`] — the shared, sharded, lock-light latency oracle cache:
//!   one warm [`SharedOracle`] per (hardware, model) fingerprint reused
//!   across fleet replicas and sweep cells, with deterministic hit/miss/
//!   simulator-call counters surfaced in telemetry and the CLI summary.
//! * [`fault`] — seeded, deterministic fault injection (crash / drain /
//!   slowdown / link degradation) plus the recovery policy (bounded retry
//!   with backoff, timeouts, admission shedding, degraded chunk sizes)
//!   that turns best-case serving numbers into under-fault numbers.
//! * [`events`] — the deterministic global event heap (`(time, priority,
//!   seq)` min-order via `total_cmp`) shared by the engine clocks and the
//!   fleet re-dispatch loop.
//! * [`fleet`] — multi-replica data-parallel serving: N replica engines
//!   behind a pluggable load balancer ([`Balancer`]) with cross-replica
//!   re-dispatch of crash losses, per-replica fault targeting
//!   (`replica:<i>` + `correlated_fraction`), and fleet-aggregate
//!   reporting.
//! * [`metrics`] — per-request timelines, percentile aggregation, and
//!   SLO goodput.
//! * [`sweep`] — the SLO-aware cost sweep reporting $/1M-tokens-at-SLO
//!   across hardware presets *and* scheduler modes (the Table IV
//!   comparison, under traffic), optionally across fleet sizes.
//!
//! Everything is deterministic in the workload seed, and the quantizing
//! oracle keeps mapper work bounded, so thousand-request traces of
//! GPT-3-class models simulate in seconds.

pub mod events;
pub mod fault;
pub mod fleet;
pub mod metrics;
pub mod oracle;
pub mod scheduler;
pub mod sweep;
pub mod workload;

pub use fault::{FaultEvent, FaultKind, FaultSpec, FaultTarget, RecoveryPolicy};
pub use fleet::{serve_fleet, validate_fleet, Balancer, FleetConfig};
pub use metrics::{RequestMetrics, Slo, Summary};
pub use oracle::{OracleCache, OracleSnapshot, SharedOracle};
pub use scheduler::{
    kv_capacity_tokens, Policy, Preemption, RunStats, SchedulerConfig, ServeMode,
};
pub use workload::{Arrival, Diurnal, FlashCrowd, LengthDist, Request, WorkloadSpec};

use crate::graph::inference::Simulator;
use crate::graph::ModelConfig;
use crate::hardware::SystemSpec;

/// The complete result of one serving run: the SLO summary plus the
/// scheduler's iteration/preemption accounting. `to_json` is byte-stable
/// for identical inputs — the deterministic-replay tests and the golden
/// harness both lock it.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub summary: Summary,
    pub stats: RunStats,
    /// Per-replica stats when the run came from [`serve_fleet`] with
    /// `replicas > 1`; empty for single-pool runs (and omitted from the
    /// JSON, keeping the legacy report byte-identical).
    pub replica_stats: Vec<RunStats>,
}

impl ServeReport {
    /// Stable JSON rendering (part of the `eval` report schema).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        let mut fields = vec![("summary", self.summary.to_json()), ("stats", self.stats.to_json())];
        if !self.replica_stats.is_empty() {
            let per = self.replica_stats.iter().map(|s| s.to_json()).collect();
            fields.push(("replicas", Json::Arr(per)));
        }
        obj(fields)
    }
}

/// Serve one workload on one system end to end: run the scheduler in the
/// configured mode and summarize under the SLO. Returns the report plus
/// per-request metrics.
pub fn serve_once(
    sim: &Simulator,
    sys: &SystemSpec,
    model: &ModelConfig,
    cfg: &SchedulerConfig,
    requests: &[workload::Request],
    slo: &Slo,
) -> (ServeReport, Vec<RequestMetrics>) {
    let (per_req, stats) = scheduler::simulate(sim, sys, model, cfg, requests);
    let summary = metrics::summarize(&per_req, slo, stats.makespan_s);
    (ServeReport { summary, stats, replica_stats: vec![] }, per_req)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::presets;

    #[test]
    fn serve_once_end_to_end_on_small_model() {
        let sim = Simulator::new();
        let sys = presets::system("a100").unwrap();
        let model = ModelConfig::gpt_small();
        let cfg = SchedulerConfig::for_system(&sys, &model, Policy::Fcfs);
        let reqs = workload::generate(&WorkloadSpec::poisson(25.0, 100, 1));
        let (report, per_req) = serve_once(&sim, &sys, &model, &cfg, &reqs, &Slo::relaxed());
        let (summary, stats) = (&report.summary, &report.stats);
        assert_eq!(summary.requests, 100);
        assert_eq!(per_req.len(), 100);
        assert!(summary.throughput_tok_s > 0.0);
        assert!(summary.ttft_p50_s <= summary.ttft_p99_s);
        assert!(summary.tpot_p50_s <= summary.tpot_p99_s);
        assert!(stats.makespan_s > 0.0);
        assert!(summary.goodput_tok_s <= summary.throughput_tok_s + 1e-12);
        // The report JSON nests both halves under stable keys.
        let j = report.to_json();
        assert!(j.get("summary").and_then(|s| s.get("ttft_mean_s")).is_some());
        assert!(j.get("stats").and_then(|s| s.get("preemptions")).is_some());
    }
}
