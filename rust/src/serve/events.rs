//! The global event heap: a deterministic min-heap of timestamped events
//! shared by every clock in the serving simulator.
//!
//! Ordering is total and reproducible: events pop by `(time, priority,
//! seq)` — time via `f64::total_cmp` (no NaN panics, `-0.0 < 0.0`),
//! priority as an explicit tie-break between same-time event classes
//! (e.g. the disaggregated engine gives the prefill pool priority 0 and
//! the decode pool priority 1, reproducing the historical
//! "prefill wins ties" clock pick bit for bit), and an insertion serial
//! so equal `(time, priority)` events pop in push order. The fleet layer
//! reuses the same heap to order replica loss events for re-dispatch.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<T> {
    time: f64,
    priority: u8,
    seq: u64,
    payload: T,
}

impl<T> Entry<T> {
    /// Reversed key comparison: the *greatest* entry under this ordering
    /// is the earliest event, so `BinaryHeap` (a max-heap) pops min-first.
    fn key_cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .total_cmp(&self.time)
            .then(other.priority.cmp(&self.priority))
            .then(other.seq.cmp(&self.seq))
    }
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.key_cmp(other) == Ordering::Equal
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.key_cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        self.key_cmp(other)
    }
}

/// Deterministic min-heap of `(time, priority, payload)` events.
pub struct EventHeap<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

impl<T> Default for EventHeap<T> {
    fn default() -> Self {
        EventHeap::new()
    }
}

impl<T> EventHeap<T> {
    pub fn new() -> EventHeap<T> {
        EventHeap { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `payload` at `time`. Lower `priority` pops first among
    /// same-time events; equal `(time, priority)` pops in push order.
    pub fn push(&mut self, time: f64, priority: u8, payload: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { time, priority, seq, payload });
    }

    /// Pop the earliest event as `(time, payload)`.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| (e.time, e.payload))
    }

    /// Timestamp of the earliest event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Drop all pending events (the insertion serial keeps counting, so
    /// ordering stays stable across reuse).
    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut h = EventHeap::new();
        h.push(3.0, 0, "c");
        h.push(1.0, 0, "a");
        h.push(2.0, 0, "b");
        assert_eq!(h.peek_time(), Some(1.0));
        assert_eq!(h.len(), 3);
        assert_eq!(h.pop(), Some((1.0, "a")));
        assert_eq!(h.pop(), Some((2.0, "b")));
        assert_eq!(h.pop(), Some((3.0, "c")));
        assert_eq!(h.pop(), None);
        assert!(h.is_empty());
    }

    #[test]
    fn priority_breaks_time_ties_then_push_order() {
        let mut h = EventHeap::new();
        h.push(1.0, 1, "decode");
        h.push(1.0, 0, "prefill");
        assert_eq!(h.pop(), Some((1.0, "prefill")), "lower priority pops first");
        assert_eq!(h.pop(), Some((1.0, "decode")));
        // Equal (time, priority): FIFO by insertion serial.
        h.push(2.0, 0, "first");
        h.push(2.0, 0, "second");
        assert_eq!(h.pop(), Some((2.0, "first")));
        assert_eq!(h.pop(), Some((2.0, "second")));
    }

    #[test]
    fn total_cmp_handles_infinities_and_negative_zero() {
        let mut h = EventHeap::new();
        h.push(f64::INFINITY, 0, "never");
        h.push(0.0, 0, "zero");
        h.push(-0.0, 0, "neg zero");
        assert_eq!(h.pop(), Some((-0.0, "neg zero")), "-0.0 sorts before 0.0 under total_cmp");
        assert_eq!(h.pop(), Some((0.0, "zero")));
        assert_eq!(h.pop(), Some((f64::INFINITY, "never")));
    }

    #[test]
    fn clear_keeps_the_serial_monotone() {
        let mut h = EventHeap::new();
        h.push(1.0, 0, 1u32);
        h.clear();
        assert!(h.is_empty());
        h.push(1.0, 0, 2u32);
        h.push(1.0, 0, 3u32);
        assert_eq!(h.pop(), Some((1.0, 2)));
        assert_eq!(h.pop(), Some((1.0, 3)));
    }
}
