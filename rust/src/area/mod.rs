//! The area model (paper §III-D, Table II, Fig. 6).
//!
//! Component areas are estimated at a TSMC-7nm-class node from transistor
//! counts of open-source designs/generators and annotated die photos, then
//! composed bottom-up: lanes (vector units, systolic arrays, register
//! files, per-lane overhead) → cores (+ local buffer, per-core overhead
//! including a crossbar share) → device (+ global buffer, memory
//! controller/PHY, device-device interconnect, fixed system logic).
//!
//! Calibration: the per-core overhead and effective MAC/SRAM densities are
//! fitted so the three Table IV dies reproduce the paper's areas
//! (GA100 826 mm², latency-oriented 478 mm², throughput-oriented 787 mm²)
//! — mirroring the paper, which likewise back-solves per-lane/per-core
//! overheads from annotated NVIDIA/AMD die photos. PHY area does not scale
//! with process (analog), controller area does.

pub mod sram;

use crate::hardware::{DeviceSpec, MemProtocol};
use crate::util::json::{num, obj, Json};

/// Table II-style component parameters (7 nm, µm²). The FP64/INT32/lane/
/// HBM rows reproduce the paper's Table II; the derived rows (FP32, FP16
/// MAC, register file) and the crossbar-inclusive core overhead are
/// documented fits.
#[derive(Debug, Clone)]
pub struct AreaParams {
    /// 64-bit FPU (Table II: 685,300 transistors).
    pub fp64_unit_um2: f64,
    /// 32-bit FPU ≈ half an FP64 unit.
    pub fp32_unit_um2: f64,
    /// 32-bit integer ALU (Table II: 177,000 transistors).
    pub int32_alu_um2: f64,
    /// One FP16 MAC PE of a systolic array, incl. operand routing —
    /// effective density fitted to tensor-core area shares.
    pub fp16_mac_um2: f64,
    /// Register file, µm² per bit (multi-ported; EMPIRE-style empirical
    /// model [54]).
    pub regfile_um2_per_bit: f64,
    /// Per-lane overhead: control, scheduler slice (Table II: 996,200 t).
    pub lane_overhead_um2: f64,
    /// Per-core overhead: instruction front-end + the core's share of the
    /// core-to-core crossbar (paper: back-solved from die photos).
    pub core_overhead_um2: f64,
    /// 1024-bit HBM2e controller (Table II).
    pub hbm_ctrl_um2: f64,
    /// 1024-bit HBM2e PHY (Table II; analog, does not scale).
    pub hbm_phy_um2: f64,
    /// One PCIe 5.0 channel (controller + PHY), ~4 GB/s per channel.
    pub pcie5_channel_um2: f64,
    /// One DDR5 64-bit channel interface.
    pub ddr5_channel_um2: f64,
    /// NVLink-class link (PHY + controller) per ~50 GB/s link.
    pub nvlink_um2: f64,
    /// Fixed device-level logic: command processors, host interface,
    /// display/copy engines.
    pub device_fixed_um2: f64,
}

impl Default for AreaParams {
    fn default() -> Self {
        AreaParams {
            fp64_unit_um2: 7116.0,
            fp32_unit_um2: 3558.0,
            int32_alu_um2: 1838.0,
            fp16_mac_um2: 1340.0,
            regfile_um2_per_bit: 0.60,
            lane_overhead_um2: 10_344.0,
            core_overhead_um2: 1_660_000.0,
            hbm_ctrl_um2: 5_740_000.0,
            hbm_phy_um2: 10_450_000.0,
            pcie5_channel_um2: 235_000.0,
            ddr5_channel_um2: 4_800_000.0,
            nvlink_um2: 2_000_000.0,
            device_fixed_um2: 25_000_000.0,
        }
    }
}

/// Die-area breakdown in mm² (Fig. 6a categories).
#[derive(Debug, Clone, PartialEq)]
pub struct DieBreakdown {
    pub vector_units_mm2: f64,
    pub int_units_mm2: f64,
    pub systolic_mm2: f64,
    pub regfile_mm2: f64,
    pub lane_overhead_mm2: f64,
    pub local_buffer_mm2: f64,
    pub core_overhead_mm2: f64,
    pub global_buffer_mm2: f64,
    pub memory_interface_mm2: f64,
    pub interconnect_mm2: f64,
    pub device_fixed_mm2: f64,
}

impl DieBreakdown {
    pub fn core_total_mm2(&self) -> f64 {
        self.vector_units_mm2
            + self.int_units_mm2
            + self.systolic_mm2
            + self.regfile_mm2
            + self.lane_overhead_mm2
            + self.local_buffer_mm2
            + self.core_overhead_mm2
    }

    pub fn total_mm2(&self) -> f64 {
        self.core_total_mm2()
            + self.global_buffer_mm2
            + self.memory_interface_mm2
            + self.interconnect_mm2
            + self.device_fixed_mm2
    }

    /// (label, mm²) pairs for tables/plots.
    pub fn rows(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("vector units", self.vector_units_mm2),
            ("int units", self.int_units_mm2),
            ("systolic arrays", self.systolic_mm2),
            ("register files", self.regfile_mm2),
            ("lane overhead", self.lane_overhead_mm2),
            ("local buffers", self.local_buffer_mm2),
            ("core overhead", self.core_overhead_mm2),
            ("global buffer", self.global_buffer_mm2),
            ("memory interface", self.memory_interface_mm2),
            ("device interconnect", self.interconnect_mm2),
            ("device fixed", self.device_fixed_mm2),
        ]
    }

    pub fn to_json(&self) -> Json {
        obj(self
            .rows()
            .into_iter()
            .map(|(k, v)| (k, num(v)))
            .chain([("total", num(self.total_mm2()))])
            .collect())
    }
}

/// Memory-interface area from bandwidth/capacity and protocol.
pub fn memory_interface_mm2(p: &AreaParams, dev: &DeviceSpec) -> f64 {
    let bw = dev.memory.bandwidth_bytes_per_s;
    let cap_gb = dev.memory.capacity_bytes as f64 / 1e9;
    match dev.memory.protocol {
        MemProtocol::HBM2E => {
            // One 1024-bit HBM2e stack ≈ 410 GB/s and 16 GB.
            let stacks = (bw / 410e9).ceil().max((cap_gb / 16.0).ceil());
            stacks * (p.hbm_ctrl_um2 + p.hbm_phy_um2) / 1e6
        }
        MemProtocol::PCIE5CXL => {
            // ~3.94 GB/s per PCIe 5.0 channel (paper: 256 channels → 1 TB/s).
            let channels = (bw / 3.94e9).ceil();
            channels * p.pcie5_channel_um2 / 1e6
        }
        MemProtocol::DDR5 | MemProtocol::HostDRAM => {
            // ~40 GB/s per 64-bit DDR5-5200 channel.
            let channels = (bw / 40e9).ceil();
            channels * p.ddr5_channel_um2 / 1e6
        }
    }
}

/// Compute the full die breakdown for a device description.
pub fn die_breakdown(p: &AreaParams, dev: &DeviceSpec, d2d_bw_bytes_per_s: f64) -> DieBreakdown {
    let cores = dev.core_count as f64;
    let lanes = dev.core.lane_count as f64;
    let lane = &dev.core.lane;

    let vector = cores * lanes * lane.vector_width as f64 * p.fp32_unit_um2 / 1e6;
    // INT32 ALUs: half the vector width per lane (the GA100 ratio of 64
    // INT32 to 128 FP32 per SM).
    let ints = cores * lanes * (lane.vector_width as f64 / 2.0) * p.int32_alu_um2 / 1e6;
    let systolic = cores
        * lanes
        * (lane.systolic_rows * lane.systolic_cols * lane.systolic_count) as f64
        * p.fp16_mac_um2
        / 1e6;
    let regfile = cores * lanes * (lane.register_bytes * 8) as f64 * p.regfile_um2_per_bit / 1e6;
    let lane_ovh = cores * lanes * p.lane_overhead_um2 / 1e6;
    let local = cores * sram::sram_mm2(p, dev.core.local_buffer_bytes);
    let core_ovh = cores * p.core_overhead_um2 / 1e6;
    let global = sram::sram_mm2(p, dev.global_buffer_bytes);
    let mem_if = memory_interface_mm2(p, dev);
    // NVLink-class links at ~50 GB/s per link.
    let links = (d2d_bw_bytes_per_s / 50e9).ceil();
    let icnt = links * p.nvlink_um2 / 1e6;

    DieBreakdown {
        vector_units_mm2: vector,
        int_units_mm2: ints,
        systolic_mm2: systolic,
        regfile_mm2: regfile,
        lane_overhead_mm2: lane_ovh,
        local_buffer_mm2: local,
        core_overhead_mm2: core_ovh,
        global_buffer_mm2: global,
        memory_interface_mm2: mem_if,
        interconnect_mm2: icnt,
        device_fixed_mm2: p.device_fixed_um2 / 1e6,
    }
}

/// Convenience: total die area in mm² with default parameters and a
/// 600 GB/s interconnect.
pub fn die_mm2(dev: &DeviceSpec) -> f64 {
    die_breakdown(&AreaParams::default(), dev, 600e9).total_mm2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::presets;

    #[test]
    fn table4_die_areas_reproduce() {
        // Paper Table IV: GA100 826 mm², latency-oriented 478 mm²,
        // throughput-oriented 787 mm². Require < 7% error.
        for (name, paper) in
            [("ga100", 826.0), ("latency-oriented", 478.0), ("throughput-oriented", 787.0)]
        {
            let dev = presets::device(name).unwrap();
            let got = die_mm2(&dev);
            let err: f64 = (got - paper) / paper;
            assert!(
                err.abs() < 0.07,
                "{name}: model {got:.0} mm² vs paper {paper} mm² ({:+.1}%)",
                err * 100.0
            );
        }
    }

    #[test]
    fn aldebaran_within_paper_error_band() {
        // Fig. 6a: Aldebaran (MI210 die) ≈ 724 mm²; paper reports 8.1%
        // model error — require < 12% here. CDNA2 CUs carry a 512 KB
        // vector register file (128 KB per SIMD lane).
        let mut dev = presets::mi210();
        dev.core.lane.register_bytes = 128 * 1024;
        let got = die_breakdown(&AreaParams::default(), &dev, 300e9).total_mm2();
        let err: f64 = (got - 724.0) / 724.0;
        assert!(err.abs() < 0.12, "aldebaran model {got:.0} mm² ({:+.1}% err)", err * 100.0);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let dev = presets::a100();
        let b = die_breakdown(&AreaParams::default(), &dev, 600e9);
        let sum: f64 = b.rows().iter().map(|(_, v)| v).sum();
        assert!((sum - b.total_mm2()).abs() < 1e-9);
        assert!(b.core_total_mm2() < b.total_mm2());
        for (name, v) in b.rows() {
            assert!(v >= 0.0, "{name} negative");
        }
    }

    #[test]
    fn pruning_cores_shrinks_die_substantially() {
        // Paper §V-A: latency design reduces die area by 42.1% vs GA100.
        let ga = die_mm2(&presets::ga100());
        let lat = die_mm2(&presets::latency_oriented());
        let shrink = 1.0 - lat / ga;
        assert!(
            (0.35..0.50).contains(&shrink),
            "area shrink {:.1}% (paper: 42.1%)",
            shrink * 100.0
        );
    }

    #[test]
    fn hbm_vs_pcie_memory_interface() {
        let p = AreaParams::default();
        let a100 = presets::a100();
        let thr = presets::throughput_oriented();
        let hbm = memory_interface_mm2(&p, &a100);
        let pcie = memory_interface_mm2(&p, &thr);
        // 5 HBM stacks ≈ 81 mm²; 254 PCIe channels ≈ 60 mm².
        assert!((70.0..95.0).contains(&hbm), "hbm {hbm:.1}");
        assert!((45.0..75.0).contains(&pcie), "pcie {pcie:.1}");
    }

    #[test]
    fn design_a_uses_much_less_area_than_b() {
        // Paper §IV-B: design A (quarter compute) uses 57.8% of B's area.
        let a = die_mm2(&presets::design('A').unwrap());
        let b = die_mm2(&presets::design('B').unwrap());
        let ratio = a / b;
        assert!((0.45..0.80).contains(&ratio), "A/B area ratio {ratio:.2} (paper 0.578)");
    }

    #[test]
    fn json_emission() {
        let b = die_breakdown(&AreaParams::default(), &presets::a100(), 600e9);
        let j = b.to_json();
        assert!(j.get("total").unwrap().as_f64().unwrap() > 0.0);
    }
}
