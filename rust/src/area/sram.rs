//! CACTI-lite: SRAM macro area vs capacity at a 7nm-class node.
//!
//! The paper derives buffer areas with CACTI 6.0 scaled down to 7 nm. We
//! fit the same trend with a two-parameter model: a 6T bit-cell area plus
//! a periphery overhead factor that falls with macro size (sense amps,
//! decoders, and redundancy amortize over bigger arrays). Anchors:
//! shipping-7nm cache macros land near 1.0 mm²/MB at multi-MB sizes
//! (e.g. Zen2 L3) and ~1.3–1.6 mm²/MB at sub-256-KB sizes.

use super::AreaParams;

/// 7nm high-density 6T bit cell, µm².
pub const BITCELL_UM2: f64 = 0.027;

/// Periphery overhead multiplier as a function of macro capacity.
pub fn overhead_factor(bytes: u64) -> f64 {
    let kb = bytes as f64 / 1024.0;
    if kb >= 4096.0 {
        3.5
    } else if kb >= 1024.0 {
        3.8
    } else if kb >= 256.0 {
        4.2
    } else if kb >= 64.0 {
        4.8
    } else {
        6.0
    }
}

/// SRAM macro area in mm² for a buffer of `bytes`.
pub fn sram_mm2(_p: &AreaParams, bytes: u64) -> f64 {
    let bits = bytes as f64 * 8.0;
    bits * BITCELL_UM2 * overhead_factor(bytes) / 1e6
}

/// Density in mm² per MB (for reporting).
pub fn mm2_per_mb(bytes: u64) -> f64 {
    sram_mm2(&AreaParams::default(), bytes) / (bytes as f64 / (1024.0 * 1024.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multi_mb_density_near_one_mm2_per_mb() {
        let d = mm2_per_mb(40 * 1024 * 1024);
        assert!((0.7..1.1).contains(&d), "40MB density {d:.2} mm²/MB");
    }

    #[test]
    fn small_macros_less_dense() {
        assert!(mm2_per_mb(32 * 1024) > mm2_per_mb(8 * 1024 * 1024));
    }

    #[test]
    fn area_monotone_in_capacity() {
        let mut last = 0.0;
        for kb in [16u64, 64, 192, 1024, 4096, 40 * 1024] {
            let a = sram_mm2(&AreaParams::default(), kb * 1024);
            assert!(a > last);
            last = a;
        }
    }

    #[test]
    fn a100_l1_and_l2_plausible() {
        // 192 KB L1: ~0.25-0.35 mm²; 40 MB L2: ~30-40 mm².
        let l1 = sram_mm2(&AreaParams::default(), 192 * 1024);
        let l2 = sram_mm2(&AreaParams::default(), 40 * 1024 * 1024);
        assert!((0.2..0.4).contains(&l1), "L1 {l1:.3}");
        assert!((25.0..42.0).contains(&l2), "L2 {l2:.1}");
    }
}
