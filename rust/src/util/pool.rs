//! A scoped work-stealing thread pool (rayon is unavailable offline).
//!
//! Two fan-out primitives, both order-preserving:
//!
//! * [`parallel_map`] — a fixed number of workers claim *chunks* of the
//!   work list off a shared atomic index. The calling thread is one of
//!   the workers, so `threads: 4` costs three spawns. Chunked claiming
//!   (instead of one `fetch_add` per item) keeps the index cache line
//!   from becoming the bottleneck on short items.
//! * [`parallel_map_shared`] — the *hybrid* primitive behind the mapper
//!   engine: workers are borrowed from a process-wide token budget of
//!   `default_threads() − 1` tokens. An outer sweep (experiment cells,
//!   eval suites) grabs what is idle; when one of its workers drains the
//!   work list it returns its token immediately, so a *nested*
//!   `parallel_map_shared` (the mapper's per-candidate loop) running in
//!   the sweep's tail can pick the token up. Both levels of parallelism
//!   get used without the thread counts multiplying: across *shared*
//!   fan-outs, total live workers never exceed `default_threads()`.
//!   (`parallel_map`'s explicit thread count deliberately bypasses the
//!   budget — don't nest a shared map under a fixed pool sized to all
//!   cores, or the two add up.)

use std::sync::atomic::{AtomicIsize, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

/// Number of worker threads to use: `LLMCOMPASS_THREADS` env override, else
/// available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("LLMCOMPASS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Chunk size for the work-stealing index: large enough to amortize the
/// atomic claim, small enough that ragged per-item costs still balance
/// across workers (each worker sees ~8 chunks).
fn chunk_size(n: usize, workers: usize) -> usize {
    (n / (workers * 8)).max(1)
}

/// The process-wide worker-token budget: how many *extra* threads (beyond
/// the calling one) may be live across all `parallel_map_shared` calls.
fn token_pool() -> &'static AtomicIsize {
    static POOL: OnceLock<AtomicIsize> = OnceLock::new();
    POOL.get_or_init(|| AtomicIsize::new(default_threads() as isize - 1))
}

/// Borrow up to `max` worker tokens; returns how many were acquired
/// (possibly 0 — callers must degrade to serial, never block).
fn acquire_tokens(max: usize) -> usize {
    if max == 0 {
        return 0;
    }
    let pool = token_pool();
    let mut cur = pool.load(Ordering::Relaxed);
    loop {
        if cur <= 0 {
            return 0;
        }
        let take = (cur as usize).min(max);
        match pool.compare_exchange_weak(
            cur,
            cur - take as isize,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return take,
            Err(c) => cur = c,
        }
    }
}

fn release_tokens(n: usize) {
    if n > 0 {
        token_pool().fetch_add(n as isize, Ordering::Relaxed);
    }
}

/// Donate the calling thread's core to the budget while it blocks on
/// something out-of-band (a condvar, a channel); pair with
/// [`withdraw_token`] on wake. A blocked thread is not a live worker, so
/// lending its capacity keeps e.g. a mapper search running wide while the
/// threads coalescing on its result sleep.
pub(crate) fn donate_token() {
    token_pool().fetch_add(1, Ordering::Relaxed);
}

/// Take back the capacity donated before blocking. May briefly drive the
/// budget negative (when the donated token is currently in use), which
/// simply pauses new grants until a worker releases — never blocks.
pub(crate) fn withdraw_token() {
    token_pool().fetch_sub(1, Ordering::Relaxed);
}

/// Releases one worker token on drop — even if the worker's closure
/// panics, the budget is restored.
struct TokenGuard;

impl Drop for TokenGuard {
    fn drop(&mut self) {
        release_tokens(1);
    }
}

/// The shared claim-and-fill loop: grab a chunk of indices, fill slots.
fn steal_loop<T, R, F>(
    items: &[T],
    slots: &[Mutex<Option<R>>],
    next: &AtomicUsize,
    chunk: usize,
    f: &F,
) where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    loop {
        let start = next.fetch_add(chunk, Ordering::Relaxed);
        if start >= n {
            break;
        }
        for i in start..(start + chunk).min(n) {
            let r = f(&items[i]);
            *slots[i].lock().unwrap() = Some(r);
        }
    }
}

fn collect_slots<R>(slots: Vec<Mutex<Option<R>>>) -> Vec<R> {
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

/// Apply `f` to every item of `items` in parallel, preserving order.
///
/// `f` must be `Sync` (shared across workers by reference); items are read
/// by shared reference. The calling thread participates as one of the
/// `threads` workers; results are written into per-index slots so no
/// ordering coordination is needed. This primitive uses exactly the
/// thread count it is given — it does not consult the shared token
/// budget (see [`parallel_map_shared`] for that).
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    run_stealing(items, threads - 1, false, &f)
}

/// Like [`parallel_map`], but workers are borrowed from the process-wide
/// token budget — the work-stealing *hybrid* mode. Nested calls never
/// multiply threads: whatever level has work claims the idle tokens, and
/// a worker returns its token the moment the list it serves is drained.
/// The worker set also *grows* mid-map: between chunks the calling thread
/// re-polls the budget, so tokens freed while the map runs (a sibling map
/// finishing, or a thread donating its core while it blocks on this map's
/// result) are put to work instead of idling. With no tokens available at
/// all the map runs serially on the calling thread, still polling.
pub fn parallel_map_shared<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n <= 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let extra = acquire_tokens(n - 1);
    run_stealing(items, extra, true, &f)
}

/// Fan `items` across `extra` spawned workers plus the calling thread.
/// When `tokened`, each spawned worker holds one budget token, returns it
/// as soon as it exits the claim loop, and the calling thread grows the
/// worker set whenever a fresh token becomes available between chunks.
fn run_stealing<T, R, F>(items: &[T], extra: usize, tokened: bool, f: &F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let next = AtomicUsize::new(0);
    let chunk = chunk_size(n, extra + 1);
    let mut slots: Vec<Mutex<Option<R>>> = Vec::with_capacity(n);
    for _ in 0..n {
        slots.push(Mutex::new(None));
    }
    std::thread::scope(|scope| {
        for _ in 0..extra {
            scope.spawn(|| {
                // Lazily constructed: a guard only exists (and so only
                // releases a token on drop) when this worker holds one.
                let _token = tokened.then(|| TokenGuard);
                steal_loop(items, &slots, &next, chunk, f);
            });
        }
        if !tokened {
            steal_loop(items, &slots, &next, chunk, f);
            return;
        }
        // Caller's claim loop with growth: each newly acquired token
        // spawns a late worker (which hands the token back on exit).
        loop {
            let start = next.fetch_add(chunk, Ordering::Relaxed);
            if start >= n {
                break;
            }
            for i in start..(start + chunk).min(n) {
                let r = f(&items[i]);
                *slots[i].lock().unwrap() = Some(r);
            }
            if next.load(Ordering::Relaxed) < n && acquire_tokens(1) == 1 {
                scope.spawn(|| {
                    let _token = TokenGuard;
                    steal_loop(items, &slots, &next, chunk, f);
                });
            }
        }
    });
    collect_slots(slots)
}

/// Parallel reduce: map each item then fold results with `combine`.
/// `identity` seeds each worker-local accumulator.
pub fn parallel_reduce<T, A, F, C>(items: &[T], threads: usize, identity: A, f: F, combine: C) -> A
where
    T: Sync,
    A: Send + Clone,
    F: Fn(&T) -> A + Sync,
    C: Fn(A, A) -> A + Sync + Send + Copy,
{
    let partials = parallel_map(items, threads, f);
    partials.into_iter().fold(identity, combine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let items = vec![1u32, 2, 3];
        assert_eq!(parallel_map(&items, 1, |&x| x + 1), vec![2, 3, 4]);
        assert_eq!(parallel_map::<u32, u32, _>(&[], 4, |&x| x), Vec::<u32>::new());
    }

    #[test]
    fn every_item_processed_exactly_once() {
        let count = AtomicU64::new(0);
        let items: Vec<usize> = (0..257).collect();
        let out = parallel_map(&items, 4, |&i| {
            count.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(count.load(Ordering::Relaxed), 257);
        let distinct: HashSet<usize> = out.into_iter().collect();
        assert_eq!(distinct.len(), 257);
    }

    #[test]
    fn reduce_sums() {
        let items: Vec<u64> = (1..=100).collect();
        let total = parallel_reduce(&items, 4, 0u64, |&x| x, |a, b| a + b);
        assert_eq!(total, 5050);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn shared_map_preserves_order_and_coverage() {
        let items: Vec<u64> = (0..500).collect();
        let out = parallel_map_shared(&items, |&x| x + 1);
        assert_eq!(out, items.iter().map(|x| x + 1).collect::<Vec<_>>());
        assert_eq!(parallel_map_shared::<u64, u64, _>(&[], |&x| x), Vec::<u64>::new());
    }

    #[test]
    fn nested_shared_maps_do_not_deadlock_or_lose_items() {
        // An outer shared map whose items each run an inner shared map —
        // the hybrid shape of an experiment sweep over mapper searches.
        // Tokens are finite, so inner maps may run serial, but every item
        // must still be produced, in order.
        let outer: Vec<u64> = (0..16).collect();
        let out = parallel_map_shared(&outer, |&o| {
            let inner: Vec<u64> = (0..64).collect();
            parallel_map_shared(&inner, |&i| o * 64 + i).iter().sum::<u64>()
        });
        for (o, sum) in outer.iter().zip(&out) {
            let expect: u64 = (0..64).map(|i| o * 64 + i).sum();
            assert_eq!(*sum, expect);
        }
    }

    #[test]
    fn repeated_shared_maps_stay_correct() {
        // The global pool is shared with concurrently running tests (and
        // condvar waiters donate/withdraw transiently), so its level
        // cannot be asserted race-free here. What can: token accounting
        // must balance well enough that many successive shared maps keep
        // completing correctly — a lost-token leak would starve them to
        // serial (still correct) but an over-release or double-free-style
        // bug would corrupt results or deadlock the scope joins.
        for round in 0..50u64 {
            let items: Vec<u64> = (0..64).collect();
            let out = parallel_map_shared(&items, |&x| x * 3 + round);
            assert_eq!(out, items.iter().map(|x| x * 3 + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn chunk_size_sane() {
        assert_eq!(chunk_size(1, 8), 1);
        assert_eq!(chunk_size(1000, 4), 31);
        assert!(chunk_size(7, 1) >= 1);
    }
}
