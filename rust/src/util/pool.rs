//! A scoped thread pool (rayon is unavailable offline).
//!
//! The mapper's parameter search and the experiment sweeps are
//! embarrassingly parallel; `parallel_map` fans a work list across
//! `std::thread` workers using an atomic work-stealing index and returns
//! results in input order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use: `LLMCOMPASS_THREADS` env override, else
/// available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("LLMCOMPASS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every item of `items` in parallel, preserving order.
///
/// `f` must be `Sync` (shared across workers by reference); items are read
/// by shared reference. Results are written into per-index slots so no
/// ordering coordination is needed.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter().map(|t| f(t)).collect();
    }

    let next = AtomicUsize::new(0);
    let mut slots: Vec<Mutex<Option<R>>> = Vec::with_capacity(n);
    for _ in 0..n {
        slots.push(Mutex::new(None));
    }

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });

    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

/// Parallel reduce: map each item then fold results with `combine`.
/// `identity` seeds each worker-local accumulator.
pub fn parallel_reduce<T, A, F, C>(items: &[T], threads: usize, identity: A, f: F, combine: C) -> A
where
    T: Sync,
    A: Send + Clone,
    F: Fn(&T) -> A + Sync,
    C: Fn(A, A) -> A + Sync + Send + Copy,
{
    let partials = parallel_map(items, threads, f);
    partials.into_iter().fold(identity, combine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let items = vec![1u32, 2, 3];
        assert_eq!(parallel_map(&items, 1, |&x| x + 1), vec![2, 3, 4]);
        assert_eq!(parallel_map::<u32, u32, _>(&[], 4, |&x| x), Vec::<u32>::new());
    }

    #[test]
    fn every_item_processed_exactly_once() {
        let count = AtomicU64::new(0);
        let items: Vec<usize> = (0..257).collect();
        let out = parallel_map(&items, 4, |&i| {
            count.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(count.load(Ordering::Relaxed), 257);
        let distinct: HashSet<usize> = out.into_iter().collect();
        assert_eq!(distinct.len(), 257);
    }

    #[test]
    fn reduce_sums() {
        let items: Vec<u64> = (1..=100).collect();
        let total = parallel_reduce(&items, 4, 0u64, |&x| x, |a, b| a + b);
        assert_eq!(total, 5050);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
