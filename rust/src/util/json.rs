//! A small, dependency-free JSON parser and writer.
//!
//! The vendored registry has no `serde`, so hardware descriptions, artifact
//! manifests, and experiment reports are (de)serialized through this module.
//! It supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) plus two conveniences used by config files:
//! `//`-to-end-of-line comments and trailing commas.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a `BTreeMap` so output is
/// deterministic (stable diffs for generated reports).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error produced by the parser, with a byte offset and a line number.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
    pub line: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at line {} (byte {}): {}", self.line, self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document from a string.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object field access; returns `None` for non-objects/missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element access.
    pub fn at(&self, idx: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(idx),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Typed field lookup helpers that produce descriptive errors; used by
    /// config loading so a bad hardware file reports *which* key is wrong.
    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key).and_then(Json::as_f64).ok_or_else(|| JsonError {
            msg: format!("missing or non-numeric field `{key}`"),
            offset: 0,
            line: 0,
        })
    }

    pub fn req_u64(&self, key: &str) -> Result<u64, JsonError> {
        self.get(key).and_then(Json::as_u64).ok_or_else(|| JsonError {
            msg: format!("missing or non-integer field `{key}`"),
            offset: 0,
            line: 0,
        })
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key).and_then(Json::as_str).ok_or_else(|| JsonError {
            msg: format!("missing or non-string field `{key}`"),
            offset: 0,
            line: 0,
        })
    }

    /// Optional numeric field with a default.
    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize pretty-printed with two-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => out.push_str(&fmt_num(*n)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

/// Build a `Json::Obj` from key/value pairs (ergonomic constructor).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Build a `Json::Num`.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// Build a `Json::Str`.
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// One structural difference between two JSON documents: the dotted path
/// (`results.serving.summary.ttft_p50_s`, array indices as `[3]`) plus
/// the rendered expected/actual values at that path.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonDiff {
    pub path: String,
    pub expected: String,
    pub actual: String,
}

impl fmt::Display for JsonDiff {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: expected {}, actual {}", self.path, self.expected, self.actual)
    }
}

/// Field-by-field comparison of two documents with a float tolerance:
/// numbers are equal when `|a − b| ≤ max(abs_tol, rel_tol · max(|a|,|b|))`
/// (NaN equals NaN, so sentinel values survive a round trip); everything
/// else — including object key sets and array lengths — must match
/// exactly. Returns every difference with its path, empty when the
/// documents agree. Used by the golden-report regression harness.
pub fn diff_with_tolerance(expected: &Json, actual: &Json, rel_tol: f64, abs_tol: f64) -> Vec<JsonDiff> {
    let mut out = Vec::new();
    diff_walk(expected, actual, rel_tol, abs_tol, String::new(), &mut out);
    out
}

/// [`diff_with_tolerance`] with an ignore list: a difference whose path
/// equals an ignore entry, or is a descendant of one (`<entry>.` /
/// `<entry>[` prefix), is dropped. This is how the golden harness
/// excludes inherently nondeterministic report fields (host wall-clock
/// telemetry) while every simulated field stays locked.
pub fn diff_with_tolerance_ignoring(
    expected: &Json,
    actual: &Json,
    rel_tol: f64,
    abs_tol: f64,
    ignore: &[&str],
) -> Vec<JsonDiff> {
    let ignored = |path: &str| {
        ignore.iter().any(|p| {
            path == *p
                || (path.len() > p.len()
                    && path.starts_with(p)
                    && matches!(path.as_bytes()[p.len()], b'.' | b'['))
        })
    };
    diff_with_tolerance(expected, actual, rel_tol, abs_tol)
        .into_iter()
        .filter(|d| !ignored(&d.path))
        .collect()
}

// Keep mismatch reports readable: type + size for containers, the value
// itself for leaves.
fn render_leaf(v: &Json) -> String {
    match v {
        Json::Obj(m) => format!("<object with {} keys>", m.len()),
        Json::Arr(a) => format!("<array of {}>", a.len()),
        other => other.to_string_compact(),
    }
}

fn diff_walk(
    expected: &Json,
    actual: &Json,
    rel_tol: f64,
    abs_tol: f64,
    path: String,
    out: &mut Vec<JsonDiff>,
) {
    let here = |p: &str| if p.is_empty() { "<root>".to_string() } else { p.to_string() };
    match (expected, actual) {
        (Json::Num(a), Json::Num(b)) => {
            let close = (a.is_nan() && b.is_nan())
                || (a - b).abs() <= abs_tol.max(rel_tol * a.abs().max(b.abs()));
            if !close {
                out.push(JsonDiff {
                    path: here(&path),
                    expected: render_leaf(expected),
                    actual: render_leaf(actual),
                });
            }
        }
        (Json::Obj(ea), Json::Obj(aa)) => {
            for (k, ev) in ea {
                let sub = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                match aa.get(k) {
                    Some(av) => diff_walk(ev, av, rel_tol, abs_tol, sub, out),
                    None => out.push(JsonDiff {
                        path: sub,
                        expected: render_leaf(ev),
                        actual: "<missing>".to_string(),
                    }),
                }
            }
            for (k, av) in aa {
                if !ea.contains_key(k) {
                    let sub = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                    out.push(JsonDiff {
                        path: sub,
                        expected: "<missing>".to_string(),
                        actual: render_leaf(av),
                    });
                }
            }
        }
        (Json::Arr(ea), Json::Arr(aa)) => {
            if ea.len() != aa.len() {
                out.push(JsonDiff {
                    path: here(&path),
                    expected: format!("<array of {}>", ea.len()),
                    actual: format!("<array of {}>", aa.len()),
                });
                return;
            }
            for (i, (ev, av)) in ea.iter().zip(aa).enumerate() {
                diff_walk(ev, av, rel_tol, abs_tol, format!("{path}[{i}]"), out);
            }
        }
        _ if expected == actual => {}
        _ => out.push(JsonDiff {
            path: here(&path),
            expected: render_leaf(expected),
            actual: render_leaf(actual),
        }),
    }
}

fn fmt_num(n: f64) -> String {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; emit null per common practice.
        return "null".to_string();
    }
    if n.fract() == 0.0 && n.abs() < 1e15 {
        format!("{}", n as i64)
    } else {
        // Shortest round-trippable representation Rust offers.
        let s = format!("{n}");
        s
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        let line = 1 + self.src[..self.pos.min(self.src.len())]
            .iter()
            .filter(|&&b| b == b'\n')
            .count();
        JsonError { msg: msg.to_string(), offset: self.pos, line }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        loop {
            match self.peek() {
                Some(b' ') | Some(b'\t') | Some(b'\n') | Some(b'\r') => {
                    self.pos += 1;
                }
                // `//` comments (config convenience).
                Some(b'/') if self.src.get(self.pos + 1) == Some(&b'/') => {
                    while let Some(b) = self.peek() {
                        self.pos += 1;
                        if b == b'\n' {
                            break;
                        }
                    }
                }
                _ => break,
            }
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        if self.bump() != Some(b'"') {
            return Err(self.err("expected string"));
        }
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(
                                char::from_u32(c).ok_or_else(|| self.err("bad surrogate pair"))?,
                            );
                        } else {
                            out.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad \\u escape"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.src.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.src[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.bump(); // '{'
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.bump();
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            if self.peek() == Some(b'}') {
                // Trailing comma convenience.
                self.bump();
                return Ok(Json::Obj(map));
            }
            let key = self.string()?;
            self.skip_ws();
            if self.bump() != Some(b':') {
                return Err(self.err("expected ':' in object"));
            }
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.bump(); // '['
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.bump();
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            if self.peek() == Some(b']') {
                self.bump();
                return Ok(Json::Arr(items));
            }
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().at(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("a").unwrap().at(2).unwrap().get("b").unwrap().as_str(), Some("c"));
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn parse_comments_and_trailing_commas() {
        let v = Json::parse(
            "{\n // a comment\n \"x\": 1, // inline\n \"y\": [1, 2,],\n}",
        )
        .unwrap();
        assert_eq!(v.req_u64("x").unwrap(), 1);
        assert_eq!(v.get("y").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn reject_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"core": {"lanes": 4, "name": "sm"}, "list": [1.5, true, null, "x"]}"#;
        let v = Json::parse(src).unwrap();
        let again = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, again);
        let again = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn error_reports_line() {
        let err = Json::parse("{\n\"a\": 1,\n@}").unwrap_err();
        assert_eq!(err.line, 3);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 42, "s": "t", "f": 1.5}"#).unwrap();
        assert_eq!(v.req_u64("n").unwrap(), 42);
        assert_eq!(v.req_str("s").unwrap(), "t");
        assert!(v.req_u64("f").is_err());
        assert!(v.req_str("missing").is_err());
        assert_eq!(v.opt_f64("absent", 7.0), 7.0);
    }

    #[test]
    fn integer_formatting_stays_integral() {
        let v = Json::Num(1024.0);
        assert_eq!(v.to_string_compact(), "1024");
    }

    #[test]
    fn diff_identical_documents_is_empty() {
        let v = Json::parse(r#"{"a": {"b": [1, 2.5, "x"]}, "c": true}"#).unwrap();
        assert!(diff_with_tolerance(&v, &v, 1e-9, 1e-12).is_empty());
    }

    #[test]
    fn diff_tolerates_float_noise_but_not_drift() {
        let a = Json::parse(r#"{"t": 1.0}"#).unwrap();
        let noise = Json::parse(r#"{"t": 1.0000000001}"#).unwrap();
        let drift = Json::parse(r#"{"t": 1.01}"#).unwrap();
        assert!(diff_with_tolerance(&a, &noise, 1e-9, 1e-12).is_empty());
        let d = diff_with_tolerance(&a, &drift, 1e-9, 1e-12);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].path, "t");
        assert!(d[0].to_string().contains("expected 1"), "{}", d[0]);
        // Zero against tiny absolute noise passes through abs_tol.
        let z = Json::parse(r#"{"t": 0.0}"#).unwrap();
        let eps = Json::parse(r#"{"t": 1e-13}"#).unwrap();
        assert!(diff_with_tolerance(&z, &eps, 1e-9, 1e-12).is_empty());
    }

    #[test]
    fn diff_reports_paths_for_structural_mismatches() {
        let a = Json::parse(r#"{"r": {"x": 1, "y": [1, 2]}, "gone": 3}"#).unwrap();
        let b = Json::parse(r#"{"r": {"x": "one", "y": [1, 2, 3]}, "new": 4}"#).unwrap();
        let d = diff_with_tolerance(&a, &b, 1e-9, 1e-12);
        let paths: Vec<&str> = d.iter().map(|x| x.path.as_str()).collect();
        assert!(paths.contains(&"r.x"), "{paths:?}");
        assert!(paths.contains(&"r.y"), "array length mismatch at r.y: {paths:?}");
        assert!(paths.contains(&"gone"), "missing key reported: {paths:?}");
        assert!(paths.contains(&"new"), "extra key reported: {paths:?}");
        let gone = d.iter().find(|x| x.path == "gone").unwrap();
        assert_eq!(gone.actual, "<missing>");
        // Array element paths carry indices.
        let e1 = Json::parse("[1, 2]").unwrap();
        let e2 = Json::parse("[1, 9]").unwrap();
        let d = diff_with_tolerance(&e1, &e2, 1e-9, 1e-12);
        assert_eq!(d[0].path, "[1]");
        // NaN sentinels compare equal to themselves.
        let n = Json::Num(f64::NAN);
        assert!(diff_with_tolerance(&n, &n.clone(), 1e-9, 1e-12).is_empty());
    }

    #[test]
    fn diff_ignore_paths_drop_exact_matches_and_descendants_only() {
        let a = Json::parse(
            r#"{"telemetry": {"host": {"eval_wall_s": 0.5}, "mapper": {"searches": 3}},
                "results": {"x": 1}}"#,
        )
        .unwrap();
        let b = Json::parse(
            r#"{"telemetry": {"host": {"eval_wall_s": 9.9}, "mapper": {"searches": 4}},
                "results": {"x": 2}}"#,
        )
        .unwrap();
        // The host subtree is excluded; everything else still reports.
        let d = diff_with_tolerance_ignoring(&a, &b, 1e-9, 1e-12, &["telemetry.host"]);
        let paths: Vec<&str> = d.iter().map(|x| x.path.as_str()).collect();
        assert_eq!(paths, vec!["results.x", "telemetry.mapper.searches"], "{paths:?}");
        // An ignore entry matches itself (a host subtree of another shape)
        // and array descendants, but never a sibling sharing the prefix.
        let a = Json::parse(r#"{"host": [1], "hostile": 1}"#).unwrap();
        let b = Json::parse(r#"{"host": [2], "hostile": 2}"#).unwrap();
        let d = diff_with_tolerance_ignoring(&a, &b, 1e-9, 1e-12, &["host"]);
        let paths: Vec<&str> = d.iter().map(|x| x.path.as_str()).collect();
        assert_eq!(paths, vec!["hostile"], "{paths:?}");
        // Empty ignore list behaves exactly like diff_with_tolerance.
        assert_eq!(
            diff_with_tolerance_ignoring(&a, &b, 1e-9, 1e-12, &[]),
            diff_with_tolerance(&a, &b, 1e-9, 1e-12)
        );
    }
}
