//! Self-contained substrates: JSON, CLI parsing, table/CSV emission, PRNG,
//! thread pool, a mini property-testing framework, statistics helpers, and
//! the telemetry recorder (Chrome trace-event export).
//!
//! The build environment is fully offline and its vendored registry carries
//! no serde/clap/criterion/proptest/rayon, so LLMCompass implements the
//! pieces it needs from scratch. Each submodule is dependency-free and unit
//! tested in place.

pub mod json;
pub mod cli;
pub mod table;
pub mod prng;
pub mod pool;
pub mod quick;
pub mod stats;
pub mod telemetry;

/// Format a byte count using binary units (KiB/MiB/GiB).
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a duration given in seconds with an adaptive unit (ns/us/ms/s).
pub fn fmt_seconds(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: u64, b: u64) -> u64 {
    ceil_div(a, b) * b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024), "5.00 MiB");
    }

    #[test]
    fn seconds_formatting() {
        assert_eq!(fmt_seconds(2.0), "2.000 s");
        assert_eq!(fmt_seconds(0.5e-3), "500.00 us");
        assert_eq!(fmt_seconds(3e-9), "3.0 ns");
        assert_eq!(fmt_seconds(0.25), "250.000 ms");
    }

    #[test]
    fn ceil_div_and_round_up() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(round_up(10, 4), 12);
        assert_eq!(round_up(8, 4), 8);
    }
}
