//! Statistics helpers: mean/σ, percentiles, geometric mean, and the error
//! metrics used to compare simulated vs measured latencies (paper Fig. 5).

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n−1 denominator); 0 when n < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Geometric mean; requires strictly positive inputs.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    assert!(xs.iter().all(|&x| x > 0.0), "geomean requires positive values");
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Percentile with linear interpolation; `p` in [0, 100]. NaN samples are
/// dropped before ranking (an unserved request's NaN timestamp must not
/// poison the tail of everyone else); an empty or all-NaN slice reports 0.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Relative error |sim − real| / real, as used for the paper's error rates.
pub fn rel_error(sim: f64, real: f64) -> f64 {
    assert!(real != 0.0, "relative error vs zero reference");
    (sim - real).abs() / real.abs()
}

/// Mean relative error across paired samples (the paper's "average error
/// rate" metric — e.g. 10.4% across operators, 4.1% for inference).
pub fn mean_rel_error(sim: &[f64], real: &[f64]) -> f64 {
    assert_eq!(sim.len(), real.len());
    if sim.is_empty() {
        return 0.0;
    }
    let errs: Vec<f64> = sim.iter().zip(real).map(|(&s, &r)| rel_error(s, r)).collect();
    mean(&errs)
}

/// Spearman rank correlation between two paired samples — used to report
/// *trend* agreement between simulated and measured latencies (does the
/// model order the design points correctly?), which survives calibration
/// error that a mean-relative-error metric punishes.
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 1.0;
    }
    let rank = |v: &[f64]| -> Vec<f64> {
        let mut idx: Vec<usize> = (0..v.len()).collect();
        idx.sort_by(|&a, &b| v[a].total_cmp(&v[b]));
        let mut r = vec![0.0; v.len()];
        for (rank_pos, &i) in idx.iter().enumerate() {
            r[i] = rank_pos as f64;
        }
        r
    };
    let rx = rank(xs);
    let ry = rank(ys);
    let mx = mean(&rx);
    let my = mean(&ry);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..n {
        num += (rx[i] - mx) * (ry[i] - my);
        dx += (rx[i] - mx).powi(2);
        dy += (ry[i] - my).powi(2);
    }
    if dx == 0.0 || dy == 0.0 {
        return 0.0;
    }
    num / (dx * dy).sqrt()
}

/// Min/max of a slice (NaN-free inputs assumed).
pub fn minmax(xs: &[f64]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    (lo, hi)
}

/// Online mean/σ accumulator (Welford) for streaming benchmark samples.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_ratios() {
        let xs = [2.0, 8.0];
        assert!((geomean(&xs) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_empty_slice_is_zero() {
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[], p), 0.0);
        }
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn percentile_single_element_is_that_element() {
        for p in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[7.25], p), 7.25);
        }
    }

    #[test]
    fn percentile_endpoints_are_min_and_max_unsorted() {
        // p=0 / p=100 must return the extremes regardless of input order.
        let xs = [9.0, -3.0, 4.0, 0.5, 7.0];
        assert_eq!(percentile(&xs, 0.0), -3.0);
        assert_eq!(percentile(&xs, 100.0), 9.0);
        // Interior percentiles are bounded by the extremes and monotone.
        let mut last = f64::NEG_INFINITY;
        for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 100.0] {
            let v = percentile(&xs, p);
            assert!((-3.0..=9.0).contains(&v));
            assert!(v >= last, "percentile not monotone at p={p}");
            last = v;
        }
    }

    #[test]
    #[should_panic]
    fn percentile_rejects_out_of_range_p() {
        percentile(&[1.0], 101.0);
    }

    #[test]
    fn percentile_drops_nan_samples_instead_of_panicking() {
        // Regression: `partial_cmp().unwrap()` used to panic the moment a
        // NaN (e.g. an unserved request's timestamp) reached the sort.
        let xs = [4.0, f64::NAN, 1.0, 2.0, f64::NAN, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        // All-NaN behaves like empty.
        assert_eq!(percentile(&[f64::NAN, f64::NAN], 99.0), 0.0);
        assert_eq!(median(&[f64::NAN]), 0.0);
    }

    #[test]
    fn error_metrics() {
        assert!((rel_error(11.0, 10.0) - 0.1).abs() < 1e-12);
        let sim = [11.0, 9.0];
        let real = [10.0, 10.0];
        assert!((mean_rel_error(&sim, &real) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.stddev() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn minmax_works() {
        assert_eq!(minmax(&[3.0, -1.0, 2.0]), (-1.0, 3.0));
    }

    #[test]
    fn spearman_detects_monotone_agreement() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((spearman(&xs, &[10.0, 20.0, 30.0, 40.0]) - 1.0).abs() < 1e-12);
        assert!((spearman(&xs, &[40.0, 30.0, 20.0, 10.0]) + 1.0).abs() < 1e-12);
        // Nonlinear but monotone still perfect.
        assert!((spearman(&xs, &[1.0, 8.0, 27.0, 64.0]) - 1.0).abs() < 1e-12);
        assert_eq!(spearman(&[1.0], &[2.0]), 1.0);
    }
}
