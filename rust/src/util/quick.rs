//! A miniature property-based testing framework (proptest is unavailable
//! offline). Provides generators over a seeded [`Rng`], a `forall` runner
//! that reports the failing case, and greedy integer shrinking.
//!
//! Usage:
//! ```no_run
//! use llmcompass::util::quick::{forall, Gen};
//! forall("add commutes", 200, |g| {
//!     let a = g.u64(0, 1000);
//!     let b = g.u64(0, 1000);
//!     ((a, b), a + b == b + a)
//! });
//! ```

use super::prng::Rng;
use std::fmt::Debug;

/// Generator context handed to each property trial.
pub struct Gen {
    rng: Rng,
    /// Log of drawn integers — used for shrinking.
    draws: Vec<u64>,
    /// When replaying a shrunk candidate, values are read from here.
    replay: Option<Vec<u64>>,
    replay_idx: usize,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), draws: Vec::new(), replay: None, replay_idx: 0 }
    }

    fn draw(&mut self, lo: u64, hi: u64) -> u64 {
        let v = if let Some(replay) = &self.replay {
            let raw = replay.get(self.replay_idx).copied().unwrap_or(lo);
            self.replay_idx += 1;
            raw.clamp(lo, hi)
        } else {
            self.rng.range(lo, hi)
        };
        self.draws.push(v);
        v
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.draw(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.draw(lo as u64, hi as u64) as usize
    }

    /// Power of two in `[2^lo_exp, 2^hi_exp]` — tile sizes etc.
    pub fn pow2(&mut self, lo_exp: u32, hi_exp: u32) -> u64 {
        1u64 << self.draw(lo_exp as u64, hi_exp as u64) as u32
    }

    /// f64 in `[lo, hi)` derived from a lattice draw so it shrinks.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let t = self.draw(0, 1_000_000) as f64 / 1_000_000.0;
        lo + t * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.draw(0, 1) == 1
    }

    /// Pick one of the provided items.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        let i = self.draw(0, items.len() as u64 - 1) as usize;
        &items[i]
    }
}

/// Outcome of a `forall` run (exposed for meta-testing).
#[derive(Debug)]
pub enum Outcome<C> {
    Pass { trials: u32 },
    Fail { case: C, shrunk_draws: Vec<u64> },
}

/// Run `trials` random trials of `prop`. The closure returns the case (for
/// reporting) and whether the property held. Panics on failure, printing the
/// (shrunk) counterexample. Seed is fixed per property name for
/// reproducibility; override with `LLMCOMPASS_QC_SEED`.
pub fn forall<C: Debug, F>(name: &str, trials: u32, prop: F)
where
    F: Fn(&mut Gen) -> (C, bool),
{
    match run(name, trials, &prop) {
        Outcome::Pass { .. } => {}
        Outcome::Fail { case, shrunk_draws } => {
            panic!(
                "property `{name}` failed\n counterexample: {case:?}\n raw draws: {shrunk_draws:?}"
            );
        }
    }
}

/// Like [`forall`] but returns the outcome instead of panicking.
pub fn run<C: Debug, F>(name: &str, trials: u32, prop: &F) -> Outcome<C>
where
    F: Fn(&mut Gen) -> (C, bool),
{
    let seed = std::env::var("LLMCOMPASS_QC_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or_else(|| fnv1a(name.as_bytes()));
    for t in 0..trials {
        let mut g = Gen::new(seed.wrapping_add(t as u64));
        let (case, ok) = prop(&mut g);
        if !ok {
            let draws = g.draws.clone();
            let (case, draws) = shrink(prop, case, draws);
            return Outcome::Fail { case, shrunk_draws: draws };
        }
    }
    Outcome::Pass { trials }
}

/// Shrink each recorded draw toward zero with a per-draw binary search:
/// for monotone properties this finds the exact threshold; for others it
/// still yields some smaller failing case. Two passes catch cross-draw
/// interactions cheaply.
fn shrink<C: Debug, F>(prop: &F, mut best_case: C, mut draws: Vec<u64>) -> (C, Vec<u64>)
where
    F: Fn(&mut Gen) -> (C, bool),
{
    let still_fails = |draws: &Vec<u64>| -> Option<C> {
        let mut g = Gen::new(0);
        g.replay = Some(draws.clone());
        let (case, ok) = prop(&mut g);
        (!ok).then_some(case)
    };
    for _pass in 0..2 {
        for i in 0..draws.len() {
            let orig = draws[i];
            if orig == 0 {
                continue;
            }
            // Does zero already fail?
            draws[i] = 0;
            if let Some(case) = still_fails(&draws) {
                best_case = case;
                continue;
            }
            // Binary search the smallest failing value in (lo_pass, hi_fail].
            let mut lo = 0u64; // known passing
            let mut hi = orig; // known failing
            while hi - lo > 1 {
                let mid = lo + (hi - lo) / 2;
                draws[i] = mid;
                match still_fails(&draws) {
                    Some(case) => {
                        best_case = case;
                        hi = mid;
                    }
                    None => lo = mid,
                }
            }
            draws[i] = hi;
        }
    }
    (best_case, draws)
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("sum symmetric", 100, |g| {
            let a = g.u64(0, 1000);
            let b = g.u64(0, 1000);
            ((a, b), a + b == b + a)
        });
    }

    #[test]
    fn failing_property_is_caught_and_shrunk() {
        // Property: x < 500. Fails for x >= 500; shrinking should drive the
        // counterexample to exactly 500.
        let out = run("x below 500", 500, &|g: &mut Gen| {
            let x = g.u64(0, 1000);
            (x, x < 500)
        });
        match out {
            Outcome::Fail { case, .. } => assert_eq!(case, 500),
            Outcome::Pass { .. } => panic!("property should have failed"),
        }
    }

    #[test]
    fn pow2_in_range() {
        forall("pow2 bounds", 100, |g| {
            let v = g.pow2(2, 8);
            (v, v.is_power_of_two() && (4..=256).contains(&v))
        });
    }

    #[test]
    fn f64_bounds() {
        forall("f64 bounds", 100, |g| {
            let v = g.f64(-2.0, 3.0);
            (v, (-2.0..=3.0).contains(&v))
        });
    }

    #[test]
    fn deterministic_per_name() {
        let a = matches!(
            run("det", 10, &|g: &mut Gen| {
                let x = g.u64(0, u64::MAX);
                (x, x % 2 == 0)
            }),
            Outcome::Fail { .. }
        );
        let b = matches!(
            run("det", 10, &|g: &mut Gen| {
                let x = g.u64(0, u64::MAX);
                (x, x % 2 == 0)
            }),
            Outcome::Fail { .. }
        );
        assert_eq!(a, b);
    }
}
