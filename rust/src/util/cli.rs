//! A declarative command-line argument parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! with defaults, and positional arguments, plus auto-generated `--help`.

use std::collections::BTreeMap;
use std::fmt;

/// Specification of one option/flag.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// `None` means the option is a boolean flag.
    pub default: Option<String>,
    pub is_flag: bool,
}

/// A parsed argument set.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// String option value (falls back to declared default).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<u64>()
                .map(Some)
                .map_err(|_| CliError(format!("--{name} expects an integer, got `{v}`"))),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| CliError(format!("--{name} expects a number, got `{v}`"))),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
}

/// A command with a name, description, and option specs.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new() }
    }

    /// Declare a `--key value` option with an optional default.
    pub fn opt(mut self, name: &'static str, default: Option<&str>, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: default.map(|s| s.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--flag`.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    /// Parse raw arguments (excluding program and subcommand names).
    pub fn parse(&self, raw: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        // Seed defaults.
        for spec in &self.opts {
            if let Some(d) = &spec.default {
                args.values.insert(spec.name.to_string(), d.clone());
            }
        }
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                if name == "help" {
                    return Err(CliError(self.help()));
                }
                let spec = self.opts.iter().find(|s| s.name == name).ok_or_else(|| {
                    CliError(format!("unknown option --{name}\n\n{}", self.help()))
                })?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(CliError(format!("flag --{name} takes no value")));
                    }
                    args.flags.insert(name.to_string(), true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError(format!("option --{name} needs a value")))?,
                    };
                    args.values.insert(name.to_string(), val);
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    /// Render the help text for this command.
    pub fn help(&self) -> String {
        let mut out = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for spec in &self.opts {
            let kind = if spec.is_flag {
                String::new()
            } else if let Some(d) = &spec.default {
                format!(" <value> (default: {d})")
            } else {
                " <value>".to_string()
            };
            out.push_str(&format!("  --{}{}\n      {}\n", spec.name, kind, spec.help));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("simulate", "run a simulation")
            .opt("hardware", Some("a100"), "hardware preset")
            .opt("batch", Some("8"), "batch size")
            .opt("out", None, "output path")
            .flag("verbose", "chatty output")
    }

    fn v(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&v(&[])).unwrap();
        assert_eq!(a.get("hardware"), Some("a100"));
        assert_eq!(a.get_u64("batch").unwrap(), Some(8));
        assert_eq!(a.get("out"), None);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = cmd()
            .parse(&v(&["--hardware", "mi210", "--batch=16", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.get("hardware"), Some("mi210"));
        assert_eq!(a.get_u64("batch").unwrap(), Some(16));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1".to_string()]);
    }

    #[test]
    fn errors() {
        assert!(cmd().parse(&v(&["--nope"])).is_err());
        assert!(cmd().parse(&v(&["--batch"])).is_err());
        assert!(cmd().parse(&v(&["--batch", "abc"])).unwrap().get_u64("batch").is_err());
        assert!(cmd().parse(&v(&["--verbose=1"])).is_err());
    }

    #[test]
    fn help_lists_options() {
        let h = cmd().help();
        assert!(h.contains("--hardware"));
        assert!(h.contains("default: a100"));
        let err = cmd().parse(&v(&["--help"])).unwrap_err();
        assert!(err.0.contains("simulate"));
    }
}
