//! Span/counter/event recorder with two clock domains, serialized as
//! Chrome trace-event JSON (loadable in Perfetto or `chrome://tracing`).
//!
//! The framework simulates a cluster, so there are two distinct notions
//! of time worth tracing:
//!
//! * **simulated seconds** — the discrete-event clock of the serving
//!   scheduler and the graph schedules. Deterministic for a seeded
//!   scenario; two runs of the same scenario emit byte-identical
//!   simulated-time traces (asserted by the integration suite).
//! * **host wall-clock** — where the *framework itself* spends time
//!   (mapper parameter searches, per-scenario evaluation). Inherently
//!   nondeterministic; kept in a separate buffer and excluded from the
//!   golden comparisons.
//!
//! Both domains land in one trace file as separate Perfetto *processes*
//! (`pid` 1 = simulated time, `pid` 2 = host wall-clock); every named
//! track becomes a thread (`tid`) inside its process, labeled through
//! `"M"` metadata events. Timestamps are microseconds, the unit the
//! trace-event format mandates.
//!
//! The recorder is a no-op when disabled: every record method begins
//! with a branch on an `Option` and returns before allocating or
//! locking, so instrumented code paths cost one predictable branch per
//! call site. Call sites that must *build* strings or argument lists
//! guard on [`Recorder::is_enabled`] first. Handles are shared as
//! `Arc<Recorder>` and threaded through `Evaluator`, `Simulator`, and
//! the serving scheduler; the CLI only constructs an enabled recorder
//! under `--trace <path>`.

use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Clock domain an event belongs to.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Clock {
    /// Simulated seconds (deterministic; golden-comparable).
    Sim,
    /// Host wall-clock seconds since the recorder was created.
    Host,
}

/// Perfetto process id for the simulated-time clock domain.
const SIM_PID: u64 = 1;
/// Perfetto process id for the host wall-clock domain.
const HOST_PID: u64 = 2;

/// One trace event. `ph` is the Chrome trace-event phase: `X` complete
/// span (with `dur`), `C` counter sample, `i` instant.
struct Event {
    ph: char,
    name: String,
    cat: &'static str,
    ts_us: f64,
    dur_us: f64,
    tid: u64,
    args: Vec<(String, Json)>,
}

impl Event {
    fn to_json(&self, pid: u64) -> Json {
        let mut o: Vec<(&str, Json)> = vec![
            ("name", Json::Str(self.name.clone())),
            ("cat", Json::Str(self.cat.to_string())),
            ("ph", Json::Str(self.ph.to_string())),
            ("ts", Json::Num(self.ts_us)),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(self.tid as f64)),
        ];
        if self.ph == 'X' {
            o.push(("dur", Json::Num(self.dur_us)));
        }
        if self.ph == 'i' {
            // Thread-scoped instant: renders as a marker on its track.
            o.push(("s", Json::Str("t".to_string())));
        }
        if !self.args.is_empty() {
            let mut args = BTreeMap::new();
            for (k, v) in &self.args {
                args.insert(k.clone(), v.clone());
            }
            o.push(("args", Json::Obj(args)));
        }
        json::obj(o)
    }
}

/// Mutable recorder state behind the mutex: per-domain event buffers and
/// the track-name → `tid` interning table.
struct Inner {
    sim: Vec<Event>,
    host: Vec<Event>,
    /// Track name → (clock, tid). tids are assigned per process in
    /// first-use order, starting at 1.
    tracks: BTreeMap<String, (Clock, u64)>,
    next_tid: [u64; 2],
}

impl Inner {
    fn track_id(&mut self, clock: Clock, track: &str) -> u64 {
        if let Some(&(_, tid)) = self.tracks.get(track) {
            return tid;
        }
        let slot = match clock {
            Clock::Sim => 0,
            Clock::Host => 1,
        };
        let tid = self.next_tid[slot];
        self.next_tid[slot] += 1;
        self.tracks.insert(track.to_string(), (clock, tid));
        tid
    }

    fn push(&mut self, clock: Clock, ev: Event) {
        match clock {
            Clock::Sim => self.sim.push(ev),
            Clock::Host => self.host.push(ev),
        }
    }
}

/// The recorder. Construct with [`Recorder::disabled`] (the default —
/// every record call is a no-op) or [`Recorder::enabled`].
pub struct Recorder {
    inner: Option<Mutex<Inner>>,
    /// Host-clock zero; host timestamps are relative to recorder
    /// creation so traces start near t = 0 in both domains.
    epoch: Instant,
}

impl Default for Recorder {
    fn default() -> Self {
        Recorder::disabled()
    }
}

impl Recorder {
    /// A recorder that drops everything. All record methods early-return
    /// without locking or allocating.
    pub fn disabled() -> Recorder {
        Recorder { inner: None, epoch: Instant::now() }
    }

    /// A recorder that buffers events for [`Recorder::write_chrome_trace`].
    pub fn enabled() -> Recorder {
        Recorder {
            inner: Some(Mutex::new(Inner {
                sim: Vec::new(),
                host: Vec::new(),
                tracks: BTreeMap::new(),
                next_tid: [1, 1],
            })),
            epoch: Instant::now(),
        }
    }

    /// Whether events are being buffered. Instrumentation that needs to
    /// build names/args checks this first so the disabled path allocates
    /// nothing.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Host wall-clock seconds since the recorder was created. Returns
    /// 0.0 when disabled so callers can grab timestamps unconditionally.
    #[inline]
    pub fn host_now_s(&self) -> f64 {
        if self.inner.is_none() {
            return 0.0;
        }
        self.epoch.elapsed().as_secs_f64()
    }

    /// Record a complete span (`ph: "X"`) from `start_s` to `end_s` on
    /// the given clock. Spans with negative duration are clamped to 0.
    pub fn span(
        &self,
        clock: Clock,
        track: &str,
        name: &str,
        start_s: f64,
        end_s: f64,
        args: &[(&str, Json)],
    ) {
        let Some(m) = &self.inner else { return };
        let mut inner = m.lock().unwrap();
        let tid = inner.track_id(clock, track);
        inner.push(
            clock,
            Event {
                ph: 'X',
                name: name.to_string(),
                cat: cat_of(clock),
                ts_us: start_s * 1e6,
                dur_us: ((end_s - start_s).max(0.0)) * 1e6,
                tid,
                args: args.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
            },
        );
    }

    /// Record an instant event (`ph: "i"`, thread scope) on a track.
    pub fn instant(&self, clock: Clock, track: &str, name: &str, t_s: f64, args: &[(&str, Json)]) {
        let Some(m) = &self.inner else { return };
        let mut inner = m.lock().unwrap();
        let tid = inner.track_id(clock, track);
        inner.push(
            clock,
            Event {
                ph: 'i',
                name: name.to_string(),
                cat: cat_of(clock),
                ts_us: t_s * 1e6,
                dur_us: 0.0,
                tid,
                args: args.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
            },
        );
    }

    /// Record a counter sample (`ph: "C"`). Perfetto keys counter tracks
    /// by `(pid, name)`, so `name` *is* the track; each sample carries a
    /// single `value` series.
    pub fn counter(&self, clock: Clock, name: &str, t_s: f64, value: f64) {
        let Some(m) = &self.inner else { return };
        let mut inner = m.lock().unwrap();
        inner.push(
            clock,
            Event {
                ph: 'C',
                name: name.to_string(),
                cat: cat_of(clock),
                ts_us: t_s * 1e6,
                dur_us: 0.0,
                tid: 0,
                args: vec![("value".to_string(), Json::Num(value))],
            },
        );
    }

    /// Convenience: a simulated-time span.
    pub fn span_sim(&self, track: &str, name: &str, start_s: f64, end_s: f64, a: &[(&str, Json)]) {
        self.span(Clock::Sim, track, name, start_s, end_s, a);
    }

    /// Convenience: a simulated-time instant.
    pub fn instant_sim(&self, track: &str, name: &str, t_s: f64, args: &[(&str, Json)]) {
        self.instant(Clock::Sim, track, name, t_s, args);
    }

    /// Convenience: a simulated-time counter sample.
    pub fn counter_sim(&self, name: &str, t_s: f64, value: f64) {
        self.counter(Clock::Sim, name, t_s, value);
    }

    /// Convenience: a host wall-clock span ending now. Pair with
    /// [`Recorder::host_now_s`] for the start timestamp.
    pub fn span_host(&self, track: &str, name: &str, start_s: f64, args: &[(&str, Json)]) {
        if self.inner.is_none() {
            return;
        }
        let end = self.host_now_s();
        self.span(Clock::Host, track, name, start_s, end, args);
    }

    /// Convenience: a host wall-clock counter sample stamped now.
    pub fn counter_host(&self, name: &str, value: f64) {
        if self.inner.is_none() {
            return;
        }
        let t = self.host_now_s();
        self.counter(Clock::Host, name, t, value);
    }

    /// Number of buffered events across both clock domains (0 when
    /// disabled). Metadata events are synthesized at serialization time
    /// and not counted.
    pub fn event_count(&self) -> usize {
        match &self.inner {
            None => 0,
            Some(m) => {
                let inner = m.lock().unwrap();
                inner.sim.len() + inner.host.len()
            }
        }
    }

    /// The full trace as Chrome trace-event JSON:
    /// `{"traceEvents": [...], "displayTimeUnit": "ms"}` with metadata
    /// events first, then simulated-time events, then host events.
    pub fn to_json(&self) -> Json {
        self.serialize(true)
    }

    /// Only the deterministic simulated-time portion of the trace (same
    /// envelope, no host process). Two runs of the same seeded scenario
    /// produce byte-identical output from this method.
    pub fn sim_trace_json(&self) -> Json {
        self.serialize(false)
    }

    fn serialize(&self, include_host: bool) -> Json {
        let mut events: Vec<Json> = Vec::new();
        if let Some(m) = &self.inner {
            let inner = m.lock().unwrap();
            // Process metadata.
            let mut meta = |pid: u64, kind: &str, name: &str, tid: Option<u64>| {
                let mut o: Vec<(&str, Json)> = vec![
                    ("name", Json::Str(kind.to_string())),
                    ("ph", Json::Str("M".to_string())),
                    ("pid", Json::Num(pid as f64)),
                ];
                if let Some(tid) = tid {
                    o.push(("tid", Json::Num(tid as f64)));
                }
                let mut args = BTreeMap::new();
                args.insert("name".to_string(), Json::Str(name.to_string()));
                o.push(("args", Json::Obj(args)));
                events.push(json::obj(o));
            };
            meta(SIM_PID, "process_name", "simulated time", None);
            if include_host {
                meta(HOST_PID, "process_name", "host wall-clock", None);
            }
            // Thread (track) metadata, in tid order per process for a
            // stable serialization.
            let mut named: Vec<(&String, &(Clock, u64))> = inner.tracks.iter().collect();
            named.sort_by_key(|(_, (clock, tid))| (pid_of(*clock), *tid));
            for (name, (clock, tid)) in named {
                if *clock == Clock::Host && !include_host {
                    continue;
                }
                meta(pid_of(*clock), "thread_name", name, Some(*tid));
            }
            for ev in &inner.sim {
                events.push(ev.to_json(SIM_PID));
            }
            if include_host {
                for ev in &inner.host {
                    events.push(ev.to_json(HOST_PID));
                }
            }
        }
        json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::Str("ms".to_string())),
        ])
    }

    /// Write the trace to `path` as compact JSON.
    pub fn write_chrome_trace(&self, path: &std::path::Path) -> Result<(), String> {
        let text = self.to_json().to_string_compact();
        std::fs::write(path, text).map_err(|e| format!("write {}: {e}", path.display()))
    }
}

/// A borrowed view of a [`Recorder`] that prefixes every track name —
/// how each fleet replica gets its own set of trace tracks ("replica 0
/// engine", "replica 0 req 7", …) without threading a prefix through
/// every instrumentation call site. An empty prefix is a pure
/// pass-through: identical track names, byte-identical traces.
pub struct ScopedRecorder<'a> {
    rec: &'a Recorder,
    prefix: String,
}

impl<'a> ScopedRecorder<'a> {
    pub fn new(rec: &'a Recorder, prefix: &str) -> ScopedRecorder<'a> {
        ScopedRecorder { rec, prefix: prefix.to_string() }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.rec.is_enabled()
    }

    pub fn span_sim(&self, track: &str, name: &str, start_s: f64, end_s: f64, a: &[(&str, Json)]) {
        if !self.rec.is_enabled() {
            return;
        }
        if self.prefix.is_empty() {
            self.rec.span_sim(track, name, start_s, end_s, a);
        } else {
            self.rec.span_sim(&format!("{}{track}", self.prefix), name, start_s, end_s, a);
        }
    }

    pub fn instant_sim(&self, track: &str, name: &str, t_s: f64, args: &[(&str, Json)]) {
        if !self.rec.is_enabled() {
            return;
        }
        if self.prefix.is_empty() {
            self.rec.instant_sim(track, name, t_s, args);
        } else {
            self.rec.instant_sim(&format!("{}{track}", self.prefix), name, t_s, args);
        }
    }

    pub fn counter_sim(&self, name: &str, t_s: f64, value: f64) {
        if !self.rec.is_enabled() {
            return;
        }
        if self.prefix.is_empty() {
            self.rec.counter_sim(name, t_s, value);
        } else {
            self.rec.counter_sim(&format!("{}{name}", self.prefix), t_s, value);
        }
    }
}

fn cat_of(clock: Clock) -> &'static str {
    match clock {
        Clock::Sim => "sim",
        Clock::Host => "host",
    }
}

fn pid_of(clock: Clock) -> u64 {
    match clock {
        Clock::Sim => SIM_PID,
        Clock::Host => HOST_PID,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace_events(j: &Json) -> Vec<Json> {
        match j.get("traceEvents") {
            Some(Json::Arr(a)) => a.clone(),
            _ => panic!("trace lacks traceEvents array"),
        }
    }

    #[test]
    fn events_have_valid_shape() {
        let rec = Recorder::enabled();
        rec.span_sim("pool", "prefill", 0.001, 0.004, &[("batch", Json::Num(4.0))]);
        rec.instant_sim("pool", "preempt", 0.002, &[]);
        rec.counter_sim("kv_tokens", 0.003, 1234.0);
        let t0 = rec.host_now_s();
        rec.span_host("mapper", "search", t0, &[]);
        let j = rec.to_json();
        let events = trace_events(&j);
        assert!(events.len() >= 4 + 3, "expected events + metadata, got {}", events.len());
        for ev in &events {
            let ph = ev.get("ph").and_then(Json::as_str).expect("ph present");
            assert!(
                ["X", "C", "i", "M"].contains(&ph),
                "unexpected phase {ph:?}"
            );
            assert!(ev.get("name").is_some(), "event lacks name");
            if ph != "M" {
                let ts = ev.get("ts").and_then(Json::as_f64).expect("ts present");
                assert!(ts >= 0.0 && ts.is_finite(), "ts out of range: {ts}");
            }
            if ph == "X" {
                let dur = ev.get("dur").and_then(Json::as_f64).expect("X span has dur");
                assert!(dur >= 0.0 && dur.is_finite(), "negative span duration: {dur}");
            }
            if ph == "i" {
                assert_eq!(ev.get("s").and_then(Json::as_str), Some("t"));
            }
        }
        // The serialized form parses back.
        let round = Json::parse(&j.to_string_compact()).expect("trace JSON parses");
        assert_eq!(trace_events(&round).len(), events.len());
    }

    #[test]
    fn spans_are_monotone_and_clamped() {
        let rec = Recorder::enabled();
        rec.span_sim("t", "ok", 1.0, 3.0, &[]);
        rec.span_sim("t", "inverted", 5.0, 4.0, &[]); // clamped to dur 0
        for ev in trace_events(&rec.to_json()) {
            if ev.get("ph").and_then(Json::as_str) == Some("X") {
                assert!(ev.get("dur").and_then(Json::as_f64).unwrap() >= 0.0);
            }
        }
    }

    #[test]
    fn clock_domains_are_separate_processes() {
        let rec = Recorder::enabled();
        rec.span_sim("sched", "iter", 0.0, 1.0, &[]);
        let t0 = rec.host_now_s();
        rec.span_host("mapper", "search", t0, &[]);
        let pids: Vec<f64> = trace_events(&rec.to_json())
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .map(|e| e.get("pid").and_then(Json::as_f64).unwrap())
            .collect();
        assert_eq!(pids, vec![SIM_PID as f64, HOST_PID as f64]);
        // Sim-only serialization excludes the host process entirely.
        let sim_only = rec.sim_trace_json();
        assert!(trace_events(&sim_only)
            .iter()
            .all(|e| e.get("pid").and_then(Json::as_f64) == Some(SIM_PID as f64)));
    }

    #[test]
    fn tracks_are_interned_with_metadata() {
        let rec = Recorder::enabled();
        rec.span_sim("pool a", "x", 0.0, 1.0, &[]);
        rec.span_sim("pool b", "y", 0.0, 1.0, &[]);
        rec.span_sim("pool a", "z", 1.0, 2.0, &[]);
        let events = trace_events(&rec.to_json());
        let names: Vec<&str> = events
            .iter()
            .filter(|e| {
                e.get("ph").and_then(Json::as_str) == Some("M")
                    && e.get("name").and_then(Json::as_str) == Some("thread_name")
            })
            .map(|e| e.get("args").and_then(|a| a.get("name")).and_then(Json::as_str).unwrap())
            .collect();
        assert_eq!(names, vec!["pool a", "pool b"]);
        // Both "pool a" spans share a tid; "pool b" differs.
        let tids: Vec<f64> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .map(|e| e.get("tid").and_then(Json::as_f64).unwrap())
            .collect();
        assert_eq!(tids[0], tids[2]);
        assert_ne!(tids[0], tids[1]);
    }

    #[test]
    fn disabled_recorder_is_noop() {
        let rec = Recorder::disabled();
        assert!(!rec.is_enabled());
        rec.span_sim("t", "a", 0.0, 1.0, &[]);
        rec.instant_sim("t", "b", 0.5, &[]);
        rec.counter_sim("c", 0.5, 1.0);
        rec.span_host("t", "d", 0.0, &[]);
        assert_eq!(rec.event_count(), 0);
        assert_eq!(trace_events(&rec.to_json()).len(), 0);
        assert_eq!(rec.host_now_s(), 0.0);
    }

    /// The disabled recorder must add no measurable overhead: a million
    /// record calls are early-returned branches, so even a very slow CI
    /// box finishes far inside the (generous) bound.
    #[test]
    fn disabled_recorder_has_no_measurable_overhead() {
        let rec = Recorder::disabled();
        let start = Instant::now();
        for i in 0..1_000_000u64 {
            rec.span_sim("track", "span", i as f64, i as f64 + 1.0, &[]);
            rec.counter_sim("counter", i as f64, i as f64);
        }
        let elapsed = start.elapsed();
        assert_eq!(rec.event_count(), 0);
        assert!(
            elapsed.as_millis() < 500,
            "2M no-op record calls took {elapsed:?}; the disabled path must not lock or allocate"
        );
    }

    #[test]
    fn scoped_recorder_prefixes_tracks_and_passes_through_when_empty() {
        // Empty prefix: byte-identical to recording on the Recorder itself.
        let direct = {
            let rec = Recorder::enabled();
            rec.span_sim("engine", "prefill", 0.0, 1.0, &[]);
            rec.counter_sim("kv_tokens", 0.5, 64.0);
            rec.sim_trace_json().to_string_compact()
        };
        let scoped_empty = {
            let rec = Recorder::enabled();
            let sc = ScopedRecorder::new(&rec, "");
            sc.span_sim("engine", "prefill", 0.0, 1.0, &[]);
            sc.counter_sim("kv_tokens", 0.5, 64.0);
            rec.sim_trace_json().to_string_compact()
        };
        assert_eq!(direct, scoped_empty);
        // Non-empty prefix lands on prefixed tracks.
        let rec = Recorder::enabled();
        let sc = ScopedRecorder::new(&rec, "replica 2 ");
        assert!(sc.is_enabled());
        sc.span_sim("engine", "decode", 0.0, 1.0, &[]);
        sc.instant_sim("req 1", "done", 1.0, &[]);
        let text = rec.sim_trace_json().to_string_compact();
        assert!(text.contains("replica 2 engine"), "missing prefixed track: {text}");
        assert!(text.contains("replica 2 req 1"), "missing prefixed track: {text}");
        // Disabled recorder: still a no-op through the scope.
        let off = Recorder::disabled();
        let sc = ScopedRecorder::new(&off, "replica 0 ");
        assert!(!sc.is_enabled());
        sc.span_sim("engine", "decode", 0.0, 1.0, &[]);
        assert_eq!(off.event_count(), 0);
    }

    #[test]
    fn identical_recordings_serialize_identically() {
        let run = || {
            let rec = Recorder::enabled();
            rec.span_sim("pool", "prefill", 0.25, 0.5, &[("batch", Json::Num(3.0))]);
            rec.counter_sim("kv_tokens", 0.5, 768.0);
            rec.instant_sim("req 1", "preempt", 0.75, &[("kv", Json::Num(128.0))]);
            // Host events must not leak into the sim trace.
            let t0 = rec.host_now_s();
            rec.span_host("mapper", "search", t0, &[]);
            rec.sim_trace_json().to_string_compact()
        };
        assert_eq!(run(), run());
    }
}
