//! Deterministic pseudo-random number generation (no `rand` crate offline).
//!
//! `SplitMix64` for seeding and `Xoshiro256**` as the workhorse generator —
//! both are public-domain algorithms with well-studied statistical quality.
//! Used by the property-testing framework, workload generators, and the
//! mapper's randomized search order.

/// SplitMix64: used to expand a single `u64` seed into a full state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — fast, 256-bit state, period 2^256 − 1.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Rng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method to
    /// avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        if lo == 0 && hi == u64::MAX {
            return self.next_u64(); // full range: `hi-lo+1` would overflow
        }
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }

    /// Sample from a (truncated) geometric-ish distribution favouring small
    /// values; handy for generating realistic request lengths.
    pub fn skewed(&mut self, max: u64) -> u64 {
        let u = self.f64();
        let v = (u * u * max as f64) as u64;
        v.min(max.saturating_sub(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn below_respects_bound_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        for _ in 0..100 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
        }
        assert_eq!(r.range(7, 7), 7);
    }
}
