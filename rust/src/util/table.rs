//! ASCII table, CSV, and heatmap rendering for experiment reports.
//!
//! Every experiment regenerator prints the paper's rows/series through this
//! module and can also dump CSV for plotting.

use std::fmt::Write as _;

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, title: &str) -> Self {
        self.title = Some(title.to_string());
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            let _ = writeln!(out, "== {t} ==");
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                let cell = cells.get(i).map(|s| s.as_str()).unwrap_or("");
                let _ = write!(line, " {:<w$} ", cell, w = widths[i]);
                if i + 1 < ncol {
                    line.push('|');
                }
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header));
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row));
        }
        out
    }

    /// Render as CSV (RFC-4180-ish quoting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }
}

/// Render a 2-D grid of values as a text heatmap (for the paper's Fig. 10 /
/// Fig. 12 style input-length × output-length matrices).
pub struct Heatmap<'a> {
    pub title: &'a str,
    /// Row labels, outer → printed top to bottom.
    pub row_labels: Vec<String>,
    pub col_labels: Vec<String>,
    /// `values[r][c]`.
    pub values: Vec<Vec<f64>>,
    /// printf-style precision for cells.
    pub precision: usize,
}

impl<'a> Heatmap<'a> {
    pub fn render(&self) -> String {
        assert_eq!(self.values.len(), self.row_labels.len());
        let mut out = format!("== {} ==\n", self.title);
        let cellw = self
            .values
            .iter()
            .flatten()
            .map(|v| format!("{:.p$}", v, p = self.precision).len())
            .chain(self.col_labels.iter().map(|l| l.len()))
            .max()
            .unwrap_or(6)
            + 1;
        let roww = self.row_labels.iter().map(|l| l.len()).max().unwrap_or(4) + 1;
        let _ = write!(out, "{:>roww$} ", "");
        for c in &self.col_labels {
            let _ = write!(out, "{c:>cellw$}");
        }
        out.push('\n');
        for (r, label) in self.row_labels.iter().enumerate() {
            let _ = write!(out, "{label:>roww$} ");
            for v in &self.values[r] {
                let _ = write!(out, "{:>cellw$.p$}", v, p = self.precision);
            }
            out.push('\n');
        }
        out
    }

    /// CSV form with row/col labels.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, ",{}", self.col_labels.join(","));
        for (r, label) in self.row_labels.iter().enumerate() {
            let cells: Vec<String> =
                self.values[r].iter().map(|v| format!("{:.p$}", v, p = self.precision)).collect();
            let _ = writeln!(out, "{},{}", label, cells.join(","));
        }
        out
    }
}

/// Write a report file under `reports/`, creating the directory if needed.
pub fn write_report(name: &str, content: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("reports");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, content)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["op", "latency"]).with_title("demo");
        t.row(vec!["matmul".into(), "1.25 ms".into()]);
        t.row(vec!["softmax".into(), "80 us".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("matmul"));
        // Header separator present and aligned columns share the pipe offset.
        let lines: Vec<&str> = s.lines().collect();
        let pipe_pos: Vec<usize> =
            lines.iter().filter_map(|l| l.find('|')).collect();
        assert!(pipe_pos.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new(&["name", "note"]);
        t.row(vec!["a,b".into(), "say \"hi\"".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    fn heatmap_renders() {
        let h = Heatmap {
            title: "norm perf",
            row_labels: vec!["2048".into(), "1024".into()],
            col_labels: vec!["256".into(), "512".into()],
            values: vec![vec![0.8, 0.88], vec![0.87, 0.92]],
            precision: 2,
        };
        let s = h.render();
        assert!(s.contains("0.88"));
        let csv = h.to_csv();
        assert!(csv.starts_with(",256,512"));
        assert!(csv.contains("1024,0.87,0.92"));
    }
}
