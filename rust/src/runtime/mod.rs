//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only place the `xla` crate is touched. The Rust binary is
//! self-contained after `make artifacts`: Python never runs on the request
//! path. Pattern follows /opt/xla-example/load_hlo.
//!
//! ```text
//! PjRtClient::cpu()
//!   → HloModuleProto::from_text_file(artifacts/<name>.hlo.txt)
//!   → XlaComputation::from_proto → client.compile → executable.execute
//! ```
//!
//! All artifacts are lowered with `return_tuple=True`, so results come back
//! as one tuple literal that [`Runtime::run`] flattens.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Argument metadata from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ArgMeta {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ArgMeta {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One artifact entry from `manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub args: Vec<ArgMeta>,
}

/// The serving model's hyperparameters, recorded by the AOT step.
#[derive(Debug, Clone, Default)]
pub struct ModelMeta {
    pub layers: u64,
    pub d_model: u64,
    pub heads: u64,
    pub d_ff: u64,
    pub vocab: u64,
    pub max_seq: u64,
    pub n_params: u64,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelMeta,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let m = v.get("model").ok_or_else(|| anyhow!("manifest missing `model`"))?;
        let g = |key: &str| m.req_u64(key).map_err(|e| anyhow!("manifest model: {e}"));
        let model = ModelMeta {
            layers: g("layers")?,
            d_model: g("d_model")?,
            heads: g("heads")?,
            d_ff: g("d_ff")?,
            vocab: g("vocab")?,
            max_seq: g("max_seq")?,
            n_params: g("n_params")?,
        };
        let arts = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing `artifacts`"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let args = a
                .get("args")
                .and_then(Json::as_arr)
                .map(|list| {
                    list.iter()
                        .map(|arg| ArgMeta {
                            shape: arg
                                .get("shape")
                                .and_then(Json::as_arr)
                                .map(|s| {
                                    s.iter()
                                        .filter_map(|d| d.as_u64())
                                        .map(|d| d as usize)
                                        .collect()
                                })
                                .unwrap_or_default(),
                            dtype: arg
                                .get("dtype")
                                .and_then(Json::as_str)
                                .unwrap_or("float32")
                                .to_string(),
                        })
                        .collect()
                })
                .unwrap_or_default();
            artifacts.push(ArtifactMeta {
                name: a.req_str("name").map_err(|e| anyhow!("{e}"))?.to_string(),
                file: a.req_str("file").map_err(|e| anyhow!("{e}"))?.to_string(),
                args,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), model, artifacts })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// A compiled executable plus its metadata.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// Host-side tensor in the runtime's exchange format.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32(vec![v], vec![])
    }

    pub fn f32(&self) -> Option<&[f32]> {
        match self {
            HostTensor::F32(v, _) => Some(v),
            _ => None,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        Ok(match self {
            HostTensor::F32(v, _) => xla::Literal::vec1(v).reshape(&dims)?,
            HostTensor::I32(v, _) => xla::Literal::vec1(v).reshape(&dims)?,
        })
    }
}

/// The PJRT runtime: one CPU client + a compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, Executable>,
}

impl Runtime {
    /// Create a CPU runtime over an artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by name (cached).
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let meta = self
                .manifest
                .find(name)
                .ok_or_else(|| anyhow!("artifact `{name}` not in manifest"))?
                .clone();
            let path = self.manifest.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(name.to_string(), Executable { meta, exe });
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact with host tensors; returns the flattened tuple
    /// elements as host tensors (all our artifacts return f32 arrays).
    pub fn run(&mut self, name: &str, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let exe = self.load(name)?;
        if args.len() != exe.meta.args.len() {
            bail!("artifact `{name}` expects {} args, got {}", exe.meta.args.len(), args.len());
        }
        let literals: Vec<xla::Literal> =
            args.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = exe.exe.execute::<xla::Literal>(&literals)?;
        let mut out = result[0][0].to_literal_sync()?;
        let tuple = out.decompose_tuple()?;
        let mut host = Vec::with_capacity(tuple.len());
        for lit in tuple {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let v = lit.to_vec::<f32>()?;
            host.push(HostTensor::F32(v, dims));
        }
        Ok(host)
    }

    /// Execute and time an artifact: returns (result, mean seconds/iter).
    /// `warmup` iterations exclude compile + first-touch cost.
    pub fn run_timed(
        &mut self,
        name: &str,
        args: &[HostTensor],
        warmup: usize,
        iters: usize,
    ) -> Result<(Vec<HostTensor>, f64)> {
        for _ in 0..warmup {
            self.run(name, args)?;
        }
        let start = Instant::now();
        let mut out = Vec::new();
        for _ in 0..iters.max(1) {
            out = self.run(name, args)?;
        }
        let secs = start.elapsed().as_secs_f64() / iters.max(1) as f64;
        Ok((out, secs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full runtime round-trips live in rust/tests/ (they need built
    // artifacts); here we test manifest parsing and host-tensor plumbing.

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join("llmcompass-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "model": {"layers": 6, "d_model": 384, "heads": 6, "d_ff": 1536,
                         "vocab": 8192, "max_seq": 128, "n_params": 17000000},
              "artifacts": [
                {"name": "init", "file": "init.hlo.txt", "args": []},
                {"name": "matmul_16x768x768", "file": "m.hlo.txt",
                 "args": [{"shape": [16, 768], "dtype": "float32"},
                           {"shape": [768, 768], "dtype": "float32"}]}
              ]
            }"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.d_model, 384);
        assert_eq!(m.artifacts.len(), 2);
        let mm = m.find("matmul_16x768x768").unwrap();
        assert_eq!(mm.args[0].shape, vec![16, 768]);
        assert_eq!(mm.args[0].elements(), 16 * 768);
        assert!(m.find("nope").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_missing_dir_errors() {
        let err = Manifest::load(Path::new("/nonexistent-llmcompass")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn host_tensor_shapes() {
        let t = HostTensor::F32(vec![0.0; 6], vec![2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.f32().unwrap().len(), 6);
        let s = HostTensor::scalar_i32(7);
        assert_eq!(s.shape(), &[] as &[usize]);
        assert!(s.f32().is_none());
    }
}
