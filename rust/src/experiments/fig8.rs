//! Fig. 8 — impact of main-memory bandwidth: sweep 400 → 3200 GB/s on an
//! otherwise-A100 device, with the per-operator latency breakdown the
//! paper stacks in its bars.
//!
//! Paper findings: prefill gains 14.3% from 800→2000 GB/s then saturates
//! (+3.5% to 3200); decode speeds up 1.88x over the same range and keeps
//! gaining (implication ③: decode is much more BW-sensitive).

use super::Ctx;
use crate::graph::layer::Phase;
use crate::graph::ModelConfig;
use crate::hardware::{presets, InterconnectSpec, SystemSpec};
use crate::util::table::{write_report, Table};
use anyhow::Result;
use std::fmt::Write as _;

pub fn bandwidths(quick: bool) -> Vec<f64> {
    if quick {
        vec![400e9, 1200e9, 2400e9, 3200e9]
    } else {
        vec![400e9, 800e9, 1200e9, 1600e9, 2000e9, 2400e9, 2800e9, 3200e9]
    }
}

pub fn run(ctx: &Ctx) -> Result<String> {
    let model = ModelConfig::gpt3_175b();
    let (batch, seq) = (8, 2048);
    let kv = seq + 1024;

    let mut pre_t = Table::new(&["BW GB/s", "prefill ms", "matmul ms", "vecop ms", "comm ms"])
        .with_title("Fig. 8a — prefill latency per GPT-3 layer vs memory bandwidth");
    let mut dec_t = Table::new(&["BW GB/s", "decode ms", "matmul ms", "vecop ms", "comm ms"])
        .with_title("Fig. 8b — decode latency per GPT-3 layer per token vs memory bandwidth");
    let mut csv = String::from("bw_gbs,phase,op,seconds\n");
    let mut series: Vec<(f64, f64, f64)> = Vec::new();

    for bw in bandwidths(ctx.quick) {
        let mut dev = presets::a100();
        dev.name = format!("a100-bw{}", (bw / 1e9) as u64);
        dev.memory.bandwidth_bytes_per_s = bw;
        let sys = SystemSpec {
            device: dev,
            device_count: 4,
            interconnect: InterconnectSpec::nvlink_like(600e9),
        };
        let pre = ctx.sim().layer(&sys, &model, Phase::Prefill { batch, seq });
        let dec = ctx.sim().layer(&sys, &model, Phase::Decode { batch, kv_len: kv });
        let split = |rep: &crate::graph::inference::LayerReport| {
            let mm: f64 = rep
                .breakdown
                .iter()
                .filter(|(n, _)| n.contains("proj") || n.contains("_K_V") || n.contains("mul"))
                .map(|(_, s)| s)
                .sum();
            let comm: f64 = rep
                .breakdown
                .iter()
                .filter(|(n, _)| n.starts_with("AllReduce"))
                .map(|(_, s)| s)
                .sum();
            let vec = rep.total_s - mm - comm;
            (mm, vec, comm)
        };
        let (pm, pv, pc) = split(&pre);
        let (dm, dv, dc) = split(&dec);
        pre_t.row(vec![
            format!("{:.0}", bw / 1e9),
            format!("{:.2}", pre.total_s * 1e3),
            format!("{:.2}", pm * 1e3),
            format!("{:.2}", pv * 1e3),
            format!("{:.2}", pc * 1e3),
        ]);
        dec_t.row(vec![
            format!("{:.0}", bw / 1e9),
            format!("{:.3}", dec.total_s * 1e3),
            format!("{:.3}", dm * 1e3),
            format!("{:.3}", dv * 1e3),
            format!("{:.3}", dc * 1e3),
        ]);
        for (name, s) in &pre.breakdown {
            let _ = writeln!(csv, "{},prefill,{name},{s}", bw / 1e9);
        }
        for (name, s) in &dec.breakdown {
            let _ = writeln!(csv, "{},decode,{name},{s}", bw / 1e9);
        }
        series.push((bw, pre.total_s, dec.total_s));
    }

    let mut out = pre_t.render();
    let _ = writeln!(out, "\n{}", dec_t.render());
    // Implication ③ check against the paper's anchor points (skip in quick
    // mode where 800/2000 are not sampled).
    let find = |bw: f64| series.iter().find(|(b, _, _)| (*b - bw).abs() < 1.0);
    if let (Some(lo), Some(hi)) = (find(800e9), find(2000e9)) {
        let _ = writeln!(
            out,
            "800→2000 GB/s: prefill -{:.1}% (paper 14.3%), decode speedup {:.2}x (paper 1.88x)",
            (1.0 - hi.1 / lo.1) * 100.0,
            lo.2 / hi.2
        );
    }
    write_report("fig8.csv", &csv)?;
    Ok(out)
}
