//! Regenerators for every table and figure in the paper's evaluation
//! (DESIGN.md §4 maps each experiment id to its modules).
//!
//! Each experiment prints the paper's rows/series as ASCII tables and
//! writes CSV under `reports/`. `quick` mode trims sweep points so the
//! integration tests can exercise every experiment in seconds.

pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod serve_sweep;
pub mod tab4;
pub mod variants;

use crate::eval::Evaluator;
use crate::graph::inference::Simulator;
use anyhow::Result;

/// Shared context for experiment runs.
pub struct Ctx {
    /// The unified evaluator; its simulator's mapper caches persist
    /// across every experiment run through this context.
    pub eval: Evaluator,
    /// Trim sweeps for fast smoke runs.
    pub quick: bool,
    /// Where AOT artifacts live (fig5 measured side).
    pub artifact_dir: std::path::PathBuf,
}

impl Ctx {
    /// The evaluator's mapper runs in work-stealing hybrid mode: sweep
    /// cells fan out over `util::pool::parallel_map_shared`, and whatever
    /// workers the sweep leaves idle are picked up by the mapper's own
    /// candidate loops — both levels of parallelism, no multiplication.
    pub fn new(quick: bool) -> Ctx {
        Ctx { eval: Evaluator::hybrid(), quick, artifact_dir: default_artifact_dir() }
    }

    /// The shared analytical simulator (shorthand for `self.eval.sim`).
    pub fn sim(&self) -> &Simulator {
        &self.eval.sim
    }
}

/// Default artifact directory: the `LLMCOMPASS_ARTIFACT_DIR` environment
/// variable when set and non-empty, else `./artifacts`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    artifact_dir_from(std::env::var("LLMCOMPASS_ARTIFACT_DIR").ok())
}

/// Pure core of [`default_artifact_dir`], unit-testable without touching
/// process environment (concurrent `set_var`/`getenv` is a data race).
fn artifact_dir_from(env_value: Option<String>) -> std::path::PathBuf {
    match env_value {
        Some(v) if !v.is_empty() => std::path::PathBuf::from(v),
        _ => std::path::PathBuf::from("artifacts"),
    }
}

/// Experiment registry: (id, description, runner).
pub fn registry() -> Vec<(&'static str, &'static str, fn(&Ctx) -> Result<String>)> {
    vec![
        (
            "fig5",
            "Performance-model validation: simulated vs measured operator latency",
            fig5::run,
        ),
        ("fig6", "Area-model validation: GA100/Aldebaran die + core breakdowns", fig6::run),
        ("fig7", "Compute-system designs A-E: prefill/decode latency (Table III)", fig7::run),
        ("fig8", "Memory-bandwidth sweep 400-3200 GB/s with operator breakdown", fig8::run),
        ("fig9", "Local/global buffer size sweeps", fig9::run),
        ("fig10", "Latency-oriented design: end-to-end perf heatmap vs GA100", fig10::run),
        ("fig11", "Decoding latency comparison: A100 / GA100 / latency design", fig11::run),
        ("fig12", "Throughput-oriented design: tokens/s heatmap, PP=8", fig12::run),
        ("tab4", "Table IV: designs, die area, cost, performance/cost", tab4::run),
        (
            "serve",
            "SLO-aware serving cost sweep: goodput and $/1M-tokens across presets",
            serve_sweep::run,
        ),
        (
            "variants",
            "Ablation: MQA/GQA, parallel blocks, MoE (paper §II-A variant support)",
            variants::run,
        ),
    ]
}

/// Run one experiment by id.
pub fn run(id: &str, ctx: &Ctx) -> Result<String> {
    for (name, _, f) in registry() {
        if name == id {
            return f(ctx);
        }
    }
    anyhow::bail!(
        "unknown experiment `{id}`; available: {}",
        registry().iter().map(|(n, _, _)| *n).collect::<Vec<_>>().join(", ")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all_eval_artifacts() {
        let ids: Vec<&str> = registry().iter().map(|(n, _, _)| *n).collect();
        for id in ["fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "tab4"] {
            assert!(ids.contains(&id), "{id} missing");
        }
    }

    #[test]
    fn unknown_experiment_errors() {
        let ctx = Ctx::new(true);
        assert!(run("nope", &ctx).is_err());
    }

    #[test]
    fn artifact_dir_env_override() {
        let p = std::path::PathBuf::from;
        assert_eq!(artifact_dir_from(Some("/tmp/llmcompass-art".into())), p("/tmp/llmcompass-art"));
        assert_eq!(artifact_dir_from(Some(String::new())), p("artifacts"));
        assert_eq!(artifact_dir_from(None), p("artifacts"));
        assert_eq!(Ctx::new(true).artifact_dir, default_artifact_dir());
    }
}
