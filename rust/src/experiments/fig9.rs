//! Fig. 9 — impact of local-buffer size (and the global-buffer sweep the
//! paper describes in §IV-D text): A100-spec device, sweeping one buffer
//! at a time.
//!
//! Paper findings: local 64→192 KB improves prefill 18.0% (+5.8% area);
//! 192 KB→1 MB gains only 0.2% (+28.8% area); decode flat (implications
//! ④/⑤: buffers help prefill until the systolic arrays saturate).

use super::Ctx;
use crate::area::die_mm2;
use crate::graph::layer::Phase;
use crate::graph::ModelConfig;
use crate::hardware::{presets, InterconnectSpec, SystemSpec};
use crate::util::table::{write_report, Table};
use anyhow::Result;
use std::fmt::Write as _;

pub fn run(ctx: &Ctx) -> Result<String> {
    let model = ModelConfig::gpt3_175b();
    let (batch, seq) = (8, 2048);
    let kv = seq + 1024;

    let locals_kb: Vec<u64> =
        if ctx.quick { vec![64, 192, 1024] } else { vec![64, 128, 192, 256, 512, 1024] };
    let globals_mb: Vec<u64> = if ctx.quick { vec![10, 40, 80] } else { vec![10, 20, 40, 80] };

    let mut lt = Table::new(&["local KB", "prefill ms", "decode ms", "die mm²"])
        .with_title("Fig. 9 — local buffer size sweep (A100 spec, TP=4)");
    let mut csv = String::from("kind,size,prefill_s,decode_s,die_mm2\n");
    let mut local_rows = Vec::new();
    for &kb in &locals_kb {
        let mut dev = presets::a100();
        dev.name = format!("a100-l1-{kb}k");
        dev.core.local_buffer_bytes = kb * 1024;
        let area = die_mm2(&dev);
        let sys = SystemSpec {
            device: dev,
            device_count: 4,
            interconnect: InterconnectSpec::nvlink_like(600e9),
        };
        let pre = ctx.sim().layer(&sys, &model, Phase::Prefill { batch, seq }).total_s;
        let dec = ctx.sim().layer(&sys, &model, Phase::Decode { batch, kv_len: kv }).total_s;
        lt.row(vec![
            kb.to_string(),
            format!("{:.2}", pre * 1e3),
            format!("{:.3}", dec * 1e3),
            format!("{:.0}", area),
        ]);
        let _ = writeln!(csv, "local,{kb},{pre},{dec},{area}");
        local_rows.push((kb, pre, dec, area));
    }

    let mut gt = Table::new(&["global MB", "prefill ms", "decode ms", "die mm²"])
        .with_title("§IV-D — global buffer size sweep (A100 spec, TP=4)");
    for &mb in &globals_mb {
        let mut dev = presets::a100();
        dev.name = format!("a100-l2-{mb}m");
        dev.global_buffer_bytes = mb * 1024 * 1024;
        let area = die_mm2(&dev);
        let sys = SystemSpec {
            device: dev,
            device_count: 4,
            interconnect: InterconnectSpec::nvlink_like(600e9),
        };
        let pre = ctx.sim().layer(&sys, &model, Phase::Prefill { batch, seq }).total_s;
        let dec = ctx.sim().layer(&sys, &model, Phase::Decode { batch, kv_len: kv }).total_s;
        gt.row(vec![
            mb.to_string(),
            format!("{:.2}", pre * 1e3),
            format!("{:.3}", dec * 1e3),
            format!("{:.0}", area),
        ]);
        let _ = writeln!(csv, "global,{mb},{pre},{dec},{area}");
    }

    let mut out = lt.render();
    let _ = writeln!(out, "\n{}", gt.render());
    if let (Some(small), Some(base)) = (
        local_rows.iter().find(|r| r.0 == 64),
        local_rows.iter().find(|r| r.0 == 192),
    ) {
        let _ = writeln!(
            out,
            "local 64→192 KB: prefill -{:.1}% (paper 18.0%), decode {:+.1}% (paper ~0%)",
            (1.0 - base.1 / small.1) * 100.0,
            (base.2 / small.2 - 1.0) * 100.0
        );
    }
    write_report("fig9.csv", &csv)?;
    Ok(out)
}
