//! Ablation: the model variations the paper claims LLMCompass "seamlessly
//! supports" (§II-A) — Multi-Query / Grouped-Query Attention, PaLM-style
//! parallel attention + MLP, and Mixture-of-Experts — evaluated on the
//! Fig. 5h/i setting (A100 ×4, batch 8, seq 2048, decode at KV 3072).
//!
//! The serving-relevant story: MQA collapses the decode KV read and
//! multiplies the memory-capacity-limited batch, while MoE multiplies
//! weight traffic only until the routed token count caps the experts
//! touched.

use super::Ctx;
use crate::graph::inference::max_batch;
use crate::graph::layer::Phase;
use crate::graph::{Attention, ModelConfig};
use crate::hardware::presets;
use crate::util::table::{write_report, Table};
use anyhow::Result;
use std::fmt::Write as _;

pub fn run(ctx: &Ctx) -> Result<String> {
    let sys = presets::system("a100x4").unwrap();
    let a100 = presets::a100();
    let (batch, seq, kv) = (8, 2048, 3072);

    let mut gqa8 = ModelConfig::gpt3_175b();
    gqa8.name = "gpt3-gqa8".into();
    gqa8.attention = Attention::GroupedQuery { groups: 8 };
    let mut parallel = ModelConfig::gpt3_175b();
    parallel.name = "gpt3-parallel".into();
    parallel.parallel_blocks = true;

    let models = vec![
        ModelConfig::gpt3_175b(),
        gqa8,
        ModelConfig::gpt3_palm_style(),
        parallel,
        ModelConfig::gpt3_moe(16),
    ];

    let mut t = Table::new(&[
        "model",
        "prefill ms/layer",
        "decode ms/layer",
        "KV KiB/token/layer",
        "params/layer (M)",
        "max batch (TP=4, 4k ctx)",
    ])
    .with_title("§II-A variants on 4xA100 (b=8, s=2048, decode @ KV 3072)");
    let mut csv = String::from("model,prefill_s,decode_s,kv_bytes,params,max_batch\n");
    let mut rows = Vec::new();
    for m in &models {
        let pre = ctx.sim().layer(&sys, m, Phase::Prefill { batch, seq }).total_s;
        let dec = ctx.sim().layer(&sys, m, Phase::Decode { batch, kv_len: kv }).total_s;
        let kv_b = m.kv_bytes_per_token_per_layer();
        let params = m.params_per_layer();
        let mb = max_batch(&a100, m, m.layers, 4, 4096);
        t.row(vec![
            m.name.clone(),
            format!("{:.2}", pre * 1e3),
            format!("{:.3}", dec * 1e3),
            format!("{:.1}", kv_b as f64 / 1024.0),
            format!("{:.0}", params as f64 / 1e6),
            mb.to_string(),
        ]);
        let _ = writeln!(csv, "{},{pre},{dec},{kv_b},{params},{mb}", m.name);
        rows.push((m.name.clone(), pre, dec, mb));
    }

    let mut out = t.render();
    let base = &rows[0];
    let mqa = rows.iter().find(|r| r.0.contains("mqa")).unwrap();
    let _ = writeln!(
        out,
        "MQA + parallel blocks: decode {:.2}x faster per layer; max batch {} vs {} for MHA \
         (GPT-3 weights alone overflow 4xA100, hence 0) — the variant support the paper \
         claims in §II-A, exercised end to end.",
        base.2 / mqa.2,
        mqa.3,
        base.3
    );
    write_report("variants.csv", &csv)?;
    Ok(out)
}
