//! Fig. 10 — end-to-end performance of the latency-oriented design
//! normalized to GA100: input-length × output-length heatmap of
//! 1/latency, batch 16, 4-way tensor parallelism, 48 GPT-3 layers.
//!
//! Paper: 95.3% of GA100 performance on average, worst (0.80) at
//! input 2048 / output 256, ~0.99 at short input / long output.

use super::Ctx;
use crate::graph::ModelConfig;
use crate::hardware::{presets, InterconnectSpec, SystemSpec};
use crate::util::stats;
use crate::util::table::{write_report, Heatmap};
use anyhow::Result;
use std::fmt::Write as _;

pub const LAYERS: u64 = 48; // half of GPT-3, as in the paper
pub const BATCH: u64 = 16;

pub fn lengths(quick: bool) -> (Vec<u64>, Vec<u64>) {
    if quick {
        (vec![2048, 512], vec![256, 1024, 2048])
    } else {
        (
            vec![2048, 1024, 512, 256],
            vec![256, 512, 768, 1024, 1280, 1536, 1792, 2048],
        )
    }
}

fn tp4(dev: crate::hardware::DeviceSpec) -> SystemSpec {
    SystemSpec { device: dev, device_count: 4, interconnect: InterconnectSpec::nvlink_like(600e9) }
}

/// Compute the normalized-performance grid; also returned for tab4.
pub fn normalized_grid(ctx: &Ctx) -> (Vec<u64>, Vec<u64>, Vec<Vec<f64>>) {
    let model = ModelConfig::gpt3_175b();
    let (ins, outs) = lengths(ctx.quick);
    let ga = tp4(presets::ga100());
    let lat = tp4(presets::latency_oriented());
    // Grid cells are independent; fan them across the shared work-stealing
    // budget (the mapper/LUT caches behind `Simulator` are concurrency-safe
    // and shared). The hybrid mapper picks idle workers back up for its
    // candidate loops as cells drain.
    let cells: Vec<(u64, u64)> =
        ins.iter().flat_map(|&i| outs.iter().map(move |&o| (i, o))).collect();
    let values = crate::util::pool::parallel_map_shared(&cells, |&(s_in, s_out)| {
        let t_ga = ctx.sim().e2e_latency(&ga, &model, BATCH, s_in, s_out, LAYERS);
        let t_lat = ctx.sim().e2e_latency(&lat, &model, BATCH, s_in, s_out, LAYERS);
        t_ga / t_lat // perf = 1/latency, normalized to GA100
    });
    let grid: Vec<Vec<f64>> =
        values.chunks(outs.len()).map(|row| row.to_vec()).collect();
    (ins, outs, grid)
}

pub fn run(ctx: &Ctx) -> Result<String> {
    let (ins, outs, grid) = normalized_grid(ctx);
    let h = Heatmap {
        title:
            "Fig. 10 — latency-oriented design, perf (1/latency) normalized to GA100 \
             (rows: input len, cols: output len; b=16, TP=4, 48 layers)",
        row_labels: ins.iter().map(|v| v.to_string()).collect(),
        col_labels: outs.iter().map(|v| v.to_string()).collect(),
        values: grid.clone(),
        precision: 2,
    };
    let mut out = h.render();
    let flat: Vec<f64> = grid.iter().flatten().copied().collect();
    let avg = stats::mean(&flat);
    let (lo, hi) = stats::minmax(&flat);
    let _ = writeln!(
        out,
        "average normalized performance: {avg:.3} (paper: 0.953); range [{lo:.2}, {hi:.2}] \
         (paper: 0.80 at in=2048/out=256 up to 0.99)"
    );
    write_report("fig10.csv", &h.to_csv())?;
    Ok(out)
}
