//! Fig. 11 — decoding latency per GPT-3 layer per token: NVIDIA A100
//! (108 SM product bin) vs full GA100 vs the latency-oriented design,
//! across KV lengths.
//!
//! Paper: the pruned latency-oriented design achieves *identical* decoding
//! performance to a GA100 — decode is IO-bound, so halving compute and
//! SRAM does not hurt (motivating salvaging binned dies for inference).

use super::Ctx;
use crate::graph::layer::Phase;
use crate::graph::ModelConfig;
use crate::hardware::{presets, InterconnectSpec, SystemSpec};
use crate::util::table::{write_report, Table};
use anyhow::Result;
use std::fmt::Write as _;

pub fn run(ctx: &Ctx) -> Result<String> {
    let model = ModelConfig::gpt3_175b();
    let batch = 8;
    let kvs: Vec<u64> =
        if ctx.quick { vec![2048, 4096] } else { vec![512, 1024, 2048, 3072, 4096] };
    let devices = [
        ("a100", presets::a100()),
        ("ga100", presets::ga100()),
        ("latency-oriented", presets::latency_oriented()),
    ];

    let mut t = Table::new(&["kv len", "a100 ms", "ga100 ms", "latency-design ms", "lat/ga"])
        .with_title("Fig. 11 — decoding latency per GPT-3 layer per token (b=8, TP=4)");
    let mut csv = String::from("kv_len,a100_s,ga100_s,latency_s\n");
    let mut ratios = Vec::new();
    for &kv in &kvs {
        let mut row = Vec::new();
        for (_, dev) in &devices {
            let sys = SystemSpec {
                device: dev.clone(),
                device_count: 4,
                interconnect: InterconnectSpec::nvlink_like(600e9),
            };
            row.push(ctx.sim().layer(&sys, &model, Phase::Decode { batch, kv_len: kv }).total_s);
        }
        let ratio = row[2] / row[1];
        ratios.push(ratio);
        t.row(vec![
            kv.to_string(),
            format!("{:.3}", row[0] * 1e3),
            format!("{:.3}", row[1] * 1e3),
            format!("{:.3}", row[2] * 1e3),
            format!("{ratio:.3}"),
        ]);
        let _ = writeln!(csv, "{kv},{},{},{}", row[0], row[1], row[2]);
    }
    let mut out = t.render();
    let worst = ratios.iter().fold(0.0f64, |a, &b| a.max(b));
    let _ = writeln!(
        out,
        "latency design vs GA100 decode: worst {:.1}% slower (paper: identical)",
        (worst - 1.0) * 100.0
    );
    write_report("fig11.csv", &csv)?;
    Ok(out)
}
