//! Fig. 6 — area-model validation: die-level breakdowns of NVIDIA GA100
//! and AMD Aldebaran (6a) and core-level breakdowns (6b).
//!
//! Reference totals from the architecture white papers / annotated die
//! photos: GA100 = 826 mm², Aldebaran = 724 mm². Paper model error: 5.1%
//! (GA100) and 8.1% (Aldebaran).

use super::Ctx;
use crate::area::{die_breakdown, AreaParams};
use crate::hardware::presets;
use crate::util::table::{write_report, Table};
use anyhow::Result;
use std::fmt::Write as _;

pub const GA100_REF_MM2: f64 = 826.0;
pub const ALDEBARAN_REF_MM2: f64 = 724.0;

pub fn run(_ctx: &Ctx) -> Result<String> {
    let p = AreaParams::default();
    let ga100 = presets::ga100();
    let mut aldebaran = presets::mi210();
    // Full Aldebaran die: CDNA2 CUs carry 512 KB vector register files.
    aldebaran.core.lane.register_bytes = 128 * 1024;
    aldebaran.name = "aldebaran".into();

    let ga_b = die_breakdown(&p, &ga100, 600e9);
    let al_b = die_breakdown(&p, &aldebaran, 300e9);

    let mut t = Table::new(&["component", "GA100 mm²", "Aldebaran mm²"])
        .with_title("Fig. 6a — die area breakdown");
    for ((name, ga), (_, al)) in ga_b.rows().into_iter().zip(al_b.rows()) {
        t.row(vec![name.to_string(), format!("{ga:.1}"), format!("{al:.1}")]);
    }
    t.row(vec![
        "TOTAL (model)".into(),
        format!("{:.1}", ga_b.total_mm2()),
        format!("{:.1}", al_b.total_mm2()),
    ]);
    t.row(vec![
        "reference die".into(),
        format!("{GA100_REF_MM2:.0}"),
        format!("{ALDEBARAN_REF_MM2:.0}"),
    ]);
    t.row(vec![
        "error %".into(),
        format!("{:+.1}", (ga_b.total_mm2() / GA100_REF_MM2 - 1.0) * 100.0),
        format!("{:+.1}", (al_b.total_mm2() / ALDEBARAN_REF_MM2 - 1.0) * 100.0),
    ]);
    let mut out = t.render();

    // Fig. 6b: one core (SM / CU) broken into its pieces.
    let mut core = Table::new(&["component", "GA100 SM mm²", "Aldebaran CU mm²"])
        .with_title("Fig. 6b — core area breakdown");
    let per_core = |b: &crate::area::DieBreakdown, n: f64| {
        vec![
            ("vector units", b.vector_units_mm2 / n),
            ("int units", b.int_units_mm2 / n),
            ("systolic arrays", b.systolic_mm2 / n),
            ("register files", b.regfile_mm2 / n),
            ("lane overhead", b.lane_overhead_mm2 / n),
            ("local buffer", b.local_buffer_mm2 / n),
            ("core overhead", b.core_overhead_mm2 / n),
        ]
    };
    let ga_core = per_core(&ga_b, ga100.core_count as f64);
    let al_core = per_core(&al_b, aldebaran.core_count as f64);
    for ((name, g), (_, a)) in ga_core.iter().zip(&al_core) {
        core.row(vec![name.to_string(), format!("{g:.3}"), format!("{a:.3}")]);
    }
    let _ = writeln!(out, "\n{}", core.render());

    let mut csv = String::from("component,ga100_mm2,aldebaran_mm2\n");
    for ((name, ga), (_, al)) in ga_b.rows().into_iter().zip(al_b.rows()) {
        let _ = writeln!(csv, "{name},{ga:.2},{al:.2}");
    }
    write_report("fig6.csv", &csv)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn errors_within_paper_band() {
        let out = run(&Ctx::new(true)).unwrap();
        assert!(out.contains("Fig. 6a"));
        assert!(out.contains("Fig. 6b"));
    }
}
