//! Fig. 5 — performance-model validation: LLMCompass-predicted vs measured
//! operator latency.
//!
//! Paper: operators measured on A100 / MI210 / TPUv3; average error 10.4%
//! across operators, 4.1% for prefill/decode. Here (DESIGN.md §5) the
//! measured side is the AOT Pallas/JAX operators executed on the PJRT CPU
//! backend and timed from Rust; the predicted side is the same simulator
//! pipeline fed a calibrated CPU device description.

use super::Ctx;
use crate::calibrate::{self, Measurement};
use crate::util::stats;
use crate::util::table::{write_report, Table};
use anyhow::{Context as _, Result};
use std::fmt::Write as _;

pub fn run(ctx: &Ctx) -> Result<String> {
    let mut rt = crate::runtime::Runtime::new(&ctx.artifact_dir)
        .context("fig5 needs artifacts — run `make artifacts` first")?;
    let iters = if ctx.quick { 1 } else { 3 };
    let measured: Vec<Measurement> = calibrate::measure_operators(&mut rt, iters)?;
    let cores = crate::util::pool::default_threads() as u64;
    let dev = calibrate::tune_cpu_device(calibrate::fit_cpu_device(&measured, cores), &measured);

    let mut table = Table::new(&["operator", "measured", "predicted", "error %"])
        .with_title("Fig. 5 — simulated vs measured operator latency (CPU substitution)");
    let mut per_class: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
    for m in &measured {
        let Some(pred) = calibrate::predict(ctx.sim(), &dev, &m.name) else { continue };
        let err = stats::rel_error(pred, m.seconds);
        let class = calibrate::parse_op_name(&m.name).unwrap().0;
        per_class.entry(class).or_default().push(err);
        table.row(vec![
            m.name.clone(),
            crate::util::fmt_seconds(m.seconds),
            crate::util::fmt_seconds(pred),
            format!("{:+.1}", (pred / m.seconds - 1.0) * 100.0),
        ]);
    }

    let mut out = table.render();
    let mut summary = Table::new(&["op class", "mean |error| %", "trend (Spearman ρ)", "n"])
        .with_title("Fig. 5 summary — error rate and trend agreement per operator class");
    let mut all_errs = Vec::new();
    let mut pairs: std::collections::BTreeMap<&str, (Vec<f64>, Vec<f64>)> = Default::default();
    for m in &measured {
        if let Some(pred) = calibrate::predict(ctx.sim(), &dev, &m.name) {
            let class = calibrate::parse_op_name(&m.name).unwrap().0;
            let e = pairs.entry(class).or_default();
            e.0.push(m.seconds);
            e.1.push(pred);
        }
    }
    for (class, errs) in &per_class {
        let rho = pairs.get(class).map(|(a, b)| stats::spearman(a, b)).unwrap_or(0.0);
        summary.row(vec![
            class.to_string(),
            format!("{:.1}", stats::mean(errs) * 100.0),
            format!("{rho:.2}"),
            errs.len().to_string(),
        ]);
        all_errs.extend_from_slice(errs);
    }
    let _ = writeln!(out, "\n{}", summary.render());
    let overall = stats::mean(&all_errs) * 100.0;
    let (all_m, all_p): (Vec<f64>, Vec<f64>) = pairs
        .values()
        .flat_map(|(a, b)| a.iter().copied().zip(b.iter().copied()))
        .unzip();
    let _ = writeln!(
        out,
        "overall mean |error| = {overall:.1}%, overall trend ρ = {:.2}\n\
         (paper: 10.4% on real A100/MI210/TPUv3; our measured platform is interpret-mode\n\
         Pallas on PJRT-CPU — see DESIGN.md §5 and EXPERIMENTS.md for the substitution\n\
         discussion; trend agreement is the meaningful signal here)",
        stats::spearman(&all_m, &all_p)
    );
    let _ = writeln!(
        out,
        "calibrated cpu device: {} cores, matrix peak {:.1} GFLOP/s, mem bw {:.2} GB/s, launch {:.1} us",
        dev.core_count,
        dev.peak_matrix_flops() / 1e9,
        dev.memory.bandwidth_bytes_per_s / 1e9,
        dev.launch_overhead_s * 1e6
    );

    // CSV + calibrated device for downstream use.
    let mut csv = String::from("name,measured_s,predicted_s,rel_err\n");
    for m in &measured {
        if let Some(pred) = calibrate::predict(ctx.sim(), &dev, &m.name) {
            let _ = writeln!(
                csv,
                "{},{},{},{}",
                m.name,
                m.seconds,
                pred,
                stats::rel_error(pred, m.seconds)
            );
        }
    }
    write_report("fig5.csv", &csv)?;
    crate::hardware::config::save_system(
        &crate::hardware::SystemSpec::single(dev),
        std::path::Path::new("reports/cpu.json"),
    )
    .map_err(anyhow::Error::msg)?;
    Ok(out)
}
