//! Fig. 7 / Table III — impact of compute-system design on performance:
//! designs A–E (fewer big cores vs more small cores; A = quarter compute)
//! running one GPT-3 layer, batch 8, seq 2048, 4-way tensor parallelism.
//!
//! Paper findings to reproduce: A ≈ 3.25× slower prefill than B but ~equal
//! decode; E ≈ +12% prefill, +31% decode vs B; implication ① compute helps
//! prefill, barely decode; ② large systolic arrays hurt narrow decode.

use super::Ctx;
use crate::area::die_mm2;
use crate::graph::layer::Phase;
use crate::graph::ModelConfig;
use crate::hardware::{presets, InterconnectSpec, SystemSpec};
use crate::util::table::{write_report, Table};
use anyhow::Result;
use std::fmt::Write as _;

pub const DESIGNS: [char; 5] = ['A', 'B', 'C', 'D', 'E'];

pub fn run(ctx: &Ctx) -> Result<String> {
    let model = ModelConfig::gpt3_175b();
    let (batch, seq) = (8, 2048);
    let kv = seq + 1024; // decoding the 1024th output token

    let mut spec_t = Table::new(&[
        "design", "cores", "lanes", "vector", "systolic", "local KB", "die mm²",
    ])
    .with_title("Table III — five compute system designs");
    let mut perf_t = Table::new(&[
        "design",
        "prefill ms/layer",
        "vs B",
        "decode ms/layer",
        "vs B",
    ])
    .with_title("Fig. 7 — prefill/decode latency per GPT-3 layer (b=8, s=2048, TP=4)");

    let mut rows: Vec<(char, f64, f64)> = Vec::new();
    let mut breakdown_csv = String::from("design,op,prefill_s,decode_s\n");
    for &letter in &DESIGNS {
        let dev = presets::design(letter).unwrap();
        spec_t.row(vec![
            letter.to_string(),
            dev.core_count.to_string(),
            dev.core.lane_count.to_string(),
            dev.core.lane.vector_width.to_string(),
            format!("{}x{}", dev.core.lane.systolic_rows, dev.core.lane.systolic_cols),
            (dev.core.local_buffer_bytes / 1024).to_string(),
            format!("{:.0}", die_mm2(&dev)),
        ]);
        let sys = SystemSpec {
            device: dev,
            device_count: 4,
            interconnect: InterconnectSpec::nvlink_like(600e9),
        };
        let pre = ctx.sim().layer(&sys, &model, Phase::Prefill { batch, seq });
        let dec = ctx.sim().layer(&sys, &model, Phase::Decode { batch, kv_len: kv });
        for (name, s) in &pre.breakdown {
            let ds = dec.time_of(name);
            let _ = writeln!(breakdown_csv, "{letter},{name},{s},{ds}");
        }
        rows.push((letter, pre.total_s, dec.total_s));
    }
    let b_pre = rows.iter().find(|r| r.0 == 'B').unwrap().1;
    let b_dec = rows.iter().find(|r| r.0 == 'B').unwrap().2;
    for (letter, pre, dec) in &rows {
        perf_t.row(vec![
            letter.to_string(),
            format!("{:.2}", pre * 1e3),
            format!("{:.2}x", pre / b_pre),
            format!("{:.3}", dec * 1e3),
            format!("{:.2}x", dec / b_dec),
        ]);
    }

    let mut out = spec_t.render();
    let _ = writeln!(out, "\n{}", perf_t.render());
    let a = rows.iter().find(|r| r.0 == 'A').unwrap();
    let _ = writeln!(
        out,
        "implication ①: A (¼ compute) prefill {:.2}x of B (paper 3.25x), decode {:.2}x (paper ~1.00x)",
        a.1 / b_pre,
        a.2 / b_dec
    );
    let e = rows.iter().find(|r| r.0 == 'E').unwrap();
    let _ = writeln!(
        out,
        "implication ②: E (128x128 arrays) prefill {:+.1}% vs B (paper +12.4%), decode {:+.1}% (paper +30.8%)",
        (e.1 / b_pre - 1.0) * 100.0,
        (e.2 / b_dec - 1.0) * 100.0
    );
    write_report("fig7_breakdown.csv", &breakdown_csv)?;
    let mut csv = String::from("design,prefill_s,decode_s\n");
    for (l, p, d) in &rows {
        let _ = writeln!(csv, "{l},{p},{d}");
    }
    write_report("fig7.csv", &csv)?;
    Ok(out)
}
