//! `serve` — the SLO-aware serving cost sweep: GPT-3-class Poisson traffic
//! on the paper's hardware presets, reporting TTFT/TPOT tails, goodput,
//! and $/1M-output-tokens-at-SLO (Table IV's performance/cost comparison,
//! generalized from isolated batches to traffic) — plus a scheduler-mode
//! study comparing monolithic, chunked-prefill, and disaggregated
//! prefill/decode execution on identical hardware and traffic.
//!
//! Quick mode swaps in the small model and single-device systems so the
//! integration suite can exercise the whole path in seconds; the full run
//! sweeps 1,000 GPT-3 requests per (system, mode, rate) point.

use super::Ctx;
use crate::graph::ModelConfig;
use crate::serve::metrics::Slo;
use crate::serve::sweep::{best_per_system, run_sweep, SweepConfig, SweepRow};
use crate::util::table::{write_report, Table};
use anyhow::Result;
use std::fmt::Write as _;

fn render_rows(title: &str, rows: &[SweepRow], out: &mut String, csv: &mut Table) {
    let mut t = Table::new(&[
        "system", "mode", "repl", "rate/s", "MTBF h", "avail %", "TTFT mean", "TTFT p50/p99",
        "TPOT p50/p99", "goodput tok/s", "SLO %", "preempt", "$/1M tok",
    ])
    .with_title(title);
    for r in rows {
        let s = &r.summary;
        t.row(vec![
            r.system.clone(),
            r.mode.to_string(),
            r.replicas.to_string(),
            format!("{:.1}", r.rate_per_s),
            match r.mtbf_hours {
                // Sub-tenth-of-an-hour MTBFs (smoke-scale traces) read better in seconds.
                Some(h) if h < 0.1 => format!("{:.0}s", h * 3600.0),
                Some(h) => format!("{h:.1}"),
                None => "-".into(),
            },
            format!("{:.2}", r.availability * 100.0),
            crate::util::fmt_seconds(s.ttft_mean_s),
            format!(
                "{} / {}",
                crate::util::fmt_seconds(s.ttft_p50_s),
                crate::util::fmt_seconds(s.ttft_p99_s)
            ),
            format!(
                "{} / {}",
                crate::util::fmt_seconds(s.tpot_p50_s),
                crate::util::fmt_seconds(s.tpot_p99_s)
            ),
            format!("{:.1}", s.goodput_tok_s),
            format!("{:.1}", s.slo_attainment * 100.0),
            r.preemptions.to_string(),
            if r.usd_per_mtok.is_finite() {
                format!("{:.3}", r.usd_per_mtok)
            } else {
                "inf".into()
            },
        ]);
        csv.row(vec![
            title.to_string(),
            r.system.clone(),
            r.mode.to_string(),
            format!("{}", r.replicas),
            format!("{}", r.rate_per_s),
            match r.mtbf_hours {
                Some(h) => format!("{h}"),
                None => String::new(),
            },
            format!("{}", r.availability),
            format!("{}", r.requests_lost),
            format!("{}", s.ttft_mean_s),
            format!("{}", s.ttft_p50_s),
            format!("{}", s.ttft_p99_s),
            format!("{}", s.tpot_p50_s),
            format!("{}", s.tpot_p99_s),
            format!("{}", s.goodput_tok_s),
            format!("{}", s.slo_attainment),
            format!("{}", r.preemptions),
            format!("{}", r.cluster_cost_usd),
            format!("{}", r.usd_per_mtok),
        ]);
    }
    out.push_str(&t.render());
}

pub fn run(ctx: &Ctx) -> Result<String> {
    let (model, slos) = if ctx.quick {
        (ModelConfig::gpt_small(), vec![("relaxed", Slo::relaxed())])
    } else {
        (
            ModelConfig::gpt3_175b(),
            vec![("interactive", Slo::interactive()), ("relaxed", Slo::relaxed())],
        )
    };

    let mut out = String::new();
    let mut csv_all = Table::new(&[
        "sweep", "system", "mode", "replicas", "rate/s", "mtbf_hours", "availability",
        "requests_lost",
        "ttft_mean_s", "ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "tpot_p99_s", "goodput_tok_s",
        "attainment", "preemptions", "cluster_usd", "usd_per_mtok",
    ]);
    for (slo_name, slo) in &slos {
        let cfg = if ctx.quick {
            SweepConfig {
                systems: vec!["ga100".into(), "throughput-oriented".into()],
                rates: vec![20.0, 60.0],
                requests: 48,
                slo: *slo,
                fault_mtbf_hours: Vec::new(),
                ..SweepConfig::paper_default(48, *slo)
            }
        } else {
            SweepConfig::paper_default(1000, *slo)
        };
        let rows = run_sweep(ctx.sim(), &model, &cfg).map_err(anyhow::Error::msg)?;

        let title = format!(
            "serve sweep — {} on {} requests, SLO `{slo_name}` (TTFT ≤ {:.1} s, TPOT ≤ {:.2} s)",
            model.name, cfg.requests, slo.ttft_s, slo.tpot_s
        );
        render_rows(&title, &rows, &mut out, &mut csv_all);

        let best = best_per_system(&rows);
        let _ = writeln!(out, "best $/1M tokens at `{slo_name}` SLO:");
        for b in &best {
            let _ = writeln!(
                out,
                "  {:<24} {:<14} {:>10} at {:.1} req/s (cluster ${:.0})",
                b.system,
                b.mode,
                if b.usd_per_mtok.is_finite() {
                    format!("${:.3}", b.usd_per_mtok)
                } else {
                    "unserved".into()
                },
                b.rate_per_s,
                b.cluster_cost_usd
            );
        }
        out.push('\n');
    }

    // Scheduler-mode study: identical hardware, identical seeded traffic;
    // only the execution mode differs, so every delta is the scheduler's.
    let (system, requests) = if ctx.quick { ("a100x2", 32) } else { ("a100x8", 500) };
    let mut mode_cfg = SweepConfig::mode_comparison(system, requests, Slo::relaxed());
    if ctx.quick {
        mode_cfg.rates = vec![30.0];
    }
    let mode_rows = run_sweep(ctx.sim(), &model, &mode_cfg).map_err(anyhow::Error::msg)?;
    let title = format!(
        "scheduler-mode comparison — {} on {system}, {requests} requests (monolithic vs \
         chunked vs disaggregated)",
        model.name
    );
    render_rows(&title, &mode_rows, &mut out, &mut csv_all);
    out.push('\n');

    // SLO-under-fault study: the same seeded traffic with MTBF-driven crash
    // faults injected, answering "what do goodput, availability, and
    // $/1M-tokens-at-SLO look like when replicas actually fail?". The MTBF
    // points are scaled to the trace length so each one strikes: these are
    // smoke-scale traces of tens of simulated seconds, not production days.
    let mut fault_cfg = SweepConfig::mode_comparison(system, requests, Slo::relaxed());
    fault_cfg.rates = vec![if ctx.quick { 30.0 } else { 40.0 }];
    fault_cfg.fault_mtbf_hours = vec![10.0 / 3600.0, 60.0 / 3600.0];
    fault_cfg.fault_mttr_s = 2.0;
    let fault_rows = run_sweep(ctx.sim(), &model, &fault_cfg).map_err(anyhow::Error::msg)?;
    let title = format!(
        "SLO under fault — {} on {system}, {requests} requests, seeded MTBF crash/recovery \
         (fault-free baseline vs MTBF 10s / 60s, MTTR 2s)",
        model.name
    );
    render_rows(&title, &fault_rows, &mut out, &mut csv_all);
    out.push('\n');

    write_report("serve_sweep.csv", &csv_all.to_csv())?;
    Ok(out)
}
