//! Fig. 12 — end-to-end performance of the throughput-oriented design:
//! tokens/s heatmap (12a) and the same normalized to an 8-GA100 node
//! (12b). Setting: largest batch within memory capacity, 8-way pipeline
//! parallelism, 12 GPT-3 layers per device.
//!
//! Paper: 1.42x average throughput vs GA100, 6.4x memory capacity →
//! >12x batch; latency is ~9.21x worse (no free lunch).

use super::Ctx;
use crate::graph::ModelConfig;
use crate::hardware::{presets, InterconnectSpec, SystemSpec};
use crate::util::stats;
use crate::util::table::{write_report, Heatmap};
use anyhow::Result;
use std::fmt::Write as _;

pub fn lengths(quick: bool) -> (Vec<u64>, Vec<u64>) {
    if quick {
        (vec![1024, 256], vec![256, 1024])
    } else {
        (vec![2048, 1024, 512, 256], vec![256, 512, 1024, 2048])
    }
}

fn pp8(dev: crate::hardware::DeviceSpec) -> SystemSpec {
    SystemSpec { device: dev, device_count: 8, interconnect: InterconnectSpec::nvlink_like(600e9) }
}

/// (tokens/s grids, normalized grid, mean normalized throughput).
pub fn grids(ctx: &Ctx) -> (Vec<u64>, Vec<u64>, Vec<Vec<f64>>, Vec<Vec<f64>>, f64) {
    let model = ModelConfig::gpt3_175b();
    let (ins, outs) = lengths(ctx.quick);
    let thr = pp8(presets::throughput_oriented());
    let ga = pp8(presets::ga100());
    let cells: Vec<(u64, u64)> =
        ins.iter().flat_map(|&i| outs.iter().map(move |&o| (i, o))).collect();
    let pairs = crate::util::pool::parallel_map_shared(&cells, |&(s_in, s_out)| {
        let (tok_thr, _, _) = ctx.sim().pipeline_throughput(&thr, &model, s_in, s_out);
        let (tok_ga, _, _) = ctx.sim().pipeline_throughput(&ga, &model, s_in, s_out);
        (tok_thr, if tok_ga > 0.0 { tok_thr / tok_ga } else { f64::INFINITY })
    });
    let abs: Vec<Vec<f64>> =
        pairs.chunks(outs.len()).map(|r| r.iter().map(|p| p.0).collect()).collect();
    let norm: Vec<Vec<f64>> =
        pairs.chunks(outs.len()).map(|r| r.iter().map(|p| p.1).collect()).collect();
    let flat: Vec<f64> = norm.iter().flatten().copied().collect();
    let mean = stats::mean(&flat);
    (ins, outs, abs, norm, mean)
}

pub fn run(ctx: &Ctx) -> Result<String> {
    let (ins, outs, abs, norm, mean) = grids(ctx);
    let rl: Vec<String> = ins.iter().map(|v| v.to_string()).collect();
    let cl: Vec<String> = outs.iter().map(|v| v.to_string()).collect();
    let h_abs = Heatmap {
        title: "Fig. 12a — throughput-oriented design, tokens/s \
                (rows: input len, cols: output len; PP=8, 12 layers/device, max batch)",
        row_labels: rl.clone(),
        col_labels: cl.clone(),
        values: abs,
        precision: 0,
    };
    let h_norm = Heatmap {
        title: "Fig. 12b — normalized to an 8-GA100 node",
        row_labels: rl,
        col_labels: cl,
        values: norm,
        precision: 2,
    };
    let mut out = h_abs.render();
    let _ = writeln!(out, "\n{}", h_norm.render());
    let _ = writeln!(out, "average normalized throughput: {mean:.2}x (paper: 1.42x)");

    // Latency side of the trade-off (paper discussion: 9.21x worse).
    let model = ModelConfig::gpt3_175b();
    let (s_in, s_out) = (512, 512);
    let (_, b_thr, t_thr) =
        ctx.sim().pipeline_throughput(&pp8(presets::throughput_oriented()), &model, s_in, s_out);
    let (_, b_ga, t_ga) =
        ctx.sim().pipeline_throughput(&pp8(presets::ga100()), &model, s_in, s_out);
    // Request latency ≈ stage time × stages (one batch flowing through).
    let _ = writeln!(
        out,
        "latency trade-off at in=out=512: batch {b_thr} vs {b_ga}, request latency ratio \
         {:.2}x worse (paper: 9.21x average)",
        (t_thr * 8.0) / (t_ga * 8.0).max(1e-12)
    );
    write_report("fig12a.csv", &h_abs.to_csv())?;
    write_report("fig12b.csv", &h_norm.to_csv())?;
    Ok(out)
}
