//! Table IV — comparison with NVIDIA GA100: full specifications, die area
//! (area model), die + memory cost (cost model), normalized performance
//! (from the Fig. 10 / Fig. 12 grids), and normalized performance/cost.
//!
//! Paper bottom line: latency-oriented 1.06x, throughput-oriented 3.41x
//! performance per cost vs GA100.

use super::{fig10, fig12, Ctx};
use crate::cost::{device_cost, perf_per_cost_normalized, CostParams};
use crate::hardware::presets;
use crate::util::stats;
use crate::util::table::{write_report, Table};
use anyhow::Result;
use std::fmt::Write as _;

pub fn run(ctx: &Ctx) -> Result<String> {
    let p = CostParams::default();
    let lat = presets::latency_oriented();
    let ga = presets::ga100();
    let thr = presets::throughput_oriented();

    // Normalized performance: latency design from the Fig. 10 grid mean;
    // throughput design from the Fig. 12 normalized mean.
    let (_, _, grid) = fig10::normalized_grid(ctx);
    let flat: Vec<f64> = grid.iter().flatten().copied().collect();
    let perf_lat = stats::mean(&flat);
    let (_, _, _, _, perf_thr) = fig12::grids(ctx);

    let costs = [device_cost(&p, &lat), device_cost(&p, &ga), device_cost(&p, &thr)];
    let perfs = [perf_lat, 1.0, perf_thr];

    let mut t = Table::new(&["row", "Latency Design", "GA100 (full)", "Throughput Design"])
        .with_title("Table IV — comparison with NVIDIA GA100");
    let devs = [&lat, &ga, &thr];
    let spec_row = |label: &str, f: &dyn Fn(&crate::hardware::DeviceSpec) -> String| {
        vec![label.to_string(), f(devs[0]), f(devs[1]), f(devs[2])]
    };
    t.row(spec_row("core count", &|d| d.core_count.to_string()));
    t.row(spec_row("lane count", &|d| d.core.lane_count.to_string()));
    t.row(spec_row("vector width", &|d| d.core.lane.vector_width.to_string()));
    t.row(spec_row("systolic array", &|d| {
        format!("{}x{}", d.core.lane.systolic_rows, d.core.lane.systolic_cols)
    }));
    t.row(spec_row("local buffer (KB)", &|d| (d.core.local_buffer_bytes / 1024).to_string()));
    t.row(spec_row("global buffer (MB)", &|d| {
        (d.global_buffer_bytes / 1024 / 1024).to_string()
    }));
    t.row(spec_row("global buffer (B/clk)", &|d| d.global_buffer_bytes_per_clk.to_string()));
    t.row(spec_row("memory BW (TB/s)", &|d| {
        format!("{:.0}", d.memory.bandwidth_bytes_per_s / 1e12)
    }));
    t.row(spec_row("memory capacity (GB)", &|d| {
        format!("{:.0}", d.memory.capacity_bytes as f64 / 1e9)
    }));
    t.row(spec_row("memory protocol", &|d| d.memory.protocol.name().to_string()));
    t.row(vec![
        "die area (mm², model)".into(),
        format!("{:.0}", costs[0].die_mm2),
        format!("{:.0}", costs[1].die_mm2),
        format!("{:.0}", costs[2].die_mm2),
    ]);
    t.row(vec![
        "normalized performance".into(),
        format!("{:.2}", perfs[0]),
        "1".into(),
        format!("{:.2}", perfs[1 + 1]),
    ]);
    t.row(vec![
        "est. die cost".into(),
        format!("${:.0}", costs[0].die_cost_usd),
        format!("${:.0}", costs[1].die_cost_usd),
        format!("${:.0}", costs[2].die_cost_usd),
    ]);
    t.row(vec![
        "est. memory cost".into(),
        format!("${:.0}", costs[0].memory_cost_usd),
        format!("${:.0}", costs[1].memory_cost_usd),
        format!("${:.0}", costs[2].memory_cost_usd),
    ]);
    t.row(vec![
        "est. total cost".into(),
        format!("${:.0}", costs[0].total_usd()),
        format!("${:.0}", costs[1].total_usd()),
        format!("${:.0}", costs[2].total_usd()),
    ]);
    let ppc_lat = perf_per_cost_normalized(perfs[0], &costs[0], 1.0, &costs[1]);
    let ppc_thr = perf_per_cost_normalized(perfs[2], &costs[2], 1.0, &costs[1]);
    t.row(vec![
        "normalized perf/cost".into(),
        format!("{ppc_lat:.2}"),
        "1".into(),
        format!("{ppc_thr:.2}"),
    ]);

    let mut out = t.render();
    let _ = writeln!(
        out,
        "paper reference: die 478/826/787 mm²; cost $640/$711/$296; perf/cost 1.06/1/3.41"
    );
    write_report("tab4.csv", &t.to_csv())?;
    Ok(out)
}
