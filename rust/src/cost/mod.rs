//! The cost model (paper §III-D): per-die cost from supply-chain wafer
//! modeling [42] plus memory cost from spot/consumer prices, yielding the
//! performance/cost rows of Table IV.
//!
//! Die cost = wafer price / (dies per wafer × yield). Yield uses Murphy's
//! model with a mature-7nm defect density; dies per wafer uses the usual
//! 300 mm geometric packing estimate. IP, masks, and packaging are
//! excluded, as in the paper.

use crate::hardware::{DeviceSpec, MemProtocol};

/// Wafer/process economics. Defaults are a mature TSMC-7nm-class process:
/// public wafer price ≈ $9,346 (CSET supply-chain estimates) and defect
/// density 0.03 /cm² — the value under which the model reproduces the
/// paper's $151 / $80 / $142 die costs for GA100 / latency / throughput.
#[derive(Debug, Clone)]
pub struct CostParams {
    pub wafer_price_usd: f64,
    pub wafer_diameter_mm: f64,
    /// Defects per cm² (Murphy yield model).
    pub defect_density_per_cm2: f64,
    /// Edge/packing loss factor for rectangular dies on a round wafer.
    pub packing_efficiency: f64,
    /// $/GB of HBM2e (consumer estimates [33]: ~$7/GB).
    pub hbm2e_usd_per_gb: f64,
    /// $/GB of commodity DDR5 (DRAM spot prices [65]: ~$0.30/GB).
    pub ddr5_usd_per_gb: f64,
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams {
            wafer_price_usd: 9346.0,
            wafer_diameter_mm: 300.0,
            defect_density_per_cm2: 0.03,
            packing_efficiency: 0.90,
            hbm2e_usd_per_gb: 7.0,
            ddr5_usd_per_gb: 0.30,
        }
    }
}

/// Gross dies per wafer for a die of `die_mm2`.
pub fn dies_per_wafer(p: &CostParams, die_mm2: f64) -> f64 {
    assert!(die_mm2 > 0.0);
    let r = p.wafer_diameter_mm / 2.0;
    let wafer_area = std::f64::consts::PI * r * r;
    (wafer_area / die_mm2) * p.packing_efficiency
}

/// Murphy yield: ((1 − e^{−AD}) / AD)² with A in cm².
pub fn murphy_yield(p: &CostParams, die_mm2: f64) -> f64 {
    let ad = (die_mm2 / 100.0) * p.defect_density_per_cm2;
    if ad <= 0.0 {
        return 1.0;
    }
    let t = (1.0 - (-ad).exp()) / ad;
    t * t
}

/// Cost of one good die.
pub fn die_cost_usd(p: &CostParams, die_mm2: f64) -> f64 {
    p.wafer_price_usd / (dies_per_wafer(p, die_mm2) * murphy_yield(p, die_mm2))
}

/// Memory subsystem cost for a device.
pub fn memory_cost_usd(p: &CostParams, dev: &DeviceSpec) -> f64 {
    let gb = dev.memory.capacity_bytes as f64 / 1e9;
    match dev.memory.protocol {
        MemProtocol::HBM2E => gb * p.hbm2e_usd_per_gb,
        MemProtocol::DDR5 | MemProtocol::PCIE5CXL | MemProtocol::HostDRAM => {
            gb * p.ddr5_usd_per_gb
        }
    }
}

/// Full device cost report (Table IV rows).
#[derive(Debug, Clone)]
pub struct CostReport {
    pub die_mm2: f64,
    pub die_cost_usd: f64,
    pub memory_cost_usd: f64,
}

impl CostReport {
    pub fn total_usd(&self) -> f64 {
        self.die_cost_usd + self.memory_cost_usd
    }

    /// Stable JSON rendering (part of the `eval` report schema).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num, obj};
        obj(vec![
            ("die_mm2", num(self.die_mm2)),
            ("die_cost_usd", num(self.die_cost_usd)),
            ("memory_cost_usd", num(self.memory_cost_usd)),
            ("total_usd", num(self.total_usd())),
        ])
    }
}

/// Compute the cost report for a device (area from the area model).
pub fn device_cost(p: &CostParams, dev: &DeviceSpec) -> CostReport {
    let area = crate::area::die_mm2(dev);
    CostReport {
        die_mm2: area,
        die_cost_usd: die_cost_usd(p, area),
        memory_cost_usd: memory_cost_usd(p, dev),
    }
}

/// Performance/cost normalized against a baseline (Table IV bottom row):
/// `(perf / perf_base) / (cost / cost_base)`.
pub fn perf_per_cost_normalized(
    perf: f64,
    cost: &CostReport,
    perf_base: f64,
    cost_base: &CostReport,
) -> f64 {
    (perf / perf_base) / (cost.total_usd() / cost_base.total_usd())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::presets;

    #[test]
    fn yield_decreases_with_area() {
        let p = CostParams::default();
        let y_small = murphy_yield(&p, 100.0);
        let y_big = murphy_yield(&p, 800.0);
        assert!(y_small > y_big);
        assert!(y_small <= 1.0 && y_big > 0.0);
        assert_eq!(murphy_yield(&p, 0.0), 1.0);
    }

    #[test]
    fn table4_die_costs_reproduce() {
        // Paper Table IV: estimated die cost $151 (GA100, 826 mm²),
        // $80 (latency, 478 mm²), $142 (throughput, 787 mm²).
        let p = CostParams::default();
        for (mm2, paper) in [(826.0, 151.0), (478.0, 80.0), (787.0, 142.0)] {
            let got = die_cost_usd(&p, mm2);
            let err: f64 = (got - paper) / paper;
            assert!(
                err.abs() < 0.10,
                "die {mm2} mm²: model ${got:.0} vs paper ${paper} ({:+.1}%)",
                err * 100.0
            );
        }
    }

    #[test]
    fn table4_memory_costs_reproduce() {
        // $560 for 80 GB HBM2e; $154 for 512 GB DDR5-behind-PCIe.
        let p = CostParams::default();
        let ga = memory_cost_usd(&p, &presets::ga100());
        let thr = memory_cost_usd(&p, &presets::throughput_oriented());
        assert!((ga - 560.0).abs() < 1.0, "HBM cost {ga}");
        assert!((thr - 154.0).abs() < 3.0, "DDR cost {thr}");
    }

    #[test]
    fn table4_total_costs_and_perf_per_cost() {
        // Totals: $711 (GA100), $640 (latency), $296 (throughput); with
        // paper-normalized performance 1 / 0.953 / 1.42 the perf/cost
        // ratios are 1 / 1.06 / 3.41.
        let p = CostParams::default();
        let ga = device_cost(&p, &presets::ga100());
        let lat = device_cost(&p, &presets::latency_oriented());
        let thr = device_cost(&p, &presets::throughput_oriented());
        assert!((ga.total_usd() - 711.0).abs() / 711.0 < 0.08, "GA100 total {}", ga.total_usd());
        assert!(
            (lat.total_usd() - 640.0).abs() / 640.0 < 0.08,
            "latency total {}",
            lat.total_usd()
        );
        assert!((thr.total_usd() - 296.0).abs() / 296.0 < 0.12, "thr total {}", thr.total_usd());

        let ppc_lat = perf_per_cost_normalized(0.953, &lat, 1.0, &ga);
        let ppc_thr = perf_per_cost_normalized(1.42, &thr, 1.0, &ga);
        assert!((ppc_lat - 1.06).abs() < 0.10, "latency perf/cost {ppc_lat:.2}");
        assert!((ppc_thr - 3.41).abs() < 0.45, "throughput perf/cost {ppc_thr:.2}");
    }

    #[test]
    fn cost_monotone_in_area() {
        let p = CostParams::default();
        let mut last = 0.0;
        for mm2 in [50.0, 150.0, 400.0, 826.0] {
            let c = die_cost_usd(&p, mm2);
            assert!(c > last);
            last = c;
        }
    }
}
