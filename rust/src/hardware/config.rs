//! Load / save hardware descriptions as JSON files.
//!
//! `resolve` accepts either a preset name (`a100`, `ga100x8`, `design-C`)
//! or a path to a JSON file produced by [`save_system`] / hand-written; the
//! calibration harness writes `hardware/cpu.json` this way.

use super::{presets, SystemSpec};
use crate::util::json::Json;
use std::path::Path;

/// Resolve a system spec from a preset name or a JSON file path.
pub fn resolve(name_or_path: &str) -> Result<SystemSpec, String> {
    if let Some(sys) = presets::system(name_or_path) {
        return Ok(sys);
    }
    let p = Path::new(name_or_path);
    if p.exists() {
        return load_system(p);
    }
    Err(format!(
        "unknown hardware `{name_or_path}` (not a preset — see `hardware --list` — and not a file)"
    ))
}

/// Load a `SystemSpec` from a JSON file. The file may contain either a full
/// system object (with `device` / `device_count`) or a bare device object
/// (interpreted as a single-device system).
pub fn load_system(path: &Path) -> Result<SystemSpec, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    let v = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
    if v.get("device").is_some() {
        SystemSpec::from_json(&v)
    } else {
        super::DeviceSpec::from_json(&v).map(SystemSpec::single)
    }
    .map_err(|e| format!("{}: {e}", path.display()))
}

/// Save a `SystemSpec` to a pretty-printed JSON file.
pub fn save_system(sys: &SystemSpec, path: &Path) -> Result<(), String> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
    }
    std::fs::write(path, sys.to_json().to_string_pretty()).map_err(|e| e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_preset() {
        assert_eq!(resolve("a100x4").unwrap().device_count, 4);
        assert!(resolve("not-a-thing").is_err());
    }

    #[test]
    fn resolve_rejects_zero_count_system() {
        let err = resolve("a100x0").unwrap_err();
        assert!(err.contains("unknown hardware"), "{err}");
    }

    #[test]
    fn resolve_unknown_preset_names_the_input() {
        let err = resolve("h200").unwrap_err();
        assert!(err.contains("`h200`"), "{err}");
        assert!(err.contains("hardware --list"), "{err}");
    }

    #[test]
    fn resolve_malformed_json_file_reports_parse_error() {
        let dir = std::env::temp_dir().join("llmcompass-test-config3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("broken.json");
        std::fs::write(&path, "{ \"device\": not json").unwrap();
        let err = resolve(path.to_str().unwrap()).unwrap_err();
        assert!(err.contains("json error"), "{err}");
        assert!(err.contains("broken.json"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resolve_valid_json_with_missing_fields_names_the_key() {
        let dir = std::env::temp_dir().join("llmcompass-test-config4");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty.json");
        std::fs::write(&path, "{}").unwrap();
        let err = resolve(path.to_str().unwrap()).unwrap_err();
        assert!(err.contains("missing"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn file_roundtrip() {
        let sys = presets::system("mi210").unwrap();
        let dir = std::env::temp_dir().join("llmcompass-test-config");
        let path = dir.join("mi210.json");
        save_system(&sys, &path).unwrap();
        let loaded = resolve(path.to_str().unwrap()).unwrap();
        assert_eq!(sys, loaded);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bare_device_file_becomes_single_system() {
        let dev = presets::a100();
        let dir = std::env::temp_dir().join("llmcompass-test-config2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("dev.json");
        std::fs::write(&path, dev.to_json().to_string_pretty()).unwrap();
        let sys = load_system(&path).unwrap();
        assert_eq!(sys.device_count, 1);
        assert_eq!(sys.device, dev);
        std::fs::remove_dir_all(&dir).ok();
    }
}
