//! The hardware description template (paper §III-A, Fig. 3).
//!
//! A **system** is multiple **devices** on a device-device interconnect.
//! Each device has cores + a shared global buffer + off-chip main memory.
//! Each **core** has lanes sharing a local buffer; each **lane** has its own
//! vector unit, systolic array, registers, and control.
//!
//! LLMCompass does not distinguish cache from scratchpad — buffers are
//! explicitly managed by the mapper. Main memory may be HBM, DDR, or CXL;
//! all are described by `(bandwidth, capacity, protocol)`.

pub mod presets;
pub mod config;

use crate::util::json::{num, obj, s, Json};

/// Numeric data type of a tensor / operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    FP32,
    FP16,
    BF16,
    INT8,
}

impl DType {
    pub fn bytes(self) -> u64 {
        match self {
            DType::FP32 => 4,
            DType::FP16 | DType::BF16 => 2,
            DType::INT8 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::FP32 => "fp32",
            DType::FP16 => "fp16",
            DType::BF16 => "bf16",
            DType::INT8 => "int8",
        }
    }

    pub fn parse(v: &str) -> Option<DType> {
        match v {
            "fp32" | "f32" => Some(DType::FP32),
            "fp16" | "f16" => Some(DType::FP16),
            "bf16" => Some(DType::BF16),
            "int8" | "i8" => Some(DType::INT8),
            _ => None,
        }
    }
}

/// Main-memory technology; drives the cost model and PHY area.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemProtocol {
    HBM2E,
    DDR5,
    /// DRAM behind PCIe 5.0 / CXL channels (the throughput-oriented design).
    PCIE5CXL,
    /// Host DRAM as seen by the calibrated CPU device.
    HostDRAM,
}

impl MemProtocol {
    pub fn name(self) -> &'static str {
        match self {
            MemProtocol::HBM2E => "HBM2E",
            MemProtocol::DDR5 => "DDR5",
            MemProtocol::PCIE5CXL => "PCIe5.0/CXL",
            MemProtocol::HostDRAM => "HostDRAM",
        }
    }

    pub fn parse(v: &str) -> Option<MemProtocol> {
        match v {
            "HBM2E" | "hbm2e" => Some(MemProtocol::HBM2E),
            "DDR5" | "ddr5" => Some(MemProtocol::DDR5),
            "PCIe5.0/CXL" | "pcie5" | "cxl" => Some(MemProtocol::PCIE5CXL),
            "HostDRAM" | "host" => Some(MemProtocol::HostDRAM),
            _ => None,
        }
    }
}

/// One lane: vector unit + systolic array + registers + control logic.
#[derive(Debug, Clone, PartialEq)]
pub struct LaneSpec {
    /// FP32 SIMD lanes of the vector unit (FLOPs/cycle = 2× this, FMA).
    pub vector_width: u64,
    /// Systolic array height (rows of PEs).
    pub systolic_rows: u64,
    /// Systolic array width (columns of PEs).
    pub systolic_cols: u64,
    /// Number of systolic arrays per lane (TPUv3 has 2 MXUs per core).
    pub systolic_count: u64,
    /// Register file per lane, bytes.
    pub register_bytes: u64,
}

impl LaneSpec {
    /// Peak MACs/cycle from the systolic array(s).
    pub fn systolic_macs_per_cycle(&self) -> u64 {
        self.systolic_rows * self.systolic_cols * self.systolic_count
    }

    /// Peak vector FLOPs/cycle. One FLOP per SIMD lane per cycle — this is
    /// the convention under which Table I's A100 row (width 32 × 4 lanes ×
    /// 108 cores @ 1.41 GHz) reproduces the datasheet 19.5 TFLOPS FP32.
    pub fn vector_flops_per_cycle(&self) -> u64 {
        self.vector_width
    }
}

/// One core: multiple lanes sharing a local buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct CoreSpec {
    pub lane_count: u64,
    pub lane: LaneSpec,
    /// Local buffer (L1/shared-memory class) bytes.
    pub local_buffer_bytes: u64,
    /// Local buffer bandwidth, bytes per clock (all lanes combined).
    pub local_buffer_bytes_per_clk: u64,
}

impl CoreSpec {
    pub fn systolic_macs_per_cycle(&self) -> u64 {
        self.lane_count * self.lane.systolic_macs_per_cycle()
    }

    pub fn vector_flops_per_cycle(&self) -> u64 {
        self.lane_count * self.lane.vector_flops_per_cycle()
    }
}

/// Off-chip main memory.
#[derive(Debug, Clone, PartialEq)]
pub struct MemorySpec {
    /// Sustained bandwidth, bytes/second.
    pub bandwidth_bytes_per_s: f64,
    /// Capacity in bytes.
    pub capacity_bytes: u64,
    pub protocol: MemProtocol,
}

/// One device (GPU / TPU core / accelerator die).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    pub name: String,
    /// Core clock, Hz.
    pub frequency_hz: f64,
    pub core_count: u64,
    pub core: CoreSpec,
    /// Global buffer (L2-class) bytes.
    pub global_buffer_bytes: u64,
    /// Global buffer bandwidth, bytes per clock (device-wide).
    pub global_buffer_bytes_per_clk: u64,
    pub memory: MemorySpec,
    /// Kernel launch + framework overhead per operator launch, seconds.
    pub launch_overhead_s: f64,
}

impl DeviceSpec {
    /// Peak systolic (matrix) throughput in FLOP/s (1 MAC = 2 FLOPs).
    pub fn peak_matrix_flops(&self) -> f64 {
        2.0 * self.core_count as f64
            * self.core.systolic_macs_per_cycle() as f64
            * self.frequency_hz
    }

    /// Peak vector throughput in FLOP/s.
    pub fn peak_vector_flops(&self) -> f64 {
        self.core_count as f64 * self.core.vector_flops_per_cycle() as f64 * self.frequency_hz
    }

    /// Global buffer bandwidth in bytes/s.
    pub fn global_buffer_bw(&self) -> f64 {
        self.global_buffer_bytes_per_clk as f64 * self.frequency_hz
    }

    /// Local buffer bandwidth in bytes/s (per core).
    pub fn local_buffer_bw(&self) -> f64 {
        self.core.local_buffer_bytes_per_clk as f64 * self.frequency_hz
    }

    /// Total on-chip SRAM (local buffers + global buffer), bytes.
    pub fn total_sram_bytes(&self) -> u64 {
        self.core_count * self.core.local_buffer_bytes + self.global_buffer_bytes
    }

    /// Machine-balance arithmetic intensity (FLOP/byte) at which the device
    /// transitions from memory- to compute-bound.
    pub fn ridge_point(&self) -> f64 {
        self.peak_matrix_flops() / self.memory.bandwidth_bytes_per_s
    }

    /// A cheap structural fingerprint, used to key simulation caches so
    /// that two descriptions differing only in parameters (same `name`)
    /// never alias.
    pub fn fingerprint(&self) -> u64 {
        let repr = format!("{self:?}");
        let mut h = 0xcbf29ce484222325u64;
        for b in repr.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }
}

/// Device-device interconnect (NVLink / Infinity Link class).
#[derive(Debug, Clone, PartialEq)]
pub struct InterconnectSpec {
    /// Per-direction link bandwidth, bytes/second.
    pub link_bandwidth_bytes_per_s: f64,
    /// Link latency `L`, seconds (Eq. 1).
    pub link_latency_s: f64,
    /// Per-transfer software/protocol overhead `O`, seconds (Eq. 1).
    pub overhead_s: f64,
    /// Flit size in bytes (Eq. 2; 16 B for NVLink).
    pub flit_bytes: u64,
    /// Max payload per packet in bytes (Eq. 2; 256 B for NVLink).
    pub max_payload_bytes: u64,
}

impl InterconnectSpec {
    /// NVLink-style defaults for a given per-direction bandwidth.
    pub fn nvlink_like(bandwidth_bytes_per_s: f64) -> Self {
        InterconnectSpec {
            link_bandwidth_bytes_per_s: bandwidth_bytes_per_s,
            link_latency_s: 1.0e-6,
            overhead_s: 1.5e-6,
            flit_bytes: 16,
            max_payload_bytes: 256,
        }
    }

    /// Commodity-host PCIe 3.0 x16-class fabric (no NVLink bridges):
    /// 16 GB/s per direction with host-bridge latencies. The `@pcie`
    /// system-preset suffix selects this — the regime where per-layer
    /// all-reduces dominate tensor parallelism and pipeline parallelism
    /// earns its keep.
    pub fn pcie_host_like() -> Self {
        InterconnectSpec {
            link_bandwidth_bytes_per_s: 16e9,
            link_latency_s: 5.0e-6,
            overhead_s: 5.0e-6,
            flit_bytes: 16,
            max_payload_bytes: 256,
        }
    }
}

/// A full system: `device_count` identical devices, fully connected.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemSpec {
    pub device: DeviceSpec,
    pub device_count: u64,
    pub interconnect: InterconnectSpec,
}

impl SystemSpec {
    pub fn single(device: DeviceSpec) -> Self {
        SystemSpec {
            device,
            device_count: 1,
            interconnect: InterconnectSpec::nvlink_like(600e9),
        }
    }
}

// ---------------------------------------------------------------------------
// JSON (de)serialization
// ---------------------------------------------------------------------------

impl DeviceSpec {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(&self.name)),
            ("frequency_mhz", num(self.frequency_hz / 1e6)),
            ("core_count", num(self.core_count as f64)),
            ("lane_count", num(self.core.lane_count as f64)),
            ("vector_width", num(self.core.lane.vector_width as f64)),
            ("systolic_rows", num(self.core.lane.systolic_rows as f64)),
            ("systolic_cols", num(self.core.lane.systolic_cols as f64)),
            ("systolic_count", num(self.core.lane.systolic_count as f64)),
            ("register_kb", num(self.core.lane.register_bytes as f64 / 1024.0)),
            ("local_buffer_kb", num(self.core.local_buffer_bytes as f64 / 1024.0)),
            (
                "local_buffer_bytes_per_clk",
                num(self.core.local_buffer_bytes_per_clk as f64),
            ),
            ("global_buffer_mb", num(self.global_buffer_bytes as f64 / (1024.0 * 1024.0))),
            ("global_buffer_bytes_per_clk", num(self.global_buffer_bytes_per_clk as f64)),
            ("memory_bandwidth_gbs", num(self.memory.bandwidth_bytes_per_s / 1e9)),
            ("memory_capacity_gb", num(self.memory.capacity_bytes as f64 / 1e9)),
            ("memory_protocol", s(self.memory.protocol.name())),
            ("launch_overhead_us", num(self.launch_overhead_s * 1e6)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<DeviceSpec, String> {
        let e = |x: crate::util::json::JsonError| x.msg;
        Ok(DeviceSpec {
            name: v.req_str("name").map_err(e)?.to_string(),
            frequency_hz: v.req_f64("frequency_mhz").map_err(e)? * 1e6,
            core_count: v.req_u64("core_count").map_err(e)?,
            core: CoreSpec {
                lane_count: v.req_u64("lane_count").map_err(e)?,
                lane: LaneSpec {
                    vector_width: v.req_u64("vector_width").map_err(e)?,
                    systolic_rows: v.req_u64("systolic_rows").map_err(e)?,
                    systolic_cols: v.req_u64("systolic_cols").map_err(e)?,
                    systolic_count: v.opt_f64("systolic_count", 1.0) as u64,
                    register_bytes: (v.opt_f64("register_kb", 256.0) * 1024.0) as u64,
                },
                local_buffer_bytes: (v.req_f64("local_buffer_kb").map_err(e)? * 1024.0) as u64,
                local_buffer_bytes_per_clk: v.opt_f64("local_buffer_bytes_per_clk", 128.0) as u64,
            },
            global_buffer_bytes: (v.req_f64("global_buffer_mb").map_err(e)? * 1024.0 * 1024.0)
                as u64,
            global_buffer_bytes_per_clk: v.req_u64("global_buffer_bytes_per_clk").map_err(e)?,
            memory: MemorySpec {
                bandwidth_bytes_per_s: v.req_f64("memory_bandwidth_gbs").map_err(e)? * 1e9,
                capacity_bytes: (v.req_f64("memory_capacity_gb").map_err(e)? * 1e9) as u64,
                protocol: MemProtocol::parse(v.req_str("memory_protocol").map_err(e)?)
                    .ok_or_else(|| "unknown memory_protocol".to_string())?,
            },
            launch_overhead_s: v.opt_f64("launch_overhead_us", 4.0) * 1e-6,
        })
    }
}

impl SystemSpec {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("device", self.device.to_json()),
            ("device_count", num(self.device_count as f64)),
            (
                "interconnect",
                obj(vec![
                    (
                        "link_bandwidth_gbs",
                        num(self.interconnect.link_bandwidth_bytes_per_s / 1e9),
                    ),
                    ("link_latency_us", num(self.interconnect.link_latency_s * 1e6)),
                    ("overhead_us", num(self.interconnect.overhead_s * 1e6)),
                    ("flit_bytes", num(self.interconnect.flit_bytes as f64)),
                    ("max_payload_bytes", num(self.interconnect.max_payload_bytes as f64)),
                ]),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<SystemSpec, String> {
        let e = |x: crate::util::json::JsonError| x.msg;
        let dev = v.get("device").ok_or("missing `device`")?;
        let ic = v.get("interconnect").ok_or("missing `interconnect`")?;
        Ok(SystemSpec {
            device: DeviceSpec::from_json(dev)?,
            device_count: v.req_u64("device_count").map_err(e)?,
            interconnect: InterconnectSpec {
                link_bandwidth_bytes_per_s: ic.req_f64("link_bandwidth_gbs").map_err(e)? * 1e9,
                link_latency_s: ic.opt_f64("link_latency_us", 1.0) * 1e-6,
                overhead_s: ic.opt_f64("overhead_us", 1.5) * 1e-6,
                flit_bytes: ic.opt_f64("flit_bytes", 16.0) as u64,
                max_payload_bytes: ic.opt_f64("max_payload_bytes", 256.0) as u64,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use presets::a100;

    #[test]
    fn dtype_bytes() {
        assert_eq!(DType::FP32.bytes(), 4);
        assert_eq!(DType::FP16.bytes(), 2);
        assert_eq!(DType::BF16.bytes(), 2);
        assert_eq!(DType::INT8.bytes(), 1);
        assert_eq!(DType::parse("bf16"), Some(DType::BF16));
        assert_eq!(DType::parse("nope"), None);
    }

    #[test]
    fn a100_peak_numbers_match_datasheet() {
        let d = a100();
        // FP16 tensor core peak: 312 TFLOPS (dense).
        let tf = d.peak_matrix_flops() / 1e12;
        assert!((tf - 312.0).abs() / 312.0 < 0.01, "matrix peak {tf} TFLOPS");
        // FP32 CUDA-core peak: 19.5 TFLOPS.
        let vf = d.peak_vector_flops() / 1e12;
        assert!((vf - 19.5).abs() / 19.5 < 0.01, "vector peak {vf} TFLOPS");
        // L2 bandwidth ~7 TB/s.
        assert!(d.global_buffer_bw() > 6e12);
        // Ridge point ≈ 312e12/2e12 ≈ 156 FLOP/B.
        assert!((d.ridge_point() - 156.0).abs() < 10.0);
    }

    #[test]
    fn json_roundtrip_device() {
        let d = a100();
        let j = d.to_json();
        let d2 = DeviceSpec::from_json(&j).unwrap();
        assert_eq!(d, d2);
    }

    #[test]
    fn json_roundtrip_system() {
        let sys = presets::system("a100x4").unwrap();
        let j = sys.to_json();
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        let sys2 = SystemSpec::from_json(&parsed).unwrap();
        assert_eq!(sys, sys2);
    }

    #[test]
    fn from_json_reports_missing_fields() {
        let j = Json::parse(r#"{"name": "x"}"#).unwrap();
        let err = DeviceSpec::from_json(&j).unwrap_err();
        assert!(err.contains("frequency_mhz"), "got: {err}");
    }
}
