//! Hardware presets: the three validated commercial devices of Table I, the
//! five compute-system designs of Table III, and the latency-/throughput-
//! oriented proposals of Table IV.

use super::*;

/// NVIDIA A100 SXM4 80 GB (Table I, col 1).
///
/// 108 SMs × 4 processing blocks (lanes) × {32-wide FP32 SIMD + one tensor
/// core modeled as a 16×16 systolic array} @ 1410 MHz; 192 KB unified
/// L1/shared per SM; 40 MB L2 at 5120 B/clk; 80 GB HBM2e at 2 TB/s.
pub fn a100() -> DeviceSpec {
    DeviceSpec {
        name: "a100".into(),
        frequency_hz: 1410e6,
        core_count: 108,
        core: CoreSpec {
            lane_count: 4,
            lane: LaneSpec {
                vector_width: 32,
                systolic_rows: 16,
                systolic_cols: 16,
                systolic_count: 1,
                register_bytes: 64 * 1024, // 256 KB RF per SM / 4 lanes
            },
            local_buffer_bytes: 192 * 1024,
            local_buffer_bytes_per_clk: 128,
        },
        global_buffer_bytes: 40 * 1024 * 1024,
        global_buffer_bytes_per_clk: 5120,
        memory: MemorySpec {
            bandwidth_bytes_per_s: 2.0e12,
            capacity_bytes: 80_000_000_000,
            protocol: MemProtocol::HBM2E,
        },
        launch_overhead_s: 4.0e-6,
    }
}

/// AMD MI210 (Table I, col 2). 104 CUs @ 1700 MHz; matrix cores modeled as
/// 16×16 systolic arrays; 64 GB HBM2e at 1.6 TB/s.
pub fn mi210() -> DeviceSpec {
    DeviceSpec {
        name: "mi210".into(),
        frequency_hz: 1700e6,
        core_count: 104,
        core: CoreSpec {
            lane_count: 4,
            lane: LaneSpec {
                vector_width: 16,
                systolic_rows: 16,
                systolic_cols: 16,
                systolic_count: 1,
                register_bytes: 64 * 1024,
            },
            local_buffer_bytes: 80 * 1024,
            local_buffer_bytes_per_clk: 128,
        },
        global_buffer_bytes: 8 * 1024 * 1024,
        global_buffer_bytes_per_clk: 4096,
        memory: MemorySpec {
            bandwidth_bytes_per_s: 1.6e12,
            capacity_bytes: 64_000_000_000,
            protocol: MemProtocol::HBM2E,
        },
        launch_overhead_s: 6.0e-6,
    }
}

/// One Google TPUv3 core (Table I, col 3; each chip has two cores).
///
/// The paper folds TPUv3's HBM into the *global buffer* row (16384 MB at
/// 490 B/clk ≈ 460 GB/s per core) and leaves main-memory rows empty; we
/// model it the same way: global buffer = HBM, and `memory` mirrors the
/// same HBM so capacity checks still work.
pub fn tpuv3() -> DeviceSpec {
    DeviceSpec {
        name: "tpuv3".into(),
        frequency_hz: 940e6,
        core_count: 2,
        core: CoreSpec {
            lane_count: 1,
            lane: LaneSpec {
                vector_width: 4 * 128,
                systolic_rows: 128,
                systolic_cols: 128,
                systolic_count: 2, // two MXUs per core
                register_bytes: 512 * 1024,
            },
            local_buffer_bytes: 8192 * 1024,
            local_buffer_bytes_per_clk: 512,
        },
        global_buffer_bytes: 16384 * 1024 * 1024,
        global_buffer_bytes_per_clk: 490,
        memory: MemorySpec {
            bandwidth_bytes_per_s: 490.0 * 940e6,
            capacity_bytes: 16_384 * 1024 * 1024,
            protocol: MemProtocol::HBM2E,
        },
        launch_overhead_s: 12.0e-6,
    }
}

/// Full NVIDIA GA100 die (the baseline of Table IV): all 128 SMs enabled
/// and the full 48 MB L2 (A100 products bin to 108 SMs / 40 MB).
pub fn ga100() -> DeviceSpec {
    let mut d = a100();
    d.name = "ga100".into();
    d.core_count = 128;
    d.global_buffer_bytes = 48 * 1024 * 1024;
    d
}

/// Table III: five compute-system designs A–E. B–E hold total systolic MACs
/// and total buffer constant while trading core count against core size; A
/// has a quarter of the compute.
pub fn design(letter: char) -> Option<DeviceSpec> {
    let (cores, lanes, vw, sys, local_kb) = match letter.to_ascii_uppercase() {
        'A' => (128u64, 4u64, 8u64, 8u64, 192u64),
        'B' => (128, 4, 32, 16, 192),
        'C' => (128, 1, 128, 32, 192),
        'D' => (32, 1, 512, 64, 768),
        'E' => (8, 1, 2048, 128, 3072),
        _ => return None,
    };
    let mut d = ga100();
    d.name = format!("design-{}", letter.to_ascii_uppercase());
    d.core_count = cores;
    d.core.lane_count = lanes;
    d.core.lane.vector_width = vw;
    d.core.lane.systolic_rows = sys;
    d.core.lane.systolic_cols = sys;
    // Register file size scales with vector width (paper §IV-B).
    d.core.lane.register_bytes = 64 * 1024 * vw.max(8) / 32;
    d.core.local_buffer_bytes = local_kb * 1024;
    Some(d)
}

/// Table IV latency-oriented design: half the cores and half the L2 of a
/// GA100, same HBM2e memory system.
pub fn latency_oriented() -> DeviceSpec {
    let mut d = ga100();
    d.name = "latency-oriented".into();
    d.core_count = 64;
    d.global_buffer_bytes = 24 * 1024 * 1024;
    d.global_buffer_bytes_per_clk = 2560;
    d
}

/// Table IV throughput-oriented design: 64 cores with 32×32 systolic arrays
/// and 768 KB local buffers; 512 GB DRAM behind 256 PCIe 5.0 channels at an
/// aggregate 1 TB/s.
pub fn throughput_oriented() -> DeviceSpec {
    let mut d = ga100();
    d.name = "throughput-oriented".into();
    d.core_count = 64;
    d.core.lane.systolic_rows = 32;
    d.core.lane.systolic_cols = 32;
    d.core.local_buffer_bytes = 768 * 1024;
    d.global_buffer_bytes = 48 * 1024 * 1024;
    d.global_buffer_bytes_per_clk = 5120;
    d.memory = MemorySpec {
        bandwidth_bytes_per_s: 1.0e12,
        capacity_bytes: 512_000_000_000,
        protocol: MemProtocol::PCIE5CXL,
    };
    d
}

/// Look up a device preset by name.
pub fn device(name: &str) -> Option<DeviceSpec> {
    match name {
        "a100" => Some(a100()),
        "mi210" => Some(mi210()),
        "tpuv3" => Some(tpuv3()),
        "ga100" => Some(ga100()),
        "latency" | "latency-oriented" => Some(latency_oriented()),
        "throughput" | "throughput-oriented" => Some(throughput_oriented()),
        _ => {
            if let Some(rest) = name.strip_prefix("design-") {
                rest.chars().next().and_then(design)
            } else {
                None
            }
        }
    }
}

/// Look up a system preset: `<device>x<count>` (e.g. `a100x4`, `ga100x8`),
/// or a bare device name for a single-device system. An optional
/// `@<fabric>` suffix overrides the device-device interconnect:
/// `@nvlink` (the per-device default) or `@pcie` (a commodity host
/// without NVLink bridges — `a100x4@pcie`).
pub fn system(name: &str) -> Option<SystemSpec> {
    let (base, fabric) = match name.split_once('@') {
        Some((b, f)) => (b, Some(f)),
        None => (name, None),
    };
    let mut sys = system_base(base)?;
    match fabric {
        None | Some("nvlink") => {}
        Some("pcie") => sys.interconnect = InterconnectSpec::pcie_host_like(),
        Some(_) => return None,
    }
    Some(sys)
}

fn system_base(name: &str) -> Option<SystemSpec> {
    if let Some((dev_name, count)) = name.rsplit_once('x') {
        if let (Some(dev), Ok(n)) = (device(dev_name), count.parse::<u64>()) {
            if n == 0 {
                // `<name>x0` is a zero-device system, not a preset.
                return None;
            }
            let link_bw = match dev_name {
                "mi210" => 300e9,
                "tpuv3" => 162.5e9,
                _ => 600e9,
            };
            return Some(SystemSpec {
                device: dev,
                device_count: n,
                interconnect: InterconnectSpec::nvlink_like(link_bw),
            });
        }
    }
    device(name).map(SystemSpec::single)
}

/// All preset names (for `--list` and exhaustive tests).
pub fn all_device_names() -> Vec<&'static str> {
    vec![
        "a100",
        "mi210",
        "tpuv3",
        "ga100",
        "design-A",
        "design-B",
        "design-C",
        "design-D",
        "design-E",
        "latency-oriented",
        "throughput-oriented",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_resolve() {
        for name in all_device_names() {
            let d = device(name).unwrap_or_else(|| panic!("missing preset {name}"));
            assert!(d.frequency_hz > 0.0);
            assert!(d.core_count > 0);
            assert!(d.memory.bandwidth_bytes_per_s > 0.0);
        }
    }

    #[test]
    fn table3_designs_hold_compute_constant() {
        // B–E: same total systolic MACs/cycle and same total local buffer.
        let b = design('B').unwrap();
        let total_macs =
            |d: &DeviceSpec| d.core_count * d.core.systolic_macs_per_cycle();
        let total_buf = |d: &DeviceSpec| d.core_count * d.core.local_buffer_bytes;
        for l in ['C', 'D', 'E'] {
            let d = design(l).unwrap();
            assert_eq!(total_macs(&d), total_macs(&b), "design {l} MACs");
            assert_eq!(total_buf(&d), total_buf(&b), "design {l} buffer");
        }
        // A has a quarter of B's compute.
        let a = design('A').unwrap();
        assert_eq!(total_macs(&a) * 4, total_macs(&b));
        assert!(design('F').is_none());
    }

    #[test]
    fn tpuv3_peak_bf16() {
        // One TPUv3 chip (2 cores): ~123 TFLOPS BF16.
        let d = tpuv3();
        let tf = d.peak_matrix_flops() / 1e12;
        assert!((tf - 123.2).abs() / 123.2 < 0.02, "tpuv3 {tf} TFLOPS");
    }

    #[test]
    fn table4_designs() {
        let lat = latency_oriented();
        assert_eq!(lat.core_count, 64);
        assert_eq!(lat.memory.protocol, MemProtocol::HBM2E);
        let thr = throughput_oriented();
        assert_eq!(thr.memory.capacity_bytes, 512_000_000_000);
        assert_eq!(thr.memory.protocol, MemProtocol::PCIE5CXL);
        // Throughput design quadruples per-core systolic capability vs GA100.
        assert_eq!(
            thr.core.lane.systolic_rows * thr.core.lane.systolic_cols,
            4 * 16 * 16
        );
    }

    #[test]
    fn system_lookup() {
        let sys = system("a100x4").unwrap();
        assert_eq!(sys.device_count, 4);
        assert_eq!(sys.interconnect.link_bandwidth_bytes_per_s, 600e9);
        // Fabric suffixes: @pcie swaps the interconnect, @nvlink is the
        // default, junk is rejected.
        let pcie = system("a100x4@pcie").unwrap();
        assert_eq!(pcie.device_count, 4);
        assert_eq!(pcie.interconnect.link_bandwidth_bytes_per_s, 16e9);
        assert_eq!(pcie.device, system("a100x4").unwrap().device);
        assert_eq!(system("a100x4@nvlink").unwrap(), system("a100x4").unwrap());
        assert!(system("a100x4@warp").is_none());
        assert_eq!(system("a100@pcie").unwrap().device_count, 1);
        let sys = system("mi210x2").unwrap();
        assert_eq!(sys.interconnect.link_bandwidth_bytes_per_s, 300e9);
        let sys = system("ga100").unwrap();
        assert_eq!(sys.device_count, 1);
        assert!(system("bogusx4").is_none());
        assert!(system("a100x0").is_none(), "zero-device systems are not presets");
    }
}
