//! # LLMCompass — a hardware evaluation framework for LLM inference
//!
//! Reproduction of *"A Hardware Evaluation Framework for Large Language
//! Model Inference"* (Zhang, Ning, Prabhakar, Wentzlaff; Princeton, 2023).
//!
//! LLMCompass evaluates the performance, area, and cost of parameterized
//! hardware designs running Transformer inference workloads. The crate is
//! organized as:
//!
//! * [`hardware`] — the hardware description template (system → device →
//!   core → lane) and presets for real devices (A100, MI210, TPUv3) and the
//!   paper's proposed designs.
//! * [`arch`] — low-level architectural timing models: systolic array
//!   (SCALE-Sim style), vector unit, and LogGP-style links.
//! * [`perf`] — the operator performance model: tile-by-tile matmul
//!   simulation driven by the *mapper search engine*
//!   ([`perf::mapper`]) — an analytically lower-bound-pruned,
//!   work-stealing parameter search over tilings/schedules that returns
//!   the bit-identical winner of the exhaustive sweep at a fraction of
//!   the simulated rounds, memoized in-process per (device, shape) and
//!   across processes via a versioned on-disk mapping cache
//!   (`--mapper-cache`) — plus vector-op models (softmax/layernorm/GELU),
//!   communication primitives (ring all-reduce, peer-to-peer), and
//!   [`perf::graph_sched`], the DAG list scheduler that runs operator
//!   graphs with compute/communication overlap on per-stage resources.
//! * [`graph`] — Transformer computational graphs: the operator-graph IR
//!   ([`graph::ir`] — named-op DAGs with deterministic `tensor_parallel`
//!   / `pipeline_parallel` transforms), the per-layer lowering
//!   (prefill/decode op chains), and end-to-end latency/throughput
//!   simulation including pipeline-parallel requests (stages ×
//!   microbatches grids whose bubbles fall out of the schedule).
//! * [`area`] / [`cost`] — the area model (component transistor counts,
//!   SRAM, PHYs) and the cost model (wafer economics, memory prices,
//!   performance/cost).
//! * [`serve`] — the cluster serving simulator: discrete-event simulation
//!   of request arrivals (Poisson/bursty/trace replay) through an
//!   iteration-level scheduler with three execution modes — monolithic
//!   continuous batching, chunked prefill piggybacked onto decode
//!   iterations (Sarathi/Orca-style token budgets), and disaggregated
//!   prefill/decode device pools with a transfer-modeled, *bounded*
//!   handoff queue (Splitwise-style; the prefill pool stalls on
//!   decode-pool backpressure) — plus KV-pressure preemption with
//!   recompute-on-resume, TTFT/TPOT/goodput metrics, and an SLO-aware
//!   $/1M-token cost sweep across hardware presets *and* scheduler modes
//!   — the layer that evaluates designs under traffic instead of
//!   isolated batches. Scales out to multi-replica data-parallel
//!   *fleets* ([`serve::fleet`]): N replica engines behind a pluggable
//!   load balancer (round-robin / least-KV-pressure / session-affinity)
//!   driven off a deterministic global event heap ([`serve::events`]),
//!   with per-replica fault targeting (`replica:<i>`, correlated
//!   outages), cross-replica re-dispatch of crash losses, diurnal +
//!   flash-crowd arrival modulation, and a fleet-size axis on the cost
//!   sweep; `replicas = 1` reproduces the single-engine reports byte
//!   for byte. All iteration pricing flows through a shared, sharded
//!   latency-oracle cache ([`serve::oracle`]): one warm oracle per
//!   (hardware, model) fingerprint reused across replicas and sweep
//!   cells, with deterministic hit/miss/simulator-call counters —
//!   sharing is byte-invisible in the reports.
//! * [`eval`] — the unified scenario API: one typed, JSON-serializable
//!   [`eval::Scenario`] (hardware target + workload — operator, layer,
//!   request, arbitrary operator DAG, or traffic — + optional
//!   `{tp, pp, microbatches}` device mapping + requested outputs)
//!   evaluated by [`eval::Evaluator`] into a stable-schema
//!   [`eval::EvalReport`]. The CLI subcommands and experiment context are
//!   thin adapters over it, and suites of scenarios share one mapper
//!   cache so repeated shapes are searched once.
//! * [`tune`] — the design-space autotuner: a typed [`tune::DesignSpace`]
//!   (core/device counts, lane count, systolic dims, SRAM sizes, memory
//!   technology, fabric) searched by branch-and-bound for the paper's
//!   Section-VII question — which hardware is the most cost-effective
//!   for a workload. Reuses the mapper's tricks one level up: a provable
//!   per-design roofline floor prunes designs no mapper search needs to
//!   touch (provably frontier-preserving), candidate fan-out rides the
//!   work-stealing pool, and evaluated designs persist in a cache keyed
//!   by design fingerprint + scenario hash. Emits a [`tune::TuneReport`]:
//!   a (latency, $/1M-tokens, area) Pareto frontier with full configs,
//!   the best perf/$ or goodput/$ point, and the stock baseline.
//! * [`runtime`] / [`calibrate`] / [`coordinator`] — the executable side:
//!   load AOT-compiled JAX/Pallas artifacts via PJRT, time them, calibrate
//!   a CPU device description, and serve batched inference end-to-end.
//! * [`experiments`] — regenerators for every table and figure in the
//!   paper's evaluation section, plus the `serve` traffic sweep.
//! * [`util`] — self-contained substrates (JSON, CLI, tables, PRNG, thread
//!   pool, property testing, stats) — the offline build environment has no
//!   serde/clap/criterion/proptest, so these are built from scratch.
//!   Includes [`util::telemetry`], the crate-wide observability layer: a
//!   no-op-until-enabled span/counter/instant recorder with separate
//!   simulated-time and host-wall-clock domains, exported as Chrome
//!   trace-event JSON (`--trace`, loadable in Perfetto) and summarized in
//!   every report's schema-versioned `telemetry` section.

pub mod util;
pub mod hardware;
pub mod arch;
pub mod perf;
pub mod graph;
pub mod area;
pub mod cost;
pub mod serve;
pub mod eval;
pub mod tune;
pub mod runtime;
pub mod calibrate;
pub mod coordinator;
pub mod experiments;

/// Crate version string (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
