//! Calibration: derive a "CPU device" hardware description from measured
//! wallclocks of the AOT artifacts, enabling the paper's Fig.-5-style
//! predicted-vs-measured validation on the hardware we actually have.
//!
//! The paper validates LLMCompass against A100 / MI210 / TPUv3
//! measurements. Those devices are unavailable here, so (per the
//! substitution rule in DESIGN.md §5) the *measured* side is the same set
//! of operators — Pallas kernels inside JAX, AOT-compiled and executed on
//! the PJRT CPU backend from Rust — and the *hardware description* fed to
//! LLMCompass is fitted from micro-probes:
//!
//! * peak matmul FLOP/s   → sizes the modeled "systolic array"
//! * streaming bandwidth  → main-memory bandwidth (from GELU, 2 B/elt/dir)
//! * smallest-op latency  → kernel-launch (dispatch) overhead

use crate::hardware::{
    config, CoreSpec, DType, DeviceSpec, InterconnectSpec, LaneSpec, MemProtocol, MemorySpec,
    SystemSpec,
};
use crate::runtime::{HostTensor, Runtime};
use anyhow::Result;
use std::path::Path;

/// One measured operator sample.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Artifact name, e.g. `matmul_256x768x768`.
    pub name: String,
    pub seconds: f64,
    pub flops: f64,
    pub bytes: f64,
}

/// Parse op kind + dims from an artifact name
/// (`matmul_MxKxN`, `softmax_MxN`, `layernorm_MxN`, `gelu_N`,
/// `attention_MxNxD`). Returns None for model artifacts.
pub fn parse_op_name(name: &str) -> Option<(&str, Vec<u64>)> {
    let (kind, dims) = name.split_once('_')?;
    if !matches!(kind, "matmul" | "softmax" | "layernorm" | "gelu" | "attention") {
        return None;
    }
    let dims: Option<Vec<u64>> = dims.split('x').map(|d| d.parse().ok()).collect();
    Some((
        match kind {
            "matmul" => "matmul",
            "softmax" => "softmax",
            "layernorm" => "layernorm",
            "gelu" => "gelu",
            _ => "attention",
        },
        dims?,
    ))
}

/// Nominal FLOPs / DRAM bytes for a parsed op (f32 artifacts).
pub fn op_cost(kind: &str, dims: &[u64]) -> (f64, f64) {
    let e = 4.0; // f32
    match (kind, dims) {
        ("matmul", [m, k, n]) => {
            let (m, k, n) = (*m as f64, *k as f64, *n as f64);
            (2.0 * m * k * n, e * (m * k + k * n + m * n))
        }
        ("softmax", [m, n]) => {
            let sz = (*m * *n) as f64;
            (7.0 * sz, 2.0 * e * sz)
        }
        ("layernorm", [m, n]) => {
            let sz = (*m * *n) as f64;
            (7.0 * sz, 2.0 * e * sz)
        }
        ("gelu", [n]) => (12.0 * *n as f64, 2.0 * e * *n as f64),
        ("attention", [m, n, d]) => {
            let (m, n, d) = (*m as f64, *n as f64, *d as f64);
            (4.0 * m * n * d, e * (m * d + 2.0 * n * d + m * d))
        }
        _ => (0.0, 0.0),
    }
}

/// Random-ish but deterministic f32 input for an artifact argument.
fn make_arg(shape: &[usize], dtype: &str, seed: u64) -> HostTensor {
    let n: usize = shape.iter().product::<usize>().max(1);
    if dtype.starts_with("int") {
        let v: Vec<i32> = (0..n).map(|i| ((i as u64 * 37 + seed) % 100) as i32).collect();
        HostTensor::I32(v, shape.to_vec())
    } else {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let v: Vec<f32> = (0..n)
            .map(|_| {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                ((state >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
            })
            .collect();
        HostTensor::F32(v, shape.to_vec())
    }
}

/// Measure every operator artifact. `iters` executions after one warmup.
pub fn measure_operators(rt: &mut Runtime, iters: usize) -> Result<Vec<Measurement>> {
    let arts: Vec<_> = rt
        .manifest()
        .artifacts
        .iter()
        .filter(|a| parse_op_name(&a.name).is_some())
        .cloned()
        .collect();
    let mut out = Vec::with_capacity(arts.len());
    for art in arts {
        let args: Vec<HostTensor> = art
            .args
            .iter()
            .enumerate()
            .map(|(i, a)| make_arg(&a.shape, &a.dtype, i as u64 + 1))
            .collect();
        let (_, secs) = rt.run_timed(&art.name, &args, 1, iters)?;
        let (kind, dims) = parse_op_name(&art.name).unwrap();
        let (flops, bytes) = op_cost(kind, &dims);
        out.push(Measurement { name: art.name.clone(), seconds: secs, flops, bytes });
    }
    Ok(out)
}

/// Fit a CPU device description from measurements.
///
/// The CPU is described in the same template as a GPU: `cores` hardware
/// cores, one lane each, SIMD vector units, and a small "systolic array"
/// standing in for the FMA pipes sized so the modeled matrix peak equals
/// the *measured* GEMM throughput (interpret-mode Pallas on CPU is far
/// from the machine's true peak; the description captures the achieved
/// platform, which is what the validation needs).
pub fn fit_cpu_device(measurements: &[Measurement], cores: u64) -> DeviceSpec {
    let freq = 3.0e9;

    // Peak achieved matmul FLOP/s across probes.
    let peak_flops = measurements
        .iter()
        .filter(|m| m.name.starts_with("matmul"))
        .map(|m| m.flops / m.seconds)
        .fold(1e9, f64::max);
    // Achieved streaming bandwidth from elementwise/normalization ops.
    let bw = measurements
        .iter()
        .filter(|m| m.name.starts_with("gelu") || m.name.starts_with("softmax"))
        .map(|m| m.bytes / m.seconds)
        .fold(1e8, f64::max);
    // Dispatch overhead: the fastest op of all is dominated by launch.
    let launch = measurements.iter().map(|m| m.seconds).fold(f64::INFINITY, f64::min) * 0.5;

    // Size the per-core "systolic" array: 2·s²·cores·freq = peak.
    let s = ((peak_flops / (2.0 * cores as f64 * freq)).sqrt().ceil() as u64).max(1);

    DeviceSpec {
        name: "cpu".into(),
        frequency_hz: freq,
        core_count: cores,
        core: CoreSpec {
            lane_count: 1,
            lane: LaneSpec {
                vector_width: 8, // AVX2-class f32 SIMD
                systolic_rows: s,
                systolic_cols: s,
                systolic_count: 1,
                register_bytes: 2 * 1024,
            },
            local_buffer_bytes: 32 * 1024, // L1d
            local_buffer_bytes_per_clk: 64,
        },
        global_buffer_bytes: 32 * 1024 * 1024, // LLC
        global_buffer_bytes_per_clk: (2.0 * bw / freq).ceil() as u64 + 1,
        memory: MemorySpec {
            bandwidth_bytes_per_s: bw,
            capacity_bytes: 16_000_000_000,
            protocol: MemProtocol::HostDRAM,
        },
        launch_overhead_s: launch.clamp(1e-6, 1e-3),
    }
}

/// Refine the fitted device by coordinate descent: vary matrix peak
/// (systolic size), memory bandwidth, vector width, and launch overhead to
/// minimize the mean |log(predicted / measured)| across all probes —
/// i.e. pick the device description under which LLMCompass best explains
/// the measured platform. This mirrors how one would calibrate the model
/// to any new machine.
pub fn tune_cpu_device(initial: DeviceSpec, measurements: &[Measurement]) -> DeviceSpec {
    fn score(dev: &DeviceSpec, meas: &[Measurement]) -> f64 {
        let sim = crate::graph::inference::Simulator::new();
        let mut total = 0.0;
        let mut n = 0u32;
        for m in meas {
            if let Some(pred) = predict(&sim, dev, &m.name) {
                total += (pred / m.seconds).ln().abs();
                n += 1;
            }
        }
        if n == 0 {
            f64::INFINITY
        } else {
            total / n as f64
        }
    }

    let mut best = initial;
    let mut best_score = score(&best, measurements);
    // Two sweeps of coordinate descent over multiplicative factors.
    for _ in 0..2 {
        // systolic extent (matrix peak ∝ s²)
        for s in [1u64, 2, 3, 4, 6, 8, 12, 16] {
            let mut d = best.clone();
            d.core.lane.systolic_rows = s;
            d.core.lane.systolic_cols = s;
            let sc = score(&d, measurements);
            if sc < best_score {
                best = d;
                best_score = sc;
            }
        }
        // memory bandwidth
        let bw0 = best.memory.bandwidth_bytes_per_s;
        for f in [0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 4.0, 8.0] {
            let mut d = best.clone();
            d.memory.bandwidth_bytes_per_s = bw0 * f;
            d.global_buffer_bytes_per_clk =
                ((2.0 * bw0 * f / d.frequency_hz).ceil() as u64).max(1);
            let sc = score(&d, measurements);
            if sc < best_score {
                best = d;
                best_score = sc;
            }
        }
        // vector width (vecop throughput)
        for w in [2u64, 4, 8, 16, 32, 64] {
            let mut d = best.clone();
            d.core.lane.vector_width = w;
            let sc = score(&d, measurements);
            if sc < best_score {
                best = d;
                best_score = sc;
            }
        }
        // launch overhead
        let l0 = best.launch_overhead_s;
        for f in [0.25, 0.5, 1.0, 2.0, 4.0] {
            let mut d = best.clone();
            d.launch_overhead_s = (l0 * f).clamp(1e-7, 5e-3);
            let sc = score(&d, measurements);
            if sc < best_score {
                best = d;
                best_score = sc;
            }
        }
    }
    best
}

/// Run the full calibration: measure, fit, tune, save `hardware/cpu.json`.
pub fn calibrate(
    artifact_dir: &Path,
    out_path: &Path,
    iters: usize,
) -> Result<(Vec<Measurement>, DeviceSpec)> {
    let mut rt = Runtime::new(artifact_dir)?;
    let measurements = measure_operators(&mut rt, iters)?;
    let cores = crate::util::pool::default_threads() as u64;
    let dev = tune_cpu_device(fit_cpu_device(&measurements, cores), &measurements);
    let sys = SystemSpec {
        device: dev.clone(),
        device_count: 1,
        interconnect: InterconnectSpec::nvlink_like(10e9),
    };
    config::save_system(&sys, out_path).map_err(anyhow::Error::msg)?;
    Ok((measurements, dev))
}

/// Simulate a measured operator on a device description; returns predicted
/// seconds (the Fig.-5 comparison pairs this with `Measurement::seconds`).
pub fn predict(
    sim: &crate::graph::inference::Simulator,
    dev: &DeviceSpec,
    name: &str,
) -> Option<f64> {
    let (kind, dims) = parse_op_name(name)?;
    let sys = SystemSpec::single(dev.clone());
    let dt = DType::FP32;
    let op = match (kind, dims.as_slice()) {
        ("matmul", [m, k, n]) => crate::perf::Op::Matmul {
            b: 1,
            m: *m,
            k: *k,
            n: *n,
            dtype: dt,
            batched_b: false,
        },
        ("softmax", [m, n]) => crate::perf::Op::Softmax { m: *m, n: *n, dtype: dt },
        ("layernorm", [m, n]) => crate::perf::Op::LayerNorm { m: *m, n: *n, dtype: dt },
        ("gelu", [n]) => crate::perf::Op::Gelu { elements: *n, dtype: dt },
        ("attention", [m, n, d]) => {
            // Fused attention ≈ two chained matmuls + softmax; predict as
            // their sum (the simulator has no fused-attention op).
            let s1 = sim.op_latency(
                &sys,
                &crate::perf::Op::Matmul {
                    b: 1,
                    m: *m,
                    k: *d,
                    n: *n,
                    dtype: dt,
                    batched_b: false,
                },
            );
            let s2 = sim.op_latency(&sys, &crate::perf::Op::Softmax { m: *m, n: *n, dtype: dt });
            let s3 = sim.op_latency(
                &sys,
                &crate::perf::Op::Matmul {
                    b: 1,
                    m: *m,
                    k: *n,
                    n: *d,
                    dtype: dt,
                    batched_b: false,
                },
            );
            return Some(s1.latency_s + s2.latency_s + s3.latency_s);
        }
        _ => return None,
    };
    Some(sim.op_latency(&sys, &op).latency_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_names_parse() {
        assert_eq!(
            parse_op_name("matmul_256x768x768"),
            Some(("matmul", vec![256, 768, 768]))
        );
        assert_eq!(parse_op_name("gelu_16384"), Some(("gelu", vec![16384])));
        assert_eq!(parse_op_name("prefill_b4_s64"), None);
        assert_eq!(parse_op_name("init"), None);
        assert_eq!(parse_op_name("softmax_64x512"), Some(("softmax", vec![64, 512])));
    }

    #[test]
    fn op_costs_sane() {
        let (f, b) = op_cost("matmul", &[64, 64, 64]);
        assert_eq!(f, 2.0 * 64.0 * 64.0 * 64.0);
        assert_eq!(b, 4.0 * 3.0 * 64.0 * 64.0);
        let (f, b) = op_cost("gelu", &[1000]);
        assert_eq!(f, 12_000.0);
        assert_eq!(b, 8000.0);
    }

    #[test]
    fn fit_produces_consistent_device() {
        let meas = vec![
            Measurement {
                name: "matmul_512x512x512".into(),
                seconds: 0.01,
                flops: 2.0 * 512f64.powi(3),
                bytes: 4.0 * 3.0 * 512.0 * 512.0,
            },
            Measurement {
                name: "gelu_1048576".into(),
                seconds: 0.001,
                flops: 12.0 * 1048576.0,
                bytes: 8.0 * 1048576.0,
            },
        ];
        let dev = fit_cpu_device(&meas, 8);
        // Modeled matrix peak within 2x of the measured GEMM rate
        // (quantized by integer array geometry).
        let measured = meas[0].flops / meas[0].seconds;
        let modeled = dev.peak_matrix_flops();
        assert!(modeled >= measured * 0.9 && modeled <= measured * 4.0,
                "modeled {modeled:.2e} vs measured {measured:.2e}");
        // Bandwidth matches the gelu probe.
        let bw = meas[1].bytes / meas[1].seconds;
        assert!((dev.memory.bandwidth_bytes_per_s - bw).abs() / bw < 1e-9);
        assert!(dev.launch_overhead_s > 0.0);
    }

    #[test]
    fn make_arg_deterministic() {
        let a = make_arg(&[4, 4], "float32", 1);
        let b = make_arg(&[4, 4], "float32", 1);
        assert_eq!(a.f32().unwrap(), b.f32().unwrap());
        let c = make_arg(&[3], "int32", 2);
        assert_eq!(c.shape(), &[3]);
    }
}
